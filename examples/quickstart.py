"""Quickstart: write a secure computation in the Integer DSL, plan it for a
bounded memory budget, and execute it with real two-party garbled circuits.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import PlanConfig, plan, trace  # noqa: E402
from repro.protocols.garbled import Integer, Party, run_two_party  # noqa: E402

N = 16  # records per party


def millionaires_and_friends():
    """Paper Fig. 5 (Yao's millionaires), vectorized, plus some arithmetic."""
    alice_wealth = Integer(32, N).mark_input(Party.Garbler, tag=0)
    bob_wealth = Integer(32, N).mark_input(Party.Evaluator, tag=1)
    richer = alice_wealth.cmp_ge(bob_wealth)
    richer.mark_output(0)
    combined = alice_wealth + bob_wealth
    combined.mark_output(1)
    spread = alice_wealth - bob_wealth
    spread.mark_output(2)


def main():
    rng = np.random.default_rng(0)
    alice = rng.integers(0, 1 << 20, N, dtype=np.uint64)
    bob = rng.integers(0, 1 << 20, N, dtype=np.uint64)

    # 1. trace the DSL program -> MAGE-virtual bytecode
    prog = trace(millionaires_and_friends, protocol="gc", page_shift=12)
    print(f"bytecode: {len(prog)} instructions over "
          f"{prog.num_vpages()} MAGE-virtual pages")

    # 2. plan it for a tiny physical budget (Belady MIN + prefetch)
    mem, report = plan(prog, PlanConfig(num_frames=6, lookahead=100,
                                        prefetch_pages=2))
    rs, ss = report.replacement, report.schedule
    print(f"memory program: {rs.swap_ins} swap-ins / {rs.swap_outs} "
          f"swap-outs, {ss.prefetched} prefetched, "
          f"{ss.sync_fallbacks} sync fallbacks")

    # 3. run REAL garbled circuits: both parties, bounded memory
    outs = run_two_party(mem, mem,
                         lambda tag: alice, lambda tag: bob)
    assert np.array_equal(outs[0], (alice >= bob).astype(np.uint64))
    assert np.array_equal(outs[1], alice + bob)
    print("richer:", outs[0][:8], "...")
    print("sum   :", outs[1][:8], "...")
    print("two-party garbled-circuit execution under a 6-page budget: OK")


if __name__ == "__main__":
    main()
