"""Computational PIR over CKKS (§8.8.2) + the serving-side memory program:
a private database query executed homomorphically under a bounded budget,
and the paged-KV decode schedule the same planner produces for LM serving.

    PYTHONPATH=src python examples/pir_serving.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import PlanConfig  # noqa: E402
from repro.serve.paged_kv import plan_kv_schedule  # noqa: E402
from repro.workloads import get  # noqa: E402
from repro.workloads.runner import check_against_oracle, run  # noqa: E402


def main():
    # --- private information retrieval, for real (CKKS) ---
    n = 64
    w = get("pir")
    cfg = PlanConfig(num_frames=8, lookahead=50, prefetch_pages=2)
    outs = run(w, n, cfg=cfg)
    check_against_oracle(w, n, outs)
    print(f"PIR over a {n}-element encrypted-query database: "
          f"retrieved row decodes correctly under an 8-page budget")

    # --- the same planner on an LM decode's KV page schedule ---
    mem, rep = plan_kv_schedule(total_tokens=4096, page_size=64,
                                hbm_pages=24, lookahead=8, prefetch=4)
    rs, ss = rep.replacement, rep.schedule
    print(f"paged-KV decode plan (4096 tokens, 24-page HBM budget): "
          f"{rs.swap_ins} swap-ins, {ss.prefetched} prefetched, "
          f"{ss.sync_fallbacks} stalls")
    print("decode's KV access pattern is oblivious -> the MAGE planner "
          "prefetches every page before the attention step that reads it")


if __name__ == "__main__":
    main()
