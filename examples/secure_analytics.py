"""Secure collaborative analytics (the paper's federated-analytics story):
two parties merge their sorted record sets and detect shared credentials
(Senate Query 2 / §8.8.1) under a bounded memory budget, with the planner's
swap statistics reported — then the same workload through the OS-vs-MAGE
timing simulator.

    PYTHONPATH=src python examples/secure_analytics.py
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

import numpy as np  # noqa: E402

from repro.core import PlanConfig  # noqa: E402
from repro.workloads import get  # noqa: E402
from repro.workloads.runner import check_against_oracle, run  # noqa: E402


def main():
    n = 256
    w = get("passreuse")
    # correctness: bounded, memmap-swapped plaintext engine vs oracle
    cfg = PlanConfig(num_frames=12, lookahead=100, prefetch_pages=3)
    outs = run(w, n, cfg=cfg, use_memmap=True)
    check_against_oracle(w, n, outs)
    flagged = sum(int(v.sum()) for v in outs.values())
    print(f"passreuse n={n}: {flagged} reused credentials flagged "
          f"(bounded memory, bit-exact vs oracle)")

    # the three §8.2 scenarios through the calibrated simulator
    from common import fmt_row, run_workload  # noqa: E402
    r = run_workload("passreuse", 2048, budget_frac=0.3)
    print(fmt_row("passreuse", r))
    print(f"MAGE vs OS swapping: {r.speedup_vs_os:.1f}x; "
          f"{100 * r.pct_of_unbounded:.1f}% over unbounded")


if __name__ == "__main__":
    main()
