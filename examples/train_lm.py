"""End-to-end driver: train a (reduced) assigned architecture for a few
hundred steps with the full production loop — deterministic data pipeline,
WSD schedule, async atomic checkpoints, NaN rollback — then reload and
serve a few tokens from the trained weights.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-1.5b] \
        [--steps 200]

(Defaults are sized for a CPU laptop run of a few minutes; pass a real
mesh + full config on hardware.)
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import reduced_config  # noqa: E402
from repro.data.pipeline import DataConfig  # noqa: E402
from repro.distributed.sharding import default_rules, use_rules  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402
from repro.models import init_caches, lm_prefill  # noqa: E402
from repro.serve.serve_step import serve_step  # noqa: E402
from repro.train import checkpoint as ckpt  # noqa: E402
from repro.train.fault import FaultConfig  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402
from repro.train.train_step import TrainConfig, make_train_state  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    tcfg = TrainConfig(
        microbatches=2,
        opt=OptConfig(peak_lr=3e-3, warmup_steps=20,
                      stable_steps=max(args.steps - 60, 20),
                      decay_steps=40, schedule="wsd"))
    dcfg = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size,
                      frames_dim=cfg.d_model if cfg.is_encdec else 0)
    fcfg = FaultConfig(checkpoint_every=50)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, use_rules(default_rules(mesh)):
        report = train_loop(cfg, tcfg, dcfg, fcfg, steps=args.steps,
                            ckpt_dir=ckpt_dir, log_every=25)
        print(f"training done: {report}")

        # reload the final checkpoint and decode a few tokens
        params, opt = make_train_state(jax.random.PRNGKey(0), cfg)
        step = ckpt.latest_step(ckpt_dir)
        params, _, _ = ckpt.restore(ckpt_dir, step, params, opt)
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
            dtype=jnp.int32)
        logits, caches = lm_prefill(params, prompt, cfg, max_seq=32)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        clen = jnp.asarray([8], dtype=jnp.int32)
        generated = [int(tok[0, 0])]
        for _ in range(8):
            tok, caches, _ = serve_step(params, tok, caches, clen, cfg)
            clen = clen + 1
            generated.append(int(tok[0, 0]))
        print(f"checkpoint step {step} -> greedy decode: {generated}")


if __name__ == "__main__":
    main()
