"""Shared benchmark harness — now a thin shim over ``repro.scenarios``.

The calibration, cost models and the trace→plan→simulate path live in
``src/repro/scenarios.py`` (built on the ``repro.api.Session`` facade);
this module only re-exports them so the fig* scripts keep working as
plain scripts.  Run benchmarks with the package importable, e.g.::

    PYTHONPATH=src python benchmarks/fig8_swap.py
    PYTHONPATH=src python -m repro bench

(no ``sys.path`` games here — they broke invocation from any other cwd).
"""

from __future__ import annotations

from repro.scenarios import (  # noqa: F401
    BENCH_CKKS, CKKS_PLAN, CKKS_SLOT_BYTES, FILE_BW, GC_PLAN, GC_SLOT_BYTES,
    OS_PAGE_BYTES, PLANNER_CAP_MB, STORAGE, ScenarioCost, ScenarioResult,
    cost_fn, fmt_io_row, fmt_row, run_workload, run_workload_workers,
    scenario_spec)
