"""Shared benchmark harness: trace -> plan -> simulate the three §8.2
scenarios (Unbounded / OS Swapping / MAGE) with a calibrated storage model.

Calibration (documented, see EXPERIMENTS.md §Methodology): cloud-SSD-class
storage (800 MB/s, 150 us op latency); the OS baseline pays demand-paging
costs at 4 KiB granularity with sequential readahead (window 8), while MAGE
moves its own 64 KiB/128 KiB pages with planned, overlapped I/O — the same
asymmetry the paper measures on Azure D16d_v4 (its local SSD swap vs MAGE's
O_DIRECT aio).  Compute costs come from the protocol drivers' gate/NTT cost
models (GC: ~80ns per AND garbling; CKKS: ~N log N per NTT).

Absolute times are model outputs; the CLAIMS we validate are the paper's
ratios (MAGE-vs-OS speedups, %-of-Unbounded).
"""

from __future__ import annotations

import dataclasses
import sys
import time

sys.path.insert(0, "src")


from repro.core import (DeviceModel, PlanConfig, plan, simulate_os_paging,  # noqa: E402
                        simulate_unbounded)
from repro.core.liveness import compute_touches, working_set_pages  # noqa: E402
from repro.core.bytecode import strip_frees  # noqa: E402
from repro.core.simulator import simulate_memory_program  # noqa: E402
from repro.protocols.ckks import CkksCostModel, CkksParams  # noqa: E402
from repro.protocols.garbled.cost import GCCostModel  # noqa: E402
from repro.workloads import get  # noqa: E402

# --- calibration ------------------------------------------------------------
#
# Cloud local SSD (the D16d_v4 temp disk): ~1 GB/s streaming, 300 us op
# latency, deep queue (pipelined).  OS baseline: 4 KiB demand paging with an
# effective readahead of 2 (swap-slot fragmentation defeats clustering) and
# direct-reclaim write throttling.  CKKS per-coefficient cost models a
# memory-bandwidth-bound implementation (~10 GB/s effective), which is what
# makes the compute/transfer ratio match the paper's regime.

STORAGE = DeviceModel(bandwidth=1e9, latency=300e-6, fault_overhead=5e-6,
                      readahead=2, os_writeback_throttle_s=0.02)
OS_PAGE_BYTES = 4096
FILE_BW = 1e9               # input/output file streaming (all scenarios)
GC_SLOT_BYTES = 16          # one wire label
CKKS_SLOT_BYTES = 8
BENCH_CKKS = CkksParams(n_ring=1024, levels=2)

# paper defaults (§8.2): GC l=10000, B=256 pages; CKKS l=100, B=16
GC_PLAN = dict(lookahead=10_000, prefetch_pages=64)
CKKS_PLAN = dict(lookahead=100, prefetch_pages=16)


def cost_fn(protocol: str):
    """Driver cost model + input/output FILE streaming (paid identically in
    every scenario — §8.1.3 phase 1/3)."""
    from repro.core.bytecode import Op
    slot_bytes = GC_SLOT_BYTES if protocol == "gc" else CKKS_SLOT_BYTES
    if protocol == "gc":
        base = GCCostModel().cost
    else:
        model = CkksCostModel(pointwise=1.2e-9)
        n = BENCH_CKKS.n_ring
        base = lambda instr: model.cost(instr, n)  # noqa: E731

    def cost(instr):
        c = base(instr)
        if instr.op in (Op.INPUT, Op.OUTPUT):
            spans = instr.outs if instr.op == Op.INPUT else instr.ins
            nbytes = sum(s[1] for s in spans) * slot_bytes
            c += nbytes / FILE_BW
        return c
    return cost


@dataclasses.dataclass
class ScenarioResult:
    unbounded_s: float
    os_s: float
    mage_s: float
    plan_s: float
    plan_peak_mb: float
    swaps_in: int
    swaps_out: int
    prefetched: int
    working_set_pages: int
    budget_pages: int
    instructions: int

    @property
    def speedup_vs_os(self) -> float:
        return self.os_s / self.mage_s

    @property
    def pct_of_unbounded(self) -> float:
        return self.mage_s / self.unbounded_s - 1.0


def run_workload(name: str, n: int, budget_frac: float = 0.25,
                 num_workers: int = 1, worker: int = 0,
                 plan_overrides: dict | None = None) -> ScenarioResult:
    w = get(name)
    extra = {"ckks_params": BENCH_CKKS} if w.protocol == "ckks" else {}
    progs = w.trace(n, num_workers, **extra)
    prog = progs[worker]
    slot_bytes = GC_SLOT_BYTES if w.protocol == "gc" else CKKS_SLOT_BYTES
    page_bytes = prog.page_slots * slot_bytes
    cost = cost_fn(w.protocol)

    touches = compute_touches(prog, strip_frees(prog.instrs))
    ws = working_set_pages(touches)
    knobs = dict(GC_PLAN if w.protocol == "gc" else CKKS_PLAN)
    knobs.update(plan_overrides or {})
    min_frames = 8 + knobs["prefetch_pages"]
    budget = max(int(ws * budget_frac), min_frames)
    budget = min(budget, max(ws - 1, min_frames))
    knobs["prefetch_pages"] = min(knobs["prefetch_pages"],
                                  max(budget // 4, 1))

    t0 = time.perf_counter()
    mem, report = plan(prog, PlanConfig(num_frames=budget, **knobs),
                       track_memory=True)
    plan_s = time.perf_counter() - t0

    ub = simulate_unbounded(prog, cost)
    osr = simulate_os_paging(prog, cost, num_frames=budget,
                             page_bytes=page_bytes, model=STORAGE,
                             os_page_bytes=OS_PAGE_BYTES)
    mage = simulate_memory_program(mem, cost, page_bytes=page_bytes,
                                   model=STORAGE)
    return ScenarioResult(
        unbounded_s=ub.total, os_s=osr.total, mage_s=mage.total,
        plan_s=plan_s, plan_peak_mb=report.peak_mem_bytes / 2**20,
        swaps_in=report.replacement.swap_ins,
        swaps_out=report.replacement.swap_outs,
        prefetched=report.schedule.prefetched,
        working_set_pages=ws, budget_pages=budget,
        instructions=len(prog.instrs))


def fmt_row(name: str, r: ScenarioResult) -> str:
    return (f"{name:12s} n/a={r.instructions:7d}i ws={r.working_set_pages:5d} "
            f"budget={r.budget_pages:5d} | unb={r.unbounded_s:8.3f}s "
            f"os={r.os_s:8.3f}s mage={r.mage_s:8.3f}s | "
            f"speedup={r.speedup_vs_os:5.2f}x "
            f"overhead={100*r.pct_of_unbounded:6.1f}%")
