"""Fig. 12/13 analogue: application scaling — password-reuse detection (GC)
and computational PIR (CKKS).

Claim (§8.8): for a fixed time budget, MAGE processes ~3x the user-password
records and ~5x the PIR database elements compared to OS swapping.  We
compute records-per-second under both scenarios across problem sizes and
report the capacity ratio at equal time.

    PYTHONPATH=src python benchmarks/fig1213_apps.py [--tiny] [--json out]
"""

from __future__ import annotations

import argparse
import json

from common import run_workload
from repro.api import SCHEMA_VERSION

CASES = [("passreuse", [2048, 4096], 3.0), ("pir", [256, 512], 4.0)]
TINY_CASES = [("passreuse", [2048], 3.0), ("pir", [256], 4.0)]


def run(check: bool = True, tiny: bool = False,
        rows_out: list | None = None):
    out = {}
    rows = [] if rows_out is None else rows_out
    for name, sizes, target in (TINY_CASES if tiny else CASES):
        ratios = []
        for n in sizes:
            r = run_workload(name, n, budget_frac=0.3)
            ratio = r.os_s / r.mage_s
            ratios.append(ratio)
            rows.append({"workload": name, "n": n, "os_s": r.os_s,
                         "mage_s": r.mage_s, "capacity_ratio": ratio,
                         "target": target})
            print(f"{name:10s} n={n:6d}: os={r.os_s:8.3f}s "
                  f"mage={r.mage_s:8.3f}s -> capacity ratio ~{ratio:4.2f}x",
                  flush=True)
        out[name] = max(ratios)
        # throughput ratio ~= capacity ratio at fixed time budget for
        # near-linear workloads (PIR is linear; passreuse ~ n log n)
        if check:
            assert out[name] >= target, \
                f"{name}: expected >={target}x capacity gain, got {out[name]}"
    print(f"fig12/13 CLAIM: passreuse x{out['passreuse']:.1f}, "
          f"pir x{out['pir']:.1f} capacity at fixed time budget")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="one size per app (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows: list = []
    out = run(check=not args.no_check, tiny=args.tiny, rows_out=rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "fig1213_apps", "tiny": args.tiny,
                       "claims": out, "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
