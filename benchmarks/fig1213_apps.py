"""Fig. 12/13 analogue: application scaling — password-reuse detection (GC)
and computational PIR (CKKS).

Claim (§8.8): for a fixed time budget, MAGE processes ~3x the user-password
records and ~5x the PIR database elements compared to OS swapping.  We
compute records-per-second under both scenarios across problem sizes and
report the capacity ratio at equal time."""

from __future__ import annotations

from common import run_workload


def run(check: bool = True):
    out = {}
    for name, sizes, target in [("passreuse", [2048, 4096], 3.0),
                                ("pir", [256, 512], 4.0)]:
        ratios = []
        for n in sizes:
            r = run_workload(name, n, budget_frac=0.3)
            ratio = r.os_s / r.mage_s
            ratios.append(ratio)
            print(f"{name:10s} n={n:6d}: os={r.os_s:8.3f}s "
                  f"mage={r.mage_s:8.3f}s -> capacity ratio ~{ratio:4.2f}x",
                  flush=True)
        out[name] = max(ratios)
        # throughput ratio ~= capacity ratio at fixed time budget for
        # near-linear workloads (PIR is linear; passreuse ~ n log n)
        if check:
            assert out[name] >= target, \
                f"{name}: expected >={target}x capacity gain, got {out[name]}"
    print(f"fig12/13 CLAIM: passreuse x{out['passreuse']:.1f}, "
          f"pir x{out['pir']:.1f} capacity at fixed time budget")
    return out


if __name__ == "__main__":
    run()
