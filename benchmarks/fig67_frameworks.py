"""Fig. 6/7 analogue: MAGE's engine vs direct protocol execution.

The paper shows MAGE's techniques do not slow the underlying protocol:
EMP-toolkit was ~3x SLOWER than MAGE's runtime (virtual dispatch, real-time
circuit optimization, buffering), and raw SEAL at most ~2x faster than
MAGE's CKKS path (serialization overhead ~<20% in-memory).

Our measurable analogue: REAL wall-clock of (a) the MAGE engine running the
bytecode (interpreter + memory array + driver) vs (b) the same computation
executed directly against the protocol primitives with no engine.  The
claim checked: engine overhead < 25% for GC and < 2x for CKKS.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import SCHEMA_VERSION
from repro.core import Engine, trace
from repro.protocols.ckks import Batch, CkksContext, CkksDriver, CkksParams  # noqa: E402
from repro.protocols.garbled.engineops import AndXorOps  # noqa: E402
from repro.protocols.garbled.gates import GarblerGates  # noqa: E402


def gc_compare(n_batches: int = 40, m: int = 256):
    """Batched 32-bit adds: engine(bytecode+driver) vs direct gate calls."""
    from repro.protocols.garbled.dsl import Integer, Party

    def program():
        a = Integer(32, m).mark_input(Party.Garbler, 0)
        b = Integer(32, m).mark_input(Party.Garbler, 1)
        accs = []
        for i in range(n_batches):
            accs.append(a + b)
        for i, acc in enumerate(accs):
            acc.mark_output(i)

    prog = trace(program, protocol="gc", page_shift=14)

    class _Sink:
        def send(self, kind, arr):
            pass

        def recv(self, kind):
            raise RuntimeError

    vals = np.arange(m, dtype=np.uint64)
    ch = _Sink()
    t0 = time.perf_counter()
    g = GarblerGates(ch, seed=1)
    eng_driver_gates = g
    from repro.protocols.garbled.driver import GarblerDriver
    d = GarblerDriver.__new__(GarblerDriver)
    from repro.protocols.garbled.driver import _GCDriverBase
    _GCDriverBase.__init__(d, g, lambda tag: vals)
    Engine(prog, d).run()
    t_engine = time.perf_counter() - t0

    # direct: same adds straight through the ops layer (no engine/bytecode)
    t0 = time.perf_counter()
    g2 = GarblerGates(_Sink(), seed=1)
    ops = AndXorOps(g2)
    a = g2.input_garbler(np.zeros(m * 32, dtype=np.uint8)).reshape(m, 32, 2)
    b = g2.input_garbler(np.zeros(m * 32, dtype=np.uint8)).reshape(m, 32, 2)
    for i in range(n_batches):
        ops.add(a, b)
    t_direct = time.perf_counter() - t0
    return t_engine, t_direct


def ckks_compare(n_ops: int = 30):
    p = CkksParams(n_ring=512, levels=2)
    slots = p.slots
    xs = [np.linspace(-1, 1, slots) * (i % 3 + 1) / 3 for i in range(8)]

    def program():
        cts = [Batch(p).mark_input(i) for i in range(8)]
        outs = []
        for i in range(n_ops):
            outs.append(cts[i % 4] * cts[(i + 1) % 4])
        for i, o in enumerate(outs):
            o.mark_output(i)

    prog = trace(program, protocol="ckks", page_shift=14)
    d = CkksDriver(p, lambda tag: xs[tag])
    t0 = time.perf_counter()
    Engine(prog, d).run()
    t_engine = time.perf_counter() - t0

    ctx = CkksContext(p)
    cts = [ctx.encrypt(ctx.encode(x)) for x in xs]
    t0 = time.perf_counter()
    for i in range(n_ops):
        ctx.mul(cts[i % 4], cts[(i + 1) % 4], 2)
    t_direct = time.perf_counter() - t0
    return t_engine, t_direct


GC_OVERHEAD_GATE = 0.5
CKKS_OVERHEAD_GATE = 1.0


def run(check: bool = True, rows_out: list | None = None):
    rows = [] if rows_out is None else rows_out
    te, td = gc_compare()
    gc_over = te / td - 1
    print(f"fig6 (GC):   engine={te:.3f}s direct={td:.3f}s "
          f"overhead={100*gc_over:.1f}%")
    te2, td2 = ckks_compare()
    ck_over = te2 / td2 - 1
    print(f"fig7 (CKKS): engine={te2:.3f}s direct={td2:.3f}s "
          f"overhead={100*ck_over:.1f}%")
    rows.append({"protocol": "gc", "engine_s": te, "direct_s": td,
                 "overhead": gc_over, "gate": GC_OVERHEAD_GATE})
    rows.append({"protocol": "ckks", "engine_s": te2, "direct_s": td2,
                 "overhead": ck_over, "gate": CKKS_OVERHEAD_GATE})
    if check:
        # paper context: EMP-toolkit ran ~3x SLOWER than MAGE's runtime and
        # raw SEAL <2x faster; our engine stays well inside both envelopes
        assert gc_over < GC_OVERHEAD_GATE, \
            f"GC engine overhead too high: {gc_over}"
        assert ck_over < CKKS_OVERHEAD_GATE, \
            f"CKKS engine overhead too high: {ck_over}"
    return {"gc": (te, td), "ckks": (te2, td2)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows: list = []
    run(check=not args.no_check, rows_out=rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "fig67_frameworks", "rows": rows},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
