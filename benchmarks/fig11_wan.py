"""Fig. 11 analogue: garbled circuits over the wide area.

Models §8.7's two effects analytically over the measured per-workload
byte/OT counts (from the real protocol driver's channel statistics on a
scaled run):

 (a) concurrent OT batching: r rounds in flight over one RTT-limited flow;
 (b) multiple workers = multiple TCP flows, each with per-flow bandwidth;
     wide-area jitter makes stragglers (max-of-flows completion).

Claims: pipelining OTs improves time monotonically to a bandwidth floor
(Fig 11a); with >=2 flows the Oregon setup approaches the local time
(Fig 11b); the WAN penalty stays below the swapping penalty (§8.7's
conclusion), using fig8's merge MAGE-vs-OS gap as the reference.
"""

from __future__ import annotations

from repro.core import Engine
from repro.protocols.garbled.driver import GarblerDriver  # noqa: E402
from repro.protocols.garbled.gates import PartyChannel  # noqa: E402
from repro.workloads import get  # noqa: E402

import numpy as np  # noqa: E402

RTT_OREGON = 0.011          # s (paper: ~11 ms)
RTT_IOWA = 0.045
# same-metro cross-provider peering sustains multi-Gbps per tuned flow
# (32 MiB windows, §8.7); cross-country flows see far less
FLOW_BW_OREGON = 250e6      # bytes/s per flow
FLOW_BW_IOWA = 60e6
JITTER = 0.15               # per-flow wide-area variation (stragglers)


def measure_traffic(n: int = 256) -> tuple[int, int, float]:
    """Run the real garbler on a scaled merge to count bytes + OT batches,
    then scale per-record."""
    w = get("merge")
    prog = w.trace(n)[0]
    ch = PartyChannel()
    # drain the channel on a thread so the garbler can run alone
    import threading
    stop = threading.Event()
    stats = {"bytes": 0, "msgs": 0, "ot": 0}

    def drain():
        while not stop.is_set() or not ch.q.empty():
            try:
                kind, arr = ch.q.get(timeout=0.05)
            except Exception:
                continue
            stats["bytes"] += arr.nbytes
            stats["msgs"] += 1
            if kind == "ot":
                stats["ot"] += 1   # only OTs need round trips (tables are
                #                    one-way streaming)
    t = threading.Thread(target=drain, daemon=True)
    t.start()
    g = GarblerDriver(ch, lambda tag: np.zeros(32, dtype=np.uint64))
    Engine(prog, g).run()
    stop.set()
    t.join()
    return stats["bytes"], stats["ot"], g.cost_model.and_s


def wan_time(total_bytes: int, n_msgs: int, compute_s: float, rtt: float,
             flow_bw: float, flows: int, concurrent_ots: int) -> float:
    """Pipelined model: OT/setup round trips amortized by concurrency;
    garbled tables stream at flow bandwidth; flows split bytes evenly but
    finish at the slowest flow (jitter)."""
    ot_rounds = max(n_msgs, 1)        # OT batches needing a round trip
    setup = rtt * max(ot_rounds / concurrent_ots, 1.0)
    per_flow = total_bytes / flows / flow_bw
    slowest = per_flow * (1 + JITTER * (flows > 1) * np.log2(max(flows, 2)))
    return setup + max(slowest, compute_s)


def run(check: bool = True):
    total_bytes, n_msgs, _ = measure_traffic(n=256)
    scale = (16384 / 256) ** 1.1     # merge traffic ~ n log n
    total_bytes = int(total_bytes * scale)
    n_msgs = int(n_msgs * scale)
    compute_s = 5.8                   # fig8 merge unbounded time
    local_time = compute_s * 1.008    # fig8 merge MAGE result

    print("fig11a: concurrent OTs (Oregon, 1 flow)")
    prev = float("inf")
    times_a = []
    for c in [1, 2, 4, 8, 16, 32]:
        tt = wan_time(total_bytes, n_msgs, compute_s, RTT_OREGON,
                      FLOW_BW_OREGON, flows=1, concurrent_ots=c)
        times_a.append(tt)
        print(f"  concurrent={c:3d}: {tt:7.2f}s")
        assert tt <= prev + 1e-9
        prev = tt

    print("fig11b: workers/flows")
    for setup, rtt, bw in [("oregon", RTT_OREGON, FLOW_BW_OREGON),
                           ("iowa", RTT_IOWA, FLOW_BW_IOWA)]:
        times = []
        for flows in [1, 2, 4, 8]:
            tt = wan_time(total_bytes, n_msgs, compute_s, rtt, bw,
                          flows=flows, concurrent_ots=32)
            times.append(tt)
            print(f"  {setup:7s} flows={flows}: {tt:7.2f}s "
                  f"(local={local_time:.2f}s)")
        if setup == "oregon" and check:
            assert times[1] < 1.6 * local_time, \
                "2 flows should approach local performance (Oregon)"
    # §8.7 conclusion: WAN penalty < swapping penalty (OS was ~6.5x MAGE)
    wan_penalty = times_a[-1] / local_time
    print(f"fig11 CLAIM: WAN penalty {wan_penalty:.2f}x < OS-swap penalty "
          f"(~6.5x from fig8 merge)")
    if check:
        assert wan_penalty < 6.5
    return times_a


if __name__ == "__main__":
    run()
