"""Fig. 11 analogue: garbled circuits over the wide area — MEASURED.

§8.7's two effects, over the transport fabric instead of a pure cost
model:

 * The WAN point is a REAL two-party execution over the ``shaped``
   backend: the garbler→evaluator link gets Oregon-class latency and
   per-flow bandwidth, wall-clock is measured, and the byte/OT counts
   come from the fabric's per-link accounting (``Session.transport_stats``)
   rather than an analytic estimate.
 * The concurrency / flow-count sweeps (Fig 11a/11b) extrapolate those
   measured counts to the paper's n=16384 with the pipelined flow model
   (r OT rounds in flight over one RTT-limited flow; multiple workers =
   multiple flows with straggler jitter).

Claims: pipelining OTs improves time monotonically to a bandwidth floor
(Fig 11a); with >=2 flows the Oregon setup approaches the local time
(Fig 11b); and the measured WAN penalty stays below the swapping penalty
(§8.7's conclusion), using fig8's merge MAGE-vs-OS gap as the reference.
"""

from __future__ import annotations

import argparse
import json

import hashlib

import numpy as np

from repro.api import SCHEMA_VERSION, SLOT_BYTES, FabricSpec, JobSpec, Session
from repro.core.simulator import simulate_memory_program
from repro.protocols.garbled.gates import PartyChannel
from repro.scenarios import measure_traffic

RTT_OREGON = 0.011          # s (paper: ~11 ms)
RTT_IOWA = 0.045
# same-metro cross-provider peering sustains multi-Gbps per tuned flow
# (32 MiB windows, §8.7); cross-country flows see far less
FLOW_BW_OREGON = 250e6      # bytes/s per flow
FLOW_BW_IOWA = 60e6
JITTER = 0.15               # per-flow wide-area variation (stragglers)

MEASURE_N = 64              # scaled real run (extrapolated to 16384)
OT_TAG = PartyChannel.TAGS["ot"]

OVERLAP_N = 256             # 2-worker merge: 8 NET exchanges per worker
OVERLAP_LAT = RTT_OREGON    # shaped one-way latency per message


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def overlap_runs(check: bool, rows: list) -> None:
    """The planned-overlap engine on the same shaped WAN: the measured
    latency penalty collapses toward the bandwidth-only bound, and the
    overlap-aware simulator mode predicts the same collapse."""
    fab = FabricSpec(latency_s=OVERLAP_LAT, bandwidth=FLOW_BW_OREGON)
    kw = dict(num_workers=2, driver="gc-plaintext", transport="shaped",
              fabric=fab, warmup=True)
    ino = measure_traffic("merge", OVERLAP_N, exec_backend="scalar", **kw)
    ovl = measure_traffic("merge", OVERLAP_N, exec_backend="overlap", **kw)
    same = _digest(ino.outputs) == _digest(ovl.outputs)
    speedup = ino.seconds / ovl.seconds

    # predicted by the §8.2 simulator's overlap-aware NET cost mode, on
    # the very memory program the engine replays
    spec = JobSpec(workload="merge", n=OVERLAP_N, num_workers=2,
                   plan_mode="unbounded", driver="gc-plaintext")
    with Session(spec) as s:
        prog = s.plan()[0]
        page_bytes = prog.page_slots * SLOT_BYTES["gc"]
        cost = 5e-8                    # any flat per-instr cost; NET dominates
        p_ino = simulate_memory_program(prog, lambda i: cost, page_bytes,
                                        net_latency_s=OVERLAP_LAT,
                                        net_bandwidth=FLOW_BW_OREGON)
        p_ovl = simulate_memory_program(prog, lambda i: cost, page_bytes,
                                        net_latency_s=OVERLAP_LAT,
                                        net_bandwidth=FLOW_BW_OREGON,
                                        net_mode="overlap")
    print(f"fig11 overlap (merge n={OVERLAP_N}, 2 workers, shaped "
          f"{OVERLAP_LAT * 1e3:.0f}ms): in-order={ino.seconds:.3f}s "
          f"overlap={ovl.seconds:.3f}s ({speedup:.2f}x, identical "
          f"outputs: {same})")
    print(f"fig11 overlap predicted: net stall {p_ino.net_stall * 1e3:.1f}ms "
          f"-> {p_ovl.net_stall * 1e3:.1f}ms "
          f"({p_ino.net_stall / max(p_ovl.net_stall, 1e-12):.1f}x cut, "
          f"{p_ino.net_msgs} exchanges)")
    if check:
        assert same, "overlap engine must be output-identical"
        assert ovl.seconds < ino.seconds, \
            "overlap must beat in-order on a latency-shaped link"
        assert p_ovl.net_stall < p_ino.net_stall
    rows.append({"kind": "overlap", "n": OVERLAP_N, "latency_s": OVERLAP_LAT,
                 "inorder_s": ino.seconds, "overlap_s": ovl.seconds,
                 "speedup": speedup, "outputs_identical": same,
                 "predicted_net_stall_inorder_s": p_ino.net_stall,
                 "predicted_net_stall_overlap_s": p_ovl.net_stall,
                 "net_exchanges": p_ino.net_msgs})


def measured_runs(n: int = MEASURE_N):
    """Real two-party GC merge, twice: local fabric, then Oregon-shaped."""
    local = measure_traffic("merge", n, driver="gc-2party", check=True)
    wan = measure_traffic(
        "merge", n, driver="gc-2party", transport="shaped",
        fabric=FabricSpec(latency_s=RTT_OREGON, bandwidth=FLOW_BW_OREGON),
        check=True)
    return local, wan


def wan_time(total_bytes: int, n_msgs: int, compute_s: float, rtt: float,
             flow_bw: float, flows: int, concurrent_ots: int) -> float:
    """Pipelined model: OT/setup round trips amortized by concurrency;
    garbled tables stream at flow bandwidth; flows split bytes evenly but
    finish at the slowest flow (jitter)."""
    ot_rounds = max(n_msgs, 1)        # OT batches needing a round trip
    setup = rtt * max(ot_rounds / concurrent_ots, 1.0)
    per_flow = total_bytes / flows / flow_bw
    slowest = per_flow * (1 + JITTER * (flows > 1) * np.log2(max(flows, 2)))
    return setup + max(slowest, compute_s)


def run(check: bool = True, rows_out: list | None = None):
    rows = [] if rows_out is None else rows_out
    local, wan = measured_runs()
    ge_link = next(iter(local.links))    # the garbler→evaluator link
    total_bytes = local.total_bytes
    ot_msgs = sum(s.messages for (src, dst, tag), s in local.stats.items()
                  if tag == OT_TAG)
    print(f"fig11 measured (merge n={MEASURE_N}, link {ge_link}): "
          f"{total_bytes} B, {local.total_messages} msgs "
          f"({ot_msgs} OT batches)")
    print(f"fig11 measured: local={local.seconds:6.2f}s  "
          f"oregon-shaped={wan.seconds:6.2f}s  "
          f"(shaped moved {wan.total_bytes} B — identical traffic: "
          f"{wan.total_bytes == total_bytes})")
    wan_penalty_measured = wan.seconds / local.seconds
    print(f"fig11 CLAIM (measured): WAN penalty "
          f"{wan_penalty_measured:.2f}x < OS-swap penalty "
          f"(~6.5x from fig8 merge)")
    if check:
        assert wan.total_bytes == total_bytes, \
            "shaping must not change what crosses the link"
        assert wan_penalty_measured < 6.5
    rows.append({"kind": "measured", "n": MEASURE_N,
                 "total_bytes": total_bytes,
                 "total_messages": local.total_messages,
                 "ot_batches": ot_msgs, "local_s": local.seconds,
                 "wan_s": wan.seconds,
                 "wan_penalty_measured": wan_penalty_measured})

    # extrapolate the measured counts to the paper's size (traffic ~ n log n)
    scale = (16384 / MEASURE_N) ** 1.1
    big_bytes = int(total_bytes * scale)
    big_ots = int(ot_msgs * scale)
    compute_s = 5.8                   # fig8 merge unbounded time
    local_time = compute_s * 1.008    # fig8 merge MAGE result

    print("fig11a: concurrent OTs (Oregon, 1 flow)")
    prev = float("inf")
    times_a = []
    for c in [1, 2, 4, 8, 16, 32]:
        tt = wan_time(big_bytes, big_ots, compute_s, RTT_OREGON,
                      FLOW_BW_OREGON, flows=1, concurrent_ots=c)
        times_a.append(tt)
        rows.append({"kind": "fig11a", "concurrent_ots": c, "seconds": tt})
        print(f"  concurrent={c:3d}: {tt:7.2f}s")
        assert tt <= prev + 1e-9
        prev = tt
    print("fig11b: workers/flows")
    for setup, rtt, bw in [("oregon", RTT_OREGON, FLOW_BW_OREGON),
                           ("iowa", RTT_IOWA, FLOW_BW_IOWA)]:
        times = []
        for flows in [1, 2, 4, 8]:
            tt = wan_time(big_bytes, big_ots, compute_s, rtt, bw,
                          flows=flows, concurrent_ots=32)
            times.append(tt)
            rows.append({"kind": "fig11b", "setup": setup, "flows": flows,
                         "seconds": tt, "local_s": local_time})
            print(f"  {setup:7s} flows={flows}: {tt:7.2f}s "
                  f"(local={local_time:.2f}s)")
        if setup == "oregon" and check:
            assert times[1] < 1.6 * local_time, \
                "2 flows should approach local performance (Oregon)"
    wan_penalty = times_a[-1] / local_time
    print(f"fig11 CLAIM (extrapolated): WAN penalty {wan_penalty:.2f}x "
          f"< OS-swap penalty (~6.5x from fig8 merge)")
    if check:
        assert wan_penalty < 6.5
    rows.append({"kind": "claim", "wan_penalty_extrapolated": wan_penalty,
                 "swap_penalty_reference": 6.5})
    overlap_runs(check, rows)
    return times_a


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows: list = []
    run(check=not args.no_check, rows_out=rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "fig11_wan", "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
