"""Serving-daemon benchmark: steady-state jobs/sec, hot cache vs cold.

Starts an in-process :class:`repro.serve_daemon.ServeDaemon` on a unix
socket, then drives the same job shapes through ``repro.serve_client``
two ways:

  * **cold** — every submit bypasses the artifact cache
    (``use_cache=False``): full trace + plan each time, the §8.2
    pipeline's worst case;
  * **hot**  — one warming pass populates the cache, then every submit
    is served from validated on-disk artifacts: zero tracing and zero
    planning, verified against the daemon's own cache counters.

The acceptance claims checked here (and by the CI ``serve`` job):
hot jobs/sec strictly above cold, plan digests bitwise identical
between the two, and the hot phase performing no tracing or planning.

    PYTHONPATH=src python benchmarks/serve_bench.py [--tiny] [--json out]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from repro.api import SCHEMA_VERSION, JobSpec
from repro.serve_daemon.client import serve_client
from repro.serve_daemon.server import ServeDaemon

CASES = [("merge", 4096), ("sort", 2048), ("rsum", 128)]
TINY_CASES = [("merge", 512), ("rsum", 64)]
ROUNDS = 5
TINY_ROUNDS = 3


def bench_specs(cases) -> list[JobSpec]:
    return [JobSpec(workload=name, n=n, memory_budget=0.4,
                    plan_mode="streaming") for name, n in cases]


def drive(client, specs, rounds: int, use_cache: bool) -> dict:
    """Submit every spec ``rounds`` times; returns timing + digests."""
    digests: dict[str, list[str]] = {}
    t0 = time.perf_counter()
    jobs = 0
    for _ in range(rounds):
        for spec in specs:
            r = client.submit(spec, use_cache=use_cache)
            digests[f"{spec.workload}/{spec.n}"] = r["digests"]["plan"]
            jobs += 1
    dt = time.perf_counter() - t0
    return {"jobs": jobs, "seconds": dt, "jobs_per_s": jobs / dt,
            "digests": digests}


def run(tiny: bool = False) -> dict:
    cases = TINY_CASES if tiny else CASES
    rounds = TINY_ROUNDS if tiny else ROUNDS
    specs = bench_specs(cases)
    with tempfile.TemporaryDirectory(prefix="serve_bench_") as td:
        daemon = ServeDaemon(os.path.join(td, "cache"),
                             socket_path=os.path.join(td, "mage.sock"))
        daemon.start()
        try:
            with serve_client(daemon.address) as c:
                cold = drive(c, specs, rounds, use_cache=False)
                for spec in specs:          # warm the cache once
                    c.submit(spec)
                before = c.status()["cache"]
                hot = drive(c, specs, rounds, use_cache=True)
                after = c.status()["cache"]
                c.shutdown()
        finally:
            daemon.shutdown()

    # zero tracing + zero planning while hot: only hit counters moved
    assert after["trace_misses"] == before["trace_misses"], \
        f"hot phase traced: {before} -> {after}"
    assert after["plan_misses"] == before["plan_misses"], \
        f"hot phase planned: {before} -> {after}"
    assert after["plan_hits"] == before["plan_hits"] + hot["jobs"]
    assert hot["digests"] == cold["digests"], \
        "hot plans must be bitwise identical to cold plans"
    assert hot["jobs_per_s"] > cold["jobs_per_s"], \
        (f"hot ({hot['jobs_per_s']:.1f}/s) must beat cold "
         f"({cold['jobs_per_s']:.1f}/s)")
    return {"schema_version": SCHEMA_VERSION,
            "cases": [{"workload": w, "n": n} for w, n in cases],
            "rounds": rounds,
            "cold": cold, "hot": hot,
            "speedup": hot["jobs_per_s"] / cold["jobs_per_s"],
            "cache": after}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="small sizes + fewer rounds (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the report as JSON (CI artifact)")
    args = ap.parse_args(argv)
    report = run(tiny=args.tiny)
    print(f"serve_bench: cold {report['cold']['jobs_per_s']:8.1f} jobs/s")
    print(f"serve_bench: hot  {report['hot']['jobs_per_s']:8.1f} jobs/s "
          f"({report['speedup']:.1f}x, digests identical, "
          f"0 traces / 0 plans while hot)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
