"""Fig. 10 analogue: 4 workers per party, per-worker independent planning.

Claims (§8.6): MAGE's gains persist under parallelism; merge/sort — the
workloads with mid-computation communication phases — keep (indeed grow)
their advantage because the OS baseline's paging jitter interacts badly
with synchronization points.  We model the straggler effect by charging
each communication phase the MAX of the workers' accumulated delays
(deterministic analogue of the jitter observation).

Each case is one ``Session`` (``run_workload_workers``): the facade
resolves a per-worker fraction-of-working-set budget, plans every worker
independently (§6.1), and simulates all three scenarios per worker.  GC
cases drop the prefetch buffer to 16 pages so the smaller per-worker
working sets still see real memory pressure (the floor is 8 + B frames).
"""

from __future__ import annotations

import argparse
import json

from common import fmt_row, run_workload_workers

from repro.api import SCHEMA_VERSION
from repro.scenarios import measure_traffic
from repro.workloads import get

WORKERS = 4
CASES = [("merge", 16384), ("sort", 8192), ("mvmul", 384), ("rsum", 256),
         ("rmvmul", 24)]
TINY_CASES = [("merge", 2048), ("sort", 1024), ("rsum", 64)]
GC_OVERRIDES = {"prefetch_pages": 16}
TRAFFIC_N = 4096            # measured-traffic case (scaled merge)
TINY_TRAFFIC_N = 512


def measured_worker_traffic(check: bool = True, tiny: bool = False):
    """The communication phases are real: run merge's bitonic exchanges
    for REAL over the fabric and report the per-link byte accounting
    (what the straggler model charges at each sync point)."""
    n = TINY_TRAFFIC_N if tiny else TRAFFIC_N
    r = measure_traffic("merge", n, num_workers=WORKERS, check=check)
    print(f"fig10 measured traffic (merge n={n}, p={WORKERS}, "
          f"{r.seconds:.2f}s):")
    for (src, dst), s in sorted(r.links.items()):
        print(f"  worker{src} -> worker{dst}: {s.messages:4d} msgs "
              f"{s.bytes:10d} B")
    if check:
        assert r.links, "bitonic merge must exchange remote pairs"
        # bitonic exchanges are symmetric: both directions move equal bytes
        for (src, dst), s in r.links.items():
            back = r.links.get((dst, src))
            assert back is not None and back.bytes == s.bytes, \
                f"asymmetric exchange on link {src}<->{dst}"
    return r


def run(check: bool = True, tiny: bool = False,
        rows_out: list | None = None):
    results = {}
    rows = [] if rows_out is None else rows_out
    for name, n in (TINY_CASES if tiny else CASES):
        overrides = GC_OVERRIDES if get(name).protocol == "gc" else None
        per_worker = run_workload_workers(name, n, num_workers=WORKERS,
                                          budget_frac=0.4,
                                          plan_overrides=overrides)
        # workers synchronize: wall time = max over workers; the OS case
        # additionally pays jitter at each sync (max-of-delays effect)
        ub = max(r.unbounded_s for r in per_worker)
        osr = max(r.os_s for r in per_worker)
        mage = max(r.mage_s for r in per_worker)
        results[name] = (ub, osr, mage)
        rows.append({"workload": name, "n": n, "workers": WORKERS,
                     "unbounded_s": ub, "os_s": osr, "mage_s": mage,
                     "speedup": osr / mage,
                     "overhead_pct": 100 * (mage / ub - 1)})
        print(f"fig10 {name:8s} p={WORKERS}: unb={ub:8.3f}s os={osr:8.3f}s "
              f"mage={mage:8.3f}s speedup={osr/mage:5.2f}x "
              f"overhead={100*(mage/ub-1):6.1f}%", flush=True)
        print("  " + fmt_row(f"{name}/w0", per_worker[0]), flush=True)
    if check and not tiny:
        # at tiny sizes per-worker sets fit in memory and the OS case
        # pays no paging — the claim is only meaningful at full sizes
        assert all(osr > mg for _, osr, mg in results.values()), \
            "MAGE must keep beating OS under parallelism"
    traffic = measured_worker_traffic(check=check, tiny=tiny)
    rows.append({"workload": "merge/traffic",
                 "n": TINY_TRAFFIC_N if tiny else TRAFFIC_N,
                 "workers": WORKERS, "seconds": traffic.seconds,
                 "links": {f"{src}->{dst}": {"messages": s.messages,
                                             "bytes": s.bytes}
                           for (src, dst), s in sorted(traffic.links.items())}})
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (claim gate skipped)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows: list = []
    run(check=not args.no_check, tiny=args.tiny, rows_out=rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "fig10_parallel", "tiny": args.tiny,
                       "workers": WORKERS, "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
