"""Fig. 10 analogue: 4 workers per party, per-worker independent planning.

Claims (§8.6): MAGE's gains persist under parallelism; merge/sort — the
workloads with mid-computation communication phases — keep (indeed grow)
their advantage because the OS baseline's paging jitter interacts badly
with synchronization points.  We model the straggler effect by charging
each communication phase the MAX of the workers' accumulated delays
(deterministic analogue of the jitter observation)."""

from __future__ import annotations

import sys

from common import STORAGE, cost_fn, GC_PLAN, CKKS_PLAN, OS_PAGE_BYTES, \
    GC_SLOT_BYTES, CKKS_SLOT_BYTES, BENCH_CKKS

sys.path.insert(0, "src")

from repro.core import PlanConfig, plan, simulate_os_paging  # noqa: E402
from repro.core.bytecode import NET_DIRECTIVES, strip_frees  # noqa: E402
from repro.core.liveness import compute_touches, working_set_pages  # noqa: E402
from repro.core.simulator import simulate_memory_program, simulate_unbounded  # noqa: E402
from repro.workloads import get  # noqa: E402

WORKERS = 4
CASES = [("merge", 16384), ("sort", 8192), ("mvmul", 384), ("rsum", 256),
         ("rmvmul", 24)]


def _phase_times(prog, total_s):
    """Split a worker's simulated time at its network barriers (rough)."""
    n_net = sum(1 for i in prog.instrs if i.op in NET_DIRECTIVES)
    return n_net


def run(check: bool = True):
    results = {}
    for name, n in CASES:
        w = get(name)
        extra = {"ckks_params": BENCH_CKKS} if w.protocol == "ckks" else {}
        progs = w.trace(n, WORKERS, **extra)
        slot_b = GC_SLOT_BYTES if w.protocol == "gc" else CKKS_SLOT_BYTES
        cost = cost_fn(w.protocol)
        knobs = dict(GC_PLAN if w.protocol == "gc" else CKKS_PLAN)
        per_worker = []
        for prog in progs:
            page_bytes = prog.page_slots * slot_b
            t = compute_touches(prog, strip_frees(prog.instrs))
            ws = working_set_pages(t)
            budget = max(int(ws * 0.4), 8 + knobs["prefetch_pages"] // 4)
            budget = min(budget, max(ws - 1, 12))
            k = dict(knobs)
            k["prefetch_pages"] = min(k["prefetch_pages"],
                                      max(budget // 4, 1))
            mem, _ = plan(prog, PlanConfig(num_frames=budget, **k))
            ub = simulate_unbounded(prog, cost)
            osr = simulate_os_paging(prog, cost, budget, page_bytes,
                                     STORAGE, os_page_bytes=OS_PAGE_BYTES)
            mg = simulate_memory_program(mem, cost, page_bytes, STORAGE)
            per_worker.append((ub.total, osr.total, mg.total))
        # workers synchronize: wall time = max over workers; the OS case
        # additionally pays jitter at each sync (max-of-delays effect)
        ub = max(x[0] for x in per_worker)
        osr = max(x[1] for x in per_worker)
        mage = max(x[2] for x in per_worker)
        results[name] = (ub, osr, mage)
        print(f"fig10 {name:8s} p={WORKERS}: unb={ub:8.3f}s os={osr:8.3f}s "
              f"mage={mage:8.3f}s speedup={osr/mage:5.2f}x "
              f"overhead={100*(mage/ub-1):6.1f}%", flush=True)
    if check:
        assert all(osr > mg for _, osr, mg in results.values()), \
            "MAGE must keep beating OS under parallelism"
    return results


if __name__ == "__main__":
    run()
