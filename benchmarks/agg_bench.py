"""Secure-aggregation scalability: clients/sec, latency, and the
bounded-memory + zero-re-plan evidence.

The number of parties is the scalability axis here (ROADMAP's
"millions of users" item): thousands of input-only clients stream
additive shares through a handful of gateway endpoints to a small
compute fleet (docs/AGGREGATE.md).  Three measured rows:

* ``inproc_fanin`` — the throughput row.  N clients/round on the inproc
  fabric with a per-link in-flight byte bound; the claim (gated here and
  by the CI ``aggregate`` job) is >= 1000 sustained clients/round-sec at
  full size, with server memory *counter-verified* bounded: every
  gateway→server reorder buffer's high-water mark must stay under the
  configured knob plus one message.
* ``shaped_wan`` — the latency row.  The same run over a ``shaped`` WAN
  (configurable per-link latency/bandwidth) reporting per-client
  p50/p90/p99 share-to-ingest latency and per-link byte accounting —
  measured traffic, not a model.
* ``plan_cache`` — the offline/online row.  Two runs against one
  ``ArtifactCache``: the cold run pays exactly one round-plan build, the
  hot run re-plans nothing (``agg_misses == 0``), asserted from the
  cache counters.

    PYTHONPATH=src python benchmarks/agg_bench.py [--tiny] [--json out]
"""

from __future__ import annotations

import argparse
import json
import tempfile

from repro.aggregate import AggSpec, run_aggregation, verify_aggregates
from repro.api import SCHEMA_VERSION
from repro.serve_daemon.cache import ArtifactCache

#: full-size / CI-tiny shapes (clients, vec_len, rounds)
FULL = {"clients": 2000, "vec_len": 64, "rounds": 3}
TINY = {"clients": 300, "vec_len": 16, "rounds": 2}
#: per-link in-flight byte bound for the fan-in row (the knob the
#: reorder high-water marks are checked against)
INFLIGHT_BYTES = 256 << 10
#: sustained clients per round-second the fan-in row must hit
GATE_CLIENTS_PER_S = 1000.0
TINY_GATE_CLIENTS_PER_S = 200.0
#: WAN shape for the latency row
WAN_LATENCY_S = 0.002
WAN_BANDWIDTH = 200e6


def _reorder_bounded(res, spec: AggSpec) -> tuple[bool, int]:
    """Did every gateway→server buffer stay under its knob (+1 msg)?"""
    slack = spec.vec_len * 8       # one admitted-over-the-line message
    worst = 0
    ok = True
    for (src, dst), st in res.reorder.items():
        if dst < spec.servers and src >= spec.servers:
            worst = max(worst, st.peak_bytes)
            if st.max_bytes and st.peak_bytes > st.max_bytes + slack:
                ok = False
    return ok, worst


def bench_fanin(shape: dict, check: bool) -> dict:
    spec = AggSpec(**shape, max_inflight_bytes=INFLIGHT_BYTES)
    res = run_aggregation(spec)
    if check:
        verify_aggregates(res)
    bounded, peak = _reorder_bounded(res, spec)
    return {
        "case": "inproc_fanin", "transport": "inproc", **shape,
        "seconds": res.seconds, "clients_per_s": res.clients_per_s,
        "latency_ms": res.latency_ms,
        "inflight_bytes_knob": INFLIGHT_BYTES,
        "reorder_peak_bytes": peak, "reorder_bounded": bounded,
        "admission_peak_frames": res.admission["peak_frames"],
    }


def bench_wan(shape: dict, check: bool) -> dict:
    from repro.core.transport import FabricSpec
    spec = AggSpec(**shape, max_inflight_bytes=INFLIGHT_BYTES)
    res = run_aggregation(
        spec, transport="shaped",
        fabric_spec=FabricSpec(latency_s=WAN_LATENCY_S,
                               bandwidth=WAN_BANDWIDTH))
    if check:
        verify_aggregates(res)
    link_bytes = {f"{s}->{d}": st.bytes
                  for (s, d), st in sorted(res.link_totals.items())}
    return {
        "case": "shaped_wan", "transport": "shaped", **shape,
        "latency_s": WAN_LATENCY_S, "bandwidth": WAN_BANDWIDTH,
        "seconds": res.seconds, "clients_per_s": res.clients_per_s,
        "latency_ms": res.latency_ms, "link_bytes": link_bytes,
        "total_bytes": sum(link_bytes.values()),
    }


def bench_plan_cache(shape: dict, check: bool) -> dict:
    spec = AggSpec(**shape)
    with tempfile.TemporaryDirectory(prefix="agg_cache_") as d:
        cold_cache = ArtifactCache(d)
        cold = run_aggregation(spec, cache=cold_cache)
        hot_cache = ArtifactCache(d)     # fresh counters, same artifacts
        hot = run_aggregation(spec, cache=hot_cache)
        if check:
            verify_aggregates(cold)
            verify_aggregates(hot)
        row = {
            "case": "plan_cache", "transport": "inproc", **shape,
            "cold_events": cold.plan_events, "hot_events": hot.plan_events,
            "cold_misses": cold_cache.stats.agg_misses,
            "cold_hits": cold_cache.stats.agg_hits,
            "hot_misses": hot_cache.stats.agg_misses,
            "hot_hits": hot_cache.stats.agg_hits,
        }
    return row


def run(check: bool = True, tiny: bool = False) -> list[dict]:
    shape = TINY if tiny else FULL
    gate = TINY_GATE_CLIENTS_PER_S if tiny else GATE_CLIENTS_PER_S
    rows = []

    r = bench_fanin(shape, check)
    rows.append(r)
    print(f"inproc_fanin: {r['clients']} clients x {r['rounds']} rounds -> "
          f"{r['clients_per_s']:.0f} clients/s, reorder peak "
          f"{r['reorder_peak_bytes']} B (knob {INFLIGHT_BYTES} B, "
          f"bounded={r['reorder_bounded']})", flush=True)
    if check:
        assert r["reorder_bounded"], \
            "reorder high-water mark exceeded the in-flight byte knob"
        assert r["clients_per_s"] >= gate, \
            f"sustained {r['clients_per_s']:.0f} clients/s < gate {gate:.0f}"

    r = bench_wan(shape, check)
    rows.append(r)
    lat = r["latency_ms"]
    print(f"shaped_wan:  {WAN_LATENCY_S*1e3:.0f} ms / "
          f"{WAN_BANDWIDTH/1e6:.0f} MB/s links -> "
          f"{r['clients_per_s']:.0f} clients/s, per-client latency "
          f"p50={lat.get('p50', 0):.1f} p90={lat.get('p90', 0):.1f} "
          f"p99={lat.get('p99', 0):.1f} ms, {r['total_bytes']} B on the "
          f"wire", flush=True)
    if check:
        assert lat, "shaped WAN row produced no latency samples"

    r = bench_plan_cache(shape, check)
    rows.append(r)
    print(f"plan_cache:  cold {r['cold_misses']} miss / {r['cold_hits']} "
          f"hit, hot {r['hot_misses']} miss / {r['hot_hits']} hit",
          flush=True)
    if check:
        assert r["cold_misses"] == 1 and r["hot_misses"] == 0, \
            "hot rounds must reuse the cached round plan (zero re-plans)"
        assert r["hot_hits"] == shape["rounds"], \
            "every hot round should hit the plan cache"

    print(f"agg CLAIM: {rows[0]['clients_per_s']:.0f} clients/s sustained "
          f"fan-in under a {INFLIGHT_BYTES >> 10} KiB in-flight bound, "
          f"zero hot re-plans")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows = run(check=not args.no_check, tiny=args.tiny)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "agg", "tiny": args.tiny,
                       "gate_clients_per_s": (TINY_GATE_CLIENTS_PER_S
                                              if args.tiny
                                              else GATE_CLIENTS_PER_S),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
