"""Fig. 9 analogue: same comparison at larger problem sizes (the paper's
16 GiB-limit experiment, scaled).  Sort is excluded exactly as in the paper
(its planning intermediates were the limiting factor there; here we keep the
parallel for fidelity and to bound runtime)."""

from __future__ import annotations

from common import fmt_row, run_workload

CASES = [("merge", 32768), ("ljoin", 512), ("mvmul", 512),
         ("binfclayer", 4096), ("rsum", 512), ("rstats", 256),
         ("rmvmul", 32), ("n_rmatmul", 10), ("t_rmatmul", 10)]


def run(check: bool = True):
    rows = {}
    for name, n in CASES:
        rows[name] = run_workload(name, n, budget_frac=0.3)
        print("fig9:", fmt_row(name, rows[name]), flush=True)
    beats = sum(r.os_s > r.mage_s for r in rows.values())
    ov60 = sum(r.pct_of_unbounded <= 0.60 for r in rows.values())
    print(f"fig9 CLAIMS: beats-OS {beats}/{len(rows)} | <=60% {ov60}/{len(rows)}")
    if check:
        assert beats == len(rows)
        assert ov60 >= len(rows) - 1
    return rows


if __name__ == "__main__":
    run()
