"""Fig. 9 analogue: same comparison at larger problem sizes (the paper's
16 GiB-limit experiment, scaled).  Sort is excluded exactly as in the paper
(its planning intermediates were the limiting factor there; here we keep the
parallel for fidelity and to bound runtime).  The largest merge runs through
the out-of-core file pipeline: its trace exceeds the planner's own memory
cap, which is precisely the regime the streaming planner exists for."""

from __future__ import annotations

import argparse

from common import PLANNER_CAP_MB, fmt_row, run_workload

CASES = [("merge", 32768), ("ljoin", 512), ("mvmul", 512),
         ("binfclayer", 4096), ("rsum", 512), ("rstats", 256),
         ("rmvmul", 32), ("n_rmatmul", 10), ("t_rmatmul", 10)]

# 18.1M-instruction virtual trace (~2.6 GiB on disk, 6.7 GiB memory
# program) — 4.4x the PR-4 size (bitonic merge wants a power of two;
# this is the 2^21 → 2^23 step).  The whole trace→plan→simulate path is
# array-speed and O(chunk): record-array planner cores, chunk-streaming
# OS-paging baseline and working-set sizing (PR 4), and the vectorized
# simulator cores with chunked cost models (PR 5) — simulator memory
# stays flat at any trace length.  Measured: ws=524k pages,
# budget=157k frames, MAGE 7.0x over OS at 0.7% over unbounded.
STREAM_CASE = ("merge", 8388608)


def run(check: bool = True, streaming: bool = True, stream_case=None,
        sim_core: str = "array"):
    stream_case = stream_case if stream_case is not None else STREAM_CASE
    rows = {}
    for name, n in CASES:
        rows[name] = run_workload(name, n, budget_frac=0.3,
                                  sim_core=sim_core)
        print("fig9:", fmt_row(name, rows[name]), flush=True)
    if streaming:
        name, n = stream_case
        r = run_workload(name, n, budget_frac=0.3, plan_mode="streaming",
                         sim_core=sim_core)
        rows[f"{name}@{n}"] = r
        print("fig9 (file pipeline):", fmt_row(f"{name}@{n}", r), flush=True)
        print(f"fig9 streaming: memory program "
              f"{r.program_bytes / 2**20:.1f} MiB "
              f"(planner cap {PLANNER_CAP_MB:.0f} MiB), "
              f"planner peak {r.plan_peak_mb:.1f} MiB")
        if check:
            assert r.program_bytes > PLANNER_CAP_MB * 2**20
            # planner peak is lookahead-bound, not program-bound (§6.1)
            assert r.plan_peak_mb * 2**20 < r.program_bytes / 2
    beats = sum(r.os_s > r.mage_s for r in rows.values())
    ov60 = sum(r.pct_of_unbounded <= 0.60 for r in rows.values())
    print(f"fig9 CLAIMS: beats-OS {beats}/{len(rows)} | <=60% {ov60}/{len(rows)}")
    if check:
        assert beats == len(rows)
        assert ov60 >= len(rows) - 1
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--stream-n", type=int, default=None,
                    help="override the streaming case's merge size")
    ap.add_argument("--sim-core", default="array",
                    choices=("array", "scalar"))
    ap.add_argument("--no-check", action="store_true")
    ap.add_argument("--no-streaming", action="store_true")
    args = ap.parse_args(argv)
    stream_case = ("merge", args.stream_n) if args.stream_n else None
    run(check=not args.no_check, streaming=not args.no_streaming,
        stream_case=stream_case, sim_core=args.sim_core)


if __name__ == "__main__":
    main()
