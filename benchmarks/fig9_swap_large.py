"""Fig. 9 analogue: same comparison at larger problem sizes (the paper's
16 GiB-limit experiment, scaled).  Sort is excluded exactly as in the paper
(its planning intermediates were the limiting factor there; here we keep the
parallel for fidelity and to bound runtime).  The largest merge runs through
the out-of-core file pipeline: its trace exceeds the planner's own memory
cap, which is precisely the regime the streaming planner exists for."""

from __future__ import annotations

from common import PLANNER_CAP_MB, fmt_row, run_workload

CASES = [("merge", 32768), ("ljoin", 512), ("mvmul", 512),
         ("binfclayer", 4096), ("rsum", 512), ("rstats", 256),
         ("rmvmul", 32), ("n_rmatmul", 10), ("t_rmatmul", 10)]

# ~190 MiB virtual trace — ~23x past the 8 MiB planner cap and 8x the
# PR-1 size (bitonic merge wants a power of two; this is the ~10x step).
# The whole trace→plan→simulate path is now O(chunk) (record-array
# planner cores + chunk-streaming OS-paging baseline + streaming
# working-set sizing), so the only per-instruction Python left on this
# path is the simulators' cost-model calls.
STREAM_CASE = ("merge", 2097152)


def run(check: bool = True, streaming: bool = True):
    rows = {}
    for name, n in CASES:
        rows[name] = run_workload(name, n, budget_frac=0.3)
        print("fig9:", fmt_row(name, rows[name]), flush=True)
    if streaming:
        name, n = STREAM_CASE
        r = run_workload(name, n, budget_frac=0.3, plan_mode="streaming")
        rows[f"{name}@{n}"] = r
        print("fig9 (file pipeline):", fmt_row(f"{name}@{n}", r), flush=True)
        print(f"fig9 streaming: memory program "
              f"{r.program_bytes / 2**20:.1f} MiB "
              f"(planner cap {PLANNER_CAP_MB:.0f} MiB), "
              f"planner peak {r.plan_peak_mb:.1f} MiB")
        if check:
            assert r.program_bytes > PLANNER_CAP_MB * 2**20
            # planner peak is lookahead-bound, not program-bound (§6.1)
            assert r.plan_peak_mb * 2**20 < r.program_bytes / 2
    beats = sum(r.os_s > r.mage_s for r in rows.values())
    ov60 = sum(r.pct_of_unbounded <= 0.60 for r in rows.values())
    print(f"fig9 CLAIMS: beats-OS {beats}/{len(rows)} | <=60% {ov60}/{len(rows)}")
    if check:
        assert beats == len(rows)
        assert ov60 >= len(rows) - 1
    return rows


if __name__ == "__main__":
    run()
