"""Table 1 analogue: planning time and planner peak memory per workload.

Claims (§8.5): planning time and memory-program size are linear in the
COMPUTATION size (we check near-linear scaling across 2x problem sizes);
CKKS planning is much cheaper than GC planning (coarser instructions); and
the planner's own memory stays far below the runtime budget.

``--streaming`` additionally sweeps synthetic programs past the planner's
own memory cap: the legacy in-memory planner materializes the whole program
(peak memory linear in length), while the streaming pipeline
(``plan_streaming``: file -> annotate -> replace -> schedule -> file) holds
only chunk-sized buffers plus O(frames + lookahead) state, so it plans
programs 10x+ larger than the cap with flat peak memory — the paper's
"nearly zero-cost" planning claim at scale.

``--cores`` compares the two planner cores — the vectorized record-array
core (``core="array"``, the default) against the scalar reference
transducers — on a paging-realistic trace (pages hold several values, so
most touches hit residency, the regime the paper's 64 KiB+ pages live in).
Outputs are verified bitwise-identical via ``records_digest`` and the
per-stage speedup line is the PR-4 headline: >=10x replacement+scheduling
throughput at the default chunk size.

Usage (run with the package importable, e.g. PYTHONPATH=src):
  python benchmarks/table1_planning.py                # workload table
  python benchmarks/table1_planning.py --streaming    # out-of-core sweep
  python benchmarks/table1_planning.py --cores        # array vs scalar
  python benchmarks/table1_planning.py --tiny --json out.json   # CI smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import tempfile
import time

import numpy as np

from common import run_workload

from repro.core import PlanConfig, plan, plan_streaming
from repro.core.bytecode import (DEFAULT_CHUNK_INSTRS, Instr, Op, Program,
                                 ProgramWriter, RECORD_BYTES)
from repro.core.liveness import file_digest

CASES = [("merge", 8192), ("sort", 8192), ("ljoin", 256), ("mvmul", 256),
         ("binfclayer", 2048), ("rsum", 256), ("rstats", 128),
         ("rmvmul", 16), ("n_rmatmul", 8), ("t_rmatmul", 8)]
TINY_CASES = [("merge", 2048), ("rsum", 128)]

# --- streaming sweep configuration ------------------------------------------
#
# The planner memory cap is what Table 1 bounds: the planner's own peak
# memory, independent of how large the planned program is.  The sweep's
# largest size exceeds 10x the cap in on-disk program bytes.

PLANNER_CAP_MB = 8.0
SWEEP_SIZES = [40_000, 160_000, 560_000]
TINY_SWEEP_SIZES = [3_000, 9_000]
LEGACY_MAX = 200_000            # materializing beyond this is the point...
SWEEP_CHUNK = 2048
LIVE_PAGES = 2048
PAGE_SHIFT = 6


def synth_instrs(n: int, live_pages: int = LIVE_PAGES,
                 page_shift: int = PAGE_SHIFT, seed: int = 0,
                 local_frac: float = 0.9):
    """Deterministic synthetic GC-style trace with skewed page locality.

    A generator, so the streaming path never materializes the program: one
    value per page, writes round-robin over ``live_pages``, reads mostly
    nearby pages with a tail of far references (what makes Belady work)."""
    psize = 1 << page_shift
    rng = np.random.default_rng(seed)
    for i in range(live_pages):
        yield Instr(Op.INPUT, outs=((i * psize, psize),), imm=(i,))
    i = live_pages
    while i < n:
        m = min(4096, n - i)
        loc = rng.random(m) < local_frac
        near = rng.integers(1, 64, m)
        far = rng.integers(0, live_pages, m)
        r2 = rng.integers(1, 128, m)
        for j in range(m):
            wp = (i + j) % live_pages
            a = (wp - int(near[j])) % live_pages if loc[j] else int(far[j])
            b = (wp - int(r2[j])) % live_pages
            yield Instr(Op.ADD, outs=((wp * psize, psize),),
                        ins=((a * psize, psize), (b * psize, psize)))
        i += m


def _sweep_config() -> PlanConfig:
    return PlanConfig(num_frames=512 + 64, lookahead=1000, prefetch_pages=64)


# --- core-comparison configuration -------------------------------------------
#
# The sweep trace above is a deliberate worst case for ANY planner core
# (one whole-page value per instruction, so nearly every instruction
# evicts and the planner is event-bound).  The core comparison instead
# uses a paging-realistic trace: pages hold VALS_PER_PAGE values (the
# paper's 64 KiB GC pages hold thousands), so the vast majority of touches
# hit residency and the array core's batched no-miss fast path carries the
# chunk.  Swap traffic still exists (cold faults + far references).

CORES_N = 120_000
TINY_CORES_N = 12_000
CORES_LIVE_PAGES = 1024
VALS_PER_PAGE = 8


def synth_value_instrs(n: int, live_pages: int = CORES_LIVE_PAGES,
                       page_shift: int = PAGE_SHIFT,
                       vals_per_page: int = VALS_PER_PAGE, seed: int = 1,
                       local_frac: float = 0.99,
                       write_pages: int | None = None):
    """Value-granular GC-style trace: several values per page, reads mostly
    over recently-written values with a tail of far references.  ADDs carry
    GC width immediates (one 32-bit lane) so the trace is priceable by
    ``GCCostModel`` — the ``--sim`` section replays it through the timing
    simulators."""
    psize = 1 << page_shift
    vw = psize // vals_per_page
    nvals = live_pages * vals_per_page
    wvals = (write_pages if write_pages is not None
             else live_pages // 2) * vals_per_page
    rng = np.random.default_rng(seed)
    for p in range(live_pages):
        yield Instr(Op.INPUT, outs=((p * psize, psize),), imm=(p,))
    i = live_pages
    while i < n:
        m = min(4096, n - i)
        loc = rng.random(m) < local_frac
        near = rng.integers(1, 128, m)
        far = rng.integers(0, nvals, m)
        r2 = rng.integers(1, 256, m)
        for j in range(m):
            wv = (i + j) % wvals
            a = (wv - int(near[j])) % wvals if loc[j] else int(far[j])
            b = (wv - int(r2[j])) % wvals
            yield Instr(Op.ADD, outs=((wv * vw, vw),),
                        ins=((a * vw, vw), (b * vw, vw)), imm=(1, 32))
        i += m


def _cores_config(live_pages: int) -> PlanConfig:
    b = live_pages // 16
    return PlanConfig(num_frames=live_pages * 5 // 8 + b, lookahead=2000,
                      prefetch_pages=b)


def run_cores(n: int = CORES_N, live_pages: int = CORES_LIVE_PAGES,
              chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
              check: bool = True) -> dict:
    """Array-vs-scalar core comparison: per-stage seconds + instr/s, the
    combined replacement+scheduling speedup, and a bitwise output check."""
    cfg0 = _cores_config(live_pages)
    out: dict = {"n": n, "chunk_instrs": chunk_instrs,
                 "live_pages": live_pages,
                 "num_frames": cfg0.num_frames}
    wd = tempfile.mkdtemp(prefix="mage_cores_")
    try:
        vpath = os.path.join(wd, "virtual.bc")
        w = ProgramWriter(vpath, page_shift=PAGE_SHIFT, protocol="gc",
                          vspace_slots=live_pages << PAGE_SHIFT,
                          chunk_instrs=chunk_instrs)
        w.extend(synth_value_instrs(n, live_pages))
        pf = w.close()
        digests = {}
        for core in ("scalar", "array"):
            cfg = dataclasses.replace(cfg0, core=core)
            t0 = time.perf_counter()
            mem, rep = plan_streaming(pf, cfg, workdir=wd,
                                      chunk_instrs=chunk_instrs)
            total = time.perf_counter() - t0
            digests[core] = file_digest(mem)
            out[core] = dict(
                total_s=total, annotate_s=rep.annotate_s,
                replacement_s=rep.replacement_s,
                scheduling_s=rep.scheduling_s,
                annotate_ips=n / max(rep.annotate_s, 1e-12),
                replacement_ips=n / max(rep.replacement_s, 1e-12),
                scheduling_ips=n / max(rep.scheduling_s, 1e-12),
                swap_ins=rep.replacement.swap_ins,
                swap_outs=rep.replacement.swap_outs)
            os.unlink(mem.path)
            print(f"cores[{core:6s}]: rep {out[core]['replacement_ips']:>10,.0f} i/s "
                  f"sched {out[core]['scheduling_ips']:>10,.0f} i/s "
                  f"(rep {rep.replacement_s:.2f}s + sched "
                  f"{rep.scheduling_s:.2f}s, {n} instrs)")
    finally:
        shutil.rmtree(wd, ignore_errors=True)
    s, a = out["scalar"], out["array"]
    out["identical"] = digests["scalar"] == digests["array"]
    out["speedup"] = {
        "replacement": s["replacement_s"] / max(a["replacement_s"], 1e-12),
        "scheduling": s["scheduling_s"] / max(a["scheduling_s"], 1e-12),
        "rep_sched": (s["replacement_s"] + s["scheduling_s"])
        / max(a["replacement_s"] + a["scheduling_s"], 1e-12),
    }
    sp = out["speedup"]
    print(f"array-vs-scalar speedup: replacement {sp['replacement']:.1f}x, "
          f"scheduling {sp['scheduling']:.1f}x, combined "
          f"{sp['rep_sched']:.1f}x (outputs "
          f"{'bitwise-identical' if out['identical'] else 'DIFFER!'})")
    assert out["identical"], "array/scalar memory programs differ"
    if check:
        assert sp["rep_sched"] >= 10.0, \
            f"array core only {sp['rep_sched']:.1f}x scalar (< 10x claim)"
    return out


def run_sim(n: int = CORES_N, live_pages: int = CORES_LIVE_PAGES,
            chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
            check: bool = True) -> dict:
    """Array-vs-scalar SIMULATOR core comparison on the value-granular
    trace: replay all three §8.2 scenarios (unbounded / OS paging / MAGE
    memory program) under both cores, assert the SimResults are exactly
    equal, and report per-scenario + combined instr/s.  The PR-5 headline:
    >=5x simulate-stage throughput at the default chunk size (CI gates 3x
    on the smoke size)."""
    from repro.core.simulator import (DeviceModel, simulate_memory_program,
                                      simulate_os_paging, simulate_unbounded)
    from repro.scenarios import GC_SLOT_BYTES, OS_PAGE_BYTES, cost_fn

    cfg = _cores_config(live_pages)
    page_bytes = (1 << PAGE_SHIFT) * GC_SLOT_BYTES
    model = DeviceModel(bandwidth=1e9, latency=300e-6, readahead=2)
    cost = cost_fn("gc")
    out: dict = {"n": n, "chunk_instrs": chunk_instrs,
                 "live_pages": live_pages, "num_frames": cfg.num_frames}
    wd = tempfile.mkdtemp(prefix="mage_sim_")
    try:
        vpath = os.path.join(wd, "virtual.bc")
        w = ProgramWriter(vpath, page_shift=PAGE_SHIFT, protocol="gc",
                          vspace_slots=live_pages << PAGE_SHIFT,
                          chunk_instrs=chunk_instrs)
        w.extend(synth_value_instrs(n, live_pages))
        pf = w.close()
        mem, _rep = plan_streaming(pf, cfg, workdir=wd,
                                   chunk_instrs=chunk_instrs)
        results: dict = {}
        for core in ("scalar", "array"):
            row: dict = {}
            t0 = time.perf_counter()
            r_unb = simulate_unbounded(pf, cost, core=core,
                                       chunk_instrs=chunk_instrs)
            row["unbounded_s"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            r_os = simulate_os_paging(pf, cost, cfg.num_frames, page_bytes,
                                      model, os_page_bytes=OS_PAGE_BYTES,
                                      core=core, chunk_instrs=chunk_instrs)
            row["os_s"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            r_mage = simulate_memory_program(mem, cost, page_bytes, model,
                                             core=core,
                                             chunk_instrs=chunk_instrs)
            row["mage_s"] = time.perf_counter() - t0
            row["total_s"] = row["unbounded_s"] + row["os_s"] + row["mage_s"]
            total_instrs = 2 * n + len(mem)
            row["ips"] = total_instrs / max(row["total_s"], 1e-12)
            results[core] = (r_unb, r_os, r_mage)
            out[core] = row
            print(f"sim[{core:6s}]: unb {n / max(row['unbounded_s'], 1e-12):>11,.0f} i/s "
                  f"os {n / max(row['os_s'], 1e-12):>11,.0f} i/s "
                  f"mage {len(mem) / max(row['mage_s'], 1e-12):>11,.0f} i/s "
                  f"(total {row['total_s']:.2f}s, {total_instrs} instrs)")
        os.unlink(mem.path)
    finally:
        shutil.rmtree(wd, ignore_errors=True)
    out["identical"] = results["scalar"] == results["array"]
    out["speedup"] = {
        k: out["scalar"][f"{k}_s"] / max(out["array"][f"{k}_s"], 1e-12)
        for k in ("unbounded", "os", "mage")}
    out["speedup"]["combined"] = \
        out["scalar"]["total_s"] / max(out["array"]["total_s"], 1e-12)
    sp = out["speedup"]
    print(f"array-vs-scalar sim speedup: unbounded {sp['unbounded']:.1f}x, "
          f"os {sp['os']:.1f}x, mage {sp['mage']:.1f}x, combined "
          f"{sp['combined']:.1f}x (results "
          f"{'exactly equal' if out['identical'] else 'DIFFER!'})")
    assert out["identical"], "array/scalar simulator results differ"
    if check:
        assert sp["combined"] >= 5.0, \
            f"array sim core only {sp['combined']:.1f}x scalar (< 5x claim)"
    return out


def run_streaming(sizes=None, check: bool = True, cap_mb: float = PLANNER_CAP_MB,
                  legacy_max: int = LEGACY_MAX) -> list[dict]:
    sizes = sizes or SWEEP_SIZES
    cfg = _sweep_config()
    rows = []
    print(f"{'instrs':>9s} {'file (MiB)':>11s} "
          f"{'legacy s':>9s} {'legacy MiB':>11s} "
          f"{'stream s':>9s} {'stream MiB':>11s}")
    for n in sizes:
        wd = tempfile.mkdtemp(prefix="mage_table1_")
        try:
            vpath = os.path.join(wd, "virtual.bc")
            w = ProgramWriter(vpath, page_shift=PAGE_SHIFT, protocol="gc",
                              vspace_slots=LIVE_PAGES << PAGE_SHIFT,
                              chunk_instrs=SWEEP_CHUNK)
            w.extend(synth_instrs(n))
            pf = w.close()
            file_mb = os.path.getsize(vpath) / 2**20

            t0 = time.perf_counter()
            mem, rep = plan_streaming(pf, cfg, workdir=wd,
                                      track_memory=True,
                                      chunk_instrs=SWEEP_CHUNK)
            stream_s = time.perf_counter() - t0
            stream_mb = rep.peak_mem_bytes / 2**20

            legacy_s = legacy_mb = None
            if n <= legacy_max:
                prog = Program(instrs=list(synth_instrs(n)),
                               page_shift=PAGE_SHIFT, protocol="gc",
                               vspace_slots=LIVE_PAGES << PAGE_SHIFT)
                t0 = time.perf_counter()
                _, lrep = plan(prog, cfg, track_memory=True)
                legacy_s = time.perf_counter() - t0
                legacy_mb = lrep.peak_mem_bytes / 2**20
                del prog

            rows.append(dict(
                instrs=n, file_mb=file_mb, memory_prog_instrs=len(mem),
                legacy_s=legacy_s, legacy_peak_mb=legacy_mb,
                stream_s=stream_s, stream_peak_mb=stream_mb,
                annotate_s=rep.annotate_s, replacement_s=rep.replacement_s,
                scheduling_s=rep.scheduling_s,
                annotate_ips=n / max(rep.annotate_s, 1e-12),
                replacement_ips=n / max(rep.replacement_s, 1e-12),
                scheduling_ips=n / max(rep.scheduling_s, 1e-12)))
            fmt = lambda v, p: ("   skipped" if v is None  # noqa: E731
                                else f"{v:{p}}")
            print(f"{n:9d} {file_mb:11.1f} "
                  f"{fmt(legacy_s, '9.2f')} {fmt(legacy_mb, '11.1f')} "
                  f"{stream_s:9.2f} {stream_mb:11.1f}")
        finally:
            shutil.rmtree(wd, ignore_errors=True)
    if check:
        biggest = rows[-1]
        assert biggest["file_mb"] >= 10 * cap_mb, \
            f"sweep too small: {biggest['file_mb']:.0f} MiB < 10x{cap_mb} cap"
        for r in rows:
            assert r["stream_peak_mb"] <= cap_mb, \
                f"planner peak {r['stream_peak_mb']:.1f} MiB over the " \
                f"{cap_mb} MiB cap at n={r['instrs']}"
        # sub-linear: program grows >=10x, streaming peak must stay ~flat
        growth = rows[-1]["stream_peak_mb"] / max(rows[0]["stream_peak_mb"],
                                                  1e-9)
        scale = rows[-1]["instrs"] / rows[0]["instrs"]
        assert growth < max(scale / 4, 2.0), \
            f"streaming peak grew {growth:.1f}x over a {scale:.0f}x sweep"
        print(f"checks OK: file {biggest['file_mb']:.0f} MiB >= "
              f"10x{cap_mb:.0f} MiB cap; peak growth {growth:.2f}x "
              f"over {scale:.0f}x instructions")
    return rows


def run(check: bool = True, cases=None) -> dict:
    cases = cases or CASES
    rows = {}
    print(f"{'workload':12s} {'instrs':>8s} {'plan (s)':>9s} "
          f"{'peak (MiB)':>11s} {'s / 10k instr':>14s}")
    for name, n in cases:
        r = run_workload(name, n)
        rows[name] = r
        print(f"{name:12s} {r.instructions:8d} {r.plan_s:9.3f} "
              f"{r.plan_peak_mb:11.2f} {1e4 * r.plan_s / r.instructions:14.4f}")
    # linearity: doubling the problem ~doubles planning time (within 3x)
    lin = {}
    for name, n in [("merge", cases[0][1] * 2), ("rsum", 512)]:
        if name not in rows:
            continue
        r2 = run_workload(name, n)
        base = rows[name]
        ratio = (r2.plan_s / max(base.plan_s, 1e-9)) / \
            (r2.instructions / base.instructions)
        lin[name] = ratio
        print(f"linearity {name}: time-ratio/instr-ratio = {ratio:.2f}")
    if check:
        for name, ratio in lin.items():
            assert 0.3 < ratio < 3.0, f"{name} planning not ~linear: {ratio}"
        if "merge" in rows and "rsum" in rows:
            gc_rate = rows["merge"].plan_s / rows["merge"].instructions
            ck_rate = rows["rsum"].plan_s / rows["rsum"].instructions
            print(f"per-instr plan cost: gc={gc_rate*1e6:.1f}us "
                  f"ckks={ck_rate*1e6:.1f}us")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--streaming", action="store_true",
                    help="run the out-of-core planner sweep")
    ap.add_argument("--cores", action="store_true",
                    help="run the array-vs-scalar planner core comparison")
    ap.add_argument("--sim", action="store_true",
                    help="run the array-vs-scalar SIMULATOR core comparison")
    ap.add_argument("--tiny", action="store_true",
                    help="small sizes + no scale assertions (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write results as JSON (CI artifact)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip claim assertions")
    args = ap.parse_args(argv)
    check = not args.no_check and not args.tiny
    only = args.streaming or args.cores or args.sim

    results: dict = {"record_bytes": RECORD_BYTES}
    if args.streaming or args.tiny:
        results["streaming"] = run_streaming(
            sizes=TINY_SWEEP_SIZES if args.tiny else None, check=check)
    if args.cores or args.tiny:
        results["cores"] = run_cores(
            n=TINY_CORES_N if args.tiny else CORES_N,
            live_pages=CORES_LIVE_PAGES // 2 if args.tiny
            else CORES_LIVE_PAGES,
            check=check)
    if args.sim or args.tiny:
        results["sim"] = run_sim(
            n=TINY_CORES_N if args.tiny else CORES_N,
            live_pages=CORES_LIVE_PAGES // 2 if args.tiny
            else CORES_LIVE_PAGES,
            check=check)
    if not only:
        rows = run(check=check, cases=TINY_CASES if args.tiny else None)
        results["table1"] = {k: dataclasses.asdict(v) for k, v in rows.items()}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
