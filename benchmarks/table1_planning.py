"""Table 1 analogue: planning time and planner peak memory per workload.

Claims (§8.5): planning time and memory-program size are linear in the
COMPUTATION size (we check near-linear scaling across 2x problem sizes);
CKKS planning is much cheaper than GC planning (coarser instructions); and
the planner's own memory stays far below the runtime budget.
"""

from __future__ import annotations

from common import run_workload

CASES = [("merge", 8192), ("sort", 8192), ("ljoin", 256), ("mvmul", 256),
         ("binfclayer", 2048), ("rsum", 256), ("rstats", 128),
         ("rmvmul", 16), ("n_rmatmul", 8), ("t_rmatmul", 8)]


def run(check: bool = True):
    rows = {}
    print(f"{'workload':12s} {'instrs':>8s} {'plan (s)':>9s} "
          f"{'peak (MiB)':>11s} {'s / 10k instr':>14s}")
    for name, n in CASES:
        r = run_workload(name, n)
        rows[name] = r
        print(f"{name:12s} {r.instructions:8d} {r.plan_s:9.3f} "
              f"{r.plan_peak_mb:11.2f} {1e4 * r.plan_s / r.instructions:14.4f}")
    # linearity: doubling the problem ~doubles planning time (within 3x)
    lin = {}
    for name, n in [("merge", 16384), ("rsum", 512)]:
        r2 = run_workload(name, n)
        base = rows[name]
        ratio = (r2.plan_s / max(base.plan_s, 1e-9)) / \
            (r2.instructions / base.instructions)
        lin[name] = ratio
        print(f"linearity {name}: time-ratio/instr-ratio = {ratio:.2f}")
    if check:
        for name, ratio in lin.items():
            assert 0.3 < ratio < 3.0, f"{name} planning not ~linear: {ratio}"
        gc_rate = rows["merge"].plan_s / rows["merge"].instructions
        ck_rate = rows["rsum"].plan_s / rows["rsum"].instructions
        print(f"per-instr plan cost: gc={gc_rate*1e6:.1f}us "
              f"ckks={ck_rate*1e6:.1f}us")
    return rows


if __name__ == "__main__":
    run()
