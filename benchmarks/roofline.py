"""Execution-backend roofline: batched vs scalar engine throughput.

The exec/ subsystem (see docs/ENGINE.md) precomputes, per memory plan, a
batch schedule that groups independent identically-shaped instructions so
the drivers dispatch one gathered NumPy/Pallas call per group instead of
one Python call per instruction.  This benchmark measures what that buys:
for each case it plans once, builds the batch schedule *outside* the
timed region (it is a cached plan artifact in production — see
``ArtifactCache.put_batch``), then times the engine loop itself under
both backends with fresh drivers per run and reports instructions/sec.

Outputs must be bitwise identical between the backends — the schedule is
a pure reorder of independent instructions — and the claim checked here
(and by the CI ``exec`` job) is that on the gate cases the batched
backend sustains >= 3x the scalar backend's instruction throughput.

    PYTHONPATH=src python benchmarks/roofline.py [--tiny] [--json out]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from repro.api import (SCHEMA_VERSION, STORAGE_BACKENDS, JobSpec, Session,
                       _driver_def)
from repro.core.engine import Engine
from repro.core.transport import build_fabric
from repro.exec import build_batch_schedule, make_batched

#: (workload, n, memory_budget, gate) — gate cases must hit the >= 3x
#: claim; non-gate cases ride along for digest equality + the report
#: (CKKS reductions are compute-bound chains, batching is a wash there).
CASES = [
    ("sort", 4096, 256, True),
    ("sort", 16384, 1024, True),
    ("merge", 16384, None, False),      # unbounded: I/O+FREE rows dominate
    ("rsum", 128, 64, False),           # CKKS digest coverage
]
TINY_CASES = [
    ("sort", 4096, 256, True),
    ("rsum", 64, 32, False),
]
REPS = 3
GATE_SPEEDUP = 3.0


def _digest(outputs: dict) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def bench_case(workload: str, n: int, budget, reps: int = REPS) -> dict:
    """Plan once, then time scalar vs batched engine runs on worker 0."""
    kw = {"workload": workload, "n": n}
    if budget is None:
        kw["plan_mode"] = "unbounded"
    else:
        kw["memory_budget"] = budget
    spec = JobSpec(**kw)
    sess = Session(spec)
    prog = sess.plan()[0]
    sched = build_batch_schedule(prog, spec.chunk_instrs)

    def run_once(batched: bool) -> tuple[float, str]:
        fx = build_fabric("inproc", 1, None)
        fx.connect()
        drv = _driver_def(sess.spec.driver).factory(sess, fx)[0]
        if batched:
            drv = make_batched(drv)
        stg = STORAGE_BACKENDS["ram"]((prog.page_slots, drv.lane), drv.dtype)
        eng = Engine(prog, drv, storage=stg, net=fx.view(0, 0, 1),
                     batch_schedule=sched if batched else None)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        dg = _digest(drv.outputs)
        fx.close()
        return dt, dg

    # warmup runs (JIT/driver caches + the program's record-chunk cache)
    _, dg_scalar = run_once(False)
    _, dg_batched = run_once(True)
    scalar_s = min(run_once(False)[0] for _ in range(reps))
    batched_s = min(run_once(True)[0] for _ in range(reps))
    st = sched.stats()
    return {
        "workload": workload, "n": n, "memory_budget": budget,
        "driver": sess.spec.driver,
        "n_records": st["n_records"],
        "batchable_instructions": st["batchable_instructions"],
        "scalar_instructions": st["scalar_instructions"],
        "max_batch": st["max_batch"],
        "scalar_s": scalar_s, "batched_s": batched_s,
        "scalar_kinstr_s": st["n_records"] / scalar_s / 1e3,
        "batched_kinstr_s": st["n_records"] / batched_s / 1e3,
        "speedup": scalar_s / batched_s,
        "digest_scalar": dg_scalar, "digest_batched": dg_batched,
    }


def run(check: bool = True, tiny: bool = False) -> list[dict]:
    cases = TINY_CASES if tiny else CASES
    rows = []
    print(f"{'workload':10s} {'n':>6s} {'budget':>7s} {'recs':>7s} "
          f"{'maxb':>5s} {'scalar':>12s} {'batched':>12s} {'speedup':>8s}")
    for workload, n, budget, gate in cases:
        r = bench_case(workload, n, budget)
        r["gate"] = gate
        rows.append(r)
        print(f"{workload:10s} {n:6d} {str(budget):>7s} "
              f"{r['n_records']:7d} {r['max_batch']:5d} "
              f"{r['scalar_kinstr_s']:7.1f} ki/s {r['batched_kinstr_s']:7.1f}"
              f" ki/s {r['speedup']:7.2f}x", flush=True)
        if check:
            assert r["digest_scalar"] == r["digest_batched"], \
                f"{workload} n={n}: batched outputs diverge from scalar " \
                f"({r['digest_batched']} != {r['digest_scalar']})"
            if gate:
                assert r["speedup"] >= GATE_SPEEDUP, \
                    f"{workload} n={n}: batched {r['speedup']:.2f}x < " \
                    f"{GATE_SPEEDUP}x scalar"
    best = max(r["speedup"] for r in rows)
    print(f"roofline CLAIM: batched backend up to {best:.1f}x scalar "
          f"instruction throughput, outputs bitwise identical")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows = run(check=not args.no_check, tiny=args.tiny)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "roofline", "tiny": args.tiny,
                       "gate_speedup": GATE_SPEEDUP, "rows": rows},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
