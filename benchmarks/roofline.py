"""Harness roofline report: reads experiments/dryrun/*.json and prints the
per-(arch x shape x mesh) three-term table that EXPERIMENTS.md §Roofline
embeds."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_all() -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def table(rows: list[dict], mesh: str = "pod256") -> str:
    out = [f"{'arch':24s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'dominant':>10s} {'useful':>7s} {'roofl':>6s} "
           f"{'temp(GiB)':>10s}"]
    for r in rows:
        if r.get("mesh") != mesh or not r.get("ok") or r.get("seq_shard") \
                or r.get("variant"):
            continue  # variants are §Perf artifacts, not baseline cells
        rf = r["roofline"]
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{1e3*rf['compute_s']:9.2f} {1e3*rf['memory_s']:9.2f} "
            f"{1e3*rf['collective_s']:9.2f} {rf['dominant']:>10s} "
            f"{rf['useful_flops_ratio']:7.2f} "
            f"{rf['roofline_fraction']:6.3f} "
            f"{r['memory']['temp_bytes']/2**30:10.2f}")
    return "\n".join(out)


def run(check: bool = True):
    rows = load_all()
    for mesh in ("pod256", "pod512"):
        got = [r for r in rows if r.get("mesh") == mesh
               and not r.get("seq_shard") and not r.get("variant")]
        ok = [r for r in got if r.get("ok")]
        print(f"\n=== {mesh}: {len(ok)}/{len(got)} baseline cells compile ===")
        print(table(rows, mesh))
        if check and got:
            assert len(ok) == len(got), \
                f"{mesh}: {len(got)-len(ok)} cells failed to compile"
    variants = [r for r in rows if r.get("variant") and r.get("ok")]
    if variants:
        print("\n--- §Perf variants ---")
        for r in variants:
            rf = r["roofline"]
            print(f"{r['arch']:24s} {r['shape']:12s} [{r['variant']:14s}] "
                  f"dom={rf['dominant']:10s} "
                  f"roofline={rf['roofline_fraction']:.3f} "
                  f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB")
    return rows


if __name__ == "__main__":
    run()
