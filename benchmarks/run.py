"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,...] [--no-check]

Prints each benchmark's rows plus a final name,seconds,claims CSV summary.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))

ALL = ["fig8", "fig9", "table1", "fig10", "fig11", "fig67", "fig1213",
       "nparty", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else ALL
    check = not args.no_check

    import fig8_swap, fig9_swap_large, table1_planning, fig10_parallel  # noqa
    import fig11_wan, fig67_frameworks, fig1213_apps, roofline  # noqa
    import fig_nparty  # noqa
    mods = {"fig8": fig8_swap, "fig9": fig9_swap_large,
            "table1": table1_planning, "fig10": fig10_parallel,
            "fig11": fig11_wan, "fig67": fig67_frameworks,
            "fig1213": fig1213_apps, "nparty": fig_nparty,
            "roofline": roofline}

    rows = []
    failed = []
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            mods[name].run(check=check)
            status = "pass"
        except AssertionError as e:
            status = f"CLAIM-FAIL: {e}"
            failed.append(name)
            traceback.print_exc()
        except Exception as e:  # noqa: BLE001
            status = f"ERROR: {type(e).__name__}: {e}"
            failed.append(name)
            traceback.print_exc()
        rows.append((name, time.time() - t0, status))

    print("\nname,seconds,status")
    for name, secs, status in rows:
        print(f"{name},{secs:.1f},{status}")
    if failed:
        print(f"FAILED: {failed}")
        raise SystemExit(1)
    print("ALL BENCHMARKS PASS")


if __name__ == "__main__":
    main()
