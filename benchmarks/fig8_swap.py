"""Fig. 8 analogue: Unbounded vs OS Swapping vs MAGE on all ten workloads
(scaled memory budget ~40% of working set; calibration in repro.scenarios).

Validated claims (§1/§8.4, scaled):
  * MAGE outperforms OS swapping on all 10 workloads;
  * >=4x speedup on >=7 of them (paper: 4-12x on 7);
  * within 60% of Unbounded on all 10; within 15% on >=7;
  * mvmul shows the LOWEST improvement (§8.4: high compute intensity);
  * the past-planner-cap size plans through the out-of-core file pipeline
    (plan_mode="streaming") and MAGE still beats OS there.

The I/O columns report what the simulated device actually transferred:
OS faults read whole readahead clusters (so OS read bytes can exceed
pages x page size), write-backs and MAGE swaps move whole pages.

Usage (run with the package importable, e.g. PYTHONPATH=src):
  python benchmarks/fig8_swap.py                      # full sweep
  python benchmarks/fig8_swap.py --tiny --json out.json   # CI smoke
  python benchmarks/fig8_swap.py --sim-core scalar    # reference simulator
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from common import PLANNER_CAP_MB, fmt_io_row, fmt_row, run_workload

CASES = [("merge", 16384), ("sort", 16384), ("ljoin", 256), ("mvmul", 384),
         ("binfclayer", 2048), ("rsum", 256), ("rstats", 128),
         ("rmvmul", 24), ("n_rmatmul", 8), ("t_rmatmul", 8)]
TINY_CASES = [("merge", 2048), ("rsum", 128)]

# virtual trace ≈ 11.6 MiB > the 8 MiB planner cap: only the streaming
# pipeline plans it within the planner's own memory budget (Table 1)
STREAM_CASE = ("merge", 131072)
TINY_STREAM_CASE = ("merge", 4096)


def run(budget_frac: float = 0.4, check: bool = True, streaming: bool = True,
        cases=None, stream_case=None, sim_core: str = "array",
        show_io: bool = True):
    cases = cases if cases is not None else CASES
    stream_case = stream_case if stream_case is not None else STREAM_CASE
    rows = {}
    for name, n in cases:
        rows[name] = run_workload(name, n, budget_frac=budget_frac,
                                  sim_core=sim_core)
        print("fig8:", fmt_row(name, rows[name]), flush=True)
        if show_io:
            print("fig8:", fmt_io_row(name, rows[name]), flush=True)
    sp4 = sum(r.speedup_vs_os >= 4 for r in rows.values())
    ov15 = sum(r.pct_of_unbounded <= 0.15 for r in rows.values())
    ov60 = sum(r.pct_of_unbounded <= 0.60 for r in rows.values())
    beats = sum(r.os_s > r.mage_s for r in rows.values())
    print(f"fig8 CLAIMS: beats-OS {beats}/{len(cases)} | >=4x "
          f"{sp4}/{len(cases)} | <=15% {ov15}/{len(cases)} | "
          f"<=60% {ov60}/{len(cases)}")
    if check:
        assert beats == len(cases), "MAGE must beat OS on all workloads"
        if cases == CASES:
            # the paper's per-workload count claims only make sense on
            # the full 10-workload sweep
            assert sp4 >= 7, f"expected >=4x on >=7 workloads, got {sp4}"
            assert ov15 >= 7, f"expected <=15% overhead on >=7, got {ov15}"
            assert ov60 == 10, \
                f"expected <=60% overhead on all, got {ov60}"
            mv = rows["mvmul"].speedup_vs_os
            assert all(mv <= r.speedup_vs_os + 1e-9 for r in rows.values()), \
                "mvmul should show the lowest improvement (§8.4)"
    if streaming:
        name, n = stream_case
        r = run_workload(name, n, budget_frac=budget_frac,
                         plan_mode="streaming", sim_core=sim_core)
        rows[f"{name}@{n}"] = r
        print("fig8 (file pipeline):", fmt_row(f"{name}@{n}", r), flush=True)
        print(f"fig8 streaming: memory program "
              f"{r.program_bytes / 2**20:.1f} MiB "
              f"(planner cap {PLANNER_CAP_MB:.0f} MiB), "
              f"planner peak {r.plan_peak_mb:.1f} MiB")
        if check:
            assert r.program_bytes > PLANNER_CAP_MB * 2**20, \
                "streaming case must exceed the planner memory cap"
            # out-of-core: planner peak is O(lookahead + frames), well below
            # the program it emits (flatness vs length is table1's sweep)
            assert r.plan_peak_mb * 2**20 < r.program_bytes, \
                f"streaming planner peak {r.plan_peak_mb:.1f} MiB not " \
                f"below program size {r.program_bytes / 2**20:.1f} MiB"
            assert r.os_s > r.mage_s, "MAGE must beat OS at scale too"
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="small sizes + no claim assertions (CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as JSON (CI artifact)")
    ap.add_argument("--sim-core", default="array",
                    choices=("array", "scalar"),
                    help="timing-simulator core (results identical; "
                         "see docs/SIMULATOR.md)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip claim assertions")
    ap.add_argument("--no-streaming", action="store_true",
                    help="skip the past-planner-cap file-pipeline case")
    args = ap.parse_args(argv)
    rows = run(check=not args.no_check and not args.tiny,
               streaming=not args.no_streaming,
               cases=TINY_CASES if args.tiny else None,
               stream_case=TINY_STREAM_CASE if args.tiny else None,
               sim_core=args.sim_core)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({k: dataclasses.asdict(v) for k, v in rows.items()},
                      f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
