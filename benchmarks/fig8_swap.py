"""Fig. 8 analogue: Unbounded vs OS Swapping vs MAGE on all ten workloads
(scaled memory budget ~40% of working set; calibration in repro.scenarios).

Validated claims (§1/§8.4, scaled):
  * MAGE outperforms OS swapping on all 10 workloads;
  * >=4x speedup on >=7 of them (paper: 4-12x on 7);
  * within 60% of Unbounded on all 10; within 15% on >=7;
  * mvmul shows the LOWEST improvement (§8.4: high compute intensity);
  * the past-planner-cap size plans through the out-of-core file pipeline
    (plan_mode="streaming") and MAGE still beats OS there.
"""

from __future__ import annotations

from common import PLANNER_CAP_MB, fmt_row, run_workload

CASES = [("merge", 16384), ("sort", 16384), ("ljoin", 256), ("mvmul", 384),
         ("binfclayer", 2048), ("rsum", 256), ("rstats", 128),
         ("rmvmul", 24), ("n_rmatmul", 8), ("t_rmatmul", 8)]

# virtual trace ≈ 11.6 MiB > the 8 MiB planner cap: only the streaming
# pipeline plans it within the planner's own memory budget (Table 1)
STREAM_CASE = ("merge", 131072)


def run(budget_frac: float = 0.4, check: bool = True, streaming: bool = True):
    rows = {}
    for name, n in CASES:
        rows[name] = run_workload(name, n, budget_frac=budget_frac)
        print("fig8:", fmt_row(name, rows[name]), flush=True)
    sp4 = sum(r.speedup_vs_os >= 4 for r in rows.values())
    ov15 = sum(r.pct_of_unbounded <= 0.15 for r in rows.values())
    ov60 = sum(r.pct_of_unbounded <= 0.60 for r in rows.values())
    beats = sum(r.os_s > r.mage_s for r in rows.values())
    print(f"fig8 CLAIMS: beats-OS {beats}/10 | >=4x {sp4}/10 | "
          f"<=15% {ov15}/10 | <=60% {ov60}/10")
    if check:
        assert beats == 10, "MAGE must beat OS on all workloads"
        assert sp4 >= 7, f"expected >=4x on >=7 workloads, got {sp4}"
        assert ov15 >= 7, f"expected <=15% overhead on >=7, got {ov15}"
        assert ov60 == 10, f"expected <=60% overhead on all, got {ov60}"
        mv = rows["mvmul"].speedup_vs_os
        assert all(mv <= r.speedup_vs_os + 1e-9 for r in rows.values()), \
            "mvmul should show the lowest improvement (§8.4)"
    if streaming:
        name, n = STREAM_CASE
        r = run_workload(name, n, budget_frac=budget_frac,
                         plan_mode="streaming")
        rows[f"{name}@{n}"] = r
        print("fig8 (file pipeline):", fmt_row(f"{name}@{n}", r), flush=True)
        print(f"fig8 streaming: memory program "
              f"{r.program_bytes / 2**20:.1f} MiB "
              f"(planner cap {PLANNER_CAP_MB:.0f} MiB), "
              f"planner peak {r.plan_peak_mb:.1f} MiB")
        if check:
            assert r.program_bytes > PLANNER_CAP_MB * 2**20, \
                "streaming case must exceed the planner memory cap"
            # out-of-core: planner peak is O(lookahead + frames), well below
            # the program it emits (flatness vs length is table1's sweep)
            assert r.plan_peak_mb * 2**20 < r.program_bytes, \
                f"streaming planner peak {r.plan_peak_mb:.1f} MiB not " \
                f"below program size {r.program_bytes / 2**20:.1f} MiB"
            assert r.os_s > r.mage_s, "MAGE must beat OS at scale too"
    return rows


if __name__ == "__main__":
    run()
