"""N-party Shamir over the WAN — planner behavior + measured overlap win.

The shamir_stats trace is the overlap engine's ideal adversary-turned-
showcase: its B elementwise-square resharing rounds are mutually
independent, so an in-order engine pays ~B sequential WAN round
latencies while the planned out-of-order engine issues every round's
sends up front and fills the latency window with the other rounds'
local field work (docs/OVERLAP.md, docs/SHAMIR.md).  Three sections:

 * planner: per party count, the budgeted planner's swap/prefetch stats
   on the round-structured trace — MUL rounds appear as ordinary NET
   directives, so planning is protocol-blind (same pipeline as GC/CKKS);
 * predicted: the simulator's in-order vs overlap NET-stall on the very
   memory program the engine replays;
 * measured: REAL n-party execution over the ``shaped`` fabric
   (Oregon-class 11 ms one-way latency), in-order vs overlap wall time,
   digest-compared.  CLAIM (gated with --check, CI runs it): >= 1.5x on
   the 3-party MUL-heavy trace, output-identical in every cell.
"""

from __future__ import annotations

import argparse
import hashlib
import json

import numpy as np

from repro.api import (SCHEMA_VERSION, SLOT_BYTES, FabricSpec, JobSpec,
                       Session)
from repro.core.bytecode import Op
from repro.core.simulator import simulate_memory_program
from repro.scenarios import measure_traffic

LAT_OREGON = 0.011            # s one-way (paper §8.7: ~11 ms RTT/2-class)
FLOW_BW = 250e6               # bytes/s per flow

#: (n_parties, n, min measured speedup asserted under --check)
FULL = [(3, 2048, 1.5), (5, 2560, 1.0)]
TINY = [(3, 1024, 1.5), (5, 1280, 1.0)]


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def planner_rows(parties: int, n: int, rows: list) -> None:
    spec = JobSpec(workload="shamir_stats", n=n, num_workers=parties,
                   plan_mode="memory", memory_budget=0.5)
    with Session(spec) as s:
        prog = s.plan()[0]
        net = sum(1 for i in prog.instrs
                  if i.op in (Op.NET_SEND, Op.NET_RECV))
        swaps = sum(1 for i in prog.instrs
                    if i.op in (Op.SWAP_IN, Op.SWAP_OUT))
        page_bytes = prog.page_slots * SLOT_BYTES["shamir"]
        cost = 5e-8
        p_ino = simulate_memory_program(prog, lambda i: cost, page_bytes,
                                        net_latency_s=LAT_OREGON,
                                        net_bandwidth=FLOW_BW)
        p_ovl = simulate_memory_program(prog, lambda i: cost, page_bytes,
                                        net_latency_s=LAT_OREGON,
                                        net_bandwidth=FLOW_BW,
                                        net_mode="overlap")
    stall_cut = p_ino.net_stall / max(p_ovl.net_stall, 1e-12)
    print(f"fig_nparty planner ({parties} parties, n={n}): "
          f"{len(prog.instrs)} instrs, {net} NET directives, "
          f"{swaps} swaps under a 0.5 budget; predicted net stall "
          f"{p_ino.net_stall * 1e3:.1f}ms -> {p_ovl.net_stall * 1e3:.1f}ms "
          f"({stall_cut:.1f}x cut, {p_ino.net_msgs} exchanges)")
    rows.append({"kind": "planner", "parties": parties, "n": n,
                 "instructions": len(prog.instrs), "net_directives": net,
                 "swaps": swaps,
                 "predicted_net_stall_inorder_s": p_ino.net_stall,
                 "predicted_net_stall_overlap_s": p_ovl.net_stall,
                 "predicted_stall_cut": stall_cut,
                 "net_exchanges": p_ino.net_msgs})


def measured_rows(parties: int, n: int, min_speedup: float, check: bool,
                  rows: list) -> None:
    fab = FabricSpec(latency_s=LAT_OREGON, bandwidth=FLOW_BW)
    kw = dict(num_workers=parties, transport="shaped", fabric=fab,
              warmup=True, check=True)
    ino = measure_traffic("shamir_stats", n, exec_backend="scalar", **kw)
    ovl = measure_traffic("shamir_stats", n, exec_backend="overlap", **kw)
    same = _digest(ino.outputs) == _digest(ovl.outputs)
    speedup = ino.seconds / ovl.seconds
    print(f"fig_nparty measured ({parties} parties, n={n}, shaped "
          f"{LAT_OREGON * 1e3:.0f}ms): in-order={ino.seconds:.3f}s "
          f"overlap={ovl.seconds:.3f}s ({speedup:.2f}x, "
          f"{ino.total_bytes} B over {len(ino.links)} links, identical "
          f"outputs: {same})")
    if check:
        assert same, "overlap engine must be output-identical"
        assert ino.total_bytes == ovl.total_bytes, \
            "issue order must not change what crosses the fabric"
        assert speedup >= min_speedup, \
            (f"{parties}-party overlap speedup {speedup:.2f}x < "
             f"{min_speedup}x")
    rows.append({"kind": "measured", "parties": parties, "n": n,
                 "latency_s": LAT_OREGON, "inorder_s": ino.seconds,
                 "overlap_s": ovl.seconds, "speedup": speedup,
                 "min_speedup": min_speedup, "outputs_identical": same,
                 "total_bytes": ino.total_bytes,
                 "links": len(ino.links)})


def run(check: bool = True, tiny: bool = False,
        rows_out: list | None = None) -> list:
    rows = [] if rows_out is None else rows_out
    cases = TINY if tiny else FULL
    for parties, n, _ in cases:
        planner_rows(parties, n, rows)
    for parties, n, min_speedup in cases:
        measured_rows(parties, n, min_speedup, check, rows)
    three = [r for r in rows
             if r["kind"] == "measured" and r["parties"] == 3]
    print(f"fig_nparty CLAIM: overlap hides the resharing-round WAN "
          f"latency — {three[0]['speedup']:.2f}x on the 3-party "
          f"MUL-heavy trace (gate: >= 1.5x)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as a schema-stamped JSON envelope")
    ap.add_argument("--tiny", action="store_true",
                    help="smaller problem sizes (CI smoke)")
    ap.add_argument("--no-check", action="store_true")
    args = ap.parse_args()
    rows: list = []
    run(check=not args.no_check, tiny=args.tiny, rows_out=rows)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "benchmark": "fig_nparty", "rows": rows}, f,
                      indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
