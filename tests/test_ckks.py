"""CKKS: NTT exactness, encode/decode, homomorphism properties (hypothesis),
lazy relinearization, engine-driver integration."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import Engine, PlanConfig, plan, trace
from repro.protocols.ckks import Batch, CkksContext, CkksDriver, CkksParams, \
    Plain
from repro.protocols.ckks import ntt as nt
from repro.protocols.ckks.encoding import decode as e_decode, encode as e_encode
from repro.protocols.ckks.params import gen_primes, is_prime

P = CkksParams(n_ring=128, levels=2)
CTX = CkksContext(P)
SC = P.scale


def test_prime_generation():
    for n in (64, 1024):
        for q in gen_primes(n, [25, 29, 30]):
            assert is_prime(q)
            assert q % (2 * n) == 1


@pytest.mark.parametrize("n", [8, 64, 256])
def test_ntt_roundtrip_and_naive_convolution(n):
    q = gen_primes(n, [29])[0]
    rng = np.random.default_rng(n)
    a = rng.integers(0, q, n, dtype=np.uint64)
    b = rng.integers(0, q, n, dtype=np.uint64)
    assert np.array_equal(nt.ntt_inverse(nt.ntt_forward(a, q), q), a)
    assert np.array_equal(nt.negacyclic_mul(a, b, q),
                          nt.negacyclic_mul_naive(a, b, q))


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(1)
    z = rng.uniform(-1, 1, P.slots)
    c = e_encode(z, P.n_ring, SC)
    z2 = e_decode(c.astype(np.float64), P.n_ring, SC)
    assert np.abs(z2.real - z).max() < 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31))
def test_homomorphism_properties(seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, P.slots)
    y = rng.uniform(-1, 1, P.slots)
    cx = CTX.encrypt(CTX.encode(x))
    cy = CTX.encrypt(CTX.encode(y))
    dec = lambda ct, lvl, sc: CTX.decode(CTX.decrypt(ct, lvl), lvl, sc).real
    assert np.abs(dec(CTX.add(cx, cy, 2), 2, SC) - (x + y)).max() < 1e-4
    assert np.abs(dec(CTX.sub(cx, cy, 2), 2, SC) - (x - y)).max() < 1e-4
    sc1 = SC * SC / P.primes[2]
    assert np.abs(dec(CTX.mul(cx, cy, 2), 1, sc1) - x * y).max() < 1e-3
    # commutativity of the homomorphic ops
    m1 = dec(CTX.mul(cx, cy, 2), 1, sc1)
    m2 = dec(CTX.mul(cy, cx, 2), 1, sc1)
    assert np.abs(m1 - m2).max() < 1e-3


def test_lazy_relinearization_equivalence():
    rng = np.random.default_rng(5)
    xs = [rng.uniform(-1, 1, P.slots) for _ in range(4)]
    cts = [CTX.encrypt(CTX.encode(x)) for x in xs]
    # eager: sum of relinearized products
    eager = CTX.mul(cts[0], cts[1], 2)
    eager = CTX.add(eager, CTX.mul(cts[2], cts[3], 2), 1)
    # lazy: sum tensors, single relin (§7.4 optimization)
    t = CTX.add(CTX.mul_tensor(cts[0], cts[1], 2),
                CTX.mul_tensor(cts[2], cts[3], 2), 2)
    lazy = CTX.rescale(CTX.relinearize(t, 2), 2)
    sc1 = SC * SC / P.primes[2]
    d1 = CTX.decode(CTX.decrypt(eager, 1), 1, sc1).real
    d2 = CTX.decode(CTX.decrypt(lazy, 1), 1, sc1).real
    expect = xs[0] * xs[1] + xs[2] * xs[3]
    assert np.abs(d1 - expect).max() < 2e-3
    assert np.abs(d2 - expect).max() < 2e-3


def test_depth2_chain():
    rng = np.random.default_rng(6)
    x = rng.uniform(-1, 1, P.slots)
    y = rng.uniform(-1, 1, P.slots)
    cx = CTX.encrypt(CTX.encode(x))
    cy = CTX.encrypt(CTX.encode(y))
    m = CTX.mul(cx, cy, 2)                        # level 1
    mp = CTX.mul_plain(m, CTX.encode(x), 1)       # level 0
    sc = SC * SC / P.primes[2] * SC / P.primes[1]
    d = CTX.decode(CTX.decrypt(mp, 0), 0, sc).real
    assert np.abs(d - x * y * x).max() < 1e-2


def test_driver_bounded_engine_run():
    rng = np.random.default_rng(7)
    xs = [rng.uniform(-1, 1, P.slots) for _ in range(6)]
    const = np.full(P.slots, 0.5)

    def program():
        cts = [Batch(P).mark_input(i) for i in range(6)]
        pc = Plain(P).mark_input(100)
        acc = cts[0] + cts[1]
        for c in cts[2:5]:
            acc = acc + c
        acc.mark_output(0)
        (cts[4].mul_norelin(cts[5]) + cts[0].mul_norelin(cts[1])) \
            .relin().mark_output(1)
        cts[2].mul_plain(pc).mark_output(2)
        (cts[3] - cts[4]).mark_output(3)

    prog = trace(program, protocol="ckks", page_shift=11)
    prov = lambda tag: const if tag == 100 else xs[tag]
    d1 = CkksDriver(P, prov)
    Engine(prog, d1).run()
    mem, _ = plan(prog, PlanConfig(num_frames=10, lookahead=20,
                                   prefetch_pages=2))
    d2 = CkksDriver(P, prov)
    Engine(mem, d2).run()
    expect = {0: xs[0] + xs[1] + xs[2] + xs[3] + xs[4],
              1: xs[4] * xs[5] + xs[0] * xs[1],
              2: xs[2] * 0.5,
              3: xs[3] - xs[4]}
    for tag, e in expect.items():
        assert np.abs(d1.outputs[tag] - e).max() < 2e-3, tag
        assert np.allclose(d1.outputs[tag], d2.outputs[tag]), tag
