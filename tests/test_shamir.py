"""N-party Shamir protocol: field arithmetic, share/reconstruct
roundtrips, the degree-reduction MUL round, degradation (<= t shares
carry no information), fast-trace digest identity, and cross-backend /
cross-process execution of the round-structured workloads."""

import hashlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.api import JobSpec, Session, run_job
from repro.core.bytecode import Op, encode_chunk, strip_frees
from repro.protocols.shamir import (P, ShamirDriver, lagrange_at_zero,
                                    mulmod, reconstruct, share)
from repro.protocols.shamir.field import (addmod, eval_point, fold, inverse,
                                          mulmod_scalar, prf_coeffs, submod)
from repro.workloads import get
from repro.workloads.shamir_workloads import (build_shamir_cmp_records,
                                              build_shamir_stats_records,
                                              write_shamir_cmp_program,
                                              write_shamir_stats_program)


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# field arithmetic vs the Python-int reference
# ---------------------------------------------------------------------------


EDGES = [0, 1, 2, P - 2, P - 1, (1 << 31) - 1, 1 << 31, (1 << 31) + 1,
         (1 << 60) + 12345, P // 2, P // 3]


def test_mulmod_matches_python_ints_on_edges():
    a = np.array([x for x in EDGES for _ in EDGES], dtype=np.uint64)
    b = np.array(EDGES * len(EDGES), dtype=np.uint64)
    got = mulmod(a, b)
    exp = np.array([(int(x) * int(y)) % P for x, y in zip(a, b)],
                   dtype=np.uint64)
    assert np.array_equal(got, exp)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_field_ops_match_python_ints(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, P, 64, dtype=np.uint64)
    b = rng.integers(0, P, 64, dtype=np.uint64)
    ai, bi = a.astype(object), b.astype(object)
    assert np.array_equal(mulmod(a, b),
                          np.array([(int(x) * int(y)) % P
                                    for x, y in zip(ai, bi)], np.uint64))
    assert np.array_equal(addmod(a, b),
                          np.array([(int(x) + int(y)) % P
                                    for x, y in zip(ai, bi)], np.uint64))
    assert np.array_equal(submod(a, b),
                          np.array([(int(x) - int(y)) % P
                                    for x, y in zip(ai, bi)], np.uint64))


def test_fold_reduces_any_uint64():
    x = np.array([0, P, P + 1, 2 * P, (1 << 64) - 1, (1 << 63) + 17],
                 dtype=np.uint64)
    got = fold(x)
    exp = np.array([int(v) % P for v in x], dtype=np.uint64)
    assert np.array_equal(got, exp)
    assert got.max() < P


def test_inverse_and_lagrange_weights():
    for x in (1, 2, 3, P - 1, 123456789):
        assert x * inverse(x) % P == 1
    with pytest.raises(ZeroDivisionError):
        inverse(0)
    for n in (3, 4, 5, 7):
        lam = lagrange_at_zero(n)
        # interpolating any polynomial of degree <= n-1 at 0 recovers
        # its constant term: check on f(x) = 5 + 3x + 2x^2
        f = lambda x: (5 + 3 * x + 2 * x * x) % P  # noqa: E731
        got = sum(l * f(eval_point(i)) for i, l in enumerate(lam)) % P
        assert got == 5


def test_prf_coeffs_deterministic_and_key_separated():
    a = prf_coeffs(0x1234, 7, 3, 32)
    assert np.array_equal(a, prf_coeffs(0x1234, 7, 3, 32))
    assert a.max() < P
    assert not np.array_equal(a, prf_coeffs(0x1235, 7, 3, 32))
    assert not np.array_equal(a, prf_coeffs(0x1234, 8, 3, 32))
    assert not np.array_equal(a, prf_coeffs(0x1234, 7, 4, 32))


# ---------------------------------------------------------------------------
# share / reconstruct roundtrip (property over random n, t < n)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 9), st.integers(0, 2**32 - 1))
def test_share_reconstruct_roundtrip(n_parties, seed):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, n_parties))        # any t < n roundtrips
    secrets = rng.integers(0, P, 40, dtype=np.uint64)
    shares = share(secrets, n_parties, t, rng)
    assert np.array_equal(reconstruct(shares), secrets)
    # any t+1-subset suffices
    idx = sorted(rng.choice(n_parties, size=t + 1, replace=False).tolist())
    assert np.array_equal(reconstruct(shares[idx], idx), secrets)


def test_reconstruct_validates_party_rows():
    rng = np.random.default_rng(0)
    shares = share(np.arange(10, dtype=np.uint64), 4, 1, rng)
    with pytest.raises(ValueError, match="party ids"):
        reconstruct(shares[:2], [0, 1, 2])


# ---------------------------------------------------------------------------
# the degree-reduction MUL round, directly on the driver's polynomials
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_parties", (3, 5, 7))
def test_mul_round_algebra_matches_plaintext(n_parties):
    """Replay one resharing round exactly as the engines do — PRF-dealt
    input shares, per-party F_EVAL subshares, the public recombine — and
    check the reshared product reconstructs to x*y mod p."""
    rng = np.random.default_rng(42 + n_parties)
    count, t = 64, (n_parties - 1) // 2
    x = rng.integers(0, P, count, dtype=np.uint64)
    y = rng.integers(0, P, count, dtype=np.uint64)
    drivers = [ShamirDriver(n_parties, i, lambda tag: None)
               for i in range(n_parties)]
    xs = [d._poly_eval(x, d.seed_input, 11, t, d.party) for d in drivers]
    ys = [d._poly_eval(y, d.seed_input, 12, t, d.party) for d in drivers]
    # shares are consistent: any party derives every party's input share
    assert np.array_equal(xs[0],
                          drivers[1]._poly_eval(x, drivers[1].seed_input,
                                                11, t, 0))
    assert np.array_equal(reconstruct(np.stack(xs)), x)
    lam = lagrange_at_zero(n_parties)
    z = []
    for j in range(n_parties):
        sub = [d._poly_eval(mulmod(xs[d.party], ys[d.party]),
                            d.seed_reshare, 0, t, j) for d in drivers]
        acc = np.zeros(count, dtype=np.uint64)
        for i in range(n_parties):
            acc = addmod(acc, mulmod_scalar(sub[i], lam[i]))
        z.append(acc)
    assert np.array_equal(reconstruct(np.stack(z)), mulmod(x, y))
    # the reshared product is again a degree-t sharing: t+1 rows suffice
    assert np.array_equal(reconstruct(np.stack(z[:t + 1]),
                                      list(range(t + 1))), mulmod(x, y))


def test_driver_validates_parameters():
    with pytest.raises(ValueError, match="n >= 3"):
        ShamirDriver(2, 0, lambda tag: None)
    with pytest.raises(ValueError, match="out of range"):
        ShamirDriver(3, 3, lambda tag: None)
    with pytest.raises(ValueError, match="2t\\+1"):
        ShamirDriver(3, 0, lambda tag: None, threshold=2)


# ---------------------------------------------------------------------------
# degradation: <= t shares give no information about the secret
# ---------------------------------------------------------------------------


def test_threshold_hiding_share_marginals():
    """The joint view of any t parties is uniform regardless of the
    secret: compare the empirical distribution of one party's shares for
    two maximally different secrets (all-0 vs all-(p-1)) — quantiles must
    agree within sampling noise, and both must look uniform on [0, p)."""
    count, t, n = 20000, 2, 5
    rng0 = np.random.default_rng(123)
    rng1 = np.random.default_rng(123)   # same polynomial randomness
    s0 = share(np.zeros(count, dtype=np.uint64), n, t, rng0)
    s1 = share(np.full(count, P - 1, dtype=np.uint64), n, t, rng1)
    for party in (0, 3):
        a = np.sort(s0[party]).astype(np.float64) / P
        b = np.sort(s1[party]).astype(np.float64) / P
        # KS-style: max quantile gap ~ O(1/sqrt(count))
        assert np.max(np.abs(a - b)) < 0.03
        uniform = (np.arange(count) + 0.5) / count
        assert np.max(np.abs(a - uniform)) < 0.03
        assert abs(float(np.mean(a)) - 0.5) < 0.01
    # and t shares do NOT reconstruct (degree-t poly needs t+1 points)
    secrets = np.arange(100, dtype=np.uint64)
    sh = share(secrets, n, t, np.random.default_rng(7))
    wrong = reconstruct(sh[:t], list(range(t)))
    assert not np.array_equal(wrong, secrets)


# ---------------------------------------------------------------------------
# fast-trace digest identity: vectorized builders == the DSL trace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_workers", (3, 5))
@pytest.mark.parametrize("name,n,builder", [
    ("shamir_stats", 1024, build_shamir_stats_records),
    ("shamir_cmp", 512, build_shamir_cmp_records),
])
def test_fast_builders_digest_identical(name, n, builder, num_workers):
    progs = get(name).trace(n, num_workers=num_workers)
    for worker in range(num_workers):
        dsl = encode_chunk(strip_frees(progs[worker].instrs))
        fast = builder(n, worker, num_workers)
        assert dsl.shape == fast.shape, (worker, dsl.shape, fast.shape)
        assert np.array_equal(dsl, fast), worker
        assert hashlib.sha256(dsl.tobytes()).hexdigest() == \
            hashlib.sha256(fast.tobytes()).hexdigest()


def test_written_programs_match_dsl(tmp_path):
    n, nw = 1024, 3
    progs = get("shamir_stats").trace(n, num_workers=nw)
    for worker, write in ((0, write_shamir_stats_program),
                          (2, write_shamir_stats_program)):
        pf = write(tmp_path / f"w{worker}.bc", n, worker, nw)
        assert list(pf.iter_instrs()) == strip_frees(progs[worker].instrs)
        assert pf.vspace_slots == progs[worker].vspace_slots
        assert pf.meta["workload"] == "shamir_stats"
    pf = write_shamir_cmp_program(tmp_path / "c.bc", 512, 1, 3)
    cmp_progs = get("shamir_cmp").trace(512, num_workers=3)
    assert list(pf.iter_instrs()) == strip_frees(cmp_progs[1].instrs)


def test_traces_emit_visible_net_rounds():
    """Every MUL round must surface as NET directives the planner and the
    overlap pass can see: 2(n-1) messages per round per worker."""
    n, nw = 1024, 3
    b = n // 256
    prog = get("shamir_stats").trace(n, num_workers=nw)[1]
    sends = sum(1 for i in prog.instrs if i.op == Op.NET_SEND)
    recvs = sum(1 for i in prog.instrs if i.op == Op.NET_RECV)
    rounds = b + 1                       # b squares + mean^2
    assert sends == rounds * (nw - 1) + 3   # + 3 reveal sends (worker != 0)
    assert recvs == rounds * (nw - 1)
    # workloads trace identically for any n >= 3 party count
    prog5 = get("shamir_stats").trace(n, num_workers=5)[0]
    assert sum(1 for i in prog5.instrs if i.op == Op.NET_RECV) == \
        rounds * 4 + 3 * 4               # worker 0 also collects reveals


def test_workload_validates_problem_size():
    with pytest.raises(ValueError, match="multiple"):
        get("shamir_stats").trace(1000, num_workers=3)
    with pytest.raises(ValueError, match="num_workers >= 3"):
        get("shamir_stats").trace(1024, num_workers=2)


# ---------------------------------------------------------------------------
# end-to-end: backends, budgets, registered drivers
# ---------------------------------------------------------------------------


def test_stats_identical_across_backends_under_budget():
    kw = dict(workload="shamir_stats", n=1024, num_workers=3,
              plan_mode="memory", memory_budget=0.5)
    ref = run_job(JobSpec(exec_backend="scalar", **kw), check=True)
    for backend in ("batched", "overlap"):
        got = run_job(JobSpec(exec_backend=backend, **kw), check=True)
        assert _digest(got) == _digest(ref), backend


def test_cmp_reveals_exact_indicator():
    out = run_job(JobSpec(workload="shamir_cmp", n=512, num_workers=3,
                          plan_mode="unbounded"), check=True)
    (v,) = out.values()
    assert set(np.unique(v).tolist()) <= {0, 1}
    assert v[:128].max() == 0 and v[128:].min() == 1


def test_fixed_party_drivers_validate_worker_count():
    spec = JobSpec(workload="shamir_stats", n=1024, num_workers=3,
                   plan_mode="unbounded", driver="shamir-5party")
    with pytest.raises(ValueError, match="num_workers=5"):
        with Session(spec) as s:
            s.execute()
    ok = JobSpec(workload="shamir_stats", n=1024, num_workers=3,
                 plan_mode="unbounded", driver="shamir-3party")
    assert run_job(ok, check=True)


def test_auto_driver_resolves_to_shamir():
    spec = JobSpec(workload="shamir_stats", n=1024, num_workers=3,
                   plan_mode="unbounded")
    assert spec.normalized().driver == "shamir"
