"""Cross-backend x cross-transport identity matrix.

One planned (budgeted) workload per protocol family, executed under
every exec backend and every transport: all 18 cells must produce
bitwise-identical outputs to the scalar+inproc reference cell.  This is
the single test that pins the repo's central invariant — planning,
batching, overlap issue and fabric choice are *performance* knobs, never
*semantics* knobs — across all three protocol drivers at once."""

import hashlib

import numpy as np
import pytest

from repro.api import EXEC_BACKENDS, FabricSpec, JobSpec, run_job
from repro.core.transport import pick_free_ports

#: (workload, n, num_workers, driver) — one row per protocol family
CASES = [
    ("merge", 256, 2, "gc-plaintext"),
    ("rsum", 64, 1, "ckks"),
    ("shamir_stats", 1024, 3, "shamir"),
]
TRANSPORTS = ("inproc", "tcp")


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def _spec(case, backend, transport):
    name, n, workers, driver = case
    fabric = None
    if transport == "tcp":
        ports = pick_free_ports(workers)
        fabric = FabricSpec(peers=tuple(f"127.0.0.1:{p}" for p in ports))
    return JobSpec(workload=name, n=n, num_workers=workers, driver=driver,
                   plan_mode="memory", memory_budget=0.5,
                   exec_backend=backend, transport=transport, fabric=fabric)


_REFERENCE: dict[str, str] = {}


def _reference(case) -> str:
    name = case[0]
    if name not in _REFERENCE:
        out = run_job(_spec(case, "scalar", "inproc"), check=True)
        _REFERENCE[name] = _digest(out)
    return _REFERENCE[name]


@pytest.mark.parametrize("transport", TRANSPORTS)
@pytest.mark.parametrize("backend", EXEC_BACKENDS)
@pytest.mark.parametrize("case", CASES, ids=[c[0] for c in CASES])
def test_identity_cell(case, backend, transport):
    ref = _reference(case)
    out = run_job(_spec(case, backend, transport), check=True)
    assert _digest(out) == ref, \
        f"{case[0]}: {backend}+{transport} diverged from scalar+inproc"
