"""Randomized n-party all-to-all transport fuzz.

Property-based (via the ``_hypothesis_compat`` shim) schedules over 3-5
ranks: every ordered pair of ranks exchanges a random message schedule
(random tags from a small pool, random payload sizes), receivers consume
each link in a random *bounded-displacement* permutation of the sender's
order, and the link reorder buffers are depth-bounded to exactly that
displacement bound — the largest buffer the permutation provably needs.
Asserted invariants:

 * no deadlock: every thread finishes and the closing all-to-all barrier
   completes (joined with a hard timeout);
 * exact byte/message accounting per (src, dst, tag) on the send side;
 * FIFO per (src, dst, tag): same-tag messages arrive in send order even
   when the cross-tag consumption order is scrambled;
 * the reorder buffer's high-water mark never exceeds the configured
   depth bound (``reorder_stats`` verifies, not assumes).
"""

import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.transport import (InprocTransport, TcpTransport,
                                  pick_free_ports)

TAG_POOL = (100, 101, 102)
JOIN_S = 30.0


def _schedule(rng, n_ranks, n_msgs, depth):
    """Per-link send orders + a displacement-<depth receive permutation.

    Returns {(src, dst): (sends, recv_order)} where ``sends`` is a list
    of (tag, payload) in send order and ``recv_order`` a permutation of
    its indices with |perm_pos - send_pos| < depth, realizable with a
    reorder buffer of ``depth`` messages."""
    links = {}
    for src in range(n_ranks):
        for dst in range(n_ranks):
            if src == dst:
                continue
            sends = []
            for seq in range(n_msgs):
                tag = int(rng.choice(TAG_POOL))
                size = int(rng.integers(1, 64))
                payload = np.full(size, seq, dtype=np.int64)
                sends.append((tag, payload))
            # sorting i + u, u in [0, depth), displaces every index < depth
            keys = np.arange(n_msgs) + rng.uniform(0, depth, n_msgs)
            recv_order = list(np.argsort(keys, kind="stable"))
            assert max(abs(int(p) - i) for i, p in enumerate(recv_order)) \
                < depth
            links[(src, dst)] = (sends, recv_order)
    return links


def _run_fuzz(transports, links, depth, n_ranks):
    """Drive the schedule: one sender thread per rank (interleaving its
    outbound links), one receiver thread per link.  Per-link receivers
    keep every link draining independently — with that topology a
    bounded-displacement receive order provably cannot deadlock, which is
    exactly what the joins (with timeout) check."""
    for (src, dst) in links:
        transports[dst].set_depth(src, dst, max_msgs=depth)
    got = {key: [] for key in links}
    errs = []

    def sender(rank):
        try:
            my = [(k, v) for k, v in links.items() if k[0] == rank]
            rng = np.random.default_rng(1000 + rank)
            cursors = {k: 0 for k, _ in my}
            pending = {k: s for k, (s, _) in my}
            while any(cursors[k] < len(pending[k]) for k, _ in my):
                k = my[rng.integers(len(my))][0]
                if cursors[k] < len(pending[k]):
                    tag, payload = pending[k][cursors[k]]
                    transports[k[0]].send(k[0], k[1], tag, payload)
                    cursors[k] += 1
        except Exception as e:  # pragma: no cover - surfaced by the test
            errs.append(e)

    def receiver(key):
        try:
            sends, order = links[key]
            # FIFO-per-tag fabric: receiving "send position i" means
            # receiving the next undelivered message of i's tag
            by_tag = {}
            for i, (tag, _) in enumerate(sends):
                by_tag.setdefault(tag, []).append(i)
            taken = {tag: 0 for tag in by_tag}
            for want in order:
                tag = sends[want][0]
                data = transports[key[1]].recv(key[0], key[1], tag,
                                               timeout=JOIN_S)
                send_pos = by_tag[tag][taken[tag]]
                taken[tag] += 1
                got[key].append((tag, send_pos, data))
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=sender, args=(r,), daemon=True)
               for r in range(n_ranks)]
    threads += [threading.Thread(target=receiver, args=(k,), daemon=True)
                for k in links]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=JOIN_S)
    assert not any(t.is_alive() for t in threads), \
        "transport fuzz deadlocked (threads still alive)"
    assert not errs, errs

    # closing barrier completes on every rank
    group = list(range(n_ranks))
    bt = [threading.Thread(
        target=lambda r=r: transports[r].barrier(r, group), daemon=True)
        for r in group]
    for t in bt:
        t.start()
    for t in bt:
        t.join(timeout=JOIN_S)
    assert not any(t.is_alive() for t in bt), "barrier deadlocked"
    return got


def _check_results(transports, links, got, depth, n_ranks):
    # exact send-side accounting per (src, dst, tag)
    for src in range(n_ranks):
        stats = transports[src].stats()
        for (s, d), (sends, _) in links.items():
            if s != src:
                continue
            for tag in TAG_POOL:
                mine = [p for t, p in sends if t == tag]
                key = (s, d, tag)
                if not mine:
                    assert key not in stats
                    continue
                assert stats[key].messages == len(mine)
                assert stats[key].bytes == sum(p.nbytes for p in mine)
    for key, (sends, _) in links.items():
        # everything arrived, with the right payload for its send slot
        assert len(got[key]) == len(sends)
        for tag, send_pos, data in got[key]:
            assert sends[send_pos][0] == tag
            assert np.array_equal(data, sends[send_pos][1])
        # FIFO per tag: send positions per tag arrive increasing
        for tag in TAG_POOL:
            pos = [p for t, p, _ in got[key] if t == tag]
            assert pos == sorted(pos)
    # the depth bound actually held (high-water mark, receive side)
    for rank in range(n_ranks):
        for (s, d), rs in transports[rank].reorder_stats().items():
            if (s, d) in links and d == rank:
                assert rs.peak_msgs <= depth
                assert rs.pending_msgs == 0


@settings(max_examples=6, deadline=None)
@given(st.integers(3, 5), st.integers(2, 6), st.integers(0, 2**32 - 1))
def test_inproc_all_to_all_fuzz(n_ranks, depth, seed):
    rng = np.random.default_rng(seed)
    links = _schedule(rng, n_ranks, n_msgs=30, depth=depth)
    tx = InprocTransport(n_ranks)
    transports = {r: tx for r in range(n_ranks)}
    try:
        got = _run_fuzz(transports, links, depth, n_ranks)
        _check_results(transports, links, got, depth, n_ranks)
    finally:
        tx.close()


@pytest.mark.parametrize("n_ranks,depth,seed", [(3, 3, 0), (4, 2, 7)])
def test_tcp_all_to_all_fuzz(n_ranks, depth, seed):
    """Same schedule over a real localhost TCP fleet (one endpoint per
    rank, co-hosted), exercising the reader threads, the per-link
    ``set_depth`` backpressure path and the socket close path."""
    rng = np.random.default_rng(seed)
    links = _schedule(rng, n_ranks, n_msgs=12, depth=depth)
    addrs = [f"127.0.0.1:{p}" for p in pick_free_ports(n_ranks)]
    transports = {r: TcpTransport(r, addrs) for r in range(n_ranks)}
    try:
        for t in transports.values():
            t.listen()
        # co-hosted ranks block on each other's inbound connections:
        # dial concurrently (what Fabric.connect does)
        ct = [threading.Thread(target=t.connect, daemon=True)
              for t in transports.values()]
        for t in ct:
            t.start()
        for t in ct:
            t.join(timeout=JOIN_S)
        assert not any(t.is_alive() for t in ct)
        got = _run_fuzz(transports, links, depth, n_ranks)
        _check_results(transports, links, got, depth, n_ranks)
    finally:
        for t in transports.values():
            t.close()
