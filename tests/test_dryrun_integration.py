"""Integration: the multi-pod dry-run machinery end-to-end for one cheap
cell per mesh (full sweeps live in experiments/; this guards the plumbing).
Runs in a subprocess because the 512-device XLA flag must be set before jax
initializes."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=560)


@pytest.mark.slow
def test_dryrun_single_pod_decode():
    r = _run(["--arch", "qwen2-1.5b", "--shape", "decode_32k",
              "--variant", "pytest"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[OK ]" in r.stdout
    path = os.path.join(ROOT, "experiments", "dryrun",
                        "qwen2-1.5b__decode_32k__pod256__pytest.json")
    with open(path) as f:
        art = json.load(f)
    assert art["ok"]
    assert art["memory"]["temp_bytes"] < 16 * 2**30
    assert art["roofline"]["dominant"] == "memory"


@pytest.mark.slow
def test_dryrun_multi_pod_compiles():
    r = _run(["--arch", "stablelm-3b", "--shape", "decode_32k",
              "--multi-pod", "--variant", "pytest"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[OK ]" in r.stdout


def test_int8_kv_decode_matches_bf16():
    """int8 KV cache decode stays close to the bf16 cache path."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import reduced_config
    from repro.models import init_lm, lm_decode, lm_prefill

    cfg = reduced_config("internlm2-20b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, c16 = lm_prefill(params, toks, cfg, max_seq=16)
    _, c8 = lm_prefill(params, toks, cfg8, max_seq=16)
    clen = jnp.full((2,), 12, dtype=jnp.int32)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0,
                             cfg.vocab_size)
    l16, _ = lm_decode(params, nxt, c16, clen, cfg)
    l8, _ = lm_decode(params, nxt, c8, clen, cfg8)
    a, b = np.asarray(l16), np.asarray(l8)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert rel < 0.1, rel


def test_collective_parser():
    from repro.launch.analysis import collective_bytes
    hlo = """
  %ar = f32[256,4096]{1,0} all-reduce(f32[256,4096]{1,0} %x), replica_groups={}
  %ag.1 = bf16[32,1024]{1,0} all-gather(bf16[2,1024]{1,0} %y), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z), source_target_pairs={{0,1}}
  %plain = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 4096 * 4
    assert out["all-gather"] == 32 * 1024 * 2
    assert out["collective-permute"] == 8 * 4
    assert out["count"] == 3
