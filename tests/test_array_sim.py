"""Array simulator cores == scalar reference cores, exactly.

The ``core="array"`` timing simulators (one vectorized cost_chunk per
record chunk, scalar handlers only at events) must be invisible in the
results: for GC and CKKS cost models, all three §8.2 scenarios, in-memory
Programs and on-disk ProgramFiles, and any chunk size, every SimResult
field matches the scalar cores bit for bit — including NET_SEND
accounting and the OS write-back-throttle path.  The chunked cost models
themselves are property-tested against their scalar formulas over random
immediate widths.
"""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.api import JobSpec, Session
from repro.core import PlanConfig, plan
from repro.core.bytecode import (Instr, MAX_IMM, Op, Program, encode_chunk,
                                 unpack_heads, write_program)
from repro.core.simulator import (DeviceModel, simulate_memory_program,
                                  simulate_os_paging, simulate_unbounded)
from repro.protocols.ckks.driver import CkksCostModel
from repro.protocols.garbled.cost import (GCCostModel, gate_cost,
                                          gate_cost_chunk)
from repro.scenarios import (OS_PAGE_BYTES, STORAGE, ScenarioCost, cost_fn,
                             scenario_spec)

from test_core_planner import _random_program

# ---------------------------------------------------------------------------
# chunked cost models == scalar formulas (property over random imm widths)
# ---------------------------------------------------------------------------

_GC_OPS = [Op.ADD, Op.SUB, Op.MUL, Op.CMP_GE, Op.CMP_EQ, Op.SELECT, Op.XOR,
           Op.AND, Op.OR, Op.NOT, Op.MINMAX, Op.SORT_LOCAL, Op.PAIR_JOIN,
           Op.MAC8, Op.XNOR_POP_SIGN, Op.REDUCE_ADD, Op.REVERSE, Op.INPUT,
           Op.OUTPUT, Op.COPY, Op.NET_SEND, Op.NET_RECV, Op.SWAP_IN,
           Op.ISSUE_SWAP_OUT]


def _random_gc_batch(seed: int, m: int = 64):
    rng = np.random.default_rng(seed)
    cases = []
    for _ in range(m):
        op = _GC_OPS[int(rng.integers(0, len(_GC_OPS)))]
        n = int(rng.integers(1, 400))
        w = int(rng.integers(1, 65))
        kw = int(rng.integers(1, 65))
        if op == Op.SORT_LOCAL:
            if rng.random() < 0.7:
                n = 1 << int(rng.integers(1, 10))
            imm = (n, w, kw, 0, int(rng.integers(0, 2))) \
                if rng.random() < 0.5 else (n, w, kw)
        elif op == Op.PAIR_JOIN:
            imm = (n, int(rng.integers(1, 200)), w, kw)
        elif op == Op.MAC8:
            imm = (n, int(rng.integers(1, 600)), int(rng.integers(16, 65)))
        elif op == Op.XNOR_POP_SIGN:
            imm = (n, int(rng.integers(1, 3000)))
        else:
            imm = (n, w, kw)
        cases.append((op, imm))
    ops = np.array([int(o) for o, _ in cases], dtype=np.int64)
    imm = np.zeros((m, MAX_IMM), dtype=np.int64)
    n_imm = np.zeros(m, dtype=np.int64)
    for i, (_, im) in enumerate(cases):
        imm[i, :len(im)] = im
        n_imm[i] = len(im)
    return cases, ops, imm, n_imm


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_gc_cost_chunk_matches_scalar(seed):
    cases, ops, imm, n_imm = _random_gc_batch(seed)
    va, vc = gate_cost_chunk(ops, imm, n_imm)
    model_g = GCCostModel()
    model_e = GCCostModel(role="evaluator")
    cg = model_g.cost_chunk(ops, imm, n_imm)
    ce = model_e.cost_chunk(ops, imm, n_imm)
    bv = model_g.bytes_chunk(ops, imm, n_imm)
    for i, (op, im) in enumerate(cases):
        sa, sc = gate_cost(op, im)
        assert (sa, sc) == (va[i], vc[i]), (op.name, im)
        ins = Instr(op, imm=im)
        assert model_g.cost(ins) == cg[i]
        assert model_e.cost(ins) == ce[i]
        assert model_g.bytes_of(ins) == bv[i]


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(8, 13))
def test_ckks_cost_chunk_matches_scalar(seed, ring_log2):
    rng = np.random.default_rng(seed)
    model = CkksCostModel(pointwise=1.2e-9)
    n_ring = 1 << ring_log2
    ckks_ops = [Op.CT_ADD, Op.CT_MUL, Op.CT_MUL_NR, Op.CT_RELIN,
                Op.CT_ADD_PLAIN, Op.CT_MUL_PLAIN, Op.INPUT, Op.OUTPUT,
                Op.COPY, Op.NET_SEND, Op.SWAP_OUT]
    cases = []
    for _ in range(48):
        op = ckks_ops[int(rng.integers(0, len(ckks_ops)))]
        imm = (int(rng.integers(0, 8)), int(rng.integers(2, 4)),
               int(rng.integers(2, 4)))
        cases.append((op, imm))
    ops = np.array([int(o) for o, _ in cases], dtype=np.int64)
    imm = np.zeros((len(cases), MAX_IMM), dtype=np.int64)
    for i, (_, im) in enumerate(cases):
        imm[i, :len(im)] = im
    cv = model.cost_chunk(ops, imm, n_ring)
    for i, (op, im) in enumerate(cases):
        assert model.cost(Instr(op, imm=im), n_ring) == cv[i], (op.name, im)


@pytest.mark.parametrize("protocol,workload,n", [("gc", "merge", 512),
                                                 ("ckks", "rsum", 64)])
def test_scenario_cost_chunk_matches_call(protocol, workload, n):
    """The rec-level ScenarioCost.cost_chunk (protocol formulas + the
    INPUT/OUTPUT file-streaming bytes) equals __call__ per instruction on
    a real trace."""
    spec = scenario_spec(workload, n, budget_frac=0.5)
    with Session(spec) as s:
        prog = s.trace()[0]
    cost = cost_fn(protocol)
    instrs = [i for i in prog.instrs if i.op != Op.FREE]
    rec = encode_chunk(instrs)
    chunk = cost.cost_chunk(rec)
    ops = unpack_heads(rec[:, 0])[0]
    n_io = int(((ops == int(Op.INPUT)) | (ops == int(Op.OUTPUT))).sum())
    assert n_io > 0, "trace must exercise the file-streaming path"
    for i, ins in enumerate(instrs):
        assert cost(ins) == chunk[i], (i, ins.op.name)


# ---------------------------------------------------------------------------
# simulator cores: exact equality, GC/CKKS x scenarios x Program/ProgramFile
# ---------------------------------------------------------------------------


def _simulate(name, n, sim_core, plan_mode="memory", num_workers=1):
    spec = scenario_spec(name, n, budget_frac=0.4, num_workers=num_workers,
                         plan_mode=plan_mode, sim_core=sim_core)
    with Session(spec) as s:
        return s.simulate(cost_fn(s.protocol), model=STORAGE,
                          os_page_bytes=OS_PAGE_BYTES)


@pytest.mark.parametrize("plan_mode", ("memory", "streaming"))
@pytest.mark.parametrize("name,n,workers", [("merge", 1024, 2),
                                            ("rsum", 64, 1)])
def test_session_sim_cores_identical(name, n, workers, plan_mode):
    """GC (2 workers: NET_SEND accounting) + CKKS, in-memory and
    streaming plans (the latter replays a ProgramFile memory program):
    every SimResult field equal across cores."""
    sc_s = _simulate(name, n, "scalar", plan_mode, workers)
    sc_a = _simulate(name, n, "array", plan_mode, workers)
    assert len(sc_s) == len(sc_a) == workers
    for ws, wa in zip(sc_s, sc_a):
        assert wa.unbounded == ws.unbounded
        assert wa.os == ws.os
        assert wa.mage == ws.mage
    if workers > 1:
        assert any(w.mage.net_msgs > 0 for w in sc_a), \
            "multi-worker replay must account NET_SEND traffic"
        assert all(wa.mage.net_bytes == ws.mage.net_bytes
                   for ws, wa in zip(sc_s, sc_a))


def test_sim_cores_identical_on_files(tmp_path):
    """All three simulators consume ProgramFiles; results equal the
    in-memory run under both cores and any chunk size."""
    prog = _random_program(17)
    cost = lambda ins: 2.3e-6 * (1 + len(ins.ins) + len(ins.outs))  # noqa: E731
    model = DeviceModel(bandwidth=2e8, latency=1e-4)
    mem, _ = plan(prog, PlanConfig(num_frames=7, lookahead=15,
                                   prefetch_pages=2))
    vpf = write_program(prog, tmp_path / "v.bc", strip_free=True)
    mpf = write_program(mem, tmp_path / "m.bc")
    ref = (simulate_unbounded(prog, cost, core="scalar"),
           simulate_os_paging(prog, cost, 6, 1024, model,
                              os_page_bytes=256, core="scalar"),
           simulate_memory_program(mem, cost, 1024, model, core="scalar"))
    for src_v, src_m in ((prog, mem), (vpf, mpf)):
        for core in ("scalar", "array"):
            for chunk in (13, 8192):
                got = (simulate_unbounded(src_v, cost, core=core,
                                          chunk_instrs=chunk),
                       simulate_os_paging(src_v, cost, 6, 1024, model,
                                          os_page_bytes=256, core=core,
                                          chunk_instrs=chunk),
                       simulate_memory_program(src_m, cost, 1024, model,
                                               core=core,
                                               chunk_instrs=chunk))
                assert got == ref, (type(src_v).__name__, core, chunk)
    assert ref[1].reads > 0 and ref[1].writes > 0


def test_writeback_throttle_path_identical():
    """A throttled device (deep write-back queue blocks the faulter) takes
    the direct-reclaim path in both cores and still agrees."""
    prog = _swap_heavy()
    # compute-heavy: an un-throttled write-back would hide entirely under
    # the compute until the next fault, so the direct-reclaim block is the
    # only thing separating the two devices below
    cost = lambda ins: 1e-3  # noqa: E731
    throttled = DeviceModel(bandwidth=5e6, latency=1e-5,
                            os_writeback_throttle_s=1e-4)
    free = DeviceModel(bandwidth=5e6, latency=1e-5,
                       os_writeback_throttle_s=math.inf)
    rs = simulate_os_paging(prog, cost, 8, 1024, throttled, core="scalar")
    ra = simulate_os_paging(prog, cost, 8, 1024, throttled, core="array")
    assert ra == rs
    assert rs.writes > 0
    r_free = simulate_os_paging(prog, cost, 8, 1024, free, core="array")
    assert rs.stall > r_free.stall, "throttle path was not exercised"


def _swap_heavy(n=600, live_pages=32, page_shift=6):
    psize = 1 << page_shift
    rng = np.random.default_rng(5)
    instrs = [Instr(Op.INPUT, outs=((p * psize, psize),), imm=(p,))
              for p in range(live_pages)]
    for i in range(n):
        wp = i % live_pages
        a = int(rng.integers(0, live_pages))
        instrs.append(Instr(Op.ADD, outs=((wp * psize, psize),),
                            ins=((a * psize, psize),), imm=(1, 32)))
    return Program(instrs=instrs, page_shift=page_shift, protocol="gc",
                   vspace_slots=live_pages << page_shift)


def test_os_paging_large_frame_eviction_path_identical():
    """num_frames > the candidate-snapshot size exercises the argpartition
    LRU victim queue; victims must still match the scalar OrderedDict pop
    order exactly."""
    prog = _swap_heavy(n=6000, live_pages=1600)
    cost = lambda ins: 1e-7  # noqa: E731
    rs = simulate_os_paging(prog, cost, 1300, 1024, core="scalar")
    ra = simulate_os_paging(prog, cost, 1300, 1024, core="array",
                            chunk_instrs=512)
    assert ra == rs
    assert rs.reads > 0 and rs.writes > 0


def test_os_paging_accounts_actual_device_bytes():
    """read_bytes reports whole readahead clusters (which round UP past
    the page size), write_bytes whole-page write-backs."""
    prog = _swap_heavy()
    cost = lambda ins: 1e-7  # noqa: E731
    model = DeviceModel(readahead=3)
    # page = 1024 B, os_page = 256 B -> 4 os-pages, readahead 3 ->
    # 2 clusters x 768 B = 1536 B actually read per fault
    r = simulate_os_paging(prog, cost, 8, 1024, model, os_page_bytes=256)
    assert r.reads > 0
    assert r.read_bytes == r.reads * 2 * 768
    assert r.read_bytes > r.reads * 1024
    assert r.write_bytes == r.writes * 1024


def test_bad_sim_core_rejected():
    prog = _random_program(0)
    with pytest.raises(ValueError, match="core"):
        simulate_unbounded(prog, lambda i: 0.0, core="simd")
    with pytest.raises(ValueError, match="sim_core"):
        JobSpec(workload="merge", n=64, memory_budget=8, sim_core="simd")


def test_scenario_cost_is_chunkable():
    """The scenarios harness's cost object advertises the chunk API the
    array cores look for."""
    c = cost_fn("gc")
    assert isinstance(c, ScenarioCost)
    assert callable(c) and hasattr(c, "cost_chunk")
    rec = encode_chunk([Instr(Op.ADD, outs=((0, 8),), ins=((8, 8), (16, 8)),
                              imm=(1, 32))])
    assert c.cost_chunk(rec).shape == (1,)


# ---------------------------------------------------------------------------
# OS-paging fault-run batching (thrash regime) == scalar, exactly
# ---------------------------------------------------------------------------


def _thrash(pages=96, reps=6, page_shift=6, self_read=False):
    """Cyclic sweep over more pages than frames: every touch is a miss,
    so the array core's batched fault-run path carries the whole replay.
    ``self_read=True`` makes each instruction touch its page twice,
    exercising the distinct-page run cutoff."""
    psize = 1 << page_shift
    instrs = [Instr(Op.INPUT, outs=((p * psize, psize),), imm=(p,))
              for p in range(pages)]
    for _ in range(reps):
        for p in range(pages):
            ins = ((p * psize, psize),) if self_read else ()
            instrs.append(Instr(Op.ADD, outs=((p * psize, psize),),
                                ins=ins, imm=(1, 32)))
    return Program(instrs=instrs, page_shift=page_shift, protocol="gc",
                   vspace_slots=pages << page_shift)


@pytest.mark.parametrize("self_read", (False, True))
@pytest.mark.parametrize("frames", (16, 48, 90))
def test_os_paging_fault_runs_identical_on_thrash(frames, self_read):
    prog = _thrash(self_read=self_read)
    cost = lambda ins: 1e-7  # noqa: E731
    rs = simulate_os_paging(prog, cost, frames, 1024, core="scalar")
    ra = simulate_os_paging(prog, cost, frames, 1024, core="array",
                            chunk_instrs=256)
    assert ra == rs
    assert rs.reads > 0 and rs.writes > 0   # dirty evictions write back


def test_os_paging_fault_runs_identical_with_compute_between_faults():
    # flush costs accumulated between faults must fold into the batched
    # event loop in the same float order as the scalar reference
    prog = _thrash(pages=48, reps=4)
    rng = np.random.default_rng(11)
    costs = rng.uniform(1e-8, 1e-5, len(prog.instrs))
    seen = {"i": -1}

    def cost(ins):
        seen["i"] += 1
        return float(costs[seen["i"] % len(costs)])

    rs = simulate_os_paging(prog, cost, 20, 1024, core="scalar")
    seen["i"] = -1
    ra = simulate_os_paging(prog, cost, 20, 1024, core="array",
                            chunk_instrs=512)
    assert ra == rs


# ---------------------------------------------------------------------------
# memory-program NET cost modes (in-order vs planned overlap)
# ---------------------------------------------------------------------------


def _two_worker_merge_plan(plan_mode="unbounded", **kw):
    spec = JobSpec(workload="merge", n=256, num_workers=2,
                   plan_mode=plan_mode, driver="gc-plaintext", **kw)
    with Session(spec) as sess:
        return sess.plan()[0]


def test_net_cost_modes_price_the_latency_windows():
    prog = _two_worker_merge_plan()
    lat = 0.025
    zero = lambda ins: 0.0  # noqa: E731
    ino = simulate_memory_program(prog, zero, 4096, net_latency_s=lat)
    ovl = simulate_memory_program(prog, zero, 4096, net_latency_s=lat,
                                  net_mode="overlap")
    assert ino.net_msgs == ovl.net_msgs > 1
    # in-order: every exchange is a blocking round; overlap with no swap
    # barriers: all windows run concurrently -> exactly one latency
    assert ino.total == ino.net_stall == pytest.approx(ino.net_msgs * lat)
    assert ovl.total == ovl.net_stall == pytest.approx(lat)


def test_net_cost_modes_settle_at_swap_barriers():
    prog = _two_worker_merge_plan(plan_mode="memory", memory_budget=0.5)
    lat = 0.025
    cost = lambda ins: 1e-7  # noqa: E731
    base = simulate_memory_program(prog, cost, 4096)
    ino = simulate_memory_program(prog, cost, 4096, net_latency_s=lat,
                                  net_bandwidth=1e9)
    ovl = simulate_memory_program(prog, cost, 4096, net_latency_s=lat,
                                  net_bandwidth=1e9, net_mode="overlap")
    assert base.net_stall == 0.0 and base.total < ovl.total <= ino.total
    # swap barriers bound the exchange window, so overlap hides less
    # than the unbounded single-residue ideal but never less than one
    assert lat <= ovl.net_stall < ino.net_stall


def test_net_cost_modes_identical_across_cores():
    for mode in ("inorder", "overlap"):
        for prog in (_two_worker_merge_plan(),
                     _two_worker_merge_plan(plan_mode="memory",
                                            memory_budget=0.5)):
            cost = lambda ins: 1e-7  # noqa: E731
            rs = simulate_memory_program(prog, cost, 4096, core="scalar",
                                         net_latency_s=0.01,
                                         net_bandwidth=1e9, net_mode=mode)
            ra = simulate_memory_program(prog, cost, 4096, core="array",
                                         net_latency_s=0.01,
                                         net_bandwidth=1e9, net_mode=mode)
            assert ra == rs


def test_net_cost_mode_validation_and_default_off():
    prog = _two_worker_merge_plan()
    cost = lambda ins: 1e-7  # noqa: E731
    with pytest.raises(ValueError, match="net_mode"):
        simulate_memory_program(prog, cost, 4096, net_mode="banana")
    off = simulate_memory_program(prog, cost, 4096)
    explicit = simulate_memory_program(prog, cost, 4096, net_latency_s=0.0,
                                       net_bandwidth=None)
    assert off == explicit and off.net_stall == 0.0
