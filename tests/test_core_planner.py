"""Core MAGE pipeline: placement, liveness, Belady replacement, prefetch
scheduling — unit + property tests (hypothesis) on randomized traces."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Engine, INF, Op, PlanConfig, plan, plan_replacement,
                        trace)
from repro.core.bytecode import DIRECTIVES, Program, strip_frees
from repro.core.dsl import Value, current_builder
from repro.core.liveness import compute_touches, working_set_pages
from repro.core.placement import PageAllocator
from repro.core.scheduling import plan_schedule


class _Driver:
    lane = 1
    dtype = np.uint64
    name = "test"

    def __init__(self):
        self.outputs = {}

    def execute(self, op, imm, outs, ins):
        if op == Op.INPUT:
            outs[0][:, 0] = np.arange(imm[0], imm[0] + outs[0].shape[0],
                                      dtype=np.uint64)
        elif op == Op.ADD:
            outs[0][...] = ins[0] + ins[1]
        elif op == Op.MUL:
            outs[0][...] = ins[0] * ins[1]
        elif op == Op.OUTPUT:
            self.outputs[imm[0]] = np.array(ins[0][:, 0])
        else:
            raise NotImplementedError(op)

    def cost(self, instr):
        return 1e-6

    def finalize(self):
        pass


class _Vec(Value):
    def __init__(self, n, builder=None):
        super().__init__(n, builder)
        self.n = n

    def _bin(self, op, o):
        r = _Vec(self.n)
        self.builder.emit(op, outs=(r.span,), ins=(self.span, o.span))
        return r

    def __add__(self, o):
        return self._bin(Op.ADD, o)

    def __mul__(self, o):
        return self._bin(Op.MUL, o)


def _random_program(seed: int, n_vals=24, n_ops=60, width=32):
    rng = np.random.default_rng(seed)

    def prog():
        b = current_builder()
        vals = []
        for i in range(n_vals):
            v = _Vec(width)
            b.emit(Op.INPUT, outs=(v.span,), imm=(int(rng.integers(1000)),))
            vals.append(v)
        for i in range(n_ops):
            x = vals[rng.integers(len(vals))]
            y = vals[rng.integers(len(vals))]
            z = x + y if rng.random() < 0.7 else x * y
            vals[rng.integers(len(vals))] = z  # frees the replaced value
        for t, v in enumerate(vals[:4]):
            b.emit(Op.OUTPUT, ins=(v.span,), imm=(t,))
    return trace(prog, protocol="test", page_shift=6)


def _run(program, cfg=None):
    if cfg is not None:
        program, _ = plan(program, cfg)
    d = _Driver()
    Engine(program, d).run()
    return d.outputs


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


def test_slab_allocator_never_straddles_and_reuses():
    a = PageAllocator(page_shift=6)  # 64-slot pages
    spans = [a.alloc(10) for _ in range(12)]
    for s in spans:
        assert s // 64 == (s + 9) // 64, "value straddles a page"
    # fewest-free-slots heuristic: freeing one slot and reallocating reuses it
    a.free(spans[3])
    again = a.alloc(10)
    assert again == spans[3]
    with pytest.raises(ValueError):
        a.alloc(65)
    with pytest.raises(KeyError):
        a.free(spans[3] + 1)


def test_working_set_and_liveness():
    prog = _random_program(0)
    instrs = strip_frees(prog.instrs)
    t = compute_touches(prog, instrs)
    ws = working_set_pages(t)
    assert 0 < ws <= prog.num_vpages()
    # next_any is strictly increasing along each page's touch chain
    for i in range(len(instrs)):
        for k in range(int(t.offsets[i]), int(t.offsets[i + 1])):
            nxt = int(t.next_any[k])
            assert nxt == INF or nxt > i


# ---------------------------------------------------------------------------
# replacement: correctness + MIN dominance
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_bounded_equals_unbounded(seed):
    prog = _random_program(seed)
    expect = _run(prog)
    got = _run(prog, PlanConfig(num_frames=6, lookahead=15,
                                prefetch_pages=2))
    for k, v in expect.items():
        assert np.array_equal(got[k], v)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 10))
def test_min_beats_heuristics_on_swap_ins(seed, frames):
    prog = _random_program(seed)
    stats = {}
    for pol in ("min", "lru", "fifo"):
        _, s = plan_replacement(prog, frames, policy=pol)
        stats[pol] = s
    assert stats["min"].swap_ins <= stats["lru"].swap_ins
    assert stats["min"].swap_ins <= stats["fifo"].swap_ins


def test_min_matches_bruteforce_on_tiny_traces():
    """Belady MIN is optimal in swap-ins: compare against exhaustive search
    over eviction choices on tiny traces."""

    def sim_best(pages_seq, frames):
        # exhaustive: state = frozenset resident; dp over positions
        from functools import lru_cache

        @lru_cache(maxsize=None)
        def go(i, resident):
            if i == len(pages_seq):
                return 0
            p = pages_seq[i]
            rs = set(resident)
            if p in rs:
                return go(i + 1, resident)
            faults = 1
            if len(rs) < frames:
                return faults + go(i + 1, frozenset(rs | {p}))
            best = 10 ** 9
            for evict in rs:
                nxt = frozenset((rs - {evict}) | {p})
                best = min(best, go(i + 1, nxt))
            return faults + best
        return go(0, frozenset())

    rng = np.random.default_rng(7)
    for trial in range(10):
        seq = list(rng.integers(0, 6, 14))
        frames = 3
        # run MIN the same way: count cold+capacity misses
        from repro.core.replacement import MinPolicy
        pol = MinPolicy()
        resident = {}
        faults = 0
        nxt_use = {}
        for i, p in enumerate(seq):
            p = int(p)
            if p not in resident:
                faults += 1
                if len(resident) >= frames:
                    victim = pol.evict(set([p]), resident, set())
                    resident.pop(victim)
                resident[p] = True
            nu = next((j for j in range(i + 1, len(seq))
                       if seq[j] == p), INF)
            pol.touch(p, nu if nu != INF else INF, i)
        assert faults == sim_best(tuple(int(x) for x in seq), frames), \
            (trial, seq)


# ---------------------------------------------------------------------------
# scheduling invariants
# ---------------------------------------------------------------------------


def _scheduling_invariants(mem: Program):
    """No read overtakes the matching write of the same page; pf slots are
    exclusive; every ISSUE has a FINISH."""
    slot_state = {}
    write_of_page = {}
    outstanding = set()
    for pos, ins in enumerate(mem.instrs):
        if ins.op == Op.ISSUE_SWAP_IN:
            vp, slot = ins.imm
            assert slot not in slot_state, f"slot {slot} reused in flight"
            assert write_of_page.get(vp) is None, \
                f"read of page {vp} issued while its write is in flight"
            slot_state[slot] = ("r", vp)
            outstanding.add(("r", vp, slot, pos))
        elif ins.op == Op.FINISH_SWAP_IN:
            vp, slot = ins.imm[0], ins.imm[1]
            st = slot_state.pop(slot, None)
            if st is not None:
                assert st == ("r", vp)
        elif ins.op == Op.ISSUE_SWAP_OUT:
            vp, slot = ins.imm
            assert slot not in slot_state
            slot_state[slot] = ("w", vp)
            write_of_page[vp] = slot
        elif ins.op == Op.FINISH_SWAP_OUT:
            slot = ins.imm[0]
            st = slot_state.pop(slot, None)
            if st is not None and st[0] == "w":
                write_of_page.pop(st[1], None)
    assert not slot_state, f"unfinished transfers: {slot_state}"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(1, 40))
def test_schedule_invariants(seed, pf, lookahead):
    prog = _random_program(seed)
    phys, _ = plan_replacement(prog, 6 + pf)
    mem, stats = plan_schedule(phys, lookahead, pf)
    _scheduling_invariants(mem)
    # compute instructions preserved, in order
    orig = [i for i in strip_frees(prog.instrs)]
    got = [i for i in mem.instrs if i.op not in DIRECTIVES]
    assert len(orig) == len(got)
    assert [i.op for i in orig] == [i.op for i in got]


def test_memmap_backed_swap_roundtrip(tmp_path):
    prog = _random_program(42)
    expect = _run(prog)
    mem, _ = plan(prog, PlanConfig(num_frames=5, lookahead=10,
                                   prefetch_pages=2))
    d = _Driver()
    Engine(mem, d, use_memmap=True).run()
    for k, v in expect.items():
        assert np.array_equal(d.outputs[k], v)
