"""The ``aggsum`` workload and its vectorized trace builder: the NumPy
record emitter must be digest-identical to the DSL tracing path, the
streamed program file must decode to the same instructions, and the
workload must execute correctly through the standard pipeline."""

from __future__ import annotations

import hashlib

import numpy as np
import pytest

from repro.api import JobSpec, run_job
from repro.core.bytecode import Op, encode_chunk, strip_frees
from repro.workloads import get
from repro.workloads.agg_workload import (AGG_VEC, build_aggsum_records,
                                          write_aggsum_program)
from repro.workloads.gc_workloads import OUT_TAGS


def _dsl_records(n: int) -> np.ndarray:
    prog = get("aggsum").trace(n)[0]
    return encode_chunk(strip_frees(prog.instrs))


@pytest.mark.parametrize("n", [1, 2, 3, 17, 64])
def test_vectorized_builder_digest_identical_to_dsl(n):
    dsl = _dsl_records(n)
    fast = build_aggsum_records(n)
    assert dsl.shape == fast.shape == (2 * n, dsl.shape[1])
    assert np.array_equal(dsl, fast), \
        f"n={n}: vectorized records diverge from the DSL trace"
    assert hashlib.sha256(dsl.tobytes()).digest() == \
        hashlib.sha256(fast.tobytes()).digest()


def test_streamed_program_file_matches_dsl(tmp_path):
    n = 12
    pf = write_aggsum_program(tmp_path / "aggsum.bc", n)
    got = list(pf.iter_instrs())
    want = strip_frees(get("aggsum").trace(n)[0].instrs)
    assert got == want
    assert pf.vspace_slots == get("aggsum").trace(n)[0].vspace_slots
    assert pf.meta["workload"] == "aggsum"


def test_builder_rejects_empty():
    with pytest.raises(ValueError):
        build_aggsum_records(0)


def test_trace_shape_and_ops():
    prog = get("aggsum").trace(8)[0]
    counts = prog.op_counts()
    assert counts["INPUT"] == 8
    assert counts["ADD"] == 7
    assert counts["OUTPUT"] == 1


def test_aggsum_executes_and_matches_oracle():
    outs = run_job(JobSpec(workload="aggsum", n=16, plan_mode="unbounded"),
                   check=True)
    oracle = get("aggsum").oracle(16)
    assert np.array_equal(outs[OUT_TAGS], oracle[OUT_TAGS])
    assert outs[OUT_TAGS].shape == (AGG_VEC,)


def test_aggsum_executes_under_memory_budget():
    # the ADD chain touches 3 pages per step: a small budget forces swaps
    run_job(JobSpec(workload="aggsum", n=16, memory_budget=4,
                    plan_mode="memory"), check=True)


def test_aggsum_matches_aggregation_subsystem_sum():
    """The MAGE-program reduction computes the SAME aggregate the online
    secure-aggregation fleet reveals (same PRG inputs, same mod-2^64
    sum) — the two halves of the subsystem agree."""
    from repro.aggregate import AggSpec, expected_sum
    n = 16
    outs = run_job(JobSpec(workload="aggsum", n=n, plan_mode="unbounded"))
    spec = AggSpec(clients=n, vec_len=AGG_VEC)
    assert np.array_equal(outs[OUT_TAGS], expected_sum(spec, 0))


def test_records_use_input_add_output_only():
    rec = build_aggsum_records(5)
    ops = set((rec[:, 0] & 0xFFFF).tolist())
    assert ops == {int(Op.INPUT), int(Op.ADD), int(Op.OUTPUT)}
