"""exec/ subsystem: batch-schedule structure, batched-vs-scalar digest
equality across drivers and worker counts, the schedule sidecar cache."""

import hashlib
import os
from unittest import mock

import numpy as np
import pytest

from repro.api import JobSpec, Session
from repro.core.bytecode import (_IMM_OFF, _IN_OFF, _OUT_OFF,
                                 iter_record_chunks, unpack_heads)
from repro.exec import build_batch_schedule
from repro.exec.batching import _BARRIER_OPS, BatchSchedule


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def _outputs(spec: JobSpec):
    with Session(spec) as sess:
        outs = sess.execute(check=True)
        stats = sess.engine_stats
    return _digest(outs), stats


# ---------------------------------------------------------------------------
# schedule structure
# ---------------------------------------------------------------------------


def _plan_one(**kw):
    sess = Session(JobSpec(**kw))
    prog = sess.plan()[0]
    return prog, build_batch_schedule(prog, sess.spec.chunk_instrs)


def _row_spans(rec, r, n_outs, n_ins):
    spans = []
    for j in range(n_outs[r]):
        a, ln = int(rec[r, _OUT_OFF + 2 * j]), \
            int(rec[r, _OUT_OFF + 1 + 2 * j])
        if ln > 0:
            spans.append((a, ln, True))
    for j in range(n_ins[r]):
        a, ln = int(rec[r, _IN_OFF + 2 * j]), int(rec[r, _IN_OFF + 1 + 2 * j])
        if ln > 0:
            spans.append((a, ln, False))
    return spans


@pytest.mark.parametrize("kw", [
    dict(workload="sort", n=512, memory_budget=64),
    dict(workload="merge", n=256, memory_budget=64),
    dict(workload="merge", n=256, plan_mode="unbounded"),
])
def test_schedule_is_valid_topological_order(kw):
    prog, sched = _plan_one(**kw)
    sched.validate_for(prog)
    ci = 0
    covered = 0
    for start, rec, _instrs in iter_record_chunks(prog, sched.chunk_instrs):
        m = rec.shape[0]
        op, n_outs, n_ins, _ = unpack_heads(rec[:, 0])
        pos = np.full(m, -1, dtype=np.int64)   # group index per row
        for g in range(sched.chunk_groups[ci], sched.chunk_groups[ci + 1]):
            rows = sched.order[sched.bounds[g]:sched.bounds[g + 1]]
            assert np.all(pos[rows] == -1), "row scheduled twice"
            pos[rows] = g
            gop = int(sched.group_op[g])
            if gop >= 0:
                # group uniformity: shared packed word0 (op, arity,
                # float mask) and shared immediates
                assert np.all(rec[rows, 0] == rec[rows[0], 0])
                assert gop == int(op[rows[0]])
                assert np.all(rec[np.ix_(rows, range(_IMM_OFF,
                                                     _IMM_OFF + 6))]
                              == rec[rows[0], _IMM_OFF:_IMM_OFF + 6])
        assert np.all(pos >= 0), "row missing from schedule"
        covered += m
        # dependency validity: any two rows whose spans overlap must be
        # scheduled in program order (RAW, WAR and WAW all count)
        spans = [_row_spans(rec, r, n_outs, n_ins) for r in range(m)]
        for i in range(m):
            for (a1, l1, w1) in spans[i]:
                for j in range(i + 1, m):
                    if pos[j] > pos[i]:
                        continue
                    for (a2, l2, w2) in spans[j]:
                        if (w1 or w2) and a1 < a2 + l2 and a2 < a1 + l1:
                            assert pos[i] < pos[j], \
                                f"conflicting rows {i},{j} reordered"
        # barriers stay singleton-scalar in program order
        barrier = np.isin(op, list(_BARRIER_OPS))
        bpos = pos[barrier]
        assert np.all(sched.group_op[bpos] == -1)
        assert np.all(np.diff(bpos) >= 0)
        ci += 1
    assert covered == sched.n_records == len(prog.instrs)


def test_schedule_roundtrip_and_validate(tmp_path):
    prog, sched = _plan_one(workload="sort", n=512, memory_budget=64)
    p = tmp_path / "w0.batch.npz"
    sched.save(p)
    got = BatchSchedule.load(p)
    assert got.chunk_instrs == sched.chunk_instrs
    assert got.n_records == sched.n_records
    for f in ("order", "bounds", "group_op", "chunk_groups"):
        assert np.array_equal(getattr(got, f), getattr(sched, f))
    got.n_records += 1
    with pytest.raises(ValueError, match="stale sidecar"):
        got.validate_for(prog)


def test_schedule_finds_batches_on_sort():
    _, sched = _plan_one(workload="sort", n=1024, memory_budget=128)
    st = sched.stats()
    assert st["batchable_instructions"] > st["scalar_instructions"]
    assert st["max_batch"] >= 32


# ---------------------------------------------------------------------------
# batched == scalar, bitwise
# ---------------------------------------------------------------------------


def _check_equal(**kw):
    d_scalar, _ = _outputs(JobSpec(exec_backend="scalar", **kw))
    d_batched, stats = _outputs(JobSpec(exec_backend="batched", **kw))
    assert d_scalar == d_batched
    return stats


def test_batched_matches_scalar_gc_plaintext():
    stats = _check_equal(workload="sort", n=1024, memory_budget=128)
    assert sum(s.batched_instructions for s in stats) > 0
    assert sum(s.batches for s in stats) > 0


def test_batched_matches_scalar_gc_two_party():
    stats = _check_equal(workload="merge", n=128, memory_budget=32,
                         driver="gc-2party")
    # both parties batch in lockstep off the same schedule
    assert all(s.batched_instructions > 0 for s in stats)


def test_batched_matches_scalar_gc_unbounded():
    _check_equal(workload="merge", n=1024, plan_mode="unbounded")


def test_batched_matches_scalar_ckks():
    stats = _check_equal(workload="rmvmul", n=32, memory_budget=32)
    assert sum(s.batched_instructions for s in stats) > 0


def test_batched_matches_scalar_two_workers_net():
    # NET_SEND/NET_RECV barriers interleave the two workers' programs;
    # the schedules must keep that traffic in program order
    for wl, n in (("rsum", 64), ("merge", 512)):
        _check_equal(workload=wl, n=n, memory_budget=32, num_workers=2)


def test_exec_backend_spec_validation():
    with pytest.raises(ValueError, match="exec_backend"):
        JobSpec(workload="sort", n=256, memory_budget=64,
                exec_backend="vector")


# ---------------------------------------------------------------------------
# sidecar cache: schedules are built once per plan, then served
# ---------------------------------------------------------------------------


def test_batch_schedule_cache_hit_and_no_rebatching(tmp_path):
    from repro.serve_daemon.cache import ArtifactCache
    cache = ArtifactCache(tmp_path / "cache")
    kw = dict(workload="sort", n=512, memory_budget=64,
              exec_backend="batched")

    with Session(JobSpec(**kw), cache=cache) as sess:
        cold = _digest(sess.execute(check=True))
        assert sess.cache_events.get("batch") == "miss"
    assert cache.stats.batch_misses == 1

    import repro.exec.batching as batching
    real_build = batching.build_batch_schedule
    calls = {"n": 0}

    def counting_build(*a, **k):
        calls["n"] += 1
        return real_build(*a, **k)

    with mock.patch.object(batching, "build_batch_schedule",
                           counting_build):
        with Session(JobSpec(**kw), cache=cache) as sess:
            hot = _digest(sess.execute(check=True))
            assert sess.cache_events.get("batch") == "hit"
    assert calls["n"] == 0, "hot submit re-built the batch schedule"
    assert cache.stats.batch_hits == 1
    assert hot == cold
    # the sidecar is a real on-disk artifact under <root>/batch/
    entries = os.listdir(tmp_path / "cache" / "batch")
    assert len(entries) == 1


def test_serve_daemon_reports_batch_cache(tmp_path):
    from repro.serve_daemon.client import serve_client
    from repro.serve_daemon.server import ServeDaemon
    daemon = ServeDaemon(tmp_path / "cache",
                         socket_path=str(tmp_path / "sock"))
    daemon.start()
    try:
        spec = JobSpec(workload="sort", n=256, memory_budget=64,
                       exec_backend="batched")
        with serve_client(daemon.address) as c:
            r1 = c.submit(spec, execute=True)
            r2 = c.submit(spec, execute=True)
            import dataclasses
            r3 = c.submit(dataclasses.replace(spec, exec_backend="scalar"),
                          execute=True)
        assert r1["ok"] and r2["ok"] and r3["ok"]
        assert r1["cache"]["batch"] == "miss"
        assert r2["cache"]["batch"] == "hit"
        assert "batch" not in r3["cache"]          # scalar never consults it
        assert r1["outputs_digest"] == r2["outputs_digest"] \
            == r3["outputs_digest"]
    finally:
        daemon.shutdown()
