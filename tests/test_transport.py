"""The transport fabric: backend contracts (per-tag FIFO with out-of-order
buffering, thread-safe accounting, TCP framing/barrier), NET_*-heavy
multi-worker programs producing bitwise-identical outputs with identical
byte counts over ``inproc`` and ``tcp``, the ``shaped`` decorator's
latency, and the acceptance criterion: a two-process localhost-TCP run of
a planned multi-worker workload matching the single-process run exactly.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.__main__ import main
from repro.api import FabricSpec, JobSpec, Session
from repro.core.transport import (InprocTransport, LinkShape, ShapedTransport,
                                  TcpTransport, TransportError, build_fabric,
                                  pick_free_ports)
from repro.workloads import get
from repro.workloads.runner import check_against_oracle


def _arr(*vals):
    return np.asarray(vals, dtype=np.uint64)


# ---------------------------------------------------------------------------
# inproc: reorder buffering + locked accounting (the old Channels bugs)
# ---------------------------------------------------------------------------


def test_inproc_out_of_order_tags_buffer_and_match():
    t = InprocTransport(2)
    t.send(0, 1, tag=2, data=_arr(22))
    t.send(0, 1, tag=1, data=_arr(11))
    # the old Channels.recv raised "net tag mismatch" here
    assert t.recv(0, 1, tag=1)[0] == 11
    assert t.recv(0, 1, tag=2)[0] == 22


def test_inproc_per_tag_fifo():
    t = InprocTransport(2)
    for v in (1, 2, 3):
        t.send(0, 1, tag=7, data=_arr(v))
    assert [int(t.recv(0, 1, 7)[0]) for _ in range(3)] == [1, 2, 3]


def test_inproc_recv_into_out_reshapes():
    t = InprocTransport(2)
    t.send(0, 1, tag=1, data=np.arange(6, dtype=np.uint64))
    out = np.zeros((3, 2), dtype=np.uint64)
    t.recv(0, 1, tag=1, out=out)
    assert np.array_equal(out, np.arange(6).reshape(3, 2))


def test_inproc_accounting_thread_safe():
    t = InprocTransport(3)
    threads, per, msg = [], 200, _arr(1, 2, 3)

    def hammer(src, dst):
        for i in range(per):
            t.send(src, dst, tag=i, data=msg)

    for src, dst in [(0, 1), (1, 0), (2, 1), (0, 2)]:
        threads.append(threading.Thread(target=hammer, args=(src, dst)))
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    totals = t.link_totals()
    for key in [(0, 1), (1, 0), (2, 1), (0, 2)]:
        assert totals[key].messages == per
        assert totals[key].bytes == per * msg.nbytes


def test_inproc_depth_bounds_pending():
    t = InprocTransport(2)
    t.set_depth(0, 1, max_msgs=2)
    t.send(0, 1, 1, _arr(1))
    t.send(0, 1, 2, _arr(2))
    done = threading.Event()

    def third():
        t.send(0, 1, 3, _arr(3))
        done.set()

    th = threading.Thread(target=third, daemon=True)
    th.start()
    assert not done.wait(0.1)           # blocked: pending set full
    t.recv(0, 1, 1)                     # drain one -> unblocks
    assert done.wait(2.0)


def test_inproc_rejects_bad_endpoints():
    t = InprocTransport(2)
    with pytest.raises(TransportError):
        t.send(0, 0, 1, _arr(1))
    with pytest.raises(TransportError):
        t.send(0, 5, 1, _arr(1))


# ---------------------------------------------------------------------------
# tcp: framing, reorder, dtype preservation, barrier
# ---------------------------------------------------------------------------


def _tcp_pair():
    ports = pick_free_ports(2)
    addrs = [f"127.0.0.1:{p}" for p in ports]
    ts = [TcpTransport(r, addrs, connect_timeout=10) for r in range(2)]
    threads = [threading.Thread(target=t.connect) for t in ts]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return ts


def test_tcp_roundtrip_reorder_and_dtypes():
    a, b = _tcp_pair()
    try:
        a.send(0, 1, tag=5, data=np.arange(8, dtype=np.uint64).reshape(4, 2))
        a.send(0, 1, tag=3, data=np.array([7, 9], dtype=np.uint8))
        b.send(1, 0, tag=1, data=np.array([1.5, -2.0]))
        got3 = b.recv(0, 1, tag=3, timeout=10)
        assert got3.dtype == np.uint8 and list(got3) == [7, 9]
        got5 = b.recv(0, 1, tag=5, timeout=10)
        assert got5.shape == (4, 2) and got5[3, 1] == 7
        got1 = a.recv(1, 0, tag=1, timeout=10)
        assert got1.dtype == np.float64 and got1[1] == -2.0
        assert a.link_totals()[(0, 1)].messages == 2
        assert b.link_totals()[(1, 0)].messages == 1
    finally:
        a.close()
        b.close()


def test_tcp_barrier_and_close_wakes_receiver():
    a, b = _tcp_pair()
    state = {}

    def side(t, rank):
        t.barrier(rank, range(2))
        state[rank] = True

    th = threading.Thread(target=side, args=(b, 1))
    th.start()
    side(a, 0)
    th.join(10)
    assert state == {0: True, 1: True}
    # close() while a recv is outstanding must raise, not hang
    err = {}

    def waiter():
        try:
            b.recv(0, 1, tag=99, timeout=30)
        except TransportError as e:
            err["e"] = e

    th = threading.Thread(target=waiter, daemon=True)
    th.start()
    time.sleep(0.1)
    a.close()
    b.close()
    th.join(10)
    assert "e" in err


def test_tcp_dead_peer_closes_links_created_later():
    """A recv on a link FIRST touched after the peer died must raise, not
    hang (links are created lazily; the dead-peer mark closes late ones)."""
    a, b = _tcp_pair()
    a.close()                       # peer gone before b ever touched a link
    for t in b._readers:
        t.join(5.0)
    with pytest.raises(TransportError):
        b.recv(0, 1, tag=42, timeout=10)
    b.close()


# ---------------------------------------------------------------------------
# shaped: decorator adds latency, preserves payloads and accounting
# ---------------------------------------------------------------------------


def test_shaped_delays_delivery_and_preserves_traffic():
    t = ShapedTransport(InprocTransport(2),
                        default=LinkShape(latency_s=0.15, bandwidth=None))
    t.send(0, 1, 1, _arr(42))
    t0 = time.monotonic()
    assert t.recv(0, 1, 1)[0] == 42
    assert time.monotonic() - t0 >= 0.10
    assert t.link_totals()[(0, 1)].messages == 1


def test_shaped_bandwidth_serializes_link():
    bw = 1e6    # 1 MB/s; 2 x 0.05 MB messages -> >= 0.1 s
    t = ShapedTransport(InprocTransport(2),
                        default=LinkShape(latency_s=0.0, bandwidth=bw))
    payload = np.zeros(50_000 // 8, dtype=np.uint64)
    t0 = time.monotonic()
    t.send(0, 1, 1, payload)
    t.send(0, 1, 1, payload)
    t.recv(0, 1, 1)
    t.recv(0, 1, 1)
    assert time.monotonic() - t0 >= 0.08


# ---------------------------------------------------------------------------
# NET_*-heavy programs: inproc vs tcp, bitwise-identical, same byte counts
# ---------------------------------------------------------------------------


def _run_spec(transport: str, fabric: FabricSpec | None = None):
    spec = JobSpec(workload="merge", n=128, num_workers=2, memory_budget=10,
                   lookahead=40, prefetch_pages=2,
                   transport=transport, fabric=fabric)
    with Session(spec) as s:
        outs = s.execute(check=True)
        return outs, s.engine_stats, s.transport_stats


@pytest.mark.slow
def test_merge_identical_over_inproc_and_tcp():
    outs_a, stats_a, tstats_a = _run_spec("inproc")
    ports = pick_free_ports(2)
    fabric = FabricSpec(peers=tuple(f"127.0.0.1:{p}" for p in ports))
    outs_b, stats_b, tstats_b = _run_spec("tcp", fabric)
    assert sorted(outs_a) == sorted(outs_b)
    for tag in outs_a:
        assert np.array_equal(outs_a[tag], outs_b[tag]), f"tag {tag}"
    # identical per-engine traffic, identical per-link fabric accounting
    for ea, eb in zip(stats_a, stats_b):
        assert ea.net_messages == eb.net_messages
        assert ea.net_sent_bytes == eb.net_sent_bytes
        assert ea.net_recv_bytes == eb.net_recv_bytes
        assert ea.net_links == eb.net_links
    assert {k: (s.messages, s.bytes) for k, s in tstats_a.items()} == \
        {k: (s.messages, s.bytes) for k, s in tstats_b.items()}
    # engines and fabric agree on what crossed each link
    sent = sum(e.net_sent_bytes for e in stats_a)
    assert sent == sum(s.bytes for s in tstats_a.values())
    assert sent > 0


def test_engine_stats_surface_per_link_totals():
    outs, stats, tstats = _run_spec("inproc")
    for e in stats:
        assert e.net_messages == sum(m for m, _ in e.net_links.values())
        out_keys = [k for k in e.net_links]
        assert out_keys, "merge workers must exchange pairs"


def test_shaped_session_matches_inproc_outputs():
    outs_a, _, tstats_a = _run_spec("inproc")
    outs_b, _, tstats_b = _run_spec(
        "shaped", FabricSpec(latency_s=0.001, bandwidth=1e9))
    for tag in outs_a:
        assert np.array_equal(outs_a[tag], outs_b[tag])
    assert {k: s.bytes for k, s in tstats_a.items()} == \
        {k: s.bytes for k, s in tstats_b.items()}


def test_two_party_gc_over_tcp_fabric():
    """Inter-party garbled traffic rides the same fabric as NET_*."""
    ports = pick_free_ports(2)
    spec = JobSpec(workload="merge", n=64, plan_mode="unbounded",
                   driver="gc-2party", transport="tcp",
                   fabric=FabricSpec(
                       peers=tuple(f"127.0.0.1:{p}" for p in ports)))
    with Session(spec) as s:
        outs = s.execute(check=True)
        tstats = s.transport_stats
    check_against_oracle(get("merge"), 64, outs)
    # all protocol kinds crossed the garbler->evaluator link
    tags = {t for (src, dst, t) in tstats if (src, dst) == (0, 1)}
    assert {1, 3, 4, 5} <= tags     # tab, gin, ot, dec


# ---------------------------------------------------------------------------
# fabric spec / registry plumbing
# ---------------------------------------------------------------------------


def test_fabric_spec_json_roundtrip():
    spec = JobSpec(workload="merge", n=128, memory_budget=10,
                   transport="tcp",
                   fabric=FabricSpec(rank=1, peers=("a:1", "b:2")))
    d = json.loads(json.dumps(spec.to_dict()))
    back = JobSpec.from_dict(d)
    assert back.fabric == spec.fabric
    assert back.transport == "tcp"
    # transport placement never affects the plan identity
    assert back.plan_hash() == JobSpec(workload="merge", n=128,
                                       memory_budget=10).plan_hash()


def test_build_fabric_validation():
    with pytest.raises(KeyError, match="unknown transport"):
        build_fabric("bogus", 2)
    with pytest.raises(TransportError, match="peer addresses"):
        build_fabric("tcp", 2, FabricSpec(peers=("h:1",)))
    with pytest.raises(TransportError, match="single rank"):
        build_fabric("inproc", 2, FabricSpec(rank=0, peers=()))
    fx = build_fabric("inproc", 4)
    assert not fx.distributed and fx.hosted == [0, 1, 2, 3]
    fx = build_fabric("tcp", 2, FabricSpec(rank=1, peers=("h:1", "h:2")))
    assert fx.distributed and fx.hosted == [1]


def test_distributed_rank_refuses_check(tmp_path):
    spec = JobSpec(workload="merge", n=64, num_workers=2, memory_budget=10,
                   lookahead=40, prefetch_pages=2, transport="tcp",
                   fabric=FabricSpec(rank=0, peers=("h:1", "h:2")))
    with Session(spec) as s:
        with pytest.raises(ValueError, match="full outputs"):
            s.execute(check=True)


# ---------------------------------------------------------------------------
# the acceptance criterion: two OS processes over localhost TCP
# ---------------------------------------------------------------------------


def _repro_env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.mark.slow
def test_two_process_tcp_run_matches_single_process(tmp_path):
    job = tmp_path / "job"
    assert main(["plan", "--workload", "merge", "-n", "64", "--workers", "2",
                 "--budget", "10", "--lookahead", "40", "--prefetch", "2",
                 "--out", str(job)]) == 0
    single = tmp_path / "single.json"
    assert main(["run", str(job), "--check", "--json", str(single)]) == 0

    env = _repro_env()
    ports = pick_free_ports(2)
    peers = ",".join(f"127.0.0.1:{p}" for p in ports)
    procs = []
    for rank in range(2):
        out = tmp_path / f"rank{rank}.json"
        procs.append((out, subprocess.Popen(
            [sys.executable, "-m", "repro", "run", str(job),
             "--worker", str(rank), "--peers", peers, "--json", str(out)],
            env=env, cwd=str(tmp_path))))
    for _, proc in procs:
        assert proc.wait(timeout=180) == 0
    merged = {}
    for out, _ in procs:
        merged.update(json.loads(out.read_text())["outputs"])
    expect = json.loads(single.read_text())["outputs"]
    assert merged == expect, "distributed outputs must be bitwise identical"


@pytest.mark.slow
def test_cli_fabric_fleet(tmp_path, capsys):
    job = tmp_path / "job"
    assert main(["plan", "--workload", "merge", "-n", "64", "--workers", "2",
                 "--budget", "10", "--lookahead", "40", "--prefetch", "2",
                 "--out", str(job)]) == 0
    merged = tmp_path / "fleet.json"
    assert main(["fabric", str(job), "--check", "--json", str(merged)]) == 0
    assert "oracle check OK" in capsys.readouterr().out
    assert merged.exists()


# ---------------------------------------------------------------------------
# shaped+tcp: the shaped decorator over the tcp backend (cross-process WAN)
# ---------------------------------------------------------------------------


def test_shaped_tcp_registered():
    from repro.core.transport import TRANSPORTS
    assert "shaped+tcp" in TRANSPORTS


def test_shaped_tcp_roundtrip_paces_sender_and_preserves_traffic():
    ports = pick_free_ports(2)
    fabric = build_fabric("shaped+tcp", 2, FabricSpec(
        peers=tuple(f"127.0.0.1:{p}" for p in ports),
        latency_s=0.15, bandwidth=None))
    fabric.connect()
    try:
        a = fabric.transport_for(0)
        b = fabric.transport_for(1)
        assert a.paced_send and a.inner.name == "tcp"
        t0 = time.monotonic()
        a.send(0, 1, tag=4, data=_arr(1, 2, 3))
        # pacing happens at the SENDER (no side table crosses processes)
        assert time.monotonic() - t0 >= 0.10
        got = b.recv(0, 1, tag=4, timeout=10)
        assert list(got) == [1, 2, 3]
        b.send(1, 0, tag=9, data=np.array([2.5]))
        assert a.recv(1, 0, tag=9, timeout=10)[0] == 2.5
        # stats and reorder surfaces delegate through the decorator
        assert fabric.link_totals()[(0, 1)].messages == 1
        assert (0, 1) in fabric.reorder_stats()
    finally:
        fabric.close()


def test_shaped_tcp_single_rank_placement():
    """Every hosted rank gets its own paced decorator, so ``--rank K``
    placement (impossible for plain ``shaped``) builds fine."""
    fx = build_fabric("shaped+tcp", 2,
                      FabricSpec(rank=1, peers=("h:1", "h:2"),
                                 latency_s=0.01))
    assert fx.distributed and fx.hosted == [1]
    assert fx.transport_for(1).paced_send


# ---------------------------------------------------------------------------
# fan-in stress: hundreds of concurrent senders into one endpoint
# ---------------------------------------------------------------------------


def test_inproc_fan_in_stress_accounting_depth_and_barrier():
    """4 source ranks x 50 threads -> rank 0, depth-bounded links: the
    accounting must sum exactly, every reorder buffer must respect its
    configured bound, and a full-fabric barrier must still complete."""
    n_src, threads_per, msgs_per, depth = 4, 50, 10, 8
    t = InprocTransport(n_src + 1)
    for src in range(1, n_src + 1):
        t.set_depth(src, 0, max_msgs=depth)
    payload = _arr(*range(5))

    def sender(src, tid):
        for i in range(msgs_per):
            t.send(src, 0, tag=tid * 1000 + i, data=payload)

    def receiver(src, tid):
        for i in range(msgs_per):
            got = t.recv(src, 0, tag=tid * 1000 + i, timeout=30)
            assert np.array_equal(got, payload)

    workers = []
    for src in range(1, n_src + 1):
        for tid in range(threads_per):
            workers.append(threading.Thread(target=sender, args=(src, tid)))
            workers.append(threading.Thread(target=receiver, args=(src, tid)))
    for th in workers:
        th.start()
    for th in workers:
        th.join(60)
        assert not th.is_alive(), "fan-in stress deadlocked"

    totals = t.link_totals()
    for src in range(1, n_src + 1):
        assert totals[(src, 0)].messages == threads_per * msgs_per
        assert totals[(src, 0)].bytes == \
            threads_per * msgs_per * payload.nbytes
    for (src, dst), st in t.reorder_stats().items():
        if dst == 0:
            assert st.max_msgs == depth
            assert st.peak_msgs <= depth, \
                f"link {src}->{dst} exceeded its depth bound: {st}"
            assert st.pending_msgs == 0 and st.pending_bytes == 0

    # the fabric still barriers after the storm
    done = []

    def barrier(rank):
        t.barrier(rank, range(n_src + 1))
        done.append(rank)

    bthreads = [threading.Thread(target=barrier, args=(r,))
                for r in range(n_src + 1)]
    for th in bthreads:
        th.start()
    for th in bthreads:
        th.join(30)
    assert sorted(done) == list(range(n_src + 1))


def test_reorder_stats_track_pending_and_peak():
    t = InprocTransport(2)
    t.send(0, 1, 1, _arr(1, 2))
    t.send(0, 1, 2, _arr(3, 4))
    st = t.reorder_stats()[(0, 1)]
    assert st.pending_msgs == 2 and st.peak_msgs == 2
    assert st.pending_bytes == st.peak_bytes == 32
    t.recv(0, 1, 1)
    st = t.reorder_stats()[(0, 1)]
    assert st.pending_msgs == 1
    assert st.peak_msgs == 2, "peaks are high-water marks, not gauges"


def test_run_worker_requires_peers(tmp_path):
    job = tmp_path / "job"
    assert main(["plan", "--workload", "merge", "-n", "64",
                 "--budget", "10", "--lookahead", "40",
                 "--out", str(job)]) == 0
    with pytest.raises(SystemExit, match="--peers"):
        main(["run", str(job), "--worker", "0"])
    with pytest.raises(SystemExit, match="full outputs|fabric"):
        main(["run", str(job), "--worker", "0", "--peers", "a:1,b:2",
              "--check"])


# ---------------------------------------------------------------------------
# async completion handles (the overlap engine's primitives)
# ---------------------------------------------------------------------------


def test_send_async_is_eager_recv_async_is_deferred():
    t = InprocTransport(2)
    c = t.send_async(0, 1, tag=1, data=_arr(5))
    assert c.done()                      # the send already happened
    c.wait()
    out = np.zeros(1, dtype=np.uint64)
    h = t.recv_async(0, 1, tag=1, out=out)
    assert not h.done()                  # completion deferred to wait()
    assert out[0] == 0
    got = h.wait()
    assert out[0] == 5 and got[0] == 5
    assert h.done()
    assert h.wait()[0] == 5              # idempotent


def test_recv_async_channel_order_is_wait_order():
    # the handle is LAZY: data binds at wait() time, so per-channel FIFO
    # follows the order of the wait() calls — the overlap scheduler's
    # contract is "waits in post order per (src, dst, tag)", and waits
    # across different channels may interleave freely
    t = InprocTransport(2)
    for tag in (1, 2, 3):
        for v in (10 * tag, 10 * tag + 1):
            t.send_async(0, 1, tag=tag, data=_arr(v))
    outs = {tag: (np.zeros(1, dtype=np.uint64), np.zeros(1, dtype=np.uint64))
            for tag in (1, 2, 3)}
    handles = {(tag, i): t.recv_async(0, 1, tag, out=outs[tag][i])
               for tag in (1, 2, 3) for i in (0, 1)}
    # reverse TAG order (cross-channel reorder), post order within a tag
    for tag in (3, 2, 1):
        for i in (0, 1):
            handles[(tag, i)].wait()
    for tag in (1, 2, 3):
        assert outs[tag][0][0] == 10 * tag
        assert outs[tag][1][0] == 10 * tag + 1


def test_recv_async_posting_does_not_consume_under_depth_bound():
    # a posted-but-unwaited recv must NOT drain the link: the reorder
    # buffer's depth bound only releases at wait() time, which is what
    # keeps the overlap engine's in-flight window honest
    t = InprocTransport(2)
    t.set_depth(0, 1, max_msgs=2)
    t.send(0, 1, 1, _arr(1))
    t.send(0, 1, 2, _arr(2))
    outs = [np.zeros(1, dtype=np.uint64) for _ in range(3)]
    handles = [t.recv_async(0, 1, tag, out=outs[tag - 1])
               for tag in (1, 2, 3)]
    blocked = threading.Event()

    def third():
        t.send(0, 1, 3, _arr(3))
        blocked.set()

    th = threading.Thread(target=third, daemon=True)
    th.start()
    assert not blocked.wait(0.1), "posting recvs must not free the link"
    handles[0].wait()                    # completing one drains one slot
    assert blocked.wait(2.0)
    handles[1].wait()
    handles[2].wait()
    assert [int(o[0]) for o in outs] == [1, 2, 3]


def test_recv_async_wait_raises_transport_error():
    t = InprocTransport(2)
    h = t.recv_async(0, 1, tag=9, out=np.zeros(1, dtype=np.uint64),
                     timeout=0.05)
    with pytest.raises(TransportError):
        h.wait()


def test_shaped_async_pays_latency_at_wait_not_post():
    lat = 0.05
    t = ShapedTransport(InprocTransport(2),
                        LinkShape(latency_s=lat, bandwidth=None))
    t.send_async(0, 1, tag=1, data=_arr(7))
    out = np.zeros(1, dtype=np.uint64)
    t0 = time.perf_counter()
    h = t.recv_async(0, 1, tag=1, out=out)
    posted = time.perf_counter() - t0
    assert posted < lat / 2, "posting must not sleep the latency"
    h.wait()
    waited = time.perf_counter() - t0
    assert waited >= lat * 0.8
    assert out[0] == 7
