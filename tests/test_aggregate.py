"""Secure aggregation: share algebra, the offline/online split through
the artifact cache, backpressure/admission evidence, straggler
degradation semantics, the CLI, and the acceptance criteria — the
revealed aggregate bitwise-identical across single-process, 2-process
TCP, and straggler-free vs straggler-degraded runs over the same
surviving subset.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.__main__ import main
from repro.aggregate import (AggSpec, build_round_plan, client_shares,
                             client_vector, expected_sum, load_round_plan,
                             run_aggregation, verify_aggregates)
from repro.aggregate.offline import data_tag
from repro.core.transport import FabricSpec, pick_free_ports
from repro.serve_daemon.cache import ArtifactCache


# ---------------------------------------------------------------------------
# offline phase: share algebra, plan identity, cache sidecar
# ---------------------------------------------------------------------------


def test_shares_sum_to_vector_mod_2_64():
    spec = AggSpec(clients=5, vec_len=32, servers=3)
    for c in range(spec.clients):
        shares = client_shares(spec, c, rnd=0)
        assert len(shares) == 3
        total = np.zeros(32, dtype=np.uint64)
        for s in shares:
            assert s.dtype == np.uint64
            total += s
        assert np.array_equal(total, client_vector(spec.seed, c, 0, 32))


def test_shares_are_pure_functions_of_client_server_round():
    spec = AggSpec(clients=4, vec_len=16)
    a = client_shares(spec, 2, rnd=1)
    b = client_shares(spec, 2, rnd=1)
    assert all(np.array_equal(x, y) for x, y in zip(a, b))
    c = client_shares(spec, 2, rnd=2)
    assert not np.array_equal(a[0], c[0])


def test_expected_sum_over_subset():
    spec = AggSpec(clients=6, vec_len=8)
    full = expected_sum(spec, 0)
    sub = expected_sum(spec, 0, survivors=[0, 2, 4])
    rest = expected_sum(spec, 0, survivors=[1, 3, 5])
    assert np.array_equal(sub + rest, full)


def test_plan_key_ignores_online_knobs():
    a = AggSpec(clients=10, round_timeout_s=5.0, max_inflight_bytes=1)
    b = AggSpec(clients=10, round_timeout_s=99.0, max_inflight_bytes=2)
    assert a.plan_key() == b.plan_key()
    assert a.plan_key() != AggSpec(clients=11).plan_key()


def test_round_plan_partitions_clients_and_estimates():
    spec = AggSpec(clients=10, vec_len=64, gateways=3)
    plan = build_round_plan(spec)
    assert sorted(c for block in plan.gateway_clients for c in block) == \
        list(range(10))
    assert plan.share_bytes == 64 * 8
    assert plan.mem_bytes == 10 * 64 * 8
    assert plan.frames >= 1


def test_data_tags_unique_across_rounds_and_clients():
    spec = AggSpec(clients=7, rounds=3)
    tags = {data_tag(spec, r, c)
            for r in range(3) for c in range(7)}
    assert len(tags) == 21


def test_round_plan_cache_sidecar_roundtrip(tmp_path):
    cache = ArtifactCache(tmp_path)
    spec = AggSpec(clients=12, gateways=3)
    plan, ev = load_round_plan(cache, spec)
    assert ev == "miss" and cache.stats.agg_misses == 1
    again, ev = load_round_plan(cache, spec)
    assert ev == "hit" and cache.stats.agg_hits == 1
    assert again.to_dict() == plan.to_dict()
    # survives a daemon restart (fresh cache object, same root)
    plan2, ev = load_round_plan(ArtifactCache(tmp_path), spec)
    assert ev == "hit" and plan2.key == spec.plan_key()
    assert load_round_plan(None, spec)[1] == "none"


# ---------------------------------------------------------------------------
# online phase, in-process
# ---------------------------------------------------------------------------


def test_aggregation_matches_oracle_multi_round():
    spec = AggSpec(clients=40, vec_len=16, rounds=3, servers=2, gateways=3)
    res = run_aggregation(spec)
    verify_aggregates(res)
    assert len(res.rounds) == 3
    for r in res.rounds:
        assert not r.degraded and len(r.survivors) == 40
        assert np.array_equal(np.asarray(r.total, dtype=np.uint64),
                              expected_sum(spec, r.rnd))
    assert res.clients_per_s > 0
    assert res.latency_ms.keys() == {"p50", "p90", "p99"}


def test_aggregation_single_server_and_gateway():
    spec = AggSpec(clients=9, vec_len=4, servers=1, gateways=1)
    res = run_aggregation(spec)
    verify_aggregates(res)


def test_straggler_round_degrades_and_matches_survivor_oracle():
    spec = AggSpec(clients=20, vec_len=8, rounds=2)
    res = run_aggregation(spec, drop=[(0, 3), (0, 17)])
    verify_aggregates(res)
    r0, r1 = res.rounds
    assert r0.degraded and sorted(r0.survivors) == \
        [c for c in range(20) if c not in (3, 17)]
    assert not r1.degraded
    # the acceptance identity: a degraded round equals a straggler-free
    # aggregation over the same surviving subset, bitwise
    sub = AggSpec(clients=20, vec_len=8, rounds=1)
    ref = expected_sum(sub, 0, survivors=r0.survivors)
    assert np.array_equal(np.asarray(r0.total, dtype=np.uint64), ref)


def test_backpressure_bounds_inflight_bytes_counter_verified():
    spec = AggSpec(clients=150, vec_len=64, max_inflight_bytes=4096)
    res = run_aggregation(spec)
    verify_aggregates(res)
    checked = 0
    for (src, dst), st in res.reorder.items():
        if dst < spec.servers and src >= spec.servers:
            checked += 1
            assert st.max_bytes == 4096
            assert st.peak_bytes <= 4096 + spec.vec_len * 8, (src, dst, st)
    assert checked == spec.gateways * spec.servers


def test_admission_reserves_round_footprint():
    spec = AggSpec(clients=30, vec_len=16, rounds=2)
    res = run_aggregation(spec)
    adm = res.admission
    plan = build_round_plan(spec)
    assert adm["admitted"] == spec.servers * spec.rounds
    assert adm["peak_frames"] >= plan.frames
    assert adm["active"] == 0 and adm["frames_in_use"] == 0


def test_hot_rounds_reuse_cached_plan_zero_replans(tmp_path):
    spec = AggSpec(clients=25, vec_len=8, rounds=3)
    cold = ArtifactCache(tmp_path)
    res = run_aggregation(spec, cache=cold)
    assert res.plan_events == ["miss", "hit", "hit"]
    assert cold.stats.agg_misses == 1 and cold.stats.agg_hits == 2
    hot = ArtifactCache(tmp_path)
    res2 = run_aggregation(spec, cache=hot)
    assert res2.plan_events == ["hit"] * 3
    assert hot.stats.agg_misses == 0, "hot run must never re-plan"
    for a, b in zip(res.rounds, res2.rounds):
        assert np.array_equal(a.total, b.total)


def test_shaped_wan_reports_latency_percentiles():
    spec = AggSpec(clients=20, vec_len=8)
    res = run_aggregation(
        spec, transport="shaped",
        fabric_spec=FabricSpec(latency_s=0.005, bandwidth=1e9))
    verify_aggregates(res)
    assert res.latency_ms["p50"] >= 5.0, \
        "per-client latency must include the shaped link latency"


def test_bad_specs_rejected():
    with pytest.raises(ValueError):
        AggSpec(clients=0)
    with pytest.raises(ValueError):
        AggSpec(clients=4, servers=0)
    with pytest.raises(ValueError):
        AggSpec(clients=4, rounds=0)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_agg_check_and_json_envelope(tmp_path):
    out = tmp_path / "agg.json"
    assert main(["agg", "--clients", "30", "--rounds", "2", "--vec-len", "8",
                 "--check", "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["schema_version"] >= 1
    assert len(doc["rounds"]) == 2
    assert doc["rounds"][0]["survivors"] == list(range(30))
    assert doc["spec"]["clients"] == 30
    assert doc["admission"]["active"] == 0
    assert any(k in doc["reorder"] for k in ("2->0", "3->0"))


def test_cli_agg_drop_reports_degraded(tmp_path, capsys):
    out = tmp_path / "agg.json"
    assert main(["agg", "--clients", "10", "--rounds", "2", "--vec-len", "4",
                 "--drop", "1:2,5", "--check", "--json", str(out)]) == 0
    assert "DEGRADED (2 dropped)" in capsys.readouterr().out
    doc = json.loads(out.read_text())
    assert doc["rounds"][0]["degraded"] is False
    assert doc["rounds"][1]["degraded"] is True
    assert 2 not in doc["rounds"][1]["survivors"]


def test_cli_agg_bad_drop_and_missing_peers():
    with pytest.raises(SystemExit, match="--drop"):
        main(["agg", "--clients", "4", "--drop", "nope"])
    with pytest.raises(SystemExit, match="--peers"):
        main(["agg", "--clients", "4", "--rank", "0"])


# ---------------------------------------------------------------------------
# acceptance: 2-process TCP bitwise-identical to single-process
# ---------------------------------------------------------------------------


def _repro_env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.mark.slow
def test_two_process_tcp_aggregation_matches_single_process(tmp_path):
    args = ["--clients", "30", "--rounds", "2", "--vec-len", "8",
            "--servers", "1", "--gateways", "1"]
    single = tmp_path / "single.json"
    assert main(["agg", *args, "--check", "--json", str(single)]) == 0

    peers = ",".join(f"127.0.0.1:{p}" for p in pick_free_ports(2))
    env = _repro_env()
    out0 = tmp_path / "rank0.json"
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro", "agg", *args, "--peers", peers,
         "--rank", "0", "--check", "--json", str(out0)], env=env),
        subprocess.Popen(
        [sys.executable, "-m", "repro", "agg", *args, "--peers", peers,
         "--rank", "1"], env=env)]
    for p in procs:
        assert p.wait(timeout=120) == 0
    a = json.loads(single.read_text())["rounds"]
    b = json.loads(out0.read_text())["rounds"]
    assert [r["aggregate"] for r in a] == [r["aggregate"] for r in b]
    assert [r["survivors"] for r in a] == [r["survivors"] for r in b]
