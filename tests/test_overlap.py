"""Planned overlap engine (docs/OVERLAP.md): schedule structure (sends
hoisted ahead of program order, recv completion deferred to the wait,
recv rows scheduled twice), bitwise identity with the scalar engine
across drivers / transports / worker counts, the sidecar cache (hot
submits reuse the stored schedule with zero re-passes), and the serve
daemon's per-submit cache reporting."""

import hashlib
from unittest import mock

import numpy as np
import pytest

from repro.api import FabricSpec, JobSpec, Session
from repro.core.bytecode import Op, iter_record_chunks, unpack_heads
from repro.core.transport import pick_free_ports
from repro.exec import OverlapSchedule, build_overlap_schedule
from repro.exec.overlap import K_LOCAL, K_RECV_POST, K_RECV_WAIT, K_SEND
from repro.serve_daemon.client import serve_client
from repro.serve_daemon.server import ServeDaemon


def _digest(outputs) -> str:
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


def _outputs(spec: JobSpec):
    with Session(spec) as sess:
        outs = sess.execute(check=True)
        stats = sess.engine_stats
    return _digest(outs), stats


def _plan_one(**kw):
    sess = Session(JobSpec(**kw))
    prog = sess.plan()[0]
    return prog, build_overlap_schedule(prog, sess.spec.chunk_instrs)


# ---------------------------------------------------------------------------
# schedule structure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(workload="merge", n=256, num_workers=2, memory_budget=0.5),
    dict(workload="merge", n=256, num_workers=2, plan_mode="unbounded"),
])
def test_overlap_schedule_hoists_sends_and_defers_recvs(kw):
    prog, sched = _plan_one(**kw)
    sched.validate_for(prog)
    st = sched.stats()
    assert st["hoisted_sends"] > 0
    assert st["deferred_recvs"] > 0
    ci = 0
    for start, rec, _instrs in iter_record_chunks(prog, sched.chunk_instrs):
        m = rec.shape[0]
        ops = unpack_heads(rec[:, 0])[0]
        seen = np.zeros(m, dtype=np.int64)      # schedule visits per row
        posted: dict[int, int] = {}             # recv row -> post position
        pos = 0
        for g in range(sched.chunk_groups[ci], sched.chunk_groups[ci + 1]):
            rows = sched.order[sched.bounds[g]:sched.bounds[g + 1]]
            kind = int(sched.group_kind[g])
            for r in rows.tolist():
                if kind == K_SEND:
                    assert ops[r] == int(Op.NET_SEND)
                elif kind in (K_RECV_POST, K_RECV_WAIT):
                    assert ops[r] == int(Op.NET_RECV)
                    if kind == K_RECV_POST:
                        posted[r] = pos
                    else:
                        assert r in posted, "wait before its post"
                else:
                    assert kind == K_LOCAL
                seen[r] += 1
                pos += 1
        # every recv row scheduled exactly twice (post + wait), the
        # rest exactly once
        recv = ops == int(Op.NET_RECV)
        assert np.all(seen[recv] == 2)
        assert np.all(seen[~recv] == 1)
        assert len(posted) == int(recv.sum())
        ci += 1


def test_overlap_schedule_roundtrip_and_stale(tmp_path):
    prog, sched = _plan_one(workload="merge", n=256, num_workers=2,
                            memory_budget=0.5)
    p = tmp_path / "w0.overlap.npz"
    sched.save(p)
    got = OverlapSchedule.load(p)
    assert got.chunk_instrs == sched.chunk_instrs
    assert got.n_records == sched.n_records
    for f in ("order", "bounds", "group_kind", "group_op", "chunk_groups"):
        assert np.array_equal(getattr(got, f), getattr(sched, f))
    got.n_records += 1
    with pytest.raises(ValueError, match="stale sidecar"):
        got.validate_for(prog)


# ---------------------------------------------------------------------------
# overlap == scalar, bitwise
# ---------------------------------------------------------------------------


def _check_equal(**kw):
    d_scalar, _ = _outputs(JobSpec(exec_backend="scalar", **kw))
    d_overlap, stats = _outputs(JobSpec(exec_backend="overlap", **kw))
    assert d_scalar == d_overlap
    return stats


def test_overlap_matches_scalar_gc_plaintext_two_workers():
    stats = _check_equal(workload="merge", n=256, num_workers=2,
                         memory_budget=0.5)
    assert sum(s.posted_recvs for s in stats) > 0


def test_overlap_matches_scalar_two_workers_net_interleaved():
    # NET exchanges interleave the two workers' programs mid-computation;
    # the engines must drain them in channel-FIFO order either way
    for wl, n in (("rsum", 64), ("merge", 512)):
        _check_equal(workload=wl, n=n, memory_budget=32, num_workers=2)


def test_overlap_unbounded_posts_whole_exchange_window():
    d_s, _ = _outputs(JobSpec(workload="merge", n=256, num_workers=2,
                              plan_mode="unbounded", exec_backend="scalar"))
    d_o, stats = _outputs(JobSpec(workload="merge", n=256, num_workers=2,
                                  plan_mode="unbounded",
                                  exec_backend="overlap"))
    assert d_s == d_o
    # with no swap barriers every recv in the pass is posted before any
    # wait, so the in-flight window covers the whole exchange
    assert max(s.max_inflight_recvs for s in stats) >= 4


def test_overlap_matches_scalar_gc_two_party_tcp():
    ports = pick_free_ports(2)
    fab = FabricSpec(peers=tuple(f"127.0.0.1:{p}" for p in ports))
    kw = dict(workload="merge", n=64, plan_mode="unbounded",
              driver="gc-2party", transport="tcp", fabric=fab)
    d_scalar, _ = _outputs(JobSpec(exec_backend="scalar", **kw))
    ports = pick_free_ports(2)
    kw["fabric"] = FabricSpec(peers=tuple(f"127.0.0.1:{p}" for p in ports))
    d_overlap, _ = _outputs(JobSpec(exec_backend="overlap", **kw))
    assert d_scalar == d_overlap


def test_overlap_matches_scalar_on_shaped_wan():
    fab = FabricSpec(latency_s=0.002, bandwidth=1e9)
    stats = _check_equal(workload="merge", n=256, num_workers=2,
                         plan_mode="unbounded", transport="shaped",
                         fabric=fab)
    assert sum(s.posted_recvs for s in stats) > 0


def test_overlap_matches_scalar_ckks():
    _check_equal(workload="rmvmul", n=32, memory_budget=32)


# ---------------------------------------------------------------------------
# sidecar cache: hot submits reuse the stored schedule, zero re-passes
# ---------------------------------------------------------------------------


def test_overlap_cache_hot_submit_zero_repasses(tmp_path):
    spec = JobSpec(workload="merge", n=256, num_workers=2,
                   memory_budget=0.5, exec_backend="overlap")
    cache = tmp_path / "cache"
    with Session(spec, cache=cache) as s:
        d1 = _digest(s.execute(check=True))
        assert s.cache_events["overlap"] == "miss"
    with Session(spec, cache=cache) as s:
        with mock.patch("repro.exec.overlap.build_overlap_schedule",
                        side_effect=AssertionError("hot submit re-ran the "
                                                   "overlap pass")) as m:
            d2 = _digest(s.execute(check=True))
        assert s.cache_events["overlap"] == "hit"
        assert m.call_count == 0
    assert d1 == d2


def test_daemon_reports_overlap_cache(tmp_path):
    spec = JobSpec(workload="merge", n=256, num_workers=2,
                   memory_budget=0.5, exec_backend="overlap")
    d = ServeDaemon(tmp_path / "cache",
                    socket_path=str(tmp_path / "mage.sock"),
                    frame_pool=4096)
    d.start()
    try:
        with serve_client(d.address) as c:
            r1 = c.submit(spec, execute=True)
            assert r1["cache"]["overlap"] == "miss"
            r2 = c.submit(spec, execute=True)
            assert r2["cache"]["overlap"] == "hit"
            assert r2["outputs_digest"] == r1["outputs_digest"]
            assert d.cache.status()["overlap_hits"] == 1
    finally:
        d.shutdown()
