"""Documentation integrity: no dangling relative links.

Scans README.md and every markdown file under docs/ for markdown links
and validates that relative targets exist (anchors and external URLs are
skipped).  Run standalone in CI as the docs link-check step:

    python -m pytest -q tests/test_docs.py
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _doc_files():
    out = [os.path.join(REPO, "README.md")]
    docdir = os.path.join(REPO, "docs")
    if os.path.isdir(docdir):
        out += sorted(os.path.join(docdir, f) for f in os.listdir(docdir)
                      if f.endswith(".md"))
    return [p for p in out if os.path.exists(p)]


def test_required_docs_exist():
    for name in ("README.md", "docs/SIMULATOR.md", "docs/PLANNER.md",
                 "docs/API.md", "docs/DISTRIBUTED.md", "docs/ENGINE.md",
                 "docs/AGGREGATE.md", "docs/OVERLAP.md", "docs/SHAMIR.md"):
        assert os.path.exists(os.path.join(REPO, name)), f"{name} missing"


@pytest.mark.parametrize("path", _doc_files(),
                         ids=[os.path.relpath(p, REPO) for p in _doc_files()])
def test_no_dangling_relative_links(path):
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(path)
    dangling = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            dangling.append(target)
    assert not dangling, \
        f"{os.path.relpath(path, REPO)}: dangling links {dangling}"
