"""End-to-end tests for the JobSpec/Session facade and the `python -m
repro` CLI: staged trace→plan→execute against workload oracles (GC and
CKKS, streaming plans, memmap storage, multi-worker), plan/run round-trip
through on-disk artifacts with spec-hash validation, process-parallel
planning, and the engine's exception-safe I/O teardown."""

import json
import os

import numpy as np
import pytest

from repro.__main__ import main
from repro.api import (JobSpec, Session, SpecMismatchError, run_job,
                       resolve_plan_config)
from repro.core import Engine, PlanConfig, ProgramFile
from repro.core.bytecode import Instr, Op, Program
from repro.core.engine import ProtocolDriver
from repro.core.storage import MemmapStorage
from repro.core.workers import plan_workers
from repro.workloads import get
from repro.workloads.runner import check_against_oracle


# ---------------------------------------------------------------------------
# Session: staged end-to-end runs against the oracles
# ---------------------------------------------------------------------------


def test_session_gc_streaming_memmap_multiworker(tmp_path):
    spec = JobSpec(workload="merge", n=256, num_workers=2, memory_budget=12,
                   lookahead=50, prefetch_pages=3, plan_mode="streaming",
                   storage="memmap", workdir=str(tmp_path))
    with Session(spec) as s:
        planned = s.plan()
        assert all(isinstance(p, ProgramFile) for p in planned)
        outs = s.execute(check=True)
    check_against_oracle(get("merge"), 256, outs)


def test_session_ckks_streaming_memmap_multiworker():
    spec = JobSpec(workload="rsum", n=32, num_workers=2, memory_budget=8,
                   lookahead=50, prefetch_pages=2, plan_mode="streaming",
                   storage="memmap")
    with Session(spec) as s:
        outs = s.execute(check=True)
    check_against_oracle(get("rsum"), 32, outs)


def test_session_real_two_party():
    outs = run_job(JobSpec(workload="merge", n=64, plan_mode="unbounded"),
                   real=True)
    check_against_oracle(get("merge"), 64, outs)


def test_session_streaming_identical_to_memory_plan():
    """The acceptance criterion: same spec, streaming vs in-memory plan,
    instruction-identical memory programs with the spec hash stamped."""
    kw = dict(workload="sort", n=128, memory_budget=10, lookahead=40,
              prefetch_pages=2)
    with Session(JobSpec(**kw)) as a, \
            Session(JobSpec(plan_mode="streaming", **kw)) as b:
        mem = a.plan()
        memf = b.plan()
        assert list(memf[0].iter_instrs()) == mem[0].instrs
        h = JobSpec(**kw).plan_hash()
        assert mem[0].meta["spec_hash"] == h
        assert memf[0].meta["spec_hash"] == h


def test_fractional_budget_resolution():
    spec = JobSpec(workload="merge", n=1024, memory_budget=0.25,
                   lookahead=100, prefetch_pages=8)
    with Session(spec) as s:
        cfg = resolve_plan_config(spec, s.trace()[0], s.working_set(0))
        ws = s.working_set(0)
        assert 8 + 8 <= cfg.num_frames < ws
        assert cfg.prefetch_pages <= max(cfg.num_frames // 4, 1)
        outs = s.execute(check=True)
        assert outs


def test_simulate_scenarios():
    spec = JobSpec(workload="merge", n=512, memory_budget=0.3,
                   lookahead=100, prefetch_pages=8, track_plan_memory=True)
    from repro.scenarios import OS_PAGE_BYTES, STORAGE, cost_fn
    with Session(spec) as s:
        (sc,) = s.simulate(cost_fn("gc"), model=STORAGE,
                           os_page_bytes=OS_PAGE_BYTES)
    assert sc.unbounded.total > 0
    assert sc.os.total >= sc.unbounded.total
    assert sc.mage.total >= sc.unbounded.total
    assert sc.report.peak_mem_bytes > 0
    assert sc.working_set_pages > sc.config.num_frames


def test_spec_validation():
    with pytest.raises(ValueError, match="plan_mode"):
        JobSpec(workload="merge", plan_mode="bogus")
    with pytest.raises(ValueError, match="memory_budget"):
        JobSpec(workload="merge", plan_mode="memory")
    with pytest.raises(ValueError, match="no memory_budget"):
        JobSpec(workload="merge", plan_mode="unbounded", memory_budget=8)
    with pytest.raises(ValueError, match="fractional"):
        JobSpec(workload="merge", memory_budget=1.5)
    with pytest.raises(KeyError):
        run_job(JobSpec(workload="merge", n=32, plan_mode="unbounded",
                        driver="no-such-driver"))


def test_plan_hash_covers_plan_fields_only():
    a = JobSpec(workload="merge", n=128, memory_budget=10)
    assert a.plan_hash() == JobSpec(workload="merge", n=128, memory_budget=10,
                                    storage="memmap", parallel_plan="thread",
                                    plan_mode="streaming").plan_hash()
    assert a.plan_hash() != JobSpec(workload="merge", n=256,
                                    memory_budget=10).plan_hash()
    assert a.plan_hash() != JobSpec(workload="merge", n=128,
                                    memory_budget=12).plan_hash()
    # n=None resolves to the workload default before hashing
    w = get("merge")
    assert JobSpec(workload="merge", memory_budget=10).plan_hash() == \
        JobSpec(workload="merge", n=w.default_n, memory_budget=10).plan_hash()


# ---------------------------------------------------------------------------
# plan artifacts + CLI round-trip
# ---------------------------------------------------------------------------


def test_save_plan_then_from_plan(tmp_path):
    spec = JobSpec(workload="merge", n=128, num_workers=2, memory_budget=10,
                   lookahead=40, prefetch_pages=2, plan_mode="streaming")
    with Session(spec) as s:
        s.save_plan(tmp_path)
    sess = Session.from_plan(tmp_path, storage="memmap")
    with sess:
        outs = sess.execute(check=True)
    check_against_oracle(get("merge"), 128, outs)


def test_cli_plan_run_roundtrip_and_tamper_rejection(tmp_path, capsys):
    job = tmp_path / "job"
    assert main(["plan", "--workload", "merge", "-n", "128", "--workers",
                 "2", "--budget", "10", "--lookahead", "40", "--prefetch",
                 "2", "--out", str(job)]) == 0
    assert (job / "job.json").exists()
    assert (job / "worker0.memory.bc").exists()
    assert main(["run", str(job), "--check"]) == 0
    assert "oracle check OK" in capsys.readouterr().out

    # tampering with the spec after planning must be rejected
    manifest = json.loads((job / "job.json").read_text())
    manifest["spec"]["n"] = 64
    (job / "job.json").write_text(json.dumps(manifest))
    with pytest.raises(SystemExit) as ei:
        main(["run", str(job), "--check"])
    assert ei.value.code == 2


def test_from_plan_rejects_foreign_program_file(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    for d, n in ((a, 128), (b, 64)):
        with Session(JobSpec(workload="merge", n=n, memory_budget=10,
                             lookahead=40, prefetch_pages=2,
                             plan_mode="streaming")) as s:
            s.save_plan(d)
    # swap a's program file for b's: stamped hash disagrees with job.json
    os.replace(b / "worker0.memory.bc", a / "worker0.memory.bc")
    with pytest.raises(SpecMismatchError, match="artifact and spec"):
        Session.from_plan(a)


def test_cli_bench_tiny_json(tmp_path):
    out = tmp_path / "bench.json"
    assert main(["bench", "--tiny", "--cases", "rsum=64",
                 "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["schema_version"] == 1
    rows = doc["rows"]
    assert rows[0]["workload"] == "rsum"
    assert {"unbounded_s", "os_s", "mage_s", "plan_peak_mb",
            "program_bytes"} <= set(rows[0])
    # --tiny adds a streaming case through the file pipeline
    assert rows[-1]["plan_mode"] == "streaming"


# ---------------------------------------------------------------------------
# process-parallel planning (satellite: dodge the GIL)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("streaming", [False, True])
def test_plan_workers_process_mode(tmp_path, streaming):
    progs = get("merge").trace(128, 2)
    cfg = PlanConfig(num_frames=10, lookahead=40, prefetch_pages=2)
    ser, ser_rep = plan_workers(progs, cfg)
    par, par_rep = plan_workers(progs, cfg, parallel="process",
                                streaming=streaming,
                                workdir=str(tmp_path) if streaming else None)
    for a, b in zip(ser, par):
        got = list(b.iter_instrs()) if streaming else b.instrs
        assert got == a.instrs
    assert [r.replacement for r in ser_rep] == \
        [r.replacement for r in par_rep]


def test_plan_workers_per_worker_configs():
    progs = get("merge").trace(128, 2)
    cfgs = [PlanConfig(num_frames=10, lookahead=40, prefetch_pages=2),
            PlanConfig(num_frames=14, lookahead=40, prefetch_pages=2)]
    planned, _ = plan_workers(progs, cfgs)
    # memory programs carry replacement frames = budget - prefetch buffer
    assert planned[0].num_frames == cfgs[0].replacement_frames == 8
    assert planned[1].num_frames == cfgs[1].replacement_frames == 12
    with pytest.raises(ValueError, match="configs"):
        plan_workers(progs, cfgs[:1])


# ---------------------------------------------------------------------------
# engine teardown (satellite: no leaked AsyncIO threads / open storage)
# ---------------------------------------------------------------------------


class _BoomDriver(ProtocolDriver):
    lane = 1
    dtype = np.uint64

    def execute(self, op, imm, outs, ins):
        raise RuntimeError("boom")


def test_engine_closes_io_on_driver_error():
    prog = Program(instrs=[Instr(Op.INPUT, outs=((0, 4),), imm=(4, 1, 0, 0))],
                   page_shift=2, protocol="gc", vspace_slots=4)
    storage = MemmapStorage((4, 1), np.uint64)
    swap_path = storage.path
    eng = Engine(prog, _BoomDriver(), storage=storage)
    with pytest.raises(RuntimeError, match="boom"):
        eng.run()
    # storage closed (temp swap file unlinked) and I/O pool shut down
    assert not os.path.exists(swap_path)
    assert eng.io.pool._shutdown
