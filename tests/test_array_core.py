"""Record-array planner cores == scalar reference cores, bitwise.

The ``core="array"`` fast paths (replacement's batched residency probe,
scheduling's event-driven block copier) must be invisible in the output:
every policy, both pipelines (in-memory and streaming), and the edge paths
(write-allocate elision, dropped-dirty write-backs, swap-bypass) produce
memory programs whose records_digest matches the scalar core's exactly.
"""

import dataclasses
import os

import numpy as np
import pytest

from test_core_planner import _Driver, _random_program, _run

from repro.core import Engine, PlanConfig, plan, plan_streaming
from repro.core.bytecode import (Instr, Op, Program, encode_chunk,
                                 write_program)
from repro.core.liveness import (file_digest, records_digest,
                                 stripped_touches, touches_from_records,
                                 working_set_pages, working_set_pages_stream)
from repro.core.replacement import plan_replacement, plan_replacement_file
from repro.core.scheduling import plan_schedule, plan_schedule_file
from repro.core.simulator import simulate_os_paging

ALL_POLICIES = ("min", "min_clean", "lru", "fifo")


def _digest_instrs(instrs) -> int:
    return records_digest(0, encode_chunk(instrs), 0)


_digest_file = file_digest


def _swapheavy_program(n=3000, live_pages=128, page_shift=6, seed=3):
    """Whole-page values, round-robin writes: high eviction pressure that
    exercises write-allocate elision AND dropped-dirty write-backs."""
    psize = 1 << page_shift
    rng = np.random.default_rng(seed)
    instrs = [Instr(Op.INPUT, outs=((p * psize, psize),), imm=(p,))
              for p in range(live_pages)]
    for i in range(n - live_pages):
        wp = i % live_pages
        a = int(rng.integers(0, live_pages))
        b = int(rng.integers(0, live_pages))
        instrs.append(Instr(Op.ADD, outs=((wp * psize, psize),),
                            ins=((a * psize, psize), (b * psize, psize))))
    return Program(instrs=instrs, page_shift=page_shift, protocol="gc",
                   vspace_slots=live_pages << page_shift)


# ---------------------------------------------------------------------------
# stage-level equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("seed", (0, 7))
def test_replacement_cores_identical(policy, seed):
    prog = _random_program(seed)
    ps, ss = plan_replacement(prog, 7, policy=policy, core="scalar")
    pa, sa = plan_replacement(prog, 7, policy=policy, core="array",
                              chunk_instrs=23)
    assert pa.instrs == ps.instrs
    assert sa == ss


@pytest.mark.parametrize("swap_bypass", (False, True))
@pytest.mark.parametrize("seed", (0, 7))
def test_schedule_cores_identical(swap_bypass, seed):
    prog = _random_program(seed)
    phys, _ = plan_replacement(prog, 8, core="scalar")
    ms, ss = plan_schedule(phys, 13, 2, swap_bypass=swap_bypass,
                           core="scalar")
    ma, sa = plan_schedule(phys, 13, 2, swap_bypass=swap_bypass,
                           core="array", chunk_instrs=19)
    assert ma.instrs == ms.instrs
    assert sa == ss


def test_file_stage_cores_identical(tmp_path):
    prog = _random_program(11)
    vpf = write_program(prog, tmp_path / "v.bc", strip_free=True,
                        chunk_instrs=9)
    ps, ss = plan_replacement_file(vpf, tmp_path / "ps.bc", 7, core="scalar")
    pa, sa = plan_replacement_file(vpf, tmp_path / "pa.bc", 7, core="array")
    assert _digest_file(pa) == _digest_file(ps)
    assert sa == ss
    ms, sss = plan_schedule_file(ps, tmp_path / "ms.bc", 12, 2,
                                 core="scalar")
    ma, ssa = plan_schedule_file(pa, tmp_path / "ma.bc", 12, 2,
                                 core="array")
    assert _digest_file(ma) == _digest_file(ms)
    assert ssa == sss


# ---------------------------------------------------------------------------
# whole-pipeline equivalence: every policy x {in-memory, streaming}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("streaming", (False, True),
                         ids=("memory", "streaming"))
def test_plan_cores_identical(policy, streaming, tmp_path):
    prog = _random_program(5)
    cfg_s = PlanConfig(num_frames=7, lookahead=11, prefetch_pages=2,
                       policy=policy, swap_bypass=True, core="scalar")
    cfg_a = dataclasses.replace(cfg_s, core="array")
    if streaming:
        mem_s, rep_s = plan_streaming(prog, cfg_s,
                                      workdir=tmp_path / "s",
                                      chunk_instrs=13)
        mem_a, rep_a = plan_streaming(prog, cfg_a,
                                      workdir=tmp_path / "a",
                                      chunk_instrs=13)
        ds, da = _digest_file(mem_s), _digest_file(mem_a)
    else:
        mem_s, rep_s = plan(prog, cfg_s)
        mem_a, rep_a = plan(prog, cfg_a)
        ds, da = _digest_instrs(mem_s.instrs), _digest_instrs(mem_a.instrs)
    assert da == ds
    assert rep_a.replacement == rep_s.replacement
    assert rep_a.schedule == rep_s.schedule


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_cores_identical_on_swapheavy_edge_paths(policy, tmp_path):
    """The elision / dropped-dirty / sync-degrade paths, both pipelines."""
    prog = _swapheavy_program()
    cfg_s = PlanConfig(num_frames=40, lookahead=64, prefetch_pages=4,
                       policy=policy, core="scalar")
    cfg_a = dataclasses.replace(cfg_s, core="array")
    mem_s, rep_s = plan(prog, cfg_s)
    mem_a, rep_a = plan(prog, cfg_a)
    assert _digest_instrs(mem_a.instrs) == _digest_instrs(mem_s.instrs)
    assert rep_a.replacement == rep_s.replacement
    assert rep_a.schedule == rep_s.schedule
    # this trace must actually exercise the edge paths it claims to cover
    assert rep_a.replacement.elided_swap_ins > 0
    assert rep_a.replacement.dropped_dirty > 0
    memf_a, repf_a = plan_streaming(prog, cfg_a, workdir=tmp_path,
                                    chunk_instrs=256)
    assert _digest_file(memf_a) == _digest_instrs(mem_s.instrs)
    assert repf_a.replacement == rep_s.replacement


def test_swap_bypass_path_covered():
    """swap_bypass=True must take the read-from-write-buffer path in both
    cores and still agree."""
    hits = 0
    for seed in range(8):
        prog = _random_program(seed)
        cfg_s = PlanConfig(num_frames=7, lookahead=30, prefetch_pages=2,
                           swap_bypass=True, core="scalar")
        mem_s, rep_s = plan(prog, cfg_s)
        mem_a, rep_a = plan(prog, dataclasses.replace(cfg_s, core="array"))
        assert mem_a.instrs == mem_s.instrs
        assert rep_a.schedule == rep_s.schedule
        hits += rep_a.schedule.bypass_hits
    assert hits > 0, "no seed exercised the bypass path"


def test_array_core_plan_executes_correctly():
    prog = _random_program(21)
    expect = _run(prog)
    mem, _ = plan(prog, PlanConfig(num_frames=6, lookahead=15,
                                   prefetch_pages=2, core="array"))
    d = _Driver()
    Engine(mem, d).run()
    for k, v in expect.items():
        assert np.array_equal(d.outputs[k], v)


def test_custom_policy_falls_back_to_scalar_core():
    from repro.core.replacement import MinPolicy
    prog = _random_program(2)
    pa, _ = plan_replacement(prog, 7, policy=MinPolicy(), core="array")
    ps, _ = plan_replacement(prog, 7, policy="min", core="scalar")
    assert pa.instrs == ps.instrs


def test_bad_core_rejected():
    prog = _random_program(0)
    with pytest.raises(ValueError, match="core"):
        plan_replacement(prog, 7, core="simd")


# ---------------------------------------------------------------------------
# vectorized liveness helpers == scalar reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", (0, 4, 9))
def test_touches_from_records_matches_compute_touches(seed):
    prog = _random_program(seed)
    instrs, t = stripped_touches(prog)
    tv = touches_from_records(encode_chunk(instrs), prog.page_shift,
                              prog.page_slots, chunk_instrs=17)
    assert np.array_equal(tv.offsets, t.offsets)
    assert np.array_equal(tv.pages, t.pages)
    assert np.array_equal(tv.flags, t.flags)
    assert np.array_equal(tv.next_any, t.next_any)
    assert np.array_equal(tv.next_read, t.next_read)
    assert tv.num_pages == t.num_pages


@pytest.mark.parametrize("seed", (0, 4, 9))
def test_working_set_stream_matches_reference(seed):
    prog = _random_program(seed)
    _, t = stripped_touches(prog)
    assert working_set_pages_stream(prog, chunk_instrs=13) == \
        working_set_pages(t)


def test_os_paging_sim_streams_program_files(tmp_path):
    """The §8.2 OS baseline consumes ProgramFile chunks and matches the
    in-memory run exactly."""
    prog = _random_program(13)
    cost = lambda ins: 1e-6  # noqa: E731
    r_mem = simulate_os_paging(prog, cost, 6, 1024, chunk_instrs=11)
    pf = write_program(prog, os.path.join(tmp_path, "v.bc"),
                       strip_free=True)
    r_file = simulate_os_paging(pf, cost, 6, 1024, chunk_instrs=17)
    assert r_file == r_mem
    assert r_mem.reads > 0 or r_mem.writes > 0


# ---------------------------------------------------------------------------
# zero-copy codec
# ---------------------------------------------------------------------------


def test_record_array_codec_zero_copy():
    from repro.core.bytecode import (decode_chunk_array, encode_chunk_array,
                                     pack_row, RECORD_WORDS)
    prog = _random_program(1)
    arr = encode_chunk(stripped_touches(prog)[0])
    rec = decode_chunk_array(arr)
    assert rec.shape == (arr.shape[0],)
    assert np.array_equal(rec["head"], arr[:, 0])
    back = encode_chunk_array(rec)
    assert back.base is rec or back.base is rec.base  # a view, not a copy
    assert np.array_equal(back, arr)
    # pack_row == encode_chunk for an all-int instruction
    ins = Instr(Op.SWAP_IN, outs=((64, 64),), imm=(5,))
    assert pack_row(Op.SWAP_IN, outs=((64, 64),), imm=(5,)) == \
        encode_chunk([ins])[0].tolist()
    with pytest.raises(ValueError):
        decode_chunk_array(np.zeros((3, RECORD_WORDS - 1), dtype=np.int64))
