"""Out-of-core planner pipeline: bytecode file round-trip (property test),
streaming annotation vs in-memory liveness, and instruction-identical
plan() / plan_streaming() output executed by the streaming engine."""

import tempfile
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from test_core_planner import _Driver, _random_program, _run

from repro.core import (Engine, PlanConfig, plan, plan_replacement,
                        plan_streaming)
from repro.core.bytecode import (Instr, Op, ProgramFile,
                                 decode_chunk, encode_chunk, strip_frees,
                                 write_program)
from repro.core.liveness import (AnnotationReader, annotate_next_use,
                                 compute_touches)
from repro.core.replacement import plan_replacement_file
from repro.core.scheduling import plan_schedule, plan_schedule_file
from repro.core.workers import plan_workers


# ---------------------------------------------------------------------------
# file format round-trip
# ---------------------------------------------------------------------------


def _random_instrs(rng, n):
    """Adversarial instruction stream: every arity, negative and huge ints,
    bit-exact floats in imm."""
    ops = [Op.INPUT, Op.ADD, Op.SELECT, Op.MINMAX, Op.SORT_LOCAL, Op.OUTPUT,
           Op.NET_SEND, Op.FREE]
    out = []
    for _ in range(n):
        op = ops[rng.integers(len(ops))]
        span = lambda: (int(rng.integers(0, 1 << 40)),  # noqa: E731
                        int(rng.integers(1, 64)))
        n_outs = int(rng.integers(0, 3))
        n_ins = int(rng.integers(0, 5))
        imm = []
        for _ in range(int(rng.integers(0, 7))):
            if rng.random() < 0.4:
                imm.append(float(rng.normal()) * 2.0 ** int(rng.integers(-60, 60)))
            else:
                imm.append(int(rng.integers(-(1 << 62), 1 << 62)))
        out.append(Instr(op, tuple(span() for _ in range(n_outs)),
                         tuple(span() for _ in range(n_ins)), tuple(imm)))
    return out


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_encode_decode_roundtrip(seed):
    rng = np.random.default_rng(seed)
    instrs = _random_instrs(rng, int(rng.integers(0, 200)))
    assert decode_chunk(encode_chunk(instrs)) == instrs


def test_program_file_roundtrip(tmp_path):
    prog = _random_program(3)
    path = tmp_path / "prog.bc"
    pf = write_program(prog, path, chunk_instrs=7)
    assert list(pf.iter_instrs(5)) == prog.instrs
    assert len(pf) == len(prog.instrs)
    for field in ("page_shift", "protocol", "phase", "worker", "num_workers",
                  "vspace_slots"):
        assert getattr(pf, field) == getattr(prog, field), field
    assert pf.read_program().instrs == prog.instrs
    # reverse chunk iteration covers every record exactly once, backwards
    starts = [s for s, _ in pf.iter_chunks(7, reverse=True)]
    assert starts == list(range(0, len(pf), 7))[::-1]
    rejoined = []
    for _, arr in sorted(pf.iter_chunks(7, reverse=True)):
        rejoined.extend(decode_chunk(arr))
    assert rejoined == prog.instrs


def test_program_file_rejects_garbage(tmp_path):
    p = tmp_path / "bad.bc"
    p.write_bytes(b"definitely not bytecode")
    with pytest.raises(ValueError):
        ProgramFile(p)


def test_encode_rejects_unencodable():
    with pytest.raises(TypeError):
        encode_chunk([Instr(Op.INPUT, imm=("a string",))])
    too_many_ins = Instr(Op.ADD, ins=tuple((i, 1) for i in range(9)))
    with pytest.raises(ValueError):
        encode_chunk([too_many_ins])


# ---------------------------------------------------------------------------
# streaming annotation == in-memory liveness
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_annotation_matches_compute_touches(seed):
    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)
        prog = _random_program(seed)
        instrs = strip_frees(prog.instrs)
        t = compute_touches(prog, instrs)
        pf = write_program(prog, tmp / "p.bc", strip_free=True,
                           chunk_instrs=11)
        info = annotate_next_use(pf, tmp / "p.ann", chunk_instrs=11)
        assert info.num_pages == t.num_pages
        rd = AnnotationReader(tmp / "p.ann")
        flat = []
        for s, arr in rd.iter_chunks(13):
            for r in range(arr.shape[0]):
                for j in range(int(arr[r, 0])):
                    flat.append(tuple(int(arr[r, 1 + 4 * j + c])
                                      for c in range(4)))
        expect = list(zip((int(x) for x in t.pages),
                          (int(x) for x in t.flags),
                          (int(x) for x in t.next_any),
                          (int(x) for x in t.next_read)))
        assert flat == expect


def test_annotation_rejects_free_instrs(tmp_path):
    prog = _random_program(0)
    pf = write_program(prog, tmp_path / "p.bc")  # FREEs kept
    with pytest.raises(ValueError, match="FREE"):
        annotate_next_use(pf, tmp_path / "p.ann")


def test_stale_annotation_sidecar_rejected(tmp_path):
    """A sidecar from a different program must not silently plan garbage —
    caught by the record-count check or the content digest."""
    from repro.core.replacement import plan_replacement_file
    pf = write_program(_random_program(5), tmp_path / "a.bc",
                       strip_free=True)
    other = write_program(_random_program(6), tmp_path / "b.bc",
                          strip_free=True)
    ann = annotate_next_use(other, tmp_path / "b.ann")
    with pytest.raises((ValueError, KeyError)):
        plan_replacement_file(pf, tmp_path / "p.bc", 6,
                              annotations=ann.path)


def test_sidecar_digest_is_chunk_size_independent(tmp_path):
    """A valid sidecar must be accepted even when annotation and
    replacement stream with different chunk sizes."""
    from repro.core.replacement import plan_replacement_file
    prog = _random_program(7)
    pf = write_program(prog, tmp_path / "v.bc", strip_free=True)
    ann = annotate_next_use(pf, tmp_path / "v.ann", chunk_instrs=16)
    physf, _ = plan_replacement_file(pf, tmp_path / "p.bc", 6,
                                     annotations=ann.path, chunk_instrs=8)
    phys, _ = plan_replacement(prog, 6)
    assert list(physf.iter_instrs()) == phys.instrs


# ---------------------------------------------------------------------------
# streaming pipeline == in-memory pipeline, instruction for instruction
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_streaming_plan_identical_to_inmemory(seed):
    with tempfile.TemporaryDirectory() as td:
        prog = _random_program(seed)
        pol = ("min", "min_clean", "lru", "fifo")[seed % 4]
        cfg = PlanConfig(num_frames=6 + seed % 3, lookahead=5 + seed % 30,
                         prefetch_pages=1 + seed % 3, policy=pol,
                         swap_bypass=bool(seed & 1))
        mem, rep = plan(prog, cfg)
        memf, repf = plan_streaming(prog, cfg, workdir=td, chunk_instrs=13)
        assert list(memf.iter_instrs()) == mem.instrs
        assert rep.replacement == repf.replacement
        assert rep.schedule == repf.schedule
        assert memf.num_frames == mem.num_frames
        assert memf.prefetch_slots == mem.prefetch_slots
        assert memf.meta == mem.meta


def test_streaming_stage_wrappers_identical(tmp_path):
    prog = _random_program(11)
    vpf = write_program(prog, tmp_path / "v.bc", strip_free=True,
                        chunk_instrs=9)
    phys, rs = plan_replacement(prog, 7)
    physf, rsf = plan_replacement_file(vpf, tmp_path / "p.bc", 7,
                                       chunk_instrs=9)
    assert list(physf.iter_instrs()) == phys.instrs
    assert rs == rsf
    mem, ss = plan_schedule(phys, 12, 2)
    memf, ssf = plan_schedule_file(physf, tmp_path / "m.bc", 12, 2)
    assert list(memf.iter_instrs()) == mem.instrs
    assert ss == ssf
    # degenerate B=0 path keeps sync directives in both modes
    mem0, _ = plan_schedule(phys, 12, 0)
    memf0, _ = plan_schedule_file(physf, tmp_path / "m0.bc", 12, 0)
    assert list(memf0.iter_instrs()) == mem0.instrs
    assert memf0.prefetch_slots == mem0.prefetch_slots == 0


# ---------------------------------------------------------------------------
# streaming engine executes the memory program straight from its file
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_streaming_engine_matches_inmemory(seed):
    with tempfile.TemporaryDirectory() as td:
        prog = _random_program(seed)
        expect = _run(prog)
        cfg = PlanConfig(num_frames=6, lookahead=15, prefetch_pages=2)
        memf, _ = plan_streaming(prog, cfg, workdir=td)
        d = _Driver()
        Engine(memf, d).run()
        for k, v in expect.items():
            assert np.array_equal(d.outputs[k], v)


def test_streaming_engine_memmap_roundtrip(tmp_path):
    prog = _random_program(42)
    expect = _run(prog)
    memf, _ = plan_streaming(prog, PlanConfig(num_frames=5, lookahead=10,
                                              prefetch_pages=2),
                             workdir=tmp_path)
    d = _Driver()
    Engine(memf, d, use_memmap=True).run()
    for k, v in expect.items():
        assert np.array_equal(d.outputs[k], v)


# ---------------------------------------------------------------------------
# per-worker parallel planning
# ---------------------------------------------------------------------------


def test_plan_workers_parallel_and_streaming(tmp_path):
    progs = [_random_program(s) for s in (1, 2, 3)]
    cfg = PlanConfig(num_frames=6, lookahead=15, prefetch_pages=2)
    seq, _ = plan_workers(progs, cfg)
    par, _ = plan_workers(progs, cfg, parallel=True)
    for a, b in zip(seq, par):
        assert a.instrs == b.instrs
    strm, _ = plan_workers(progs, cfg, parallel=True, streaming=True,
                           workdir=str(tmp_path))
    for a, f in zip(seq, strm):
        assert isinstance(f, ProgramFile)
        assert list(f.iter_instrs()) == a.instrs
