"""Vectorized GC trace builders (src/repro/workloads/fast_trace.py):
the NumPy record emitters for merge / sort / mvmul must be digest-
identical to the FREE-stripped DSL trace, and the streamed program
files must decode to the same instructions with the same vspace."""

from __future__ import annotations

import hashlib

import numpy as np
import pytest

from repro.core.bytecode import encode_chunk, strip_frees
from repro.workloads import get
from repro.workloads.fast_trace import (build_merge_records,
                                        build_mvmul_records,
                                        build_sort_records,
                                        write_merge_program,
                                        write_mvmul_program,
                                        write_sort_program)

BUILDERS = {"merge": build_merge_records, "sort": build_sort_records,
            "mvmul": build_mvmul_records}
WRITERS = {"merge": write_merge_program, "sort": write_sort_program,
           "mvmul": write_mvmul_program}


def _dsl_records(name: str, n: int) -> np.ndarray:
    prog = get(name).trace(n)[0]
    return encode_chunk(strip_frees(prog.instrs))


@pytest.mark.parametrize("name,n", [
    ("merge", 32), ("merge", 64), ("merge", 256), ("merge", 1024),
    ("sort", 32), ("sort", 128), ("sort", 512),
    ("mvmul", 16), ("mvmul", 64), ("mvmul", 128),
])
def test_vectorized_builder_digest_identical_to_dsl(name, n):
    dsl = _dsl_records(name, n)
    fast = BUILDERS[name](n)
    assert dsl.shape == fast.shape
    assert np.array_equal(dsl, fast), \
        f"{name} n={n}: vectorized records diverge from the DSL trace"
    assert hashlib.sha256(dsl.tobytes()).digest() == \
        hashlib.sha256(fast.tobytes()).digest()


@pytest.mark.parametrize("name,n", [("merge", 128), ("sort", 64),
                                    ("mvmul", 32)])
def test_streamed_program_file_matches_dsl(tmp_path, name, n):
    pf = WRITERS[name](tmp_path / f"{name}.bc", n)
    prog = get(name).trace(n)[0]
    assert list(pf.iter_instrs()) == strip_frees(prog.instrs)
    assert pf.vspace_slots == prog.vspace_slots
    assert pf.meta["workload"] == name


@pytest.mark.parametrize("name,n", [
    ("merge", 48),     # 2n/C not a power of two
    ("merge", 33),     # not a chunk multiple
    ("sort", 96),      # n/C not a power of two
    ("sort", 0),
    ("mvmul", 24),     # not a block multiple
])
def test_builders_reject_bad_sizes(name, n):
    with pytest.raises(ValueError):
        BUILDERS[name](n)
