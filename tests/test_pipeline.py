"""GPipe pipeline parallelism over the pod axis: loss equivalence with the
plain forward + end-to-end differentiability.  Subprocess-isolated because
the 4-device host platform flag must precede jax init."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.models import init_lm, lm_loss
from repro.distributed.pipeline import pipeline_loss, split_stage_params
from repro.distributed.sharding import rules_for

cfg = dataclasses.replace(reduced_config("stablelm-3b"), n_layers=4)
params = init_lm(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
ref = float(lm_loss(params, toks, cfg, aux_weight=0.0)[0])
mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
rules = rules_for(cfg, mesh)
staged = split_stage_params(params, n_stages=2)
with mesh:
    loss = float(pipeline_loss(staged, toks, cfg, mesh, n_micro=2,
                               rules=rules))
    assert abs(loss - ref) / ref < 2e-2, (loss, ref)
    g = jax.grad(lambda p: pipeline_loss(p, toks, cfg, mesh, n_micro=2,
                                         rules=rules))(staged)
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
             for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
print("PIPELINE_OK", loss, ref)
"""


@pytest.mark.slow
def test_pipeline_loss_matches_and_differentiates():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=500)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PIPELINE_OK" in r.stdout
