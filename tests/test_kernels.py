"""Pallas kernels (interpret mode) vs pure-jnp oracles vs the numpy protocol
implementations — shape/dtype sweeps + truth tables."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.garble import kernel as gk, ops as gops, ref as gref
from repro.kernels.ntt import ops as nops, ref as nref
from repro.kernels.paged_attn import ops as pops, ref as pref
from repro.protocols.ckks import ntt as npntt
from repro.protocols.ckks.params import gen_primes
from repro.protocols.garbled import aes as npaes


# ---------------------------------------------------------------------------
# garble kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,block", [(32, 16), (64, 32), (128, 64)])
def test_garble_kernel_matches_ref_and_numpy(m, block):
    rng = np.random.default_rng(m)
    a64 = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    b64 = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    r64 = rng.integers(0, 2**63, 2, dtype=np.uint64)
    r64[0] |= 1
    a32, b32 = gops.u64_to_u32(a64), gops.u64_to_u32(b64)
    r32 = gops.u64_to_u32(r64.reshape(1, 2))[0]
    c_ref, t_ref = gref.garble_and(jnp.asarray(a32), jnp.asarray(b32),
                                   jnp.asarray(r32), 10)
    c_k, t_k = gk.garble_and_pallas(jnp.asarray(a32), jnp.asarray(b32),
                                    jnp.asarray(r32), jnp.int32(10),
                                    interpret=True, block_m=block)
    assert np.array_equal(np.asarray(c_ref), np.asarray(c_k))
    assert np.array_equal(np.asarray(t_ref), np.asarray(t_k))
    # jnp ref hash == numpy protocol hash (independent implementations)
    h_ref = gref.hash_labels(jnp.asarray(a32),
                             jnp.arange(m, dtype=jnp.int32))
    h_np = npaes.hash_labels(a64, np.arange(m, dtype=np.int64))
    assert np.array_equal(gops.u32_to_u64(np.asarray(h_ref)), h_np)


@pytest.mark.parametrize("bit_a,bit_b", [(0, 0), (0, 1), (1, 0), (1, 1)])
def test_garble_eval_kernel_truth_table(bit_a, bit_b):
    rng = np.random.default_rng(bit_a * 2 + bit_b)
    m = 32
    a64 = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    b64 = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    r64 = rng.integers(0, 2**63, 2, dtype=np.uint64)
    r64[0] |= 1
    c0, tab = gops.garble_and(a64, b64, r64, 0, block_m=16)
    wa = a64 ^ (r64[None] * np.uint64(bit_a))
    wb = b64 ^ (r64[None] * np.uint64(bit_b))
    wc = gops.eval_and(wa, wb, tab, 0, block_m=16)
    expect = c0 ^ (r64[None] * np.uint64(bit_a & bit_b))
    assert np.array_equal(wc, expect)


def test_garble_ops_match_driver_gates():
    from repro.protocols.garbled.gates import GarblerGates, PartyChannel
    ch = PartyChannel()
    g = GarblerGates(ch, seed=9)
    m = 64
    A0, B0 = g._fresh(m), g._fresh(m)
    C0 = g.and_(A0.copy(), B0.copy())
    tab = ch.recv("tab")
    c_ops, t_ops = gops.garble_and(A0, B0, g.R, 0, block_m=32)
    assert np.array_equal(c_ops, C0)
    assert np.array_equal(t_ops, tab)


# ---------------------------------------------------------------------------
# ntt kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,bits", [(64, 25), (64, 29), (256, 25),
                                    (256, 29), (512, 28)])
def test_ntt_kernel_sweep(n, bits):
    q = gen_primes(n, [bits])[0]
    rng = np.random.default_rng(n + bits)
    a = rng.integers(0, q, (8, n), dtype=np.uint64)
    b = rng.integers(0, q, (8, n), dtype=np.uint64)
    f_np = npntt.ntt_forward(a, q)
    assert np.array_equal(nops.ntt_forward(a, q), f_np)
    assert np.array_equal(nops.ntt_inverse(f_np, q), a)
    c_k = nops.negacyclic_mul(a, b, q)
    c_np = np.stack([npntt.negacyclic_mul(a[i], b[i], q) for i in range(8)])
    assert np.array_equal(c_k, c_np)


def test_ntt_ref_matches_numpy():
    n, q = 128, gen_primes(128, [29])[0]
    rng = np.random.default_rng(0)
    a = rng.integers(0, q, (4, n), dtype=np.uint64)
    psis, psis_inv, n_inv = npntt.ntt_tables(q, n)
    f = nref.ntt_forward(a, q, psis)
    assert np.array_equal(np.asarray(f), npntt.ntt_forward(a, q))
    back = nref.ntt_inverse(np.asarray(f), q, psis_inv, int(n_inv))
    assert np.array_equal(np.asarray(back), a)


def test_ntt_barrett_guard():
    with pytest.raises(AssertionError):
        from repro.kernels.ntt.kernel import _barrett_consts
        _barrett_consts((1 << 30) + 1)


# ---------------------------------------------------------------------------
# paged attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch,qh,kvh,hd,psz,mp", [
    (2, 8, 2, 64, 16, 4), (3, 4, 4, 32, 8, 3), (1, 16, 8, 128, 32, 2),
    (4, 2, 1, 64, 8, 5)])
def test_paged_attention_sweep(batch, qh, kvh, hd, psz, mp):
    rng = np.random.default_rng(batch * 100 + qh)
    num_pages = batch * mp + 2
    q = rng.normal(0, 1, (batch, qh, hd)).astype(np.float32)
    kp = rng.normal(0, 1, (num_pages, psz, kvh, hd)).astype(np.float32)
    vp = rng.normal(0, 1, (num_pages, psz, kvh, hd)).astype(np.float32)
    bt = rng.permutation(num_pages)[:batch * mp].reshape(batch, mp) \
        .astype(np.int32)
    sl = rng.integers(1, mp * psz + 1, batch).astype(np.int32)
    out_ref = np.asarray(pref.paged_decode_attention(q, kp, vp, bt, sl))
    out_k = np.asarray(pops.paged_decode_attention(q, kp, vp, bt, sl,
                                                   use_kernel=True))
    np.testing.assert_allclose(out_k, out_ref, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# odd shapes: the batched exec drivers hand the ops whatever group sizes
# the schedule produced — singletons, empty tails, non-block multiples
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [0, 1, 37])
def test_garble_ops_odd_batch(m):
    rng = np.random.default_rng(m)
    a = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    b = rng.integers(0, 2**63, (m, 2), dtype=np.uint64)
    r = rng.integers(0, 2**63, 2, dtype=np.uint64)
    r[0] |= 1
    c0, tab = gops.garble_and(a, b, r, 5, block_m=16)
    assert c0.shape == (m, 2) and tab.shape == (m, 4)
    # evaluate all four truth-table rows against the garbler's c0
    for bit_a in (0, 1):
        for bit_b in (0, 1):
            wa = a ^ (r[None] * np.uint64(bit_a))
            wb = b ^ (r[None] * np.uint64(bit_b))
            wc = gops.eval_and(wa, wb, tab, 5, block_m=16)
            assert np.array_equal(
                wc, c0 ^ (r[None] * np.uint64(bit_a & bit_b)))


@pytest.mark.parametrize("k", [1, 3, 5])
def test_ntt_odd_batch(k):
    q = gen_primes(64, [29])[0]
    rng = np.random.default_rng(k)
    a = rng.integers(0, q, (k, 64), dtype=np.uint64)
    b = rng.integers(0, q, (k, 64), dtype=np.uint64)
    f = nops.ntt_forward(a, q)
    assert np.array_equal(nops.ntt_inverse(f, q), a)
    c = nops.negacyclic_mul(a, b, q)
    c_np = np.stack([npntt.negacyclic_mul(a[i], b[i], q) for i in range(k)])
    assert np.array_equal(c, c_np)


def test_paged_attention_single_query():
    rng = np.random.default_rng(7)
    qh, kvh, hd, psz = 4, 2, 32, 8
    q = rng.normal(0, 1, (1, qh, hd)).astype(np.float32)
    kp = rng.normal(0, 1, (2, psz, kvh, hd)).astype(np.float32)
    vp = rng.normal(0, 1, (2, psz, kvh, hd)).astype(np.float32)
    bt = np.array([[0, 1]], dtype=np.int32)
    sl = np.array([3], dtype=np.int32)   # ragged: mid-page sequence end
    out_ref = np.asarray(pref.paged_decode_attention(q, kp, vp, bt, sl))
    out_k = np.asarray(pops.paged_decode_attention(q, kp, vp, bt, sl,
                                                   use_kernel=True))
    np.testing.assert_allclose(out_k, out_ref, rtol=1e-4, atol=1e-5)


def test_paged_attention_bf16():
    rng = np.random.default_rng(0)
    batch, qh, kvh, hd, psz, mp = 2, 4, 2, 64, 16, 3
    num_pages = batch * mp
    q = rng.normal(0, 1, (batch, qh, hd)).astype(np.float32)
    kp = jnp.asarray(rng.normal(0, 1, (num_pages, psz, kvh, hd)),
                     dtype=jnp.bfloat16)
    vp = jnp.asarray(rng.normal(0, 1, (num_pages, psz, kvh, hd)),
                     dtype=jnp.bfloat16)
    bt = np.arange(num_pages).reshape(batch, mp).astype(np.int32)
    sl = np.full(batch, mp * psz, dtype=np.int32)
    out_ref = np.asarray(pref.paged_decode_attention(
        np.asarray(q), np.asarray(kp, dtype=np.float32),
        np.asarray(vp, dtype=np.float32), bt, sl))
    out_k = np.asarray(pops.paged_decode_attention(q, kp, vp, bt, sl))
    np.testing.assert_allclose(out_k, out_ref, rtol=2e-2, atol=2e-2)
