"""Optional-`hypothesis` shim so the suite collects and runs offline.

When `hypothesis` is installed (CI), this module re-exports the real
`given` / `settings` / `strategies`.  When it is not (air-gapped dev
boxes, minimal containers), a small deterministic fallback runs each
property test over seeded-random draws plus the strategy's boundary
values.  It intentionally supports only what the suite uses
(`st.integers(lo, hi)`, `@settings(max_examples=..., deadline=...)`) —
extend it if a test needs more, or install hypothesis.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = min_value
            self.max_value = max_value

        def boundary(self) -> list[int]:
            vals = {self.min_value, self.max_value,
                    min(self.min_value + 1, self.max_value)}
            return sorted(vals)

        def draw(self, rng: random.Random) -> int:
            return rng.randint(self.min_value, self.max_value)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int = 0, max_value: int = 2**63 - 1
                     ) -> _Integers:
            return _Integers(min_value, max_value)

    _DEFAULT_EXAMPLES = 20

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Integers):
        def deco(fn):
            # like hypothesis, positional strategies fill the test's
            # RIGHTMOST parameters; anything to their left stays visible to
            # pytest (fixtures)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep, covered = params[:len(params) - len(strats)], \
                [p.name for p in params[len(params) - len(strats):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_EXAMPLES)
                # deterministic per-test seed (hash() is randomized per run)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                examples: list[tuple[int, ...]] = []
                if strats:
                    bounds = [s.boundary() for s in strats]
                    examples.append(tuple(b[0] for b in bounds))
                    examples.append(tuple(b[-1] for b in bounds))
                while len(examples) < n:
                    examples.append(tuple(s.draw(rng) for s in strats))
                for ex in examples[:n]:
                    fn(*args, **kwargs, **dict(zip(covered, ex)))

            # stop pytest treating the strategy-filled params as fixtures
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
