"""Garbled circuits: AES vectors, half-gates truth table, engine-ops vs
plaintext oracle (hypothesis), cost-model exactness, two-party runs."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import Engine, trace
from repro.protocols.garbled import aes
from repro.protocols.garbled.cost import gate_cost
from repro.protocols.garbled.dsl import Integer, Party
from repro.protocols.garbled.driver import PlaintextDriver, run_two_party
from repro.protocols.garbled.gates import (EvaluatorGates, GarblerGates,
                                           PartyChannel)
from repro.core.bytecode import Op


def test_aes_fips197_vector():
    key = np.frombuffer(bytes(range(16)), dtype=np.uint8).copy()
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       dtype=np.uint8).copy()
    ct = aes.aes128_encrypt_blocks(pt[None, :], aes.key_schedule(key))[0]
    assert ct.tobytes().hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"


def test_gf128_double_known():
    one = np.array([[1, 0]], dtype=np.uint64)
    assert np.array_equal(aes.gf128_double(one), [[2, 0]])
    top = np.array([[0, 1 << 63]], dtype=np.uint64)
    assert np.array_equal(aes.gf128_double(top), [[0x87, 0]])


@pytest.mark.parametrize("bit_a", [0, 1])
@pytest.mark.parametrize("bit_b", [0, 1])
def test_half_gates_truth_table(bit_a, bit_b):
    ch = PartyChannel()
    g = GarblerGates(ch, seed=3)
    e = EvaluatorGates(ch)
    m = 17
    a0, b0 = g._fresh(m), g._fresh(m)
    c0 = g.and_(a0, b0)
    wa = a0 ^ (g.R[None, :] * np.uint64(bit_a))
    wb = b0 ^ (g.R[None, :] * np.uint64(bit_b))
    wc = e.and_(wa, wb)
    expect = c0 ^ (g.R[None, :] * np.uint64(bit_a & bit_b))
    assert np.array_equal(wc, expect)


def _run_two_party_program(program, g_in, e_in, page_shift=12):
    prog = trace(program, protocol="gc", page_shift=page_shift)
    pd = PlaintextDriver(lambda t: g_in(t) if g_in(t) is not None else None)

    def provider(tag):
        v = g_in(tag)
        return v if v is not None else e_in(tag)
    pd = PlaintextDriver(provider)
    Engine(prog, pd).run()
    outs = run_two_party(prog, prog, g_in, e_in)
    return pd.outputs, outs


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_int_ops_match_plaintext(a, b):
    av = np.array([a], dtype=np.uint64)
    bv = np.array([b], dtype=np.uint64)

    def program():
        x = Integer(32, 1).mark_input(Party.Garbler, 0)
        y = Integer(32, 1).mark_input(Party.Evaluator, 1)
        (x + y).mark_output(0)
        (x - y).mark_output(1)
        (x * y).mark_output(2)
        x.cmp_ge(y).mark_output(3)
        x.cmp_eq(y).mark_output(4)
        (x ^ y).mark_output(5)
        (x & y).mark_output(6)
        (x | y).mark_output(7)
        (~x).mark_output(8)

    exp, got = _run_two_party_program(
        program, lambda t: av if t == 0 else None,
        lambda t: bv if t == 1 else None)
    for k in exp:
        assert np.array_equal(got[k], exp[k]), k


def test_gate_cost_formulas_match_counters():
    """The analytic AND counts priced by the simulator must equal the
    batcher's actual counters for every op the workloads use."""
    cases = []

    def program():
        a = Integer(32, 8).mark_input(Party.Garbler, 0)
        b = Integer(32, 8).mark_input(Party.Evaluator, 1)
        cases.append((a + b, Op.ADD))
        cases.append((a - b, Op.SUB))
        cases.append((a * b, Op.MUL))
        cases.append((a.cmp_ge(b), Op.CMP_GE))
        cases.append((a.cmp_eq(b), Op.CMP_EQ))
        mn, mx = a.minmax(b, 32)
        s = a.sort_local(32)
        j = a.pair_join(b, 32)
        r = a.reduce_add()
        for v, t in [(mn, 100), (mx, 101), (s, 102), (j, 103), (r, 104)]:
            v.mark_output(t)
        for i, (v, _) in enumerate(cases):
            v.mark_output(i)

    prog = trace(program, protocol="gc", page_shift=13)

    class _Sink:
        def send(self, kind, arr):
            pass
    from repro.protocols.garbled.driver import GarblerDriver, _GCDriverBase
    g = GarblerGates(_Sink(), seed=1)
    d = GarblerDriver.__new__(GarblerDriver)
    _GCDriverBase.__init__(d, g, lambda t: np.zeros(8, dtype=np.uint64))
    prev = 0
    for ins in prog.instrs:
        if ins.op == Op.FREE:
            continue
        before = g.counts.ands
        views_out = [np.zeros((s[1], 2), np.uint64) for s in ins.outs]
        views_in = [np.zeros((s[1], 2), np.uint64) for s in ins.ins]
        d.execute(ins.op, ins.imm, views_out, views_in)
        actual = g.counts.ands - before
        formula, _ = gate_cost(ins.op, ins.imm)
        assert actual == formula, (ins.op.name, ins.imm, actual, formula)


def test_two_party_minmax_sort_reverse():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 31, 8, dtype=np.uint64)
    b = rng.integers(0, 1 << 31, 8, dtype=np.uint64)

    def program():
        x = Integer(128, 8).mark_input(Party.Garbler, 0)
        y = Integer(128, 8).mark_input(Party.Evaluator, 1)
        mn, mx = x.minmax(y, 32)
        mn.mark_output(0)
        mx.mark_output(1)
        x.sort_local(32).mark_output(2)
        x.sort_local(32, descending=True).mark_output(3)
        x.reverse().mark_output(4)
        x.sort_local(32, merge_only=False).mark_output(5)

    exp, got = _run_two_party_program(
        program, lambda t: a if t == 0 else None,
        lambda t: b if t == 1 else None, page_shift=12)
    for k in exp:
        assert np.array_equal(got[k], exp[k]), k
