"""Per-architecture smoke tests (assignment requirement): every arch's
REDUCED config runs one forward/train step on CPU with correct shapes and
no NaNs; prefill+decode agree with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, reduced_config
from repro.models import init_lm, lm_decode, lm_forward, lm_prefill
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, make_train_state, train_step


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    b, s = 2, 32
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            rng, (b, s, cfg.d_model), dtype=jnp.float32
        ).astype(jnp.bfloat16)
    params, opt = make_train_state(rng, cfg)
    tcfg = TrainConfig(microbatches=2, opt=OptConfig(peak_lr=1e-3,
                                                     warmup_steps=2,
                                                     stable_steps=2,
                                                     decay_steps=2))
    step = jax.jit(lambda p, o, bt: train_step(p, o, bt, cfg, tcfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[1]
    l1 = jax.tree_util.tree_leaves(params2)[1]
    assert l0.shape == l1.shape
    # forward logits shape
    if not cfg.is_encdec:
        logits, aux = lm_forward(params, tokens, cfg, remat=False)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-7b", "xlstm-1.3b",
                                  "deepseek-moe-16b"])
def test_prefill_decode_consistency(arch):
    import dataclasses
    cfg = reduced_config(arch)
    if cfg.moe:
        # capacity-based routing is batch-dependent (drops differ between a
        # 16-token forward and a 15-token prefill); serving configs raise
        # the capacity factor so no tokens drop
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_lm(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                              cfg.vocab_size)
    logits_full, _ = lm_forward(params, toks, cfg, remat=False)
    lg_pre, caches = lm_prefill(params, toks[:, :-1], cfg, max_seq=32)
    clen = jnp.full((2,), 15, dtype=jnp.int32)
    lg_dec, _ = lm_decode(params, toks[:, 15:16], caches, clen, cfg)
    a = np.asarray(logits_full[:, 14])
    b = np.asarray(lg_pre[:, 0])
    c = np.asarray(logits_full[:, 15])
    d = np.asarray(lg_dec[:, 0])
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-6) < 3e-2
    assert np.abs(c - d).max() / (np.abs(c).max() + 1e-6) < 8e-2


def test_shape_applicability_rules():
    cells = {a: applicable_shapes(get_config(a)) for a in ARCHS}
    # long_500k only for sub-quadratic families
    assert "long_500k" in cells["zamba2-7b"]
    assert "long_500k" in cells["xlstm-1.3b"]
    for a in ARCHS:
        if a not in ("zamba2-7b", "xlstm-1.3b"):
            assert "long_500k" not in cells[a], a
    total = sum(len(v) for v in cells.values())
    assert total == 32  # 10 archs x 3 + 2 long_500k


def test_chunked_sdpa_matches_dense():
    from repro.models.layers import _sdpa, _sdpa_chunked
    cfg = reduced_config("qwen2-1.5b")
    rng = np.random.default_rng(0)
    b, sq, nh, hd, nkv = 2, 640, 4, 32, 2   # non-divisible by blocks
    q = jnp.asarray(rng.normal(0, 1, (b, sq, nh, hd)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, sq, nkv, hd)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, sq, nkv, hd)), dtype=jnp.float32)
    for off in (0, None):
        d1 = np.asarray(_sdpa(q, k, v, cfg, off))
        d2 = np.asarray(_sdpa_chunked(q, k, v, cfg, off, q_block=128,
                                      kv_block=256))
        np.testing.assert_allclose(d1, d2, rtol=2e-4, atol=2e-5)


def test_chunkwise_mlstm_matches_quadratic():
    from repro.models.xlstm import init_mlstm, mlstm_block, \
        mlstm_block_chunked
    cfg = reduced_config("xlstm-1.3b")
    p = init_mlstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, cfg.d_model),
                          dtype=jnp.float32).astype(jnp.bfloat16)
    y1 = np.asarray(mlstm_block(p, x, cfg), dtype=np.float32)
    y2 = np.asarray(mlstm_block_chunked(p, x, cfg, chunk=32),
                    dtype=np.float32)
    assert np.abs(y1 - y2).max() / (np.abs(y1).max() + 1e-9) < 3e-2


def test_moe_aux_loss_and_routing():
    cfg = reduced_config("deepseek-moe-16b")
    from repro.models.moe import init_moe, moe_mlp
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          dtype=jnp.bfloat16)
    out, aux = moe_mlp(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz at balance
    assert np.isfinite(np.asarray(out, dtype=np.float32)).all()
