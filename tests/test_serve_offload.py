"""Serving (paged KV + batcher) and the jaxpr offload planner."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.offload import jaxpr_trace, plan_offload
from repro.serve.paged_kv import decode_kv_trace, plan_kv_schedule
from repro.serve.serve_step import Batcher, Request


def test_paged_kv_schedule_plans_under_budget():
    mem, rep = plan_kv_schedule(total_tokens=512, page_size=16,
                                hbm_pages=12, lookahead=8, prefetch=3)
    assert rep.replacement.swap_ins > 0
    assert rep.schedule.prefetched > 0
    # the trace itself is oblivious: same inputs -> identical program
    mem2, rep2 = plan_kv_schedule(total_tokens=512, page_size=16,
                                  hbm_pages=12, lookahead=8, prefetch=3)
    assert [i.op for i in mem.instrs] == [i.op for i in mem2.instrs]


def test_kv_trace_structure():
    prog = decode_kv_trace(64, 16)
    # 4 windows: writes 1 page each; reads 0+1+2+3 pages
    writes = sum(1 for i in prog.instrs if i.outs)
    reads = sum(len(i.ins) for i in prog.instrs if not i.outs)
    assert writes == 4 and reads == 0 + 1 + 2 + 3


def test_batcher_continuous():
    b = Batcher(2)
    for i in range(5):
        b.submit(Request(rid=i, prompt=np.arange(4), max_new=2))
    placed = b.fill()
    assert len(placed) == 2
    b.retire(0)
    placed = b.fill()
    assert placed and placed[0][0] == 0
    assert b.busy()


def test_offload_planner_respects_budget_and_finds_peak():
    def fn(x, w1, w2, w3):
        a = x @ w1
        b = jax.nn.relu(a)
        c = b @ w2
        d = jax.nn.relu(c)
        e = d @ w3
        return (a * 0).sum() + e.sum() + (b * 0).sum()

    x = jnp.zeros((128, 256))
    ws = [jnp.zeros((256, 256)) for _ in range(3)]
    tr = jaxpr_trace(fn, x, *ws)
    assert tr.sizes and tr.reads
    unbounded = plan_offload(tr, budget_bytes=1 << 40)
    assert unbounded.bytes_out == 0 and unbounded.feasible
    tight = plan_offload(tr, budget_bytes=2 * unbounded.peak_unbounded // 3)
    assert tight.feasible
    assert tight.bytes_out > 0 and tight.bytes_in > 0
    # belady: offload traffic bounded by total buffer bytes
    assert tight.bytes_out <= sum(tr.sizes)


def test_offload_planner_on_model_grad():
    """The planner consumes a real train-step jaxpr (reduced model)."""
    from repro.configs import reduced_config
    from repro.models import init_lm, lm_loss
    cfg = reduced_config("stablelm-3b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 16), dtype=jnp.int32)

    def loss(p):
        return lm_loss(p, toks, cfg)[0]

    grad = jax.grad(loss)
    tr = jaxpr_trace(grad, params)
    plan = plan_offload(tr, budget_bytes=1 << 40)
    assert plan.peak_unbounded > 0
    half = plan_offload(tr, budget_bytes=max(plan.peak_unbounded // 2, 1))
    assert half.est_overhead(compute_s=1.0) >= 0.0
