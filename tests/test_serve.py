"""Serving daemon + artifact cache + admission control (docs/SERVE.md).

Covers the acceptance claims: a cache hit returns bitwise-identical
artifacts with zero tracing/planning (daemon counters), tampered entries
are rejected and transparently re-planned, LRU eviction respects the
size cap, concurrent admissions never exceed the frame pool, and the
stable ``repro.*`` public surface resolves."""

import json
import os
import threading
import time

import numpy as np
import pytest

import repro
from repro.__main__ import main
from repro.api import JobSpec, Session, estimate_job_resources
from repro.serve_daemon.admission import AdmissionController, AdmissionError
from repro.serve_daemon.cache import ArtifactCache
from repro.serve_daemon.client import ServeError, serve_client
from repro.serve_daemon.server import ServeDaemon, program_digest

SPEC = JobSpec(workload="merge", n=1024, memory_budget=24,
               plan_mode="streaming")


# ---------------------------------------------------------------------------
# artifact cache via the Session facade
# ---------------------------------------------------------------------------


def test_cache_hit_identical_digests(tmp_path):
    cache = ArtifactCache(tmp_path / "cache")
    with Session(SPEC, cache=cache) as s:
        cold = [program_digest(p) for p in s.plan()]
        assert s.cache_events == {"trace": "miss", "plan": "miss"}
    with Session(SPEC, cache=cache) as s:
        hot = [program_digest(p) for p in s.plan()]
        assert s.cache_events == {"plan": "hit"}     # no trace needed at all
        # the resolved configs + reports are restored, so simulate() works
        assert s._cfgs[0].num_frames == 24
        assert s.plan_reports[0].replacement is not None
    assert hot == cold
    assert cache.stats.plan_hits == 1 and cache.stats.invalid == 0


def test_cache_hit_execute_matches_cold(tmp_path):
    with Session(SPEC, cache=tmp_path / "c") as s:
        cold = s.execute()
    with Session(SPEC, cache=tmp_path / "c") as s:
        hot = s.execute()
        assert s.cache_events == {"plan": "hit"}
    assert sorted(cold) == sorted(hot)
    for tag in cold:
        np.testing.assert_array_equal(cold[tag], hot[tag])


def test_trace_cache_shared_across_budgets(tmp_path):
    """The trace entry is keyed by shape only: a different budget re-plans
    but serves the traced bytecode (and sidecar) from the cache."""
    cache = ArtifactCache(tmp_path / "cache")
    with Session(SPEC, cache=cache) as s:
        s.plan()
    other = JobSpec(workload="merge", n=1024, memory_budget=12,
                    plan_mode="streaming")
    assert other.trace_hash() == SPEC.trace_hash()
    assert other.plan_hash() != SPEC.plan_hash()
    with Session(other, cache=cache) as s:
        s.plan()
        assert s.cache_events == {"trace": "hit", "plan": "miss"}
        # the cached sidecar is reused: no annotation pass was run
        assert s.plan_reports[0].annotate_s == 0.0
    assert cache.stats.trace_hits == 1


def test_trace_cache_dir_standalone(tmp_path):
    """Session.trace(cache_dir=...) alone caches the traced bytecode."""
    with Session(SPEC) as s:
        progs = s.trace(cache_dir=tmp_path / "c")
        n_instrs = len(progs[0])
    with Session(SPEC) as s:
        progs = s.trace(cache_dir=tmp_path / "c")
        assert s.cache_events == {"trace": "hit"}
        assert len(progs[0]) == n_instrs
        # adopted cache files are restamped with THIS spec's identity
        assert progs[0].meta["spec_hash"] == SPEC.plan_hash()


def test_tampered_plan_rejected_and_replanned(tmp_path):
    cache = ArtifactCache(tmp_path / "cache")
    with Session(SPEC, cache=cache) as s:
        cold = [program_digest(p) for p in s.plan()]
    victim = os.path.join(cache.root, "plan", SPEC.plan_hash(),
                          "worker0.memory.bc")
    with open(victim, "r+b") as f:       # flip bytes mid-file
        f.seek(os.path.getsize(victim) // 2)
        f.write(b"\xff\xff\xff\xff")
    with Session(SPEC, cache=cache) as s:
        hot = [program_digest(p) for p in s.plan()]
        assert s.cache_events["plan"] == "miss"      # rejected, not served
    assert hot == cold                               # transparently re-planned
    assert cache.stats.invalid == 1
    assert not os.path.exists(victim) or \
        program_digest_path_ok(victim, cold[0])
    # the re-plan repopulated the entry: next session hits again
    with Session(SPEC, cache=cache) as s:
        s.plan()
        assert s.cache_events["plan"] == "hit"


def program_digest_path_ok(path, digest):
    from repro.core.bytecode import ProgramFile
    return program_digest(ProgramFile(path)) == digest


def test_tampered_manifest_spec_rejected(tmp_path):
    """from_plan-style validation: an edited manifest spec re-hashes to a
    different key, so the entry is invalid even with intact files."""
    cache = ArtifactCache(tmp_path / "cache")
    with Session(SPEC, cache=cache) as s:
        s.plan()
    man = os.path.join(cache.root, "plan", SPEC.plan_hash(),
                       "manifest.json")
    doc = json.load(open(man))
    doc["spec"]["n"] = 4096
    json.dump(doc, open(man, "w"))
    assert cache.get_plan(SPEC) is None
    assert cache.stats.invalid == 1


def test_lru_eviction_respects_cap(tmp_path):
    cache = ArtifactCache(tmp_path / "cache")
    specs = [JobSpec(workload="merge", n=n, memory_budget=16,
                     plan_mode="streaming") for n in (512, 1024, 2048)]
    for spec in specs:
        with Session(spec, cache=cache) as s:
            s.plan()
    full = cache.total_bytes()
    assert cache.entry_count() == 6          # 3 traces + 3 plans
    cache.max_bytes = full // 2
    # touch the newest spec so LRU prefers evicting the older ones
    time.sleep(0.02)
    assert cache.get_plan(specs[-1]) is not None
    with Session(JobSpec(workload="rsum", n=64, memory_budget=8,
                         plan_mode="streaming"), cache=cache) as s:
        s.plan()                             # put triggers eviction
    assert cache.total_bytes() <= cache.max_bytes
    assert cache.stats.evictions > 0
    # the just-touched plan survived; the oldest entries are gone
    assert cache.get_plan(specs[-1]) is not None
    assert cache.get_plan(specs[0]) is None


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_never_exceeds_pool():
    ctl = AdmissionController(frame_pool=100)
    peak_seen = []
    lock = threading.Lock()

    def job(frames):
        with ctl.admit(frames):
            with lock:
                peak_seen.append(ctl.frames_in_use)
            time.sleep(0.002)

    threads = [threading.Thread(target=job, args=(f,))
               for f in (60, 60, 40, 40, 30, 30, 90, 10) * 4]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ctl.frames_in_use == 0 and ctl.active == 0
    assert ctl.peak_frames <= 100
    assert max(peak_seen) <= 100
    assert ctl.admitted == len(threads)


def test_admission_reject_and_never_fits():
    ctl = AdmissionController(frame_pool=10, max_queue=1)
    with pytest.raises(AdmissionError, match="never"):
        ctl.admit(11)
    with ctl.admit(8):
        with pytest.raises(AdmissionError, match="declined to queue"):
            ctl.admit(8, queue=False)
        with pytest.raises(AdmissionError, match="timed out"):
            ctl.admit(8, timeout=0.01)
    with ctl.admit(8):                       # pool drained: admits again
        pass
    assert ctl.rejected == 2


def test_admission_memory_budget():
    ctl = AdmissionController(frame_pool=100, memory_bytes=1000)
    with pytest.raises(AdmissionError, match="memory budget"):
        ctl.admit(1, mem_bytes=2000)
    with ctl.admit(1, mem_bytes=900):
        with pytest.raises(AdmissionError):
            ctl.admit(1, mem_bytes=200, queue=False)


def test_estimate_job_resources_without_tracing(tmp_path):
    """Integer budgets are sized by arithmetic alone — no trace."""
    with Session(SPEC) as s:
        frames, mem = estimate_job_resources(s)
        assert frames == 24 and mem > 0
        assert s._progs is None              # really did not trace


# ---------------------------------------------------------------------------
# the daemon end to end
# ---------------------------------------------------------------------------


@pytest.fixture()
def daemon(tmp_path):
    d = ServeDaemon(tmp_path / "cache",
                    socket_path=str(tmp_path / "mage.sock"),
                    frame_pool=4096)
    d.start()
    yield d
    d.shutdown()


def test_daemon_hot_submit_zero_trace_zero_plan(daemon):
    with serve_client(daemon.address) as c:
        assert c.ping()["ok"]
        r1 = c.submit(SPEC, execute=True)
        assert r1["cache"] == {"trace": "miss", "plan": "miss"}
        before = c.status()["cache"]
        r2 = c.submit(SPEC, execute=True)
        after = c.status()["cache"]
        assert r2["cache"] == {"trace": "skipped", "plan": "hit"}
        assert r2["digests"] == r1["digests"]
        assert r2["outputs_digest"] == r1["outputs_digest"]
        assert r2["schema_version"] == repro.SCHEMA_VERSION
        # THE tentpole claim: the hot submission performed zero tracing
        # and zero planning, per the daemon's own counters
        assert after["trace_misses"] == before["trace_misses"]
        assert after["plan_misses"] == before["plan_misses"]
        assert after["plan_hits"] == before["plan_hits"] + 1


def test_daemon_rejects_oversized_job(daemon):
    big = JobSpec(workload="merge", n=1024, memory_budget=100_000,
                  plan_mode="streaming")
    with serve_client(daemon.address) as c:
        with pytest.raises(ServeError, match="never") as ei:
            c.submit(big)
        assert ei.value.rejected
        assert c.status()["jobs"]["rejected"] == 1


def test_daemon_bad_requests(daemon):
    with serve_client(daemon.address) as c:
        with pytest.raises(ServeError, match="unknown op"):
            c.request({"op": "frobnicate"})
        with pytest.raises(ServeError, match="unknown submit fields"):
            c.request({"op": "submit", "spec": SPEC.to_dict(), "bogus": 1})
        with pytest.raises(ServeError, match="unknown JobSpec fields"):
            c.submit({"workload": "merge", "wat": 1})
        assert c.ping()["ok"]                # the connection survived


def test_cli_submit_roundtrip(daemon, tmp_path, capsys):
    out = tmp_path / "resp.json"
    assert main(["submit", "--connect", str(daemon.address),
                 "--workload", "merge", "-n", "1024", "--budget", "24",
                 "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] and doc["schema_version"] == 1
    assert main(["submit", "--connect", str(daemon.address),
                 "--status"]) == 0
    assert '"plan_misses": 1' in capsys.readouterr().out


# ---------------------------------------------------------------------------
# stable public surface
# ---------------------------------------------------------------------------


def test_public_api_surface(tmp_path):
    assert "merge" in repro.list_workloads()
    assert {"gc-plaintext", "gc-2party", "ckks"} <= set(repro.list_drivers())
    assert {"ram", "memmap"} <= set(repro.list_storages())
    assert {"inproc", "tcp", "shaped"} <= set(repro.list_transports())
    assert repro.Session is Session and repro.JobSpec is JobSpec
    assert callable(repro.serve_client) and callable(repro.plan)
    # old import paths keep working
    from repro.api import run_job                          # noqa: F401
    from repro.serve_daemon import ServeClient             # noqa: F401
    manifest = repro.plan(SPEC, tmp_path / "job", cache=tmp_path / "c")
    assert os.path.basename(manifest) == "job.json"
    assert Session.from_plan(tmp_path / "job").spec.plan_hash() == \
        SPEC.plan_hash()
