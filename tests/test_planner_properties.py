"""Property-based planner/simulator differentials on random traces.

Hypothesis (or the offline shim in ``_hypothesis_compat``) drives random
oblivious traces — random page-touch patterns, frame budgets, policies —
and asserts the repo's two core equivalences hold on every draw:

 * the array planner core emits record-digest-identical memory programs
   to the scalar reference, stage by stage and end to end;
 * ``simulate_memory_program`` returns exactly equal SimResults across
   cores and chunk sizes.

The fixed-seed differentials in test_array_core/test_array_sim pin a few
known-tricky traces; this file keeps sampling new ones."""

import numpy as np
from _hypothesis_compat import given, settings, strategies as st
from test_core_planner import _Driver, _random_program, _run

from repro.core import PlanConfig, plan
from repro.core.bytecode import encode_chunk
from repro.core.liveness import records_digest
from repro.core.replacement import plan_replacement
from repro.core.scheduling import plan_schedule
from repro.core.simulator import simulate_memory_program

POLICIES = ("min", "min_clean", "lru", "fifo")


def _digest(instrs) -> int:
    return records_digest(0, encode_chunk(instrs), 0)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(4, 24), st.integers(0, 3))
def test_replacement_core_digests_agree(seed, frames, policy_idx):
    prog = _random_program(seed)
    policy = POLICIES[policy_idx]
    ps, ss = plan_replacement(prog, frames, policy=policy, core="scalar")
    pa, sa = plan_replacement(prog, frames, policy=policy, core="array",
                              chunk_instrs=17)
    assert _digest(pa.instrs) == _digest(ps.instrs)
    assert sa == ss


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(6, 20), st.integers(0, 4))
def test_schedule_core_digests_agree(seed, frames, prefetch):
    prog = _random_program(seed)
    phys, _ = plan_replacement(prog, frames, core="scalar")
    swap_bypass = bool(seed & 1)
    ms, ss = plan_schedule(phys, frames + 5, prefetch,
                           swap_bypass=swap_bypass, core="scalar")
    ma, sa = plan_schedule(phys, frames + 5, prefetch,
                           swap_bypass=swap_bypass, core="array",
                           chunk_instrs=13)
    assert _digest(ma.instrs) == _digest(ms.instrs)
    assert sa == ss


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(5, 16))
def test_end_to_end_plan_digests_and_outputs_agree(seed, frames):
    prog = _random_program(seed)
    policy = POLICIES[seed % len(POLICIES)]
    cfgs = [PlanConfig(num_frames=frames, lookahead=11, prefetch_pages=2,
                       policy=policy, core=c) for c in ("scalar", "array")]
    mem_s, rep_s = plan(prog, cfgs[0])
    mem_a, rep_a = plan(prog, cfgs[1])
    assert _digest(mem_a.instrs) == _digest(mem_s.instrs)
    # the report's stage-timing fields are wall clock; the *stats* must
    # agree exactly
    assert rep_a.replacement == rep_s.replacement
    assert rep_a.schedule == rep_s.schedule
    assert rep_a.peak_mem_bytes == rep_s.peak_mem_bytes
    # and the planned program still computes what the trace computes
    assert _run_outputs(mem_s) == _run_outputs(prog)


def _run_outputs(program):
    return {t: v.tolist() for t, v in _run(program).items()}


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(5, 16))
def test_simulator_exact_across_cores_and_chunks(seed, frames):
    prog = _random_program(seed)
    mem, _ = plan(prog, PlanConfig(num_frames=frames, lookahead=9,
                                   prefetch_pages=1))
    cost = _Driver().cost
    ref = simulate_memory_program(mem, cost, 1024, core="scalar")
    for core in ("scalar", "array"):
        for chunk in (7, 64, 8192):
            got = simulate_memory_program(mem, cost, 1024, core=core,
                                          chunk_instrs=chunk)
            assert got == ref, (core, chunk)
    assert ref.reads == ref.writes or ref.total > 0
