"""All twelve workloads (ten §8.1 kernels + two §8.8 apps) against their
numpy oracles: unbounded, bounded (planned, memmap-swapped), multi-worker,
and a scaled real-crypto two-party run."""

import pytest

from repro.core.planner import PlanConfig
from repro.workloads import get
from repro.workloads.runner import check_against_oracle, run

FAST = [("merge", 128), ("sort", 128), ("ljoin", 32), ("mvmul", 32),
        ("binfclayer", 128), ("rsum", 16), ("rstats", 16), ("rmvmul", 4),
        ("n_rmatmul", 2), ("t_rmatmul", 2), ("passreuse", 64), ("pir", 16)]


@pytest.mark.parametrize("name,n", FAST)
def test_unbounded_matches_oracle(name, n):
    w = get(name)
    check_against_oracle(w, n, run(w, n))


@pytest.mark.parametrize("name,n,frames", [
    ("merge", 256, 12), ("sort", 256, 12), ("ljoin", 32, 8),
    ("mvmul", 32, 8), ("binfclayer", 128, 8), ("rsum", 32, 6),
    ("rstats", 16, 8), ("rmvmul", 4, 8), ("n_rmatmul", 2, 8),
    ("t_rmatmul", 2, 8), ("passreuse", 128, 10), ("pir", 16, 6)])
def test_bounded_memmap_matches_oracle(name, n, frames):
    w = get(name)
    cfg = PlanConfig(num_frames=frames, lookahead=50, prefetch_pages=3)
    check_against_oracle(w, n, run(w, n, cfg=cfg, use_memmap=True))


@pytest.mark.parametrize("name,n,p", [
    ("merge", 256, 2), ("merge", 256, 4), ("sort", 256, 4),
    ("mvmul", 32, 2), ("rsum", 32, 4), ("rstats", 16, 2),
    ("rmvmul", 4, 2), ("ljoin", 32, 2), ("t_rmatmul", 4, 2)])
def test_multiworker_matches_oracle(name, n, p):
    w = get(name)
    check_against_oracle(w, n, run(w, n, num_workers=p))


@pytest.mark.parametrize("name,n", [("merge", 64), ("mvmul", 16),
                                    ("binfclayer", 128)])
def test_real_two_party_crypto(name, n):
    """Actual garbling + evaluation through the engine (scaled sizes)."""
    w = get(name)
    check_against_oracle(w, n, run(w, n, real=True))


def test_real_two_party_bounded_multiworker():
    w = get("sort")
    cfg = PlanConfig(num_frames=10, lookahead=30, prefetch_pages=2)
    check_against_oracle(w, 128, run(w, 128, real=True, num_workers=2,
                                     cfg=cfg))


def test_min_clean_policy_reduces_writebacks_or_matches():
    """Beyond-paper MinClean: never more swap-outs than plain MIN on the
    write-heavy ljoin trace, with bounded swap-in regression."""
    from repro.core import plan_replacement
    w = get("ljoin")
    prog = w.trace(64)[0]
    _, s_min = plan_replacement(prog, 24, policy="min")
    _, s_clean = plan_replacement(prog, 24, policy="min_clean")
    assert s_clean.swap_outs <= s_min.swap_outs
    assert s_clean.swap_ins <= int(s_min.swap_ins * 1.25) + 4
