"""Training loop fault tolerance: checkpoint atomicity + resume determinism,
NaN rollback, elastic reshard, straggler detection, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, Prefetcher, batch_for_step
from repro.launch.train import train_loop
from repro.train import checkpoint as ckpt
from repro.train.fault import FaultConfig, StepTimer
from repro.train.optimizer import OptConfig, wsd_lr
from repro.train.train_step import TrainConfig, make_train_state

CFG = reduced_config("qwen2-1.5b")
TCFG = TrainConfig(microbatches=2,
                   opt=OptConfig(peak_lr=1e-3, warmup_steps=2,
                                 stable_steps=10, decay_steps=4))
DCFG = DataConfig(seq_len=32, global_batch=4, vocab_size=CFG.vocab_size)


def test_wsd_schedule_shape():
    oc = OptConfig(peak_lr=1.0, warmup_steps=10, stable_steps=20,
                   decay_steps=10, min_lr_frac=0.1)
    lrs = [float(wsd_lr(oc, jnp.asarray(s))) for s in
           [0, 5, 10, 25, 35, 40, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0) and lrs[3] == pytest.approx(1.0)
    assert 0.1 < lrs[4] < 1.0
    assert lrs[-1] == pytest.approx(0.1)


def test_data_pipeline_deterministic_and_shardable():
    b1 = batch_for_step(DCFG, 7)
    b2 = batch_for_step(DCFG, 7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    shards = [batch_for_step(DCFG, 7, shard=i, n_shards=2)["tokens"]
              for i in range(2)]
    assert np.array_equal(np.concatenate(shards), b1["tokens"])
    pf = Prefetcher(DCFG, start_step=3)
    s, b = pf.next()
    assert s == 3 and np.array_equal(b["tokens"],
                                     batch_for_step(DCFG, 3)["tokens"])
    pf.close()


def test_checkpoint_atomic_and_corruption_tolerant(tmp_path):
    d = str(tmp_path)
    params, opt = make_train_state(jax.random.PRNGKey(0), CFG)
    ckpt.save(d, 10, params, opt)
    ckpt.save(d, 20, params, opt)
    # a crashed half-save must be ignored
    os.makedirs(os.path.join(d, "step_0000000030"))
    with open(os.path.join(d, "step_0000000030", "manifest.json"), "w") as f:
        f.write("{corrupt")
    assert ckpt.latest_step(d) == 20
    p2, o2, mf = ckpt.restore(d, 20, params, opt)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_deterministic(tmp_path):
    """Train 8 steps straight vs 4 steps + restart + 4 steps: identical."""
    fc = FaultConfig(checkpoint_every=4)
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    r1 = train_loop(CFG, TCFG, DCFG, fc, steps=8, ckpt_dir=d1, log_every=100)
    train_loop(CFG, TCFG, DCFG, fc, steps=4, ckpt_dir=d2, log_every=100)
    r2 = train_loop(CFG, TCFG, DCFG, fc, steps=8, ckpt_dir=d2, log_every=100)
    assert r2.final_step == r1.final_step == 8
    pa, oa = make_train_state(jax.random.PRNGKey(0), CFG)
    p1, _, _ = ckpt.restore(d1, 8, pa, oa)
    p2, _, _ = ckpt.restore(d2, 8, pa, oa)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_nan_rollback(tmp_path):
    fc = FaultConfig(checkpoint_every=3, max_rollbacks=2)
    r = train_loop(CFG, TCFG, DCFG, fc, steps=8, ckpt_dir=str(tmp_path),
                   inject_nan_at=5, log_every=100)
    assert r.rollbacks == 1
    assert r.final_step == 8


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one mesh, restore under a different device layout."""
    d = str(tmp_path)
    params, opt = make_train_state(jax.random.PRNGKey(0), CFG)
    ckpt.save(d, 1, params, opt)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.distributed.sharding import rules_for, \
        params_shardings
    rules = rules_for(CFG, mesh)
    shard_tree = params_shardings(
        jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        mesh, rules)
    opt_sh = {"mu": jax.tree_util.tree_map(lambda s: s, shard_tree),
              "nu": jax.tree_util.tree_map(lambda s: s, shard_tree),
              "step": None}
    p2, o2, _ = ckpt.restore(d, 1, params, opt,
                             shardings=(shard_tree, None))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detector():
    timer = StepTimer(FaultConfig(straggler_window=16, straggler_sigma=3.0))
    rng = np.random.default_rng(0)
    for i in range(20):
        assert not timer.record(i, 0.1 + 1e-4 * rng.random())
    assert timer.record(99, 1.5)
    assert timer.events and timer.events[0]["step"] == 99


def test_compressed_psum_error_feedback():
    """int8 all-reduce with error feedback: single-device psum equals the
    plain sum as residuals accumulate correctly over steps."""
    from repro.distributed.compression import quantize_int8, dequantize_int8
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (512,)) * 3.0
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    deq = dequantize_int8(q, scale)
    err = jnp.abs(deq - x)
    assert float(jnp.max(err)) <= float(scale) * 1.0 + 1e-6
    # error feedback drives the CUMULATIVE quantized sum toward the truth
    total_true = jnp.zeros_like(x)
    total_q = jnp.zeros_like(x)
    residual = jnp.zeros_like(x)
    for i in range(20):
        g = jax.random.normal(jax.random.PRNGKey(i), (512,))
        total_true = total_true + g
        q, scale = quantize_int8(g + residual, jax.random.PRNGKey(100 + i))
        sent = dequantize_int8(q, scale)
        residual = g + residual - sent
        total_q = total_q + sent
    drift = float(jnp.max(jnp.abs(total_q + residual - total_true)))
    assert drift < 1e-3
