"""GF(p) arithmetic for the Shamir driver, p = 2^61 - 1 (Mersenne).

The field choice is the standard MPC sweet spot for a NumPy engine: a
61-bit prime keeps every share in one uint64 slot (``SLOT_BYTES`` of 8,
like CKKS words), sums of a few residues stay below 2^64, and the
Mersenne structure makes the 122-bit products of ``mulmod`` reducible
with shifts and masks (2^61 = 1 mod p), so share-wise multiplication
vectorizes without 128-bit intermediates.

All array helpers are elementwise over uint64 NumPy arrays and keep
results canonical in [0, p).  Scalar helpers (inverse, Lagrange weights)
run on Python ints — they only produce *public* per-(n, t) constants
baked into instruction immediates at trace time.
"""

from __future__ import annotations

import numpy as np

#: the field modulus, a Mersenne prime: one uint64 slot per element
P = (1 << 61) - 1

_P = np.uint64(P)
_MASK30 = np.uint64((1 << 30) - 1)
_MASK31 = np.uint64((1 << 31) - 1)
_S30 = np.uint64(30)
_S31 = np.uint64(31)
_S61 = np.uint64(61)
_ONE = np.uint64(1)


def fold(x: np.ndarray) -> np.ndarray:
    """Reduce any uint64 array mod p via Mersenne folding (2^61 = 1)."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x >> _S61) + (x & _P)          # < 2^61 + 8
    x = (x >> _S61) + (x & _P)          # <= p
    return np.where(x >= _P, x - _P, x)


def addmod(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return fold(np.asarray(a, np.uint64) + np.asarray(b, np.uint64))


def submod(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return fold(np.asarray(a, np.uint64) + (_P - np.asarray(b, np.uint64)))


def mulmod(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(a * b) mod p for canonical residues, without 128-bit temporaries.

    Split both factors at bit 31: a*b = hh*2^62 + mid*2^31 + ll with
    hh < 2^60, mid < 2^62, ll < 2^62 — every partial fits uint64, and
    2^62 = 2, 2^61 = 1 mod p collapse the shifted terms.
    """
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    ah, al = a >> _S31, a & _MASK31
    bh, bl = b >> _S31, b & _MASK31
    t1 = fold((ah * bh) << _ONE)        # hh * 2^62 = 2 * hh
    mid = ah * bl + al * bh             # < 2^62
    mh, ml = mid >> _S30, mid & _MASK30
    t2 = fold(mh + (ml << _S31))        # mid * 2^31 = mh * 2^61 + ml * 2^31
    t3 = fold(al * bl)
    return fold(t1 + t2 + t3)


def mulmod_scalar(a: np.ndarray, c: int) -> np.ndarray:
    return mulmod(a, np.uint64(c % P))


# ---------------------------------------------------------------------------
# public scalar constants (Python ints)
# ---------------------------------------------------------------------------


def inverse(x: int) -> int:
    """x^-1 mod p (Fermat); x must be nonzero mod p."""
    x %= P
    if x == 0:
        raise ZeroDivisionError("0 has no inverse in GF(p)")
    return pow(x, P - 2, P)


def eval_point(party: int) -> int:
    """The public evaluation point of one party: alpha_i = i + 1."""
    return party + 1


def lagrange_at_zero(n_parties: int) -> tuple[int, ...]:
    """Reconstruction weights at x=0 over ALL n points alpha_1..alpha_n.

    Valid for any sharing of degree <= n - 1, so one weight vector serves
    both degree-t values and the degree-2t products of F_MUL_LOCAL
    (n >= 2t + 1 by construction).
    """
    pts = [eval_point(i) for i in range(n_parties)]
    out = []
    for i, ai in enumerate(pts):
        num = den = 1
        for j, aj in enumerate(pts):
            if j != i:
                num = num * aj % P
                den = den * ((aj - ai) % P) % P
        out.append(num * inverse(den) % P)
    return tuple(out)


# ---------------------------------------------------------------------------
# deterministic coefficient PRF (order-independent across backends)
# ---------------------------------------------------------------------------

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_MASK64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    x &= _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def prf_coeffs(key: int, a: int, b: int, count: int) -> np.ndarray:
    """(count,) residues derived from (key, a, b, lane) via splitmix64.

    Keyed only by trace-time constants (never by execution order), so the
    scalar, batched and overlap backends draw identical "randomness" —
    the property the cross-backend identity tests rely on.
    """
    seed = _mix64(key * 0x8CB92BA72F3D8DD7 + a * 0xD6E8FEB86659FD93 + b + 1)
    x = np.uint64(seed) + _GAMMA * np.arange(1, count + 1, dtype=np.uint64)
    x = (x ^ (x >> np.uint64(30))) * _M1
    x = (x ^ (x >> np.uint64(27))) * _M2
    x = x ^ (x >> np.uint64(31))
    return fold(x)


# ---------------------------------------------------------------------------
# share / reconstruct (the offline dealer, also used by the tests)
# ---------------------------------------------------------------------------


def share(secrets: np.ndarray, n_parties: int, threshold: int,
          rng: np.random.Generator) -> np.ndarray:
    """Deal (n_parties, count) Shamir shares of a secret vector.

    Each lane gets an independent uniform degree-``threshold`` polynomial
    f with f(0) = secret; party i holds f(alpha_{i+1}).
    """
    secrets = np.asarray(secrets, dtype=np.uint64) % _P
    count = secrets.shape[0]
    coeffs = rng.integers(0, P, size=(threshold, count), dtype=np.uint64)
    out = np.empty((n_parties, count), dtype=np.uint64)
    for i in range(n_parties):
        acc = np.zeros(count, dtype=np.uint64)
        a = np.uint64(eval_point(i))
        for k in range(threshold - 1, -1, -1):      # Horner, highest first
            acc = addmod(mulmod(acc, a), coeffs[k])
        out[i] = addmod(mulmod(acc, a), secrets)
    return out


def reconstruct(shares: np.ndarray, parties: list[int] | None = None
                ) -> np.ndarray:
    """Interpolate at 0 from (k, count) shares held by ``parties``."""
    shares = np.asarray(shares, dtype=np.uint64)
    k = shares.shape[0]
    idx = list(range(k)) if parties is None else list(parties)
    if len(idx) != k:
        raise ValueError(f"{k} share rows for {len(idx)} party ids")
    pts = [eval_point(i) for i in idx]
    acc = np.zeros(shares.shape[1:], dtype=np.uint64)
    for i, ai in enumerate(pts):
        num = den = 1
        for j, aj in enumerate(pts):
            if j != i:
                num = num * aj % P
                den = den * ((aj - ai) % P) % P
        lam = num * inverse(den) % P
        acc = addmod(acc, mulmod_scalar(shares[i], lam))
    return acc
