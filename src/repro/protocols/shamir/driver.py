"""The n-party Shamir secret-sharing protocol driver.

One :class:`ShamirDriver` runs per *worker* engine: the n Shamir parties
are deployed as the n workers of a single registry party
(``driver_parties("shamir") == 1``), so every resharing round of the
degree-reduction multiplication appears in the traced bytecode as
``F_EVAL`` + ``NET_SEND``/``NET_RECV`` + a recombine chain riding the
same all-to-all `Transport` links as worker-parallel GC — the planner
and the overlap pass see (and can hide) each round.  See docs/SHAMIR.md.

Execution is passive-secure *in structure* (round pattern, message
sizes, per-party share state); input dealing and resharing randomness
are derived from PRFs keyed only by trace-time constants (tag / round
id), the share-world analogue of the GC plaintext oracle's deterministic
garbling seed: all n engines deal consistent shares with no extra dealer
round, and the scalar/batched/overlap backends — which execute the same
instructions in different orders — draw bit-identical coefficients.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from ...core.bytecode import Instr, Op
from ...core.engine import ProtocolDriver
from .field import (P, addmod, eval_point, fold, mulmod, mulmod_scalar,
                    prf_coeffs, submod)

InputProvider = Callable[[int], np.ndarray]

#: default PRF keys: input-poly dealing / resharing polynomials
SEED_INPUT = 0x511A3170
SEED_RESHARE = 0x5ECE7B17


class ShamirDriver(ProtocolDriver):
    """Share-local field ops + deterministic share dealing for one party.

    ``threshold`` defaults to the largest t with 2t + 1 <= n_parties, the
    degree-reduction requirement of the one-round multiplication.
    """

    lane = 1
    dtype = np.uint64
    name = "shamir"

    def __init__(self, n_parties: int, party: int,
                 input_provider: InputProvider,
                 threshold: int | None = None,
                 seed_input: int = SEED_INPUT,
                 seed_reshare: int = SEED_RESHARE):
        if n_parties < 3:
            raise ValueError(f"shamir needs n >= 3 parties, got {n_parties}")
        if not 0 <= party < n_parties:
            raise ValueError(f"party {party} out of range for n={n_parties}")
        t = (n_parties - 1) // 2 if threshold is None else threshold
        if not 1 <= t or 2 * t + 1 > n_parties:
            raise ValueError(f"threshold t={t} needs 2t+1 <= n={n_parties}")
        self.n_parties = n_parties
        self.party = party
        self.threshold = t
        self.provider = input_provider
        self.seed_input = seed_input
        # fold the party id into the resharing key: each party's resharing
        # polynomial for round rid must be private to (derived only by) it
        self.seed_reshare = seed_reshare ^ (party + 1) * 0x9E3779B9
        self.outputs: dict[int, np.ndarray] = {}

    # -- polynomial helpers -------------------------------------------------

    def _poly_eval(self, const: np.ndarray, key: int, which: int,
                   t: int, at_party: int) -> np.ndarray:
        """const + sum_k c_k * alpha^k with c_k = PRF(key, which, k)."""
        count = const.shape[0]
        a = np.uint64(eval_point(at_party))
        acc = np.zeros(count, dtype=np.uint64)
        for k in range(t, 0, -1):                   # Horner, highest first
            acc = addmod(mulmod(acc, a), prf_coeffs(key, which, k, count))
        return addmod(mulmod(acc, a), const)

    # -- ProtocolDriver -----------------------------------------------------

    def execute(self, op: Op, imm: tuple, outs, ins) -> None:
        if op == Op.F_ADD:
            outs[0][:, 0] = addmod(ins[0][:, 0], ins[1][:, 0])
        elif op == Op.F_SUB:
            outs[0][:, 0] = submod(ins[0][:, 0], ins[1][:, 0])
        elif op == Op.F_MUL_LOCAL:
            outs[0][:, 0] = mulmod(ins[0][:, 0], ins[1][:, 0])
        elif op == Op.F_MULC:
            outs[0][:, 0] = mulmod_scalar(ins[0][:, 0], imm[1])
        elif op == Op.F_ADDC:
            outs[0][:, 0] = addmod(ins[0][:, 0], np.uint64(imm[1] % P))
        elif op == Op.F_MULC_ADD:
            outs[0][:, 0] = addmod(
                ins[0][:, 0], mulmod_scalar(ins[1][:, 0], imm[1]))
        elif op == Op.F_EVAL:
            _, j, t, rid = imm
            outs[0][:, 0] = self._poly_eval(ins[0][:, 0], self.seed_reshare,
                                            rid, t, j)
        elif op == Op.INPUT:
            _, tag = imm
            x = fold(np.asarray(self.provider(tag), dtype=np.uint64))
            outs[0][:, 0] = self._poly_eval(x, self.seed_input, tag,
                                            self.threshold, self.party)
        elif op == Op.OUTPUT:
            # the reveal chain already interpolated at 0: ins[0] is plain
            self.outputs[imm[1]] = np.array(ins[0][:, 0])
        elif op == Op.COPY:
            outs[0][...] = ins[0]
        else:
            raise NotImplementedError(f"shamir driver cannot run {op!r}")

    def cost(self, instr: Instr) -> float:
        n = instr.outs[0][1] if instr.outs else \
            (instr.ins[0][1] if instr.ins else 1)
        if instr.op in (Op.F_MUL_LOCAL, Op.F_EVAL, Op.INPUT):
            return 30e-9 * n
        return 6e-9 * n

    def finalize(self) -> None:
        pass
