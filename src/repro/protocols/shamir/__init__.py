"""N-party Shamir secret sharing over GF(2^61 - 1): the third protocol
family (after 2PC garbled circuits and CKKS), exercising the planner,
the all-to-all transport links and the overlap engine on genuinely
round-structured traces.  See docs/SHAMIR.md."""

from .driver import SEED_INPUT, SEED_RESHARE, ShamirDriver
from .dsl import (ROUND_TAG, REVEAL_TAG, Shared, mul, reveal,
                  share_input)
from .field import (P, addmod, inverse, lagrange_at_zero, mulmod,
                    reconstruct, share, submod)

__all__ = [
    "P", "ROUND_TAG", "REVEAL_TAG", "SEED_INPUT", "SEED_RESHARE",
    "ShamirDriver", "Shared", "addmod", "inverse", "lagrange_at_zero",
    "mul", "mulmod", "reconstruct", "reveal", "share", "share_input",
    "submod",
]
