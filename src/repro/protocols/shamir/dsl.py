"""Shamir tracing DSL: secret-shared field vectors with explicit rounds.

A :class:`Shared` is one <=page span of uint64 slots holding THIS
worker's share of a secret vector.  Linear operators (``+``, ``-``,
constant-mul) emit one share-local ``F_*`` instruction; :func:`mul`
emits a full VIFF-style degree-reduction round —

    F_MUL_LOCAL                     h = x * y           (degree 2t)
    F_EVAL x n                      subshares q_w(alpha_j), j = 0..n-1
    NET_SEND x (n-1)                subshare j -> party j
    NET_RECV x (n-1)                subshare from party i, i != w
    F_MULC + F_MULC_ADD x (n-1)     z = sum_i lambda_i * s_i  (degree t)

— so every resharing round is visible to the planner and to the overlap
pass as ordinary NET_* directives inside one barrier-free window.  Round
ids (``rid``) and tags are assigned by a deterministic per-builder
counter; all workers trace the same program shape, so sender and
receiver agree on tags without coordination.

Every Shared is pinned on the builder until the trace closes
(``_live``): shamir traces emit no mid-stream FREEs, allocations are
strictly sequential pages, and the vectorized ``fast_trace`` record
builders in ``repro.workloads.shamir_workloads`` can replay the layout
in closed form (digest-identical, tested).
"""

from __future__ import annotations

from ...core.bytecode import Op
from ...core.dsl import Builder, Value, current_builder
from .field import P, inverse, lagrange_at_zero  # noqa: F401  (re-export)

#: tag bases: one tag per resharing round (+rid) and per revealed output
#: (+out index); disjoint from the builder's fresh_tag() counter space.
ROUND_TAG = 1 << 16
REVEAL_TAG = 1 << 28


def _ctx(b: Builder) -> tuple[int, int, int]:
    """(n_parties, this party, threshold) of the active trace."""
    n = b.num_workers
    if n < 3:
        raise ValueError(f"shamir traces need num_workers >= 3, got {n}")
    return n, b.worker, (n - 1) // 2


def _next_rid(b: Builder) -> int:
    rid = getattr(b, "_shamir_rid", 0)
    b._shamir_rid = rid + 1
    return rid


class Shared(Value):
    """One worker's share of a ``count``-lane secret vector in GF(p)."""

    __slots__ = ("count",)

    def __init__(self, count: int, builder: Builder | None = None):
        super().__init__(count, builder)
        self.count = count
        # pin until finish(): no mid-trace FREEs, sequential page layout
        live = getattr(self.builder, "_shamir_live", None)
        if live is None:
            live = self.builder._shamir_live = []
        live.append(self)

    @classmethod
    def mark_input(cls, count: int, tag: int,
                   builder: Builder | None = None) -> "Shared":
        v = cls(count, builder)
        v.builder.emit(Op.INPUT, outs=(v.span,), imm=(count, tag))
        return v

    def mark_output(self, tag: int) -> None:
        self.builder.emit(Op.OUTPUT, ins=(self.span,), imm=(self.count, tag))

    # -- linear (share-local) ops ------------------------------------------

    def _bin(self, op: Op, other: "Shared") -> "Shared":
        z = Shared(self.count, self.builder)
        self.builder.emit(op, outs=(z.span,), ins=(self.span, other.span),
                          imm=(self.count,))
        return z

    def __add__(self, other: "Shared") -> "Shared":
        return self._bin(Op.F_ADD, other)

    def __sub__(self, other: "Shared") -> "Shared":
        return self._bin(Op.F_SUB, other)

    def mulc(self, c: int) -> "Shared":
        z = Shared(self.count, self.builder)
        self.builder.emit(Op.F_MULC, outs=(z.span,), ins=(self.span,),
                          imm=(self.count, c % P))
        return z

    def addc(self, c: int) -> "Shared":
        z = Shared(self.count, self.builder)
        self.builder.emit(Op.F_ADDC, outs=(z.span,), ins=(self.span,),
                          imm=(self.count, c % P))
        return z

    def mulc_add(self, other: "Shared", c: int) -> "Shared":
        """self + c * other — the Lagrange-recombine chain step."""
        z = Shared(self.count, self.builder)
        self.builder.emit(Op.F_MULC_ADD, outs=(z.span,),
                          ins=(self.span, other.span),
                          imm=(self.count, c % P))
        return z

    def __mul__(self, other: "Shared") -> "Shared":
        return mul(self, other)


def _recombine(sub_shares: list[Shared], lam: tuple[int, ...]) -> Shared:
    acc = sub_shares[0].mulc(lam[0])
    for i in range(1, len(sub_shares)):
        acc = acc.mulc_add(sub_shares[i], lam[i])
    return acc


def mul(x: Shared, y: Shared) -> Shared:
    """Secret multiply with one degree-reduction resharing round."""
    b = x.builder
    n, w, t = _ctx(b)
    count = x.count
    rid = _next_rid(b)
    h = x._bin(Op.F_MUL_LOCAL, y)
    evals = []
    for j in range(n):
        e = Shared(count, b)
        b.emit(Op.F_EVAL, outs=(e.span,), ins=(h.span,),
               imm=(count, j, t, rid))
        evals.append(e)
    for j in range(n):
        if j != w:
            b.emit(Op.NET_SEND, ins=(evals[j].span,),
                   imm=(j, ROUND_TAG + rid))
    sub_shares: list[Shared] = []
    for i in range(n):
        if i == w:
            sub_shares.append(evals[w])
        else:
            r = Shared(count, b)
            b.emit(Op.NET_RECV, outs=(r.span,), imm=(i, ROUND_TAG + rid))
            sub_shares.append(r)
    return _recombine(sub_shares, lagrange_at_zero(n))


def reveal(x: Shared, out_index: int, out_tag: int) -> None:
    """Open ``x`` toward worker 0, which interpolates and emits OUTPUT.

    Workers != 0 send their share (one NET_SEND, no output); worker 0
    collects all n shares and recombines at 0 with the public Lagrange
    weights, so the plaintext OUTPUT exists on exactly one rank — the
    single-process run and the n-process fleet merge identically.
    """
    b = x.builder
    n, w, _ = _ctx(b)
    if w != 0:
        b.emit(Op.NET_SEND, ins=(x.span,), imm=(0, REVEAL_TAG + out_index))
        return
    shares = [x]
    for j in range(1, n):
        r = Shared(x.count, b)
        b.emit(Op.NET_RECV, outs=(r.span,), imm=(j, REVEAL_TAG + out_index))
        shares.append(r)
    _recombine(shares, lagrange_at_zero(n)).mark_output(out_tag)


def share_input(count: int, tag: int) -> Shared:
    """Obtain this worker's share of input vector ``tag`` (PRF-dealt)."""
    return Shared.mark_input(count, tag, current_builder())
