"""SC protocol drivers for MAGE's engine: garbled circuits and CKKS."""
