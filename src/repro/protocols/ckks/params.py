"""CKKS parameters: RNS primes, roots of unity, scales.

Primes are NTT-friendly (q ≡ 1 mod 2N) and < 2^31 so coefficient products
fit uint64 without 128-bit arithmetic — the TPU-idiomatic choice too (32-bit
lanes; see DESIGN.md §3).  The modulus chain is [q0 | scale primes...] plus
one special prime P for hybrid key-switching (GHS-style), which keeps
relinearization noise ~e instead of ~q·e.
"""

from __future__ import annotations

import dataclasses
import functools


_MR_WITNESSES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in _MR_WITNESSES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_WITNESSES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def gen_primes(n_ring: int, bits: list[int]) -> list[int]:
    """One NTT-friendly prime per requested bit size, all distinct."""
    out: list[int] = []
    step = 2 * n_ring
    for b in bits:
        cand = (1 << b) + 1
        # search upward in steps of 2N keeping q ≡ 1 (mod 2N)
        cand += (-(cand - 1)) % step
        while (not is_prime(cand)) or cand in out:
            cand += step
        out.append(cand)
    return out


def primitive_2n_root(q: int, n_ring: int) -> int:
    """psi with psi^N ≡ -1 (mod q) — a primitive 2N-th root of unity."""
    order = 2 * n_ring
    assert (q - 1) % order == 0
    exp = (q - 1) // order
    for a in range(2, 1000):
        psi = pow(a, exp, q)
        if pow(psi, n_ring, q) == q - 1:
            return psi
    raise RuntimeError(f"no 2N-th root found for q={q}")


@dataclasses.dataclass(frozen=True)
class CkksParams:
    """Depth-`levels` CKKS with RNS modulus chain + special prime."""
    n_ring: int = 1024                 # N; slots = N/2
    levels: int = 2                    # multiplicative depth
    scale_bits: int = 25
    q0_bits: int = 29
    special_bits: int = 30
    noise_std: float = 3.2

    @functools.cached_property
    def primes(self) -> list[int]:
        bits = [self.q0_bits] + [self.scale_bits] * self.levels
        return gen_primes(self.n_ring, bits)

    @functools.cached_property
    def special_prime(self) -> int:
        got = gen_primes(self.n_ring,
                         [self.special_bits, self.special_bits])
        # avoid collision with chain primes
        for p in got:
            if p not in self.primes:
                return p
        raise RuntimeError("special prime collision")

    @property
    def slots(self) -> int:
        return self.n_ring // 2

    @property
    def scale(self) -> float:
        return float(1 << self.scale_bits)

    def level_primes(self, level: int) -> list[int]:
        """Primes of a ciphertext at ``level`` (level L = fresh)."""
        return self.primes[:level + 1]

    def ct_slots(self, level: int, ncomp: int = 2) -> int:
        """uint64 slots a ciphertext occupies in the engine array."""
        return ncomp * (level + 1) * self.n_ring

    def pt_slots(self) -> int:
        """Encoded plaintext: one poly over the full chain."""
        return (self.levels + 1) * self.n_ring
