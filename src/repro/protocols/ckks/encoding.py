"""CKKS canonical embedding via a twisted FFT (O(N log N), exact indices).

The slot evaluation points are the primitive 2N-th roots of unity
zeta_k = omega^{5^k mod 2N} (k = 0..N/2-1) with omega = exp(i*pi/N); their
conjugates are the remaining odd powers.  Evaluating a real polynomial at
ALL odd powers is a twisted DFT:

    m(omega^(2j+1)) = N * ifft(m_l * omega^l)[j]

so encode/decode are an index shuffle + one FFT — no Vandermonde matrices.
"""

from __future__ import annotations

import functools

import numpy as np


@functools.lru_cache(maxsize=None)
def _slot_indices(n: int) -> tuple[np.ndarray, np.ndarray]:
    """(slot->odd-dft-index, conjugate index) for ring dim n."""
    m = 2 * n
    idx = np.empty(n // 2, dtype=np.int64)
    cidx = np.empty(n // 2, dtype=np.int64)
    p = 1
    for k in range(n // 2):
        idx[k] = (p - 1) // 2
        cidx[k] = (m - p - 1) // 2
        p = (p * 5) % m
    return idx, cidx


@functools.lru_cache(maxsize=None)
def _twist(n: int) -> np.ndarray:
    return np.exp(1j * np.pi * np.arange(n) / n)


def encode(z: np.ndarray, n: int, scale: float) -> np.ndarray:
    """Complex slot vector (N/2,) -> integer coefficients (N,) (signed)."""
    z = np.asarray(z, dtype=np.complex128)
    assert z.shape == (n // 2,), z.shape
    idx, cidx = _slot_indices(n)
    f = np.zeros(n, dtype=np.complex128)
    f[idx] = z * scale
    f[cidx] = np.conj(z) * scale
    g = np.fft.fft(f) / n
    coeffs = np.real(g * np.conj(_twist(n)))
    return np.round(coeffs).astype(np.int64)


def decode(coeffs: np.ndarray, n: int, scale: float) -> np.ndarray:
    """Signed integer/float coefficients (N,) -> complex slots (N/2,)."""
    idx, _ = _slot_indices(n)
    g = np.asarray(coeffs, dtype=np.float64) * _twist(n)
    f = np.fft.ifft(g) * n
    return f[idx] / scale
