"""Negacyclic NTT over NTT-friendly primes (numpy; the engine's hot path).

Longa–Naehrig iterative butterflies: forward (CT/DIT) takes standard order
to bit-reversed; inverse (GS/DIF) takes bit-reversed back to standard.
Pointwise products happen in the bit-reversed domain, so the order never
needs fixing up.  Each stage is one fully-vectorized numpy expression — the
same schedule the Pallas kernel (repro.kernels.ntt) tiles into VMEM.

All arithmetic is mod q < 2^31, so uint64 products never overflow.
"""

from __future__ import annotations

import functools

import numpy as np

from .params import primitive_2n_root


def bit_reverse_indices(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


@functools.lru_cache(maxsize=None)
def ntt_tables(q: int, n: int) -> tuple[np.ndarray, np.ndarray, int]:
    """(psi powers bit-reversed, psi^-1 powers bit-reversed, N^-1 mod q)."""
    psi = primitive_2n_root(q, n)
    psi_inv = pow(psi, q - 2, q)
    pw = np.empty(n, dtype=np.uint64)
    pwi = np.empty(n, dtype=np.uint64)
    x = y = 1
    for i in range(n):
        pw[i] = x
        pwi[i] = y
        x = x * psi % q
        y = y * psi_inv % q
    rev = bit_reverse_indices(n)
    return pw[rev], pwi[rev], pow(n, q - 2, q)


def ntt_forward(a: np.ndarray, q: int) -> np.ndarray:
    """Negacyclic forward NTT; a is (..., N) uint64 standard order."""
    n = a.shape[-1]
    psis, _, _ = ntt_tables(q, n)
    qq = np.uint64(q)
    v = a.copy()
    lead = v.shape[:-1]
    t = n
    m = 1
    while m < n:
        t //= 2
        w = v.reshape(*lead, m, 2, t)
        s = psis[m:2 * m].reshape((1,) * len(lead) + (m, 1))
        u = w[..., 0, :]
        x = (w[..., 1, :] * s) % qq
        w0 = (u + x) % qq
        w1 = (u + qq - x) % qq
        v = np.stack([w0, w1], axis=-2).reshape(*lead, n)
        m *= 2
    return v


def ntt_inverse(a: np.ndarray, q: int) -> np.ndarray:
    """Inverse negacyclic NTT; input bit-reversed, output standard order."""
    n = a.shape[-1]
    _, psis_inv, n_inv = ntt_tables(q, n)
    qq = np.uint64(q)
    v = a.copy()
    lead = v.shape[:-1]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        w = v.reshape(*lead, h, 2, t)
        s = psis_inv[h:2 * h].reshape((1,) * len(lead) + (h, 1))
        u = w[..., 0, :]
        x = w[..., 1, :]
        w0 = (u + x) % qq
        w1 = ((u + qq - x) % qq * s) % qq
        v = np.stack([w0, w1], axis=-2).reshape(*lead, n)
        t *= 2
        m = h
    return (v * np.uint64(n_inv)) % qq


def negacyclic_mul(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """c = a*b mod (X^N + 1, q) — reference composition of the above."""
    fa = ntt_forward(a % np.uint64(q), q)
    fb = ntt_forward(b % np.uint64(q), q)
    return ntt_inverse((fa * fb) % np.uint64(q), q)


def negacyclic_mul_naive(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """O(N^2) oracle for tests."""
    n = a.shape[-1]
    c = np.zeros(n, dtype=np.object_)
    av = [int(x) for x in a]
    bv = [int(x) for x in b]
    for i in range(n):
        for j in range(n):
            k = i + j
            if k < n:
                c[k] = (c[k] + av[i] * bv[j]) % q
            else:
                c[k - n] = (c[k - n] - av[i] * bv[j]) % q
    return c.astype(np.uint64)
