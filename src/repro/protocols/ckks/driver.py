"""CKKS protocol driver for MAGE's engine (§7.4) + the Batch DSL.

The address space is word-addressed (one slot = 8 bytes); a ciphertext at
level l occupies ncomp*(l+1)*N slots, an encoded plaintext (levels+1)*N.
Ciphertexts are flat buffers (no serialization step — the improvement the
paper itself suggests over SEAL's pointer-laden objects; we model SEAL's
serialize cost separately in the Fig. 7 benchmark).

The Add-Multiply *engine* is trivial here (CKKS gates ARE adds/multiplies),
so the driver maps bytecode ops 1:1 onto cipher.py, including the paper's
lazy-relinearization optimization (CT_MUL_NR + CT_ADD on 3-component
ciphertexts + one CT_RELIN), which §7.4 calls out as crucial for rstats and
the linear-algebra workloads.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from ...core.bytecode import Instr, Op
from ...core.dsl import Value
from ...core.engine import ProtocolDriver
from .cipher import CkksContext
from .params import CkksParams

InputProvider = Callable[[int], np.ndarray]


@dataclasses.dataclass
class CkksCostModel:
    """Per-op seconds from NTT counts (calibrated to single-core SEAL-era
    throughput: an N-point NTT ~ kappa*N*log2(N) seconds)."""
    kappa: float = 2.0e-9
    pointwise: float = 0.3e-9     # per-coefficient modmul epilogue
    instr_overhead_s: float = 2e-6

    def ntt_s(self, n: int) -> float:
        return self.kappa * n * math.log2(max(n, 2))

    def cost(self, instr: Instr, n_ring: int) -> float:
        op, imm = instr.op, instr.imm
        t = self.instr_overhead_s
        if op == Op.CT_ADD:
            lvl, nc = imm[0], max(imm[1], imm[2])
            t += nc * (lvl + 1) * n_ring * self.pointwise
        elif op in (Op.CT_MUL, Op.CT_MUL_NR, Op.CT_RELIN, Op.CT_MUL_PLAIN):
            lvl = imm[0]
            nprime = lvl + 1
            if op in (Op.CT_MUL, Op.CT_MUL_NR):
                ntts = 7 * nprime                      # 4 fwd + 3 inv
            else:
                ntts = 0
            if op in (Op.CT_MUL, Op.CT_RELIN):
                ntts += nprime * (nprime + 1) + 2 * (nprime + 1) + 2 * nprime
            if op == Op.CT_MUL_PLAIN:
                ntts += 2 * 2 * nprime + nprime
            t += ntts * self.ntt_s(n_ring)
            t += nprime * n_ring * 6 * self.pointwise
        elif op in (Op.CT_ADD_PLAIN,):
            lvl = imm[0]
            t += (lvl + 1) * n_ring * self.pointwise
        elif op in (Op.INPUT, Op.OUTPUT):
            t += 4 * self.ntt_s(n_ring)
        return t

    def cost_chunk(self, ops: np.ndarray, imm: np.ndarray,
                   n_ring: int) -> np.ndarray:
        """Vectorized :meth:`cost` over one record chunk.

        ``ops`` is int64 [m]; ``imm`` the zero-padded int64 immediate
        matrix (the NTT-count formulas only read the integer level/
        component immediates).  Per-instruction results are IDENTICAL to
        the scalar path: every count stays exact int64 and the float
        operations replay ``cost``'s order (overhead, then NTTs, then the
        pointwise epilogue)."""
        ops = np.asarray(ops, dtype=np.int64)
        imm = np.asarray(imm, dtype=np.int64)
        t = np.full(ops.shape[0], self.instr_overhead_s, dtype=np.float64)
        ntt = self.ntt_s(n_ring)
        lvl = imm[:, 0]

        mk = ops == int(Op.CT_ADD)
        if mk.any():
            nc = np.maximum(imm[mk, 1], imm[mk, 2])
            t[mk] += (nc * (lvl[mk] + 1) * n_ring).astype(np.float64) \
                * self.pointwise
        is_mul = (ops == int(Op.CT_MUL)) | (ops == int(Op.CT_MUL_NR))
        mk = is_mul | (ops == int(Op.CT_RELIN)) | (ops == int(Op.CT_MUL_PLAIN))
        if mk.any():
            nprime = lvl[mk] + 1
            ntts = np.where(is_mul[mk], 7 * nprime, 0)
            relin = is_mul[mk] & (ops[mk] == int(Op.CT_MUL))
            relin |= ops[mk] == int(Op.CT_RELIN)
            ntts = ntts + np.where(
                relin, nprime * (nprime + 1) + 2 * (nprime + 1) + 2 * nprime,
                0)
            ntts = ntts + np.where(ops[mk] == int(Op.CT_MUL_PLAIN),
                                   2 * 2 * nprime + nprime, 0)
            t[mk] += ntts.astype(np.float64) * ntt
            t[mk] += (nprime * n_ring * 6).astype(np.float64) * self.pointwise
        mk = ops == int(Op.CT_ADD_PLAIN)
        if mk.any():
            t[mk] += ((lvl[mk] + 1) * n_ring).astype(np.float64) \
                * self.pointwise
        mk = (ops == int(Op.INPUT)) | (ops == int(Op.OUTPUT))
        if mk.any():
            t[mk] += 4 * ntt
        return t


class CkksDriver(ProtocolDriver):
    lane = 1
    dtype = np.uint64
    name = "ckks"

    def __init__(self, params: CkksParams,
                 input_provider: InputProvider | None = None,
                 seed: int = 0xCEC5):
        self.p = params
        self.ctx = CkksContext(params, seed=seed)
        self.input_provider = input_provider
        self.outputs: dict[int, np.ndarray] = {}
        self.cost_model = CkksCostModel()

    def cost(self, instr: Instr) -> float:
        return self.cost_model.cost(instr, self.p.n_ring)

    # -- layout helpers ------------------------------------------------------------

    def _ct(self, view: np.ndarray, level: int, ncomp: int = 2) -> np.ndarray:
        return view[:, 0].reshape(ncomp, level + 1, self.p.n_ring)

    def _pt(self, view: np.ndarray) -> np.ndarray:
        return view[:, 0].reshape(self.p.levels + 1, self.p.n_ring)

    def execute(self, op: Op, imm: tuple, outs, ins) -> None:
        ctx, p = self.ctx, self.p
        if op == Op.INPUT:
            tag, kind = imm[0], imm[1]
            z = np.asarray(self.input_provider(tag), dtype=np.float64)
            pt = ctx.encode(z)
            if kind == 1:
                outs[0][:, 0] = pt.reshape(-1)
            else:
                outs[0][:, 0] = ctx.encrypt(pt).reshape(-1)
        elif op == Op.OUTPUT:
            tag, level, ncomp, scale = imm[0], imm[1], imm[2], imm[3]
            ct = self._ct(ins[0], level, ncomp)
            z = ctx.decode(ctx.decrypt(ct, level), level, scale)
            self.outputs[tag] = z.real
        elif op == Op.COPY:
            outs[0][...] = ins[0]
        elif op == Op.CT_ADD:
            level, nc1, nc2 = imm[0], imm[1], imm[2]
            sub = bool(imm[3]) if len(imm) > 3 else False
            fn = ctx.sub if sub else ctx.add
            r = fn(self._ct(ins[0], level, nc1),
                   self._ct(ins[1], level, nc2), level)
            outs[0][:, 0] = r.reshape(-1)
        elif op == Op.CT_MUL:
            level = imm[0]
            r = ctx.mul(self._ct(ins[0], level), self._ct(ins[1], level),
                        level)
            outs[0][:, 0] = r.reshape(-1)
        elif op == Op.CT_MUL_NR:
            level = imm[0]
            r = ctx.mul_tensor(self._ct(ins[0], level),
                               self._ct(ins[1], level), level)
            outs[0][:, 0] = r.reshape(-1)
        elif op == Op.CT_RELIN:
            level = imm[0]
            r = ctx.rescale(ctx.relinearize(self._ct(ins[0], level, 3),
                                            level), level)
            outs[0][:, 0] = r.reshape(-1)
        elif op == Op.CT_MUL_PLAIN:
            level = imm[0]
            r = ctx.mul_plain(self._ct(ins[0], level), self._pt(ins[1]),
                              level)
            outs[0][:, 0] = r.reshape(-1)
        elif op == Op.CT_ADD_PLAIN:
            level = imm[0]
            r = ctx.add_plain(self._ct(ins[0], level), self._pt(ins[1]),
                              level)
            outs[0][:, 0] = r.reshape(-1)
        else:
            raise NotImplementedError(f"ckks driver: {op}")


# ---------------------------------------------------------------------------
# Batch DSL (§7.4: "Batches" + Add-Multiply engine)
# ---------------------------------------------------------------------------


class Plain(Value):
    """An encoded plaintext vector (usable at any level)."""

    __slots__ = ("params",)

    def __init__(self, params: CkksParams, builder=None):
        super().__init__(params.pt_slots(), builder)
        self.params = params

    def mark_input(self, tag: int) -> "Plain":
        self.builder.emit(Op.INPUT, outs=(self.span,), imm=(tag, 1))
        return self


class Batch(Value):
    """One CKKS ciphertext: a vector of N/2 encrypted reals."""

    __slots__ = ("params", "level", "ncomp", "scale")

    def __init__(self, params: CkksParams, level: int | None = None,
                 ncomp: int = 2, scale: float | None = None, builder=None):
        level = params.levels if level is None else level
        super().__init__(params.ct_slots(level, ncomp), builder)
        self.params = params
        self.level = level
        self.ncomp = ncomp
        self.scale = params.scale if scale is None else scale

    def mark_input(self, tag: int) -> "Batch":
        assert self.level == self.params.levels and self.ncomp == 2
        self.builder.emit(Op.INPUT, outs=(self.span,), imm=(tag, 0))
        return self

    def mark_output(self, tag: int) -> None:
        self.builder.emit(Op.OUTPUT, ins=(self.span,),
                          imm=(tag, self.level, self.ncomp, self.scale))

    # -- ops -------------------------------------------------------------------

    def __add__(self, o: "Batch") -> "Batch":
        assert self.level == o.level, "CKKS add: level mismatch"
        r = Batch(self.params, self.level, max(self.ncomp, o.ncomp),
                  max(self.scale, o.scale), self.builder)
        self.builder.emit(Op.CT_ADD, outs=(r.span,),
                          ins=(self.span, o.span),
                          imm=(self.level, self.ncomp, o.ncomp, 0))
        return r

    def __sub__(self, o: "Batch") -> "Batch":
        assert self.level == o.level, "CKKS sub: level mismatch"
        r = Batch(self.params, self.level, max(self.ncomp, o.ncomp),
                  max(self.scale, o.scale), self.builder)
        self.builder.emit(Op.CT_ADD, outs=(r.span,),
                          ins=(self.span, o.span),
                          imm=(self.level, self.ncomp, o.ncomp, 1))
        return r

    def __mul__(self, o: "Batch") -> "Batch":
        assert self.level == o.level and self.level >= 1, \
            f"CKKS mul needs level>=1 (have {self.level})"
        assert self.ncomp == 2 and o.ncomp == 2
        drop = self.params.level_primes(self.level)[-1]
        r = Batch(self.params, self.level - 1, 2,
                  self.scale * o.scale / drop, self.builder)
        self.builder.emit(Op.CT_MUL, outs=(r.span,),
                          ins=(self.span, o.span), imm=(self.level,))
        return r

    def mul_norelin(self, o: "Batch") -> "Batch":
        """Tensor product without relinearization (lazy-relin sums)."""
        assert self.level == o.level and self.ncomp == 2 and o.ncomp == 2
        r = Batch(self.params, self.level, 3, self.scale * o.scale,
                  self.builder)
        self.builder.emit(Op.CT_MUL_NR, outs=(r.span,),
                          ins=(self.span, o.span), imm=(self.level,))
        return r

    def relin(self) -> "Batch":
        assert self.ncomp == 3 and self.level >= 1
        drop = self.params.level_primes(self.level)[-1]
        r = Batch(self.params, self.level - 1, 2, self.scale / drop,
                  self.builder)
        self.builder.emit(Op.CT_RELIN, outs=(r.span,), ins=(self.span,),
                          imm=(self.level,))
        return r

    def mul_plain(self, pt: Plain) -> "Batch":
        assert self.level >= 1 and self.ncomp == 2
        drop = self.params.level_primes(self.level)[-1]
        r = Batch(self.params, self.level - 1, 2,
                  self.scale * self.params.scale / drop, self.builder)
        self.builder.emit(Op.CT_MUL_PLAIN, outs=(r.span,),
                          ins=(self.span, pt.span), imm=(self.level,))
        return r

    def add_plain(self, pt: Plain) -> "Batch":
        r = Batch(self.params, self.level, self.ncomp, self.scale,
                  self.builder)
        self.builder.emit(Op.CT_ADD_PLAIN, outs=(r.span,),
                          ins=(self.span, pt.span), imm=(self.level,))
        return r
