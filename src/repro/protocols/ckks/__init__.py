from .cipher import CkksContext
from .driver import Batch, CkksCostModel, CkksDriver, Plain
from .params import CkksParams

__all__ = ["Batch", "CkksContext", "CkksCostModel", "CkksDriver",
           "CkksParams", "Plain"]
