"""CKKS cipher operations in RNS form (add / mul / relin / rescale).

Secret-key RLWE over Z_q[X]/(X^N+1) with an RNS modulus chain and hybrid
key-switching through one special prime P (GHS): relinearization noise stays
~e instead of ~q*e.  Per-level evaluation keys are generated at context init
(levels <= 2, so a handful of keys).

Ciphertexts are COEFFICIENT-domain uint64 arrays shaped (ncomp, level+1, N):
flat buffers — the representation the paper suggests SEAL could use to avoid
its serialization overhead (§7.4); swapping them to storage is a plain byte
copy.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .encoding import decode, encode
from .ntt import ntt_forward, ntt_inverse
from .params import CkksParams


def _center(vals: np.ndarray, q: int) -> np.ndarray:
    """[0,q) -> centered signed int64 in (-q/2, q/2]."""
    v = vals.astype(np.int64)
    return np.where(v > q // 2, v - q, v)


def _reduce_signed(vals: np.ndarray, q: int) -> np.ndarray:
    return np.mod(vals, q).astype(np.uint64)


@dataclasses.dataclass
class EvalKey:
    """Per-digit key over extended basis primes[:level+1] + [P], NTT domain."""
    b: np.ndarray  # (level+2, N)
    a: np.ndarray  # (level+2, N)


class CkksContext:
    def __init__(self, params: CkksParams, seed: int = 0xCEC5):
        self.p = params
        rng = np.random.default_rng(seed)
        n = params.n_ring
        self.s_int = rng.integers(-1, 2, n).astype(np.int64)  # ternary
        self._s_ntt: dict[int, np.ndarray] = {}
        for q in params.primes + [params.special_prime]:
            self._s_ntt[q] = ntt_forward(_reduce_signed(self.s_int, q), q)
        self._rng = rng
        self._evk: dict[int, list[EvalKey]] = {}
        for lvl in range(1, params.levels + 1):
            self._evk[lvl] = self._make_evk(lvl)

    # -- helpers ------------------------------------------------------------------

    def _sample_error(self, n: int) -> np.ndarray:
        return np.round(self._rng.normal(0.0, self.p.noise_std, n)
                        ).astype(np.int64)

    def _sample_uniform_int(self, n: int) -> np.ndarray:
        # one "integer" ring element, reduced per prime later (close enough
        # to uniform mod Q for functional purposes)
        return self._rng.integers(0, 1 << 62, n, dtype=np.int64)

    def _s2_ntt(self, q: int) -> np.ndarray:
        s = self._s_ntt[q]
        return (s * s) % np.uint64(q)

    def _make_evk(self, level: int) -> list[EvalKey]:
        """Keys for relinearizing a level-`level` product."""
        p = self.p
        primes = p.level_primes(level)
        basis = primes + [p.special_prime]
        P = p.special_prime
        Q = 1
        for q in primes:
            Q *= q
        keys = []
        for i, qi in enumerate(primes):
            qhat = Q // qi
            qtilde = qhat * pow(qhat, -1, qi)      # CRT interpolant for q_i
            a_int = self._sample_uniform_int(p.n_ring)
            e_int = self._sample_error(p.n_ring)
            b = np.zeros((len(basis), p.n_ring), dtype=np.uint64)
            a = np.zeros_like(b)
            for j, qj in enumerate(basis):
                aj = ntt_forward(_reduce_signed(a_int, qj), qj)
                ej = ntt_forward(_reduce_signed(e_int, qj), qj)
                term = (P % qj) * (qtilde % qj) % qj
                bj = (np.uint64(qj) * np.uint64(2) + ej
                      + np.uint64(term) * self._s2_ntt(qj)
                      - (aj * self._s_ntt[qj]) % np.uint64(qj)
                      ) % np.uint64(qj)
                b[j] = bj
                a[j] = aj
            keys.append(EvalKey(b=b, a=a))
        return keys

    # -- encode / encrypt ------------------------------------------------------------

    def encode(self, z: np.ndarray, level: int | None = None,
               scale: float | None = None) -> np.ndarray:
        """Real/complex slots -> plaintext poly over the FULL chain (so the
        same encoded plaintext works at any level).  Shape (levels+1, N)."""
        p = self.p
        coeffs = encode(z, p.n_ring, scale or p.scale)
        return np.stack([_reduce_signed(coeffs, q) for q in p.primes])

    def encrypt(self, pt_full: np.ndarray) -> np.ndarray:
        """Plaintext poly (levels+1, N) -> fresh ct (2, levels+1, N)."""
        p = self.p
        a_int = self._sample_uniform_int(p.n_ring)
        e_int = self._sample_error(p.n_ring)
        c0 = np.zeros_like(pt_full)
        c1 = np.zeros_like(pt_full)
        for j, qj in enumerate(p.primes):
            qq = np.uint64(qj)
            aj = _reduce_signed(a_int, qj)
            as_ = ntt_inverse((ntt_forward(aj, qj) * self._s_ntt[qj]) % qq, qj)
            c0[j] = (pt_full[j] + _reduce_signed(e_int, qj)
                     + (qq - as_)) % qq
            c1[j] = aj
        return np.stack([c0, c1])

    def decrypt(self, ct: np.ndarray, level: int) -> np.ndarray:
        """ct (ncomp, level+1, N) -> plaintext coeffs (level+1, N)."""
        p = self.p
        primes = p.level_primes(level)
        ncomp = ct.shape[0]
        out = np.zeros((len(primes), p.n_ring), dtype=np.uint64)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            acc = ct[0, j] % qq
            spow = self._s_ntt[qj]
            cur = spow.copy()
            for k in range(1, ncomp):
                ck = ntt_forward(ct[k, j] % qq, qj)
                acc = (acc + ntt_inverse((ck * cur) % qq, qj)) % qq
                cur = (cur * spow) % qq
            out[j] = acc
        return out

    def decode(self, pt: np.ndarray, level: int, scale: float) -> np.ndarray:
        """CRT-combine centered coefficients and decode to slots."""
        p = self.p
        primes = p.level_primes(level)
        if len(primes) == 1:
            coeffs = _center(pt[0], primes[0]).astype(np.float64)
        else:
            Q = 1
            for q in primes:
                Q *= q
            acc = np.zeros(p.n_ring, dtype=object)
            for j, qj in enumerate(primes):
                qhat = Q // qj
                w = qhat * pow(qhat, -1, qj)
                acc = acc + pt[j].astype(object) * w
            acc = np.mod(acc, Q)
            acc = np.where(acc > Q // 2, acc - Q, acc)
            coeffs = acc.astype(np.float64)
        return decode(coeffs, p.n_ring, scale)

    # -- homomorphic ops ------------------------------------------------------------

    def add(self, c1: np.ndarray, c2: np.ndarray, level: int) -> np.ndarray:
        primes = self.p.level_primes(level)
        ncomp = max(c1.shape[0], c2.shape[0])
        out = np.zeros((ncomp, len(primes), self.p.n_ring), dtype=np.uint64)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            for k in range(ncomp):
                x = c1[k, j] if k < c1.shape[0] else 0
                y = c2[k, j] if k < c2.shape[0] else 0
                out[k, j] = (x + y) % qq
        return out

    def sub(self, c1: np.ndarray, c2: np.ndarray, level: int) -> np.ndarray:
        primes = self.p.level_primes(level)
        ncomp = max(c1.shape[0], c2.shape[0])
        out = np.zeros((ncomp, len(primes), self.p.n_ring), dtype=np.uint64)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            for k in range(ncomp):
                x = c1[k, j] if k < c1.shape[0] else 0
                y = c2[k, j] if k < c2.shape[0] else 0
                out[k, j] = (x + qq - y % qq) % qq
        return out

    def mul_tensor(self, c1: np.ndarray, c2: np.ndarray,
                   level: int) -> np.ndarray:
        """(c0,c1) x (d0,d1) -> 3-component ct at the same level (no relin)."""
        primes = self.p.level_primes(level)
        n = self.p.n_ring
        out = np.zeros((3, len(primes), n), dtype=np.uint64)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            a0 = ntt_forward(c1[0, j] % qq, qj)
            a1 = ntt_forward(c1[1, j] % qq, qj)
            b0 = ntt_forward(c2[0, j] % qq, qj)
            b1 = ntt_forward(c2[1, j] % qq, qj)
            out[0, j] = ntt_inverse((a0 * b0) % qq, qj)
            out[1, j] = ntt_inverse(((a0 * b1) % qq + (a1 * b0) % qq) % qq, qj)
            out[2, j] = ntt_inverse((a1 * b1) % qq, qj)
        return out

    def relinearize(self, ct3: np.ndarray, level: int) -> np.ndarray:
        """3-comp -> 2-comp at the same level (hybrid key switching)."""
        p = self.p
        primes = p.level_primes(level)
        basis = primes + [p.special_prime]
        P = p.special_prime
        evk = self._evk[level]
        n = p.n_ring
        acc0 = np.zeros((len(basis), n), dtype=np.uint64)
        acc1 = np.zeros_like(acc0)
        for i, qi in enumerate(primes):
            digit = ct3[2, i]  # integer < q_i
            for j, qj in enumerate(basis):
                qq = np.uint64(qj)
                dj = ntt_forward(digit % qq, qj)
                acc0[j] = (acc0[j] + dj * evk[i].b[j]) % qq
                acc1[j] = (acc1[j] + dj * evk[i].a[j]) % qq
        out = np.zeros((2, len(primes), n), dtype=np.uint64)
        inv_np = {qj: pow(P, -1, qj) for qj in primes}
        d0P = _center(ntt_inverse(acc0[-1], P), P)
        d1P = _center(ntt_inverse(acc1[-1], P), P)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            a0 = ntt_inverse(acc0[j], qj)
            a1 = ntt_inverse(acc1[j], qj)
            t0 = (a0 + _reduce_signed(-d0P, qj)) % qq
            t1 = (a1 + _reduce_signed(-d1P, qj)) % qq
            out[0, j] = (ct3[0, j] + t0 * np.uint64(inv_np[qj])) % qq
            out[1, j] = (ct3[1, j] + t1 * np.uint64(inv_np[qj])) % qq
        return out

    def rescale(self, ct: np.ndarray, level: int) -> np.ndarray:
        """Drop the last prime; divides the message scale by q_level."""
        p = self.p
        primes = p.level_primes(level)
        ql = primes[-1]
        inv = {qj: pow(ql, -1, qj) for qj in primes[:-1]}
        ncomp = ct.shape[0]
        out = np.zeros((ncomp, len(primes) - 1, p.n_ring), dtype=np.uint64)
        for k in range(ncomp):
            last = _center(ct[k, len(primes) - 1], ql)
            for j, qj in enumerate(primes[:-1]):
                qq = np.uint64(qj)
                t = (ct[k, j] + _reduce_signed(-last, qj)) % qq
                out[k, j] = (t * np.uint64(inv[qj])) % qq
        return out

    def mul(self, c1: np.ndarray, c2: np.ndarray, level: int) -> np.ndarray:
        """Full multiply: tensor + relinearize + rescale -> level-1 ct."""
        t = self.mul_tensor(c1, c2, level)
        r = self.relinearize(t, level)
        return self.rescale(r, level)

    def mul_plain(self, ct: np.ndarray, pt_full: np.ndarray,
                  level: int, rescale: bool = True) -> np.ndarray:
        primes = self.p.level_primes(level)
        n = self.p.n_ring
        ncomp = ct.shape[0]
        out = np.zeros((ncomp, len(primes), n), dtype=np.uint64)
        for j, qj in enumerate(primes):
            qq = np.uint64(qj)
            ptj = ntt_forward(pt_full[j] % qq, qj)
            for k in range(ncomp):
                cj = ntt_forward(ct[k, j] % qq, qj)
                out[k, j] = ntt_inverse((cj * ptj) % qq, qj)
        return self.rescale(out, level) if rescale else out

    def add_plain(self, ct: np.ndarray, pt_full: np.ndarray,
                  level: int) -> np.ndarray:
        primes = self.p.level_primes(level)
        out = ct.copy()
        for j, qj in enumerate(primes):
            out[0, j] = (ct[0, j] + pt_full[j]) % np.uint64(qj)
        return out
