"""Garbled-circuit protocol drivers for MAGE's engine (§7.3).

Wire-addressed address space: one slot = one 128-bit wire label (lane=2
uint64).  The garbler's array holds zero-labels, the evaluator's the active
labels — swapping either to storage is sound because labels are flat data
(no pointers, §7.1).

Both parties interpret the SAME bytecode; the AND-XOR engine expands each
instruction identically on both sides, keeping the streamed garbled tables
in lock-step.  ``PlaintextDriver`` executes the bytecode in the clear: it is
the correctness oracle and the cheap stand-in for paper-scale real
executions (the cryptography's cost enters through the timing model).
"""

from __future__ import annotations

import threading
from typing import Callable

import numpy as np

from ...core.bytecode import Instr, Op, Program
from ...core.engine import Engine, ProtocolDriver
from .cost import GCCostModel
from .engineops import AndXorOps
from .gates import EvaluatorGates, GarblerGates, PartyChannel

InputProvider = Callable[[int], np.ndarray]  # tag -> uint64 vector


def _split_bits(vals: np.ndarray, w: int) -> np.ndarray:
    """(n,) uint64 -> (n, w) uint8 little-endian bits."""
    n = len(vals)
    out = np.zeros((n, w), dtype=np.uint8)
    for i in range(w):
        out[:, i] = (vals >> np.uint64(i)) & np.uint64(1)
    return out


def _join_bits(bits: np.ndarray) -> np.ndarray:
    n, w = bits.shape
    out = np.zeros(n, dtype=np.uint64)
    for i in range(w):
        out |= bits[:, i].astype(np.uint64) << np.uint64(i)
    return out


class _GCDriverBase(ProtocolDriver):
    lane = 2
    dtype = np.uint64

    def __init__(self, gates, input_provider: InputProvider | None = None):
        self.gates = gates
        self.ops = AndXorOps(gates)
        self.input_provider = input_provider
        self.outputs: dict[int, np.ndarray] = {}
        self._const_cache: dict[int, np.ndarray] = {}
        self.cost_model = GCCostModel(
            role="garbler" if isinstance(gates, GarblerGates) else "evaluator")

    def cost(self, instr: Instr) -> float:
        return self.cost_model.cost(instr)

    # -- helpers ----------------------------------------------------------------

    @staticmethod
    def _shape(view: np.ndarray, n: int, w: int) -> np.ndarray:
        return view.reshape(n, w, 2)

    def execute(self, op: Op, imm: tuple, outs, ins) -> None:
        o = self.ops
        if op == Op.INPUT:
            n, w, party, tag = imm[0], imm[1], imm[2], imm[3]
            outs[0][...] = self._input(n, w, party, tag).reshape(-1, 2)
        elif op == Op.OUTPUT:
            n, w, tag = imm[0], imm[1], imm[2]
            self._output(self._shape(ins[0], n, w), n, w, tag)
        elif op == Op.COPY:
            outs[0][...] = ins[0]
        elif op in (Op.XOR, Op.AND, Op.OR, Op.NOT):
            n, w = imm[0], imm[1]
            a = self._shape(ins[0], n, w)
            g = self.gates
            if op == Op.NOT:
                r = np.stack([g.not_(a[:, i]) for i in range(w)], axis=1)
            else:
                b = self._shape(ins[1], n, w)
                if op == Op.XOR:
                    r = np.stack([g.xor(a[:, i], b[:, i])
                                  for i in range(w)], axis=1)
                elif op == Op.AND:
                    r = np.stack([g.and_(a[:, i], b[:, i])
                                  for i in range(w)], axis=1)
                else:  # OR: a ^ b ^ (a & b)
                    r = np.stack(
                        [g.xor(g.xor(a[:, i], b[:, i]),
                               g.and_(a[:, i], b[:, i])) for i in range(w)],
                        axis=1)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.ADD:
            n, w = imm[0], imm[1]
            r = o.add(self._shape(ins[0], n, w), self._shape(ins[1], n, w))
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.SUB:
            n, w = imm[0], imm[1]
            r = o.sub(self._shape(ins[0], n, w), self._shape(ins[1], n, w))
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.MUL:
            n, w = imm[0], imm[1]
            r = o.mul(self._shape(ins[0], n, w), self._shape(ins[1], n, w))
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.CMP_GE:
            n, w, kw = imm[0], imm[1], imm[2]
            r = o.cmp_ge(self._shape(ins[0], n, w),
                         self._shape(ins[1], n, w), kw)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.CMP_EQ:
            n, w, kw = imm[0], imm[1], imm[2]
            r = o.cmp_eq(self._shape(ins[0], n, w),
                         self._shape(ins[1], n, w), kw)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.SELECT:
            n, w = imm[0], imm[1]
            r = o.select(self._shape(ins[0], n, 1),
                         self._shape(ins[1], n, w),
                         self._shape(ins[2], n, w))
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.MINMAX:
            n, w, kw = imm[0], imm[1], imm[2]
            mn, mx = o.minmax(self._shape(ins[0], n, w),
                              self._shape(ins[1], n, w), kw)
            outs[0][...] = mn.reshape(-1, 2)
            outs[1][...] = mx.reshape(-1, 2)
        elif op == Op.SORT_LOCAL:
            n, w, kw = imm[0], imm[1], imm[2]
            desc = bool(imm[3]) if len(imm) > 3 else False
            merge_only = bool(imm[4]) if len(imm) > 4 else False
            r = o.sort_local(self._shape(ins[0], n, w), kw,
                             direction_up=not desc, merge_only=merge_only)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.REVERSE:
            n, w = imm[0], imm[1]
            r = self._shape(ins[0], n, w)[::-1]
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.PAIR_JOIN:
            na, nb, w, kw = imm[0], imm[1], imm[2], imm[3]
            r = o.pair_join(self._shape(ins[0], na, w),
                            self._shape(ins[1], nb, w), kw)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.MAC8:
            nr, nj, acc_w = imm[0], imm[1], imm[2]
            r = o.dot8(self._shape(ins[0], nr * nj, 8),
                       self._shape(ins[1], nj, 8),
                       self._shape(ins[2], nr, acc_w), nr, nj, acc_w)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.XNOR_POP_SIGN:
            nr, nj = imm[0], imm[1]
            r = o.xnor_pop_sign(self._shape(ins[0], nr * nj, 1),
                                self._shape(ins[1], nj, 1), nr, nj)
            outs[0][...] = r.reshape(-1, 2)
        elif op == Op.REDUCE_ADD:
            n, w = imm[0], imm[1]
            r = o.reduce_add(self._shape(ins[0], n, w))
            outs[0][...] = r.reshape(-1, 2)
        else:
            raise NotImplementedError(f"GC driver: {op}")

    # party-specific:
    def _input(self, n, w, party, tag):
        raise NotImplementedError

    def _output(self, labels, n, w, tag):
        raise NotImplementedError


class GarblerDriver(_GCDriverBase):
    name = "gc-garbler"
    PARTY = 0

    def __init__(self, channel: PartyChannel,
                 input_provider: InputProvider | None = None, seed: int = 7):
        super().__init__(GarblerGates(channel, seed=seed), input_provider)

    def _input(self, n, w, party, tag):
        g = self.gates
        if party == GarblerDriver.PARTY:
            vals = self.input_provider(tag)
            bits = _split_bits(np.asarray(vals, dtype=np.uint64), w)
            return g.input_garbler(bits.reshape(-1)).reshape(n, w, 2)
        return g.input_evaluator(n * w).reshape(n, w, 2)

    def _output(self, labels, n, w, tag):
        self.gates.output(labels.reshape(-1, 2))


class EvaluatorDriver(_GCDriverBase):
    name = "gc-evaluator"
    PARTY = 1

    def __init__(self, channel: PartyChannel,
                 input_provider: InputProvider | None = None):
        super().__init__(EvaluatorGates(channel), input_provider)

    def _input(self, n, w, party, tag):
        g = self.gates
        if party == EvaluatorDriver.PARTY:
            vals = self.input_provider(tag)
            bits = _split_bits(np.asarray(vals, dtype=np.uint64), w)
            return g.input_evaluator(bits.reshape(-1)).reshape(n, w, 2)
        return g.input_garbler(n * w).reshape(n, w, 2)

    def _output(self, labels, n, w, tag):
        bits = self.gates.output(labels.reshape(-1, 2)).reshape(n, w)
        self.outputs[tag] = _join_bits(bits)


class PlaintextDriver(ProtocolDriver):
    """Executes the same bytecode in the clear (lane=1).  Oracle + stand-in
    for paper-scale real executions; cost model = garbler's."""

    lane = 1
    dtype = np.uint64
    name = "gc-plaintext"

    def __init__(self, input_provider: InputProvider | None = None):
        self.input_provider = input_provider
        self.outputs: dict[int, np.ndarray] = {}
        self.cost_model = GCCostModel(role="garbler")

    def cost(self, instr: Instr) -> float:
        return self.cost_model.cost(instr)

    @staticmethod
    def _m(w: int) -> np.uint64:
        return np.uint64((1 << w) - 1 if w < 64 else 0xFFFFFFFFFFFFFFFF)

    def execute(self, op: Op, imm: tuple, outs, ins) -> None:
        # The bytecode is wire-addressed (count*width slots per value); a
        # plaintext value lives at its element's first wire slot (stride w).
        w = imm[1] if len(imm) > 1 else 1
        if op == Op.MAC8:
            v = [ins[0][::8, 0], ins[1][::8, 0], ins[2][::imm[2], 0]]
        elif op == Op.XNOR_POP_SIGN:
            v = [ins[0][::1, 0], ins[1][::1, 0]]
        elif op == Op.SELECT:
            v = [ins[0][::1, 0], ins[1][::w, 0], ins[2][::w, 0]]
        elif op == Op.PAIR_JOIN:
            v = []  # handled inline (imm layout differs: na, nb, w, kw)
        else:
            v = [x[::w, 0] for x in ins]
        if op == Op.INPUT:
            n, w, party, tag = imm[0], imm[1], imm[2], imm[3]
            outs[0][::w, 0] = np.asarray(self.input_provider(tag),
                                         dtype=np.uint64) & self._m(w)
        elif op == Op.OUTPUT:
            n, w, tag = imm[0], imm[1], imm[2]
            self.outputs[tag] = np.array(v[0]) & self._m(w)
        elif op == Op.COPY:
            outs[0][...] = ins[0]
        elif op == Op.ADD:
            outs[0][::w, 0] = (v[0] + v[1]) & self._m(w)
        elif op == Op.SUB:
            outs[0][::w, 0] = (v[0] - v[1]) & self._m(w)
        elif op == Op.MUL:
            outs[0][::w, 0] = (v[0] * v[1]) & self._m(w)
        elif op == Op.XOR:
            outs[0][::w, 0] = v[0] ^ v[1]
        elif op == Op.AND:
            outs[0][::w, 0] = v[0] & v[1]
        elif op == Op.OR:
            outs[0][::w, 0] = v[0] | v[1]
        elif op == Op.NOT:
            outs[0][::w, 0] = (~v[0]) & self._m(w)
        elif op == Op.CMP_GE:
            kw = imm[2]
            outs[0][:, 0] = ((v[0] & self._m(kw)) >=
                             (v[1] & self._m(kw))).astype(np.uint64)
        elif op == Op.CMP_EQ:
            kw = imm[2]
            outs[0][:, 0] = ((v[0] & self._m(kw)) ==
                             (v[1] & self._m(kw))).astype(np.uint64)
        elif op == Op.SELECT:
            outs[0][::w, 0] = np.where(v[0].astype(bool), v[1], v[2])
        elif op == Op.MINMAX:
            kw = imm[2]
            ge = (v[0] & self._m(kw)) >= (v[1] & self._m(kw))
            outs[0][::w, 0] = np.where(ge, v[1], v[0])
            outs[1][::w, 0] = np.where(ge, v[0], v[1])
        elif op == Op.SORT_LOCAL:
            kw = imm[2]
            desc = bool(imm[3]) if len(imm) > 3 else False
            order = np.argsort(v[0] & self._m(kw), kind="stable")
            if desc:
                order = order[::-1]
            outs[0][::w, 0] = v[0][order]
        elif op == Op.REVERSE:
            outs[0][::w, 0] = v[0][::-1]
        elif op == Op.PAIR_JOIN:
            na, nb, w, kw = imm[0], imm[1], imm[2], imm[3]
            a = np.repeat(ins[0][::w, 0].copy(), nb)
            b = np.tile(ins[1][::w, 0].copy(), na)
            km = self._m(kw)
            eq = (a & km) == (b & km)
            half = (w - kw) // 2
            pa = (a >> np.uint64(kw)) & self._m(half)
            pb = (b >> np.uint64(kw)) & self._m(w - kw - half)
            packed = ((a & km) | (pa << np.uint64(kw))
                      | (pb << np.uint64(kw + half))) & self._m(w)
            outs[0][::w, 0] = np.where(eq, packed, np.uint64(0))
        elif op == Op.MAC8:
            nr, nj, acc_w = imm[0], imm[1], imm[2]
            m = (v[0] & self._m(8)).reshape(nr, nj)
            vec = (v[1] & self._m(8))[None, :]
            prod = (m * vec) & self._m(16)
            tot = prod.astype(np.uint64).sum(axis=1) & self._m(acc_w)
            outs[0][::acc_w, 0] = (v[2] + tot) & self._m(acc_w)
        elif op == Op.XNOR_POP_SIGN:
            nr, nj = imm[0], imm[1]
            m = (v[0] & np.uint64(1)).reshape(nr, nj)
            vec = (v[1] & np.uint64(1))[None, :]
            cnt = (1 - (m ^ vec).astype(np.int64)).sum(axis=1)
            outs[0][:, 0] = (cnt >= (nj + 1) // 2).astype(np.uint64)
        elif op == Op.REDUCE_ADD:
            n, w = imm[0], imm[1]
            outs[0][0, 0] = np.uint64(int(v[0].sum()) & int(self._m(w)))
        else:
            raise NotImplementedError(f"plaintext driver: {op}")


def run_two_party(garbler_prog: Program, evaluator_prog: Program,
                  garbler_inputs: InputProvider,
                  evaluator_inputs: InputProvider,
                  use_memmap: bool = False,
                  channel_depth: int = 256,
                  ) -> dict[int, np.ndarray]:
    """Run garbler + evaluator engines on threads; returns evaluator outputs.

    The two programs must come from the same bytecode but may be planned with
    different memory budgets (each party swaps independently, §4).  The
    party stream rides a private two-endpoint in-process fabric; Session
    runs the same drivers over a shared (possibly TCP/shaped) fabric."""
    ch = PartyChannel(depth=channel_depth)
    gd = GarblerDriver(ch, garbler_inputs)
    ed = EvaluatorDriver(ch, evaluator_inputs)
    err: list[Exception] = []

    def _g():
        try:
            Engine(garbler_prog, gd, use_memmap=use_memmap).run()
        except Exception as e:  # pragma: no cover
            err.append(e)

    tg = threading.Thread(target=_g, daemon=True)
    tg.start()
    Engine(evaluator_prog, ed, use_memmap=use_memmap).run()
    tg.join()
    if err:
        raise err[0]
    return ed.outputs
