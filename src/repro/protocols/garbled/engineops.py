"""The AND-XOR *engine* (§4.2/§4.3): expands each bytecode instruction into a
subcircuit of AND/XOR gates at runtime.

The same code drives both parties (it only talks to the `Gates` interface),
which is what guarantees the two interpreters stay in lock-step on the table
stream.  Values are label tensors shaped (n, w, 2): n vector elements of w
bits; bit 0 is the LSB.  Wire shuffles (shifts, broadcasts, bit packing) are
free — they are just numpy reindexing of labels.

Subcircuits follow the classic constructions (Kolesnikov–Schneider adders,
§7.3 'based on those used by Obliv-C'): ripple-carry add/sub (w-1 ANDs),
comparison via borrow chain (w ANDs), mux (w ANDs), school multiplier
(~w^2 ANDs), bitonic compare-exchange networks for sort/merge.
"""

from __future__ import annotations

import numpy as np

from .gates import Gates


def _bit(x, i):
    return x[:, i]


def _stack(cols):
    return np.stack(cols, axis=1)


class AndXorOps:
    def __init__(self, gb: Gates):
        self.gb = gb

    # -- arithmetic -----------------------------------------------------------

    def add(self, a, b, cin=None, want_carry: bool = False):
        gb = self.gb
        n, w, _ = a.shape
        outs = []
        c = cin
        for i in range(w):
            ai, bi = _bit(a, i), _bit(b, i)
            if c is None:
                outs.append(gb.xor(ai, bi))
                if i < w - 1 or want_carry:
                    c = gb.and_(ai, bi)
            else:
                axc = gb.xor(ai, c)
                bxc = gb.xor(bi, c)
                outs.append(gb.xor(axc, bi))
                if i < w - 1 or want_carry:
                    c = gb.xor(gb.and_(axc, bxc), c)
        s = _stack(outs)
        return (s, c) if want_carry else s

    def sub(self, a, b):
        gb = self.gb
        n, w, _ = a.shape
        nb = _stack([gb.not_(_bit(b, i)) for i in range(w)])
        cin = gb.const_ones(n)
        return self.add(a, nb, cin=cin)

    def mul(self, a, b):
        """Truncated w-bit product (school method)."""
        gb = self.gb
        n, w, _ = a.shape
        acc = None
        for i in range(w):
            bi = np.broadcast_to(_bit(b, i)[:, None, :], (n, w - i, 2))
            pp = _stack([gb.and_(bi[:, k], _bit(a, k)) for k in range(w - i)])
            if acc is None:
                acc = pp
            else:
                hi = self.add(acc[:, i:], pp)
                acc = np.concatenate([acc[:, :i], hi], axis=1)
        return acc

    def reduce_add(self, a):
        """(n, w) -> (1, w): tree sum over the n vector elements."""
        vals = a
        while vals.shape[0] > 1:
            m = vals.shape[0] // 2
            s = self.add(vals[:m], vals[m:2 * m])
            if vals.shape[0] % 2:
                s = np.concatenate([s, vals[2 * m:]], axis=0)
            vals = s
        return vals

    # -- comparison / selection -------------------------------------------------

    def cmp_ge(self, a, b, key_w: int | None = None):
        """Unsigned a >= b: carry-out of a + ~b + 1.  Returns (n, 1, 2)."""
        gb = self.gb
        n, w, _ = a.shape
        kw = key_w or w
        c = gb.const_ones(n)
        for i in range(kw):
            ai = _bit(a, i)
            nbi = gb.not_(_bit(b, i))
            axc = gb.xor(ai, c)
            bxc = gb.xor(nbi, c)
            c = gb.xor(gb.and_(axc, bxc), c)
        return c[:, None, :]

    def cmp_eq(self, a, b, key_w: int | None = None):
        gb = self.gb
        n, w, _ = a.shape
        kw = key_w or w
        bits = [gb.not_(gb.xor(_bit(a, i), _bit(b, i))) for i in range(kw)]
        while len(bits) > 1:
            nxt = [gb.and_(bits[i], bits[i + 1])
                   for i in range(0, len(bits) - 1, 2)]
            if len(bits) % 2:
                nxt.append(bits[-1])
            bits = nxt
        return bits[0][:, None, :]

    def select(self, s, a, b):
        """s ? a : b, bitwise mux; s is (n, 1, 2)."""
        gb = self.gb
        n, w, _ = a.shape
        sb = np.broadcast_to(s, (n, w, 2))
        out = []
        for i in range(w):
            d = gb.xor(_bit(a, i), _bit(b, i))
            out.append(gb.xor(gb.and_(sb[:, i], d), _bit(b, i)))
        return _stack(out)

    def minmax(self, a, b, key_w: int):
        ge = self.cmp_ge(a, b, key_w)          # a >= b on keys
        mn = self.select(ge, b, a)
        mx = self.select(ge, a, b)
        return mn, mx

    # -- composite workload kernels ----------------------------------------------

    def sort_local(self, a, key_w: int, direction_up: bool = True,
                   merge_only: bool = False):
        """Bitonic sort (or, with ``merge_only``, just the final merging
        network applied to an already-bitonic input) of the n elements
        within one value (n power of two).

        The network layout is public, so lane shuffles are free; only the
        compare-exchanges cost gates.
        """
        n, w, _ = a.shape
        assert n & (n - 1) == 0, "bitonic sort needs power-of-two chunk"
        v = a
        k = 2 * n if merge_only else 2
        while k <= 2 * n if merge_only else k <= n:
            j = min(k, n) // 2 if merge_only else k // 2
            while j >= 1:
                idx = np.arange(n)
                partner = idx ^ j
                lo = idx[idx < partner]
                hi = lo ^ j
                up = ((lo & k) == 0) == direction_up  # per-pair direction
                if merge_only:
                    up = np.full(len(lo), direction_up)
                mn, mx = self.minmax(v[lo], v[hi], key_w)
                new = np.array(v)
                new[lo] = np.where(up[:, None, None], mn, mx)
                new[hi] = np.where(up[:, None, None], mx, mn)
                v = new
                j //= 2
            if merge_only:
                break
            k *= 2
        return v

    def bitonic_merge(self, a, key_w: int):
        """Sort a BITONIC sequence (n, w) ascending: log(n) half-cleaner
        stages — cheaper than a full bitonic sort's log^2(n) stages."""
        n, w, _ = a.shape
        assert n & (n - 1) == 0
        v = a
        j = n // 2
        while j >= 1:
            idx = np.arange(n)
            partner = idx ^ j
            lo = idx[idx < partner]
            hi = lo ^ j
            mn, mx = self.minmax(v[lo], v[hi], key_w)
            new = np.array(v)
            new[lo] = mn
            new[hi] = mx
            v = new
            j //= 2
        return v

    def merge_step(self, a, b, key_w: int):
        """Merge two sorted chunks (each (n, w)) -> (low, high) sorted chunks.

        Comparing ascending `a` against reversed `b` half-cleans the pair:
        the element-wise mins and maxes are each bitonic, so one
        bitonic_merge per side finishes the job.  This is the building block
        of the chunked 'merge'/'sort' workloads.
        """
        mn, mx = self.minmax(a, b[::-1], key_w)
        return (self.bitonic_merge(mn, key_w), self.bitonic_merge(mx, key_w))

    def pair_join(self, a, b, key_w: int):
        """Loop-join cell: all (i, j) pairs, equality on keys, output packed
        record (key | payload_a | payload_b) or zeros.  a is (na, w), b is
        (nb, w); output (na*nb, w)."""
        na, w, _ = a.shape
        nb = b.shape[0]
        aa = np.repeat(a, nb, axis=0)
        bb = np.tile(b, (na, 1, 1))
        eq = self.cmp_eq(aa, bb, key_w)
        half = (w - key_w) // 2
        packed = np.concatenate(
            [aa[:, :key_w], aa[:, key_w:key_w + half],
             bb[:, key_w:key_w + (w - key_w - half)]], axis=1)
        zeros = _stack([self.gb.const_bits(np.zeros(na * nb, dtype=np.uint8))
                        for _ in range(1)])
        zeros = np.broadcast_to(zeros, packed.shape)
        return self.select(eq, packed, zeros)

    def dot8(self, m, v, acc, nr: int, nj: int, acc_w: int = 32):
        """acc[r] += sum_j M[r,j] * v[j] with 8-bit operands.

        m is (nr*nj, 8), v is (nj, 8), acc is (nr, acc_w).
        Products are computed at 16 bits, the j-reduction tree widens to
        acc_w, and the result is added into acc.
        """
        mm = m.reshape(nr, nj, 8, 2)
        vv = np.broadcast_to(v[None], (nr, nj, 8, 2))
        prods = []
        a2 = mm.reshape(nr * nj, 8, 2)
        b2 = vv.reshape(nr * nj, 8, 2)
        prod16 = self._mul_widening(a2, b2)          # (nr*nj, 16)
        prod16 = prod16.reshape(nr, nj, 16, 2)
        # reduce over j with width growth
        vals = [prod16[:, j] for j in range(nj)]
        width = 16
        while len(vals) > 1:
            width = min(width + 1, acc_w)
            nxt = []
            for i in range(0, len(vals) - 1, 2):
                x = self._zext(vals[i], width)
                y = self._zext(vals[i + 1], width)
                nxt.append(self.add(x, y))
            if len(vals) % 2:
                nxt.append(self._zext(vals[-1], width))
            vals = nxt
        total = self._zext(vals[0], acc_w)
        return self.add(acc, total)

    def _mul_widening(self, a, b):
        """(n, w) x (n, w) -> (n, 2w) full product.

        Shifted, zero-extended partial products summed with a pairwise adder
        tree (shifts/extensions are free wire placement; the single constant
        zero wire is fanned out)."""
        n, w, _ = a.shape
        gb = self.gb
        zero = gb.const_bits(np.zeros(n, dtype=np.uint8))[:, None, :]
        pps = []
        for i in range(w):
            bi = np.broadcast_to(_bit(b, i)[:, None, :], (n, w, 2))
            pp = _stack([gb.and_(bi[:, k], _bit(a, k)) for k in range(w)])
            low = np.broadcast_to(zero, (n, i, 2))
            high = np.broadcast_to(zero, (n, w - i, 2))
            pps.append(np.concatenate([low, pp, high], axis=1))
        while len(pps) > 1:
            nxt = [self.add(pps[j], pps[j + 1])
                   for j in range(0, len(pps) - 1, 2)]
            if len(pps) % 2:
                nxt.append(pps[-1])
            pps = nxt
        return pps[0]

    def _zext(self, a, w: int):
        n, cur, _ = a.shape
        if cur >= w:
            return a[:, :w]
        z = self.gb.const_bits(np.zeros(n, dtype=np.uint8))
        pad = np.broadcast_to(z[:, None, :], (n, w - cur, 2))
        return np.concatenate([a, pad], axis=1)

    def xnor_pop_sign(self, m, v, nr: int, nj: int):
        """Binary FC layer cell (XONN): out[r] = sign(2*popcount_j(
        xnor(M[r,j], v[j])) - nj) as a single bit.  m is (nr*nj, 1),
        v is (nj, 1); output (nr, 1)."""
        gb = self.gb
        mm = m.reshape(nr, nj, 2)
        vv = np.broadcast_to(v[:, 0, :][None], (nr, nj, 2))
        xn = gb.not_(gb.xor(mm.reshape(-1, 2), vv.reshape(-1, 2)))
        bits = xn.reshape(nr, nj, 2)
        # popcount: adder tree over 1-bit values with width growth
        vals = [bits[:, j][:, None, :] for j in range(nj)]
        while len(vals) > 1:
            nxt = []
            w = vals[0].shape[1]
            for i in range(0, len(vals) - 1, 2):
                x = self._zext(vals[i], w + 1)
                y = self._zext(vals[i + 1], w + 1)
                nxt.append(self.add(x, y))
            if len(vals) % 2:
                nxt.append(self._zext(vals[-1], w + 1))
            vals = nxt
        cnt = vals[0]                              # (nr, wc)
        thresh = (nj + 1) // 2
        wc = cnt.shape[1]
        tbits = np.array([(thresh >> i) & 1 for i in range(wc)], dtype=np.uint8)
        tlab = _stack([gb.const_bits(np.full(nr, tbits[i], dtype=np.uint8))
                       for i in range(wc)])
        return self.cmp_ge(cnt, tlab)
