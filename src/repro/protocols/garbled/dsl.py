"""The Integer DSL (paper Fig. 5), vectorized.

``Integer(width, count)`` is a *vector* of ``count`` secret integers of
``width`` bits — one DSL value = one bytecode operand (§4.2 coarsening).
Operators emit bytecode; nothing is computed at trace time.  A value's
wires occupy count*width contiguous slots and must fit one MAGE-virtual
page, so workloads chunk their data at the library level (lists of
Integers), exactly like the record lists in the paper's workloads.
"""

from __future__ import annotations

import enum

from ...core.bytecode import Op
from ...core.dsl import Value


class Party(enum.IntEnum):
    Garbler = 0
    Evaluator = 1


class Integer(Value):
    __slots__ = ("width", "count")

    def __init__(self, width: int, count: int = 1, builder=None):
        super().__init__(width * count, builder)
        self.width = width
        self.count = count

    # -- I/O --------------------------------------------------------------------

    def mark_input(self, party: Party, tag: int = 0) -> "Integer":
        self.builder.emit(Op.INPUT, outs=(self.span,),
                          imm=(self.count, self.width, int(party), tag))
        return self

    def mark_output(self, tag: int = 0) -> None:
        self.builder.emit(Op.OUTPUT, ins=(self.span,),
                          imm=(self.count, self.width, tag))

    # -- helpers ----------------------------------------------------------------

    def _like(self, width=None, count=None) -> "Integer":
        return Integer(width or self.width, count or self.count, self.builder)

    def _bin(self, op: Op, other: "Integer", out: "Integer" | None = None,
             imm_extra: tuple = ()) -> "Integer":
        assert self.width == other.width and self.count == other.count, \
            f"shape mismatch {self.width}x{self.count} vs {other.width}x{other.count}"
        r = out or self._like()
        self.builder.emit(op, outs=(r.span,), ins=(self.span, other.span),
                          imm=(self.count, self.width) + imm_extra)
        return r

    # -- operators ----------------------------------------------------------------

    def __add__(self, o): return self._bin(Op.ADD, o)
    def __sub__(self, o): return self._bin(Op.SUB, o)
    def __mul__(self, o): return self._bin(Op.MUL, o)
    def __xor__(self, o): return self._bin(Op.XOR, o)
    def __and__(self, o): return self._bin(Op.AND, o)
    def __or__(self, o): return self._bin(Op.OR, o)

    def __invert__(self):
        r = self._like()
        self.builder.emit(Op.NOT, outs=(r.span,), ins=(self.span,),
                          imm=(self.count, self.width))
        return r

    def __ge__(self, o) -> "Integer":
        return self.cmp_ge(o)

    def __eq__(self, o) -> "Integer":  # type: ignore[override]
        return self.cmp_eq(o)

    __hash__ = None  # type: ignore[assignment]

    def cmp_ge(self, o: "Integer", key_w: int | None = None) -> "Integer":
        r = Integer(1, self.count, self.builder)
        self.builder.emit(Op.CMP_GE, outs=(r.span,), ins=(self.span, o.span),
                          imm=(self.count, self.width, key_w or self.width))
        return r

    def cmp_eq(self, o: "Integer", key_w: int | None = None) -> "Integer":
        r = Integer(1, self.count, self.builder)
        self.builder.emit(Op.CMP_EQ, outs=(r.span,), ins=(self.span, o.span),
                          imm=(self.count, self.width, key_w or self.width))
        return r

    def select(self, a: "Integer", b: "Integer") -> "Integer":
        """self (1-bit) ? a : b, element-wise."""
        assert self.width == 1 and a.count == b.count == self.count
        r = a._like()
        self.builder.emit(Op.SELECT, outs=(r.span,),
                          ins=(self.span, a.span, b.span),
                          imm=(a.count, a.width))
        return r

    def minmax(self, o: "Integer", key_w: int) -> tuple["Integer", "Integer"]:
        mn, mx = self._like(), self._like()
        self.builder.emit(Op.MINMAX, outs=(mn.span, mx.span),
                          ins=(self.span, o.span),
                          imm=(self.count, self.width, key_w))
        return mn, mx

    def sort_local(self, key_w: int, descending: bool = False,
                   merge_only: bool = False) -> "Integer":
        r = self._like()
        self.builder.emit(Op.SORT_LOCAL, outs=(r.span,), ins=(self.span,),
                          imm=(self.count, self.width, key_w,
                               int(descending), int(merge_only)))
        return r

    def reverse(self) -> "Integer":
        r = self._like()
        self.builder.emit(Op.REVERSE, outs=(r.span,), ins=(self.span,),
                          imm=(self.count, self.width))
        return r

    def pair_join(self, o: "Integer", key_w: int) -> "Integer":
        r = Integer(self.width, self.count * o.count, self.builder)
        self.builder.emit(Op.PAIR_JOIN, outs=(r.span,),
                          ins=(self.span, o.span),
                          imm=(self.count, o.count, self.width, key_w))
        return r

    def mac8(self, vec: "Integer", acc: "Integer") -> "Integer":
        """self: (nr*nj) 8-bit matrix chunk; vec: nj 8-bit; acc: nr wide."""
        nr, nj = acc.count, vec.count
        assert self.count == nr * nj and self.width == 8 and vec.width == 8
        r = acc._like()
        self.builder.emit(Op.MAC8, outs=(r.span,),
                          ins=(self.span, vec.span, acc.span),
                          imm=(nr, nj, acc.width))
        return r

    def xnor_pop_sign(self, vec: "Integer", rows: int) -> "Integer":
        nj = vec.count
        assert self.width == 1 and vec.width == 1 and self.count == rows * nj
        r = Integer(1, rows, self.builder)
        self.builder.emit(Op.XNOR_POP_SIGN, outs=(r.span,),
                          ins=(self.span, vec.span), imm=(rows, nj))
        return r

    def reduce_add(self) -> "Integer":
        r = Integer(self.width, 1, self.builder)
        self.builder.emit(Op.REDUCE_ADD, outs=(r.span,), ins=(self.span,),
                          imm=(self.count, self.width))
        return r


def Bit(count: int = 1, builder=None) -> Integer:
    """Bit is an alias for Integer<1> (paper §6.2.1)."""
    return Integer(1, count, builder)
