"""Analytic gate counts + timing/bandwidth model for garbled circuits.

``gate_cost(op, imm)`` mirrors the subcircuits in engineops.py exactly (a
test asserts formula == batcher counters for every op), so the timing
simulator can price paper-scale traces without executing cryptography.

Timing constants are calibrated to the paper's era (fixed-key AES-NI
garbling, §8: ~10-20M AND gates/s on a D16d_v4 core): garbling an AND
costs 4 AES calls + a 32 B table write, evaluation 2 AES calls.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ...core.bytecode import DIRECTIVES, Instr, Op


def _adder_ands(w: int, want_carry: bool = False) -> int:
    return w if want_carry else w - 1


def _tree_widen_ands(n: int, w0: int, cap: int) -> int:
    """ANDs for a pairwise reduction tree over n values of width w0 where
    each level widens by one bit up to ``cap`` (matches dot8/popcount)."""
    total = 0
    vals = n
    w = w0
    while vals > 1:
        w = min(w + 1, cap)
        pairs = vals // 2
        total += pairs * _adder_ands(w)
        vals = pairs + (vals % 2)
    return total


def _mul_widening_ands(w: int) -> int:
    # per element: w partial-product rows of w ANDs + a (w-1)-adder tree
    # at full 2w width (shifted+zero-extended rows)
    return w * w + (w - 1) * _adder_ands(2 * w)


def _bitonic_sort_ce(n: int) -> int:
    """compare-exchanges in a bitonic sort of n lanes."""
    total = 0
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            total += n // 2
            j //= 2
        k *= 2
    return total


def _bitonic_merge_ce(n: int) -> int:
    total = 0
    j = n // 2
    while j >= 1:
        total += n // 2
        j //= 2
    return total


def gate_cost(op: Op, imm: tuple) -> tuple[int, int]:
    """Returns (AND gates, const wires) for one instruction.  XORs are free
    and not modeled for time (they are ~50x cheaper than ANDs)."""
    if op in (Op.XOR, Op.AND, Op.OR, Op.NOT):
        n, w = imm[0], imm[1]
        if op == Op.AND:
            return n * w, 0
        if op == Op.OR:
            return n * w, 0
        return 0, 0
    if op in (Op.ADD,):
        n, w = imm[0], imm[1]
        return n * _adder_ands(w), 0
    if op == Op.SUB:
        n, w = imm[0], imm[1]
        return n * _adder_ands(w), n
    if op == Op.MUL:
        n, w = imm[0], imm[1]
        # truncated school multiplier
        ands = sum(w - i for i in range(w))            # partial products
        ands += sum(_adder_ands(w - i) for i in range(1, w))
        return n * ands, 0
    if op == Op.CMP_GE:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * kw, n
    if op == Op.CMP_EQ:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * (kw - 1), 0         # xnor is free; AND tree costs kw-1
    if op == Op.SELECT:
        n, w = imm[0], imm[1]
        return n * w, 0
    if op == Op.MINMAX:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * (kw + 2 * w), n
    if op == Op.SORT_LOCAL:
        n, w, kw = imm[0], imm[1], imm[2]
        merge_only = bool(imm[4]) if len(imm) > 4 else False
        ce = _bitonic_merge_ce(n) if merge_only else _bitonic_sort_ce(n)
        return ce * (kw + 2 * w), ce
    if op == Op.REVERSE:
        return 0, 0
    if op == Op.PAIR_JOIN:
        na, nb, w, kw = imm[0], imm[1], imm[2], imm[3]
        m = na * nb
        return m * ((kw - 1) + w), m
    if op == Op.MAC8:
        nr, nj, acc_w = imm[0], imm[1], imm[2]
        ands = nr * nj * _mul_widening_ands(8)
        ands += nr * _tree_widen_ands(nj, 16, acc_w)
        ands += nr * _adder_ands(acc_w)               # final acc add
        return ands, nr * nj                          # const zero per product
    if op == Op.XNOR_POP_SIGN:
        nr, nj = imm[0], imm[1]
        ands = nr * _tree_widen_ands(nj, 1, 64)
        wc = _final_tree_width(nj, 1, 64)
        ands += nr * wc                                # cmp_ge vs constant
        return ands, nr * (wc + _tree_consts(nj))
    if op == Op.REDUCE_ADD:
        n, w = imm[0], imm[1]
        return (n - 1) * _adder_ands(w), 0
    if op in (Op.INPUT, Op.OUTPUT, Op.COPY, Op.REVERSE):
        return 0, 0
    if op in (Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER) or op in DIRECTIVES:
        return 0, 0
    raise NotImplementedError(f"gate_cost: {op}")


def _final_tree_width(n: int, w0: int, cap: int) -> int:
    w = w0
    vals = n
    while vals > 1:
        w = min(w + 1, cap)
        vals = vals // 2 + (vals % 2)
    return w


def _tree_consts(n: int) -> int:
    """zero-extension const wires per row in the widening tree (upper bound
    folded into the timing model only; exact count asserted in tests via the
    batcher counters, not this helper)."""
    return 0


# ---------------------------------------------------------------------------
# Chunked (vectorized) gate-cost math.
#
# ``gate_cost_chunk`` prices a whole record chunk at once: every formula
# above restated over int64 arrays, with the log-depth helpers
# (_tree_widen_ands, _final_tree_width, the bitonic CE counts) run as
# masked vector loops of at most log2(max lane count) iterations.  All
# intermediate counts are exact int64, so per-instruction results are
# IDENTICAL to the scalar ``gate_cost`` — the contract the array-core
# timing simulators rely on (property-tested in tests/test_array_sim.py).
# ---------------------------------------------------------------------------


def _floor_log2(n: np.ndarray) -> np.ndarray:
    """Exact floor(log2(n)) for positive int64 n (frexp exponent - 1)."""
    return np.frexp(n.astype(np.float64))[1].astype(np.int64) - 1


def _bitonic_sort_ce_vec(n: np.ndarray) -> np.ndarray:
    lg = np.where(n >= 2, _floor_log2(np.maximum(n, 1)), 0)
    return np.where(n >= 2, (n // 2) * (lg * (lg + 1) // 2), 0)


def _bitonic_merge_ce_vec(n: np.ndarray) -> np.ndarray:
    half = n // 2
    lg = np.where(half >= 1, _floor_log2(np.maximum(half, 1)) + 1, 0)
    return half * lg


def _tree_widen_vec(n: np.ndarray, w0: np.ndarray, cap: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized tree walk: (total widening-adder ANDs, final width)."""
    total = np.zeros_like(n)
    vals = n.copy()
    w = np.broadcast_to(w0, n.shape).copy()
    cap = np.broadcast_to(cap, n.shape)
    while True:
        m = vals > 1
        if not m.any():
            break
        w[m] = np.minimum(w[m] + 1, cap[m])
        pairs = vals[m] // 2
        total[m] += pairs * _adder_ands_vec(w[m])
        vals[m] = pairs + vals[m] % 2
    return total, w


def _adder_ands_vec(w: np.ndarray) -> np.ndarray:
    return w - 1


def gate_cost_chunk(ops: np.ndarray, imm: np.ndarray,
                    n_imm: np.ndarray | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`gate_cost` over one chunk.

    ``ops`` is int64 [m]; ``imm`` the zero-padded [m, >=MAX_IMM] immediate
    matrix of a record chunk (raw int64 words — GC cost formulas only read
    integer immediates).  ``n_imm`` (optional, from the record heads)
    resolves SORT_LOCAL's ``len(imm) > 4`` merge flag exactly; with the
    zero-padded matrix the default is equivalent.  FREE rows (which the
    simulators never price) cost (0, 0).  Returns exact int64
    (AND gates, const wires) per instruction; raises NotImplementedError
    on ops the scalar formula would also reject.
    """
    ops = np.asarray(ops, dtype=np.int64)
    imm = np.asarray(imm, dtype=np.int64)
    m = ops.shape[0]
    ands = np.zeros(m, dtype=np.int64)
    consts = np.zeros(m, dtype=np.int64)
    handled = np.zeros(m, dtype=bool)

    def sel(*which: Op) -> np.ndarray:
        mk = np.zeros(m, dtype=bool)
        for o in which:
            mk |= ops == int(o)
        handled[mk] = True
        return mk

    c0, c1, c2, c3 = imm[:, 0], imm[:, 1], imm[:, 2], imm[:, 3]

    mk = sel(Op.AND, Op.OR, Op.SELECT)
    ands[mk] = c0[mk] * c1[mk]
    sel(Op.XOR, Op.NOT, Op.REVERSE, Op.COPY, Op.INPUT, Op.OUTPUT, Op.FREE)

    mk = sel(Op.ADD)
    ands[mk] = c0[mk] * (c1[mk] - 1)
    mk = sel(Op.SUB)
    ands[mk] = c0[mk] * (c1[mk] - 1)
    consts[mk] = c0[mk]
    mk = sel(Op.MUL)
    if mk.any():
        w = c1[mk]
        # partial products w(w+1)/2 plus the truncated adder chain
        ands[mk] = c0[mk] * (w * (w + 1) // 2 + (w - 1) * (w - 2) // 2)
    mk = sel(Op.CMP_GE)
    ands[mk] = c0[mk] * c2[mk]
    consts[mk] = c0[mk]
    mk = sel(Op.CMP_EQ)
    ands[mk] = c0[mk] * (c2[mk] - 1)
    mk = sel(Op.MINMAX)
    ands[mk] = c0[mk] * (c2[mk] + 2 * c1[mk])
    consts[mk] = c0[mk]
    mk = sel(Op.SORT_LOCAL)
    if mk.any():
        merge = (imm[:, 4][mk] != 0) if imm.shape[1] > 4 \
            else np.zeros(int(mk.sum()), dtype=bool)
        if n_imm is not None:
            merge &= np.asarray(n_imm, dtype=np.int64)[mk] > 4
        ce = np.where(merge, _bitonic_merge_ce_vec(c0[mk]),
                      _bitonic_sort_ce_vec(c0[mk]))
        ands[mk] = ce * (c2[mk] + 2 * c1[mk])
        consts[mk] = ce
    mk = sel(Op.PAIR_JOIN)
    pairs = c0[mk] * c1[mk]
    ands[mk] = pairs * ((c3[mk] - 1) + c2[mk])
    consts[mk] = pairs
    mk = sel(Op.MAC8)
    if mk.any():
        nr, nj, acc_w = c0[mk], c1[mk], c2[mk]
        tree, _ = _tree_widen_vec(nj, np.int64(16), acc_w)
        ands[mk] = nr * nj * _mul_widening_ands(8) + nr * tree \
            + nr * _adder_ands_vec(acc_w)
        consts[mk] = nr * nj
    mk = sel(Op.XNOR_POP_SIGN)
    if mk.any():
        nr, nj = c0[mk], c1[mk]
        tree, wc = _tree_widen_vec(nj, np.int64(1), np.int64(64))
        ands[mk] = nr * tree + nr * wc
        consts[mk] = nr * (wc + _tree_consts(nj))
    mk = sel(Op.REDUCE_ADD)
    ands[mk] = (c0[mk] - 1) * (c1[mk] - 1)
    sel(Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER, *DIRECTIVES)

    if not handled.all():
        bad = int(ops[~handled][0])
        raise NotImplementedError(f"gate_cost_chunk: op {bad}")
    return ands, consts


@dataclasses.dataclass
class GCCostModel:
    """Seconds/bytes per gate for the timing simulator."""
    and_s: float = 80e-9          # garble an AND (4 fixed-key AES + table)
    and_eval_s: float = 40e-9     # evaluate an AND (2 AES)
    xor_s: float = 2e-9
    instr_overhead_s: float = 2e-7
    table_bytes: int = 32         # 2 ciphertexts per AND (half gates)
    label_bytes: int = 16
    role: str = "garbler"

    def cost(self, instr: Instr) -> float:
        ands, consts = gate_cost(instr.op, instr.imm)
        per = self.and_s if self.role == "garbler" else self.and_eval_s
        return self.instr_overhead_s + ands * per

    def bytes_of(self, instr: Instr) -> int:
        ands, consts = gate_cost(instr.op, instr.imm)
        return ands * self.table_bytes + consts * self.label_bytes

    # -- chunk-level API (per-element identical to cost()/bytes_of()) --------

    def cost_chunk(self, ops: np.ndarray, imm: np.ndarray,
                   n_imm: np.ndarray | None = None) -> np.ndarray:
        """Per-instruction seconds for a record chunk (float64 [m])."""
        ands, _ = gate_cost_chunk(ops, imm, n_imm)
        per = self.and_s if self.role == "garbler" else self.and_eval_s
        return self.instr_overhead_s + ands.astype(np.float64) * per

    def bytes_chunk(self, ops: np.ndarray, imm: np.ndarray,
                    n_imm: np.ndarray | None = None) -> np.ndarray:
        """Per-instruction GC table traffic for a record chunk (int64)."""
        ands, consts = gate_cost_chunk(ops, imm, n_imm)
        return ands * self.table_bytes + consts * self.label_bytes
