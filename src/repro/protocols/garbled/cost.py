"""Analytic gate counts + timing/bandwidth model for garbled circuits.

``gate_cost(op, imm)`` mirrors the subcircuits in engineops.py exactly (a
test asserts formula == batcher counters for every op), so the timing
simulator can price paper-scale traces without executing cryptography.

Timing constants are calibrated to the paper's era (fixed-key AES-NI
garbling, §8: ~10-20M AND gates/s on a D16d_v4 core): garbling an AND
costs 4 AES calls + a 32 B table write, evaluation 2 AES calls.
"""

from __future__ import annotations

import dataclasses

from ...core.bytecode import DIRECTIVES, Instr, Op


def _adder_ands(w: int, want_carry: bool = False) -> int:
    return w if want_carry else w - 1


def _tree_widen_ands(n: int, w0: int, cap: int) -> int:
    """ANDs for a pairwise reduction tree over n values of width w0 where
    each level widens by one bit up to ``cap`` (matches dot8/popcount)."""
    total = 0
    vals = n
    w = w0
    while vals > 1:
        w = min(w + 1, cap)
        pairs = vals // 2
        total += pairs * _adder_ands(w)
        vals = pairs + (vals % 2)
    return total


def _mul_widening_ands(w: int) -> int:
    # per element: w partial-product rows of w ANDs + a (w-1)-adder tree
    # at full 2w width (shifted+zero-extended rows)
    return w * w + (w - 1) * _adder_ands(2 * w)


def _bitonic_sort_ce(n: int) -> int:
    """compare-exchanges in a bitonic sort of n lanes."""
    total = 0
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            total += n // 2
            j //= 2
        k *= 2
    return total


def _bitonic_merge_ce(n: int) -> int:
    total = 0
    j = n // 2
    while j >= 1:
        total += n // 2
        j //= 2
    return total


def gate_cost(op: Op, imm: tuple) -> tuple[int, int]:
    """Returns (AND gates, const wires) for one instruction.  XORs are free
    and not modeled for time (they are ~50x cheaper than ANDs)."""
    if op in (Op.XOR, Op.AND, Op.OR, Op.NOT):
        n, w = imm[0], imm[1]
        if op == Op.AND:
            return n * w, 0
        if op == Op.OR:
            return n * w, 0
        return 0, 0
    if op in (Op.ADD,):
        n, w = imm[0], imm[1]
        return n * _adder_ands(w), 0
    if op == Op.SUB:
        n, w = imm[0], imm[1]
        return n * _adder_ands(w), n
    if op == Op.MUL:
        n, w = imm[0], imm[1]
        # truncated school multiplier
        ands = sum(w - i for i in range(w))            # partial products
        ands += sum(_adder_ands(w - i) for i in range(1, w))
        return n * ands, 0
    if op == Op.CMP_GE:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * kw, n
    if op == Op.CMP_EQ:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * (kw - 1), 0         # xnor is free; AND tree costs kw-1
    if op == Op.SELECT:
        n, w = imm[0], imm[1]
        return n * w, 0
    if op == Op.MINMAX:
        n, w, kw = imm[0], imm[1], imm[2]
        return n * (kw + 2 * w), n
    if op == Op.SORT_LOCAL:
        n, w, kw = imm[0], imm[1], imm[2]
        merge_only = bool(imm[4]) if len(imm) > 4 else False
        ce = _bitonic_merge_ce(n) if merge_only else _bitonic_sort_ce(n)
        return ce * (kw + 2 * w), ce
    if op == Op.REVERSE:
        return 0, 0
    if op == Op.PAIR_JOIN:
        na, nb, w, kw = imm[0], imm[1], imm[2], imm[3]
        m = na * nb
        return m * ((kw - 1) + w), m
    if op == Op.MAC8:
        nr, nj, acc_w = imm[0], imm[1], imm[2]
        ands = nr * nj * _mul_widening_ands(8)
        ands += nr * _tree_widen_ands(nj, 16, acc_w)
        ands += nr * _adder_ands(acc_w)               # final acc add
        return ands, nr * nj                          # const zero per product
    if op == Op.XNOR_POP_SIGN:
        nr, nj = imm[0], imm[1]
        ands = nr * _tree_widen_ands(nj, 1, 64)
        wc = _final_tree_width(nj, 1, 64)
        ands += nr * wc                                # cmp_ge vs constant
        return ands, nr * (wc + _tree_consts(nj))
    if op == Op.REDUCE_ADD:
        n, w = imm[0], imm[1]
        return (n - 1) * _adder_ands(w), 0
    if op in (Op.INPUT, Op.OUTPUT, Op.COPY, Op.REVERSE):
        return 0, 0
    if op in (Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER) or op in DIRECTIVES:
        return 0, 0
    raise NotImplementedError(f"gate_cost: {op}")


def _final_tree_width(n: int, w0: int, cap: int) -> int:
    w = w0
    vals = n
    while vals > 1:
        w = min(w + 1, cap)
        vals = vals // 2 + (vals % 2)
    return w


def _tree_consts(n: int) -> int:
    """zero-extension const wires per row in the widening tree (upper bound
    folded into the timing model only; exact count asserted in tests via the
    batcher counters, not this helper)."""
    return 0


@dataclasses.dataclass
class GCCostModel:
    """Seconds/bytes per gate for the timing simulator."""
    and_s: float = 80e-9          # garble an AND (4 fixed-key AES + table)
    and_eval_s: float = 40e-9     # evaluate an AND (2 AES)
    xor_s: float = 2e-9
    instr_overhead_s: float = 2e-7
    table_bytes: int = 32         # 2 ciphertexts per AND (half gates)
    label_bytes: int = 16
    role: str = "garbler"

    def cost(self, instr: Instr) -> float:
        ands, consts = gate_cost(instr.op, instr.imm)
        per = self.and_s if self.role == "garbler" else self.and_eval_s
        return self.instr_overhead_s + ands * per

    def bytes_of(self, instr: Instr) -> int:
        ands, consts = gate_cost(instr.op, instr.imm)
        return ands * self.table_bytes + consts * self.label_bytes
