"""Fixed-key AES-128 for half-gates garbling (Bellare et al. [5]).

Labels are 128-bit values stored as uint64 pairs (little-endian lanes).  The
gate hash is the Davies–Meyer-style construction used by classic EMP-toolkit:

    H(x, i) = AES_k(sigma(x) XOR i) XOR sigma(x) XOR i,   sigma(x) = 2*x

where 2*x is doubling in GF(2^128) (poly x^128 + x^7 + x^2 + x + 1) and the
tweak ``i`` is the gate index.  The key is fixed and public.

This module is the *numpy* implementation used on the engine's hot path; a
jnp oracle and the TPU Pallas kernel (constant-time, lookup-free S-box) live
in ``repro.kernels.garble``.  All three must agree bit-exactly — tested
against each other and the FIPS-197 vector.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# AES tables
# ---------------------------------------------------------------------------


def _build_sbox() -> np.ndarray:
    # GF(2^8) inverse via log/antilog tables (generator 3)
    exp = np.zeros(256, dtype=np.int32)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x ^= ((x << 1) ^ (0x1B if x & 0x80 else 0)) & 0xFF  # x *= 3
    inv = np.zeros(256, dtype=np.uint8)
    for a in range(1, 256):
        inv[a] = exp[(255 - log[a]) % 255]
    s = np.zeros(256, dtype=np.uint8)
    for a in range(256):
        b = int(inv[a])
        res = 0x63
        for sh in range(5):
            res ^= ((b << sh) | (b >> (8 - sh))) & 0xFF
        s[a] = res
    return s


SBOX = _build_sbox()

# ShiftRows permutation on the 16-byte state (column-major AES state):
# byte i sits at row i%4, col i//4; row r rotates left by r.
SHIFT_ROWS = np.array([(i + 4 * (i % 4)) % 16 for i in range(16)],
                      dtype=np.intp)

FIXED_KEY = np.frombuffer(bytes(range(16)), dtype=np.uint8).copy()

RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10,
                 0x20, 0x40, 0x80, 0x1B, 0x36], dtype=np.uint8)


def key_schedule(key: np.ndarray = FIXED_KEY) -> np.ndarray:
    """Returns the 11 round keys as a (11, 16) uint8 array."""
    w = [key[4 * i:4 * i + 4].copy() for i in range(4)]
    for i in range(4, 44):
        t = w[i - 1].copy()
        if i % 4 == 0:
            t = np.roll(t, -1)
            t = SBOX[t]
            t[0] ^= RCON[i // 4 - 1]
        w.append(w[i - 4] ^ t)
    return np.concatenate(w).reshape(11, 16)


ROUND_KEYS = key_schedule()


def _xtime(b: np.ndarray) -> np.ndarray:
    return (((b.astype(np.uint16) << 1) ^
             np.where(b & 0x80, 0x1B, 0)) & 0xFF).astype(np.uint8)


def aes128_encrypt_blocks(blocks: np.ndarray,
                          round_keys: np.ndarray = ROUND_KEYS) -> np.ndarray:
    """AES-128 over a batch: blocks is (n, 16) uint8 -> (n, 16) uint8."""
    s = blocks ^ round_keys[0]
    for rnd in range(1, 10):
        s = SBOX[s]
        s = s[:, SHIFT_ROWS]
        # MixColumns on column-major state: columns are s[:, 4c:4c+4]
        v = s.reshape(-1, 4, 4)
        x = _xtime(v)
        rot1 = np.roll(v, -1, axis=2)
        rot2 = np.roll(v, -2, axis=2)
        rot3 = np.roll(v, -3, axis=2)
        mixed = x ^ rot1 ^ _xtime(rot1) ^ rot2 ^ rot3
        s = mixed.reshape(-1, 16) ^ round_keys[rnd]
    s = SBOX[s]
    s = s[:, SHIFT_ROWS]
    return s ^ round_keys[10]


# ---------------------------------------------------------------------------
# 128-bit label helpers (uint64 pairs, little-endian lanes)
# ---------------------------------------------------------------------------


def labels_to_blocks(lbl: np.ndarray) -> np.ndarray:
    """(n, 2) uint64 -> (n, 16) uint8 little-endian."""
    return lbl.astype("<u8").view(np.uint8).reshape(-1, 16)


def blocks_to_labels(blk: np.ndarray) -> np.ndarray:
    blk = np.ascontiguousarray(blk.reshape(-1, 16))
    return blk.view("<u8").reshape(-1, 2).astype(np.uint64)


def gf128_double(lbl: np.ndarray) -> np.ndarray:
    """x -> 2*x in GF(2^128) with poly 0x87 reduction; lbl is (n,2) uint64."""
    lo, hi = lbl[:, 0], lbl[:, 1]
    carry = hi >> np.uint64(63)
    nhi = (hi << np.uint64(1)) | (lo >> np.uint64(63))
    nlo = (lo << np.uint64(1)) ^ (carry * np.uint64(0x87))
    return np.stack([nlo, nhi], axis=1)


def tweak(gate_ids: np.ndarray) -> np.ndarray:
    """(n,) int64 gate indices -> (n, 2) uint64 tweak blocks."""
    t = np.zeros((len(gate_ids), 2), dtype=np.uint64)
    t[:, 0] = gate_ids.astype(np.uint64)
    return t


def hash_labels(lbl: np.ndarray, gate_ids: np.ndarray) -> np.ndarray:
    """H(x, i) = AES_k(2x ^ i) ^ 2x ^ i over a batch of labels."""
    y = gf128_double(lbl) ^ tweak(gate_ids)
    enc = aes128_encrypt_blocks(labels_to_blocks(y))
    return blocks_to_labels(enc) ^ y
