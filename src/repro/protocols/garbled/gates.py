"""Batched half-gates garbling/evaluation (ZRE15) with free XOR (KS08).

The garbler and evaluator run the SAME engine/subcircuit code against
different ``Gates`` implementations; every AND produces/consumes a 2-row
garbled table streamed over the party channel (§2.4.2 pipelining: the link
is bounded, so the full garbled circuit is never materialized).

Labels are (m, 2) uint64 arrays.  OT is simulated in-process (a trusted
OT functionality over the channel) — performance-faithful (we count OT
messages and bytes for the WAN model of §8.7) but not a real OT protocol.

Inter-party traffic rides the SAME transport fabric as the engine's NET_*
directives (``core.transport``): a :class:`PartyChannel` is a kind-tagged
window onto one (garbler_rank → evaluator_rank) link, so the garbled
stream crosses processes/machines whenever the fabric does.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ...core.transport import InprocTransport, Transport, TransportError
from .aes import hash_labels


@dataclasses.dataclass
class GateCounts:
    ands: int = 0
    xors: int = 0
    consts: int = 0


class PartyChannel:
    """Garbler→evaluator protocol stream over one fabric link.

    Each message kind (garbled tables, constants, garbler/evaluator
    inputs via OT, output decode bits) maps to a fixed tag on the
    ``(src, dst)`` link; both parties traverse the same bytecode in
    lockstep, so per-kind FIFO delivery — the transport's ordering
    contract — is exactly the ordering the protocol needs.

    Constructed bare (``PartyChannel()``) it brings its own private
    two-endpoint in-process fabric (rank 0 = garbler, rank 1 =
    evaluator) with the pending set bounded at ``depth`` messages, the
    §2.4.2 pipelining bound; in a Session, both parties' drivers get a
    channel over the session fabric's cross-party link instead."""

    TAGS = {"tab": 1, "const": 2, "gin": 3, "ot": 4, "dec": 5}

    #: a desynced pair (diverged programs, a driver bug) leaves one party
    #: waiting on a kind the other never sends; the timeout turns that
    #: deadlock into an error (the old single-queue channel failed fast on
    #: kind mismatch — per-kind FIFOs cannot, so they fail bounded instead)
    RECV_TIMEOUT_S = 600.0

    def __init__(self, transport: Transport | None = None,
                 src: int = 0, dst: int = 1, depth: int = 256,
                 recv_timeout: float | None = None):
        if transport is None:
            transport = InprocTransport(2)
        self.transport = transport
        self.src = src
        self.dst = dst
        self.recv_timeout = (self.RECV_TIMEOUT_S if recv_timeout is None
                             else recv_timeout)
        if depth and hasattr(transport, "set_depth"):
            transport.set_depth(src, dst, max_msgs=depth)
        self.ot_selections = 0

    def send(self, kind: str, arr: np.ndarray) -> None:
        # protocol messages are freshly built and never mutated by the
        # sender afterwards: skip the defensive copy on the hot path
        self.transport.send(self.src, self.dst, self.TAGS[kind], arr,
                            copy=False)

    def recv(self, kind: str) -> np.ndarray:
        try:
            return self.transport.recv(self.src, self.dst, self.TAGS[kind],
                                       timeout=self.recv_timeout)
        except TransportError as e:
            raise TransportError(
                f"party stream: no {kind!r} message on link "
                f"{self.src}->{self.dst} (protocol desync?): {e}") from e

    # -- stats (from the fabric's send-side accounting) -----------------------

    def _totals(self) -> tuple[int, int]:
        msgs = nbytes = 0
        for (s, d, _t), st in self.transport.stats().items():
            if (s, d) == (self.src, self.dst):
                msgs += st.messages
                nbytes += st.bytes
        return msgs, nbytes

    @property
    def messages(self) -> int:
        return self._totals()[0]

    @property
    def bytes_sent(self) -> int:
        return self._totals()[1]


def _mask(bits: np.ndarray, lbl: np.ndarray) -> np.ndarray:
    """bits (m,) {0,1} -> bits * lbl, label-wise."""
    return np.where(bits.astype(bool)[:, None], lbl, np.uint64(0))


def lsb(lbl: np.ndarray) -> np.ndarray:
    return (lbl[:, 0] & np.uint64(1)).astype(np.uint8)


class Gates:
    """Abstract batched gate interface; shapes are (m, 2) label arrays."""

    counts: GateCounts

    def xor(self, a, b):
        self.counts.xors += len(a)
        return a ^ b

    def not_(self, a):
        raise NotImplementedError

    def and_(self, a, b):
        raise NotImplementedError

    def const_bits(self, bits: np.ndarray):
        raise NotImplementedError

    def const_ones(self, m: int):
        return self.const_bits(np.ones(m, dtype=np.uint8))

    def input_garbler(self, bits_or_m):
        raise NotImplementedError

    def input_evaluator(self, bits_or_m):
        raise NotImplementedError

    def output(self, w) -> np.ndarray | None:
        raise NotImplementedError


class GarblerGates(Gates):
    def __init__(self, channel: PartyChannel, seed: int = 0x4d414745):
        self.ch = channel
        self.rng = np.random.default_rng(seed)
        self.R = self._fresh(1)[0]
        self.R[0] |= np.uint64(1)  # point-and-permute: lsb(Delta) = 1
        self.gid = 0
        self.counts = GateCounts()

    def _fresh(self, m: int) -> np.ndarray:
        return self.rng.integers(0, 1 << 63, (m, 2), dtype=np.int64
                                 ).astype(np.uint64)

    def not_(self, a):
        return a ^ self.R

    def and_(self, a, b):
        m = len(a)
        self.counts.ands += m
        j0 = np.arange(2 * self.gid, 2 * self.gid + 2 * m, 2, dtype=np.int64)
        j1 = j0 + 1
        self.gid += m
        pa = lsb(a)
        pb = lsb(b)
        ha0 = hash_labels(a, j0)
        ha1 = hash_labels(a ^ self.R, j0)
        hb0 = hash_labels(b, j1)
        hb1 = hash_labels(b ^ self.R, j1)
        tg = ha0 ^ ha1 ^ _mask(pb, self.R[None, :].repeat(m, 0))
        wg = ha0 ^ _mask(pa, tg)
        te = hb0 ^ hb1 ^ a
        we = hb0 ^ _mask(pb, te ^ a)
        self.ch.send("tab", np.concatenate([tg, te], axis=1))
        return wg ^ we

    def const_bits(self, bits):
        m = len(bits)
        self.counts.consts += m
        zero = self._fresh(m)
        self.ch.send("const", zero ^ _mask(bits, self.R[None, :].repeat(m, 0)))
        return zero

    def input_garbler(self, bits):
        zero = self._fresh(len(bits))
        self.ch.send("gin",
                     zero ^ _mask(bits, self.R[None, :].repeat(len(bits), 0)))
        return zero

    def input_evaluator(self, m: int):
        zero = self._fresh(m)
        # simulated OT: both labels go to the OT functionality
        self.ch.send("ot", np.concatenate([zero, zero ^ self.R], axis=1))
        return zero

    def output(self, w):
        self.ch.send("dec", lsb(w))
        return None


class EvaluatorGates(Gates):
    def __init__(self, channel: PartyChannel):
        self.ch = channel
        self.gid = 0
        self.counts = GateCounts()

    def not_(self, a):
        return a

    def and_(self, wa, wb):
        m = len(wa)
        self.counts.ands += m
        j0 = np.arange(2 * self.gid, 2 * self.gid + 2 * m, 2, dtype=np.int64)
        j1 = j0 + 1
        self.gid += m
        tab = self.ch.recv("tab")
        tg, te = tab[:, :2], tab[:, 2:]
        sa = lsb(wa)
        sb = lsb(wb)
        wg = hash_labels(wa, j0) ^ _mask(sa, tg)
        we = hash_labels(wb, j1) ^ _mask(sb, te ^ wa)
        return wg ^ we

    def const_bits(self, bits):
        self.counts.consts += len(bits)
        return self.ch.recv("const")

    def input_garbler(self, m: int):
        return self.ch.recv("gin")

    def input_evaluator(self, bits):
        pairs = self.ch.recv("ot")
        self.ch.ot_selections += len(bits)
        return np.where(bits.astype(bool)[:, None], pairs[:, 2:], pairs[:, :2])

    def output(self, w):
        pbits = self.ch.recv("dec")
        return (lsb(w) ^ pbits).astype(np.uint8)
