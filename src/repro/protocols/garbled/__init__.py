from .driver import (EvaluatorDriver, GarblerDriver, PartyChannel,
                     PlaintextDriver, run_two_party)
from .dsl import Bit, Integer, Party

__all__ = ["EvaluatorDriver", "GarblerDriver", "PartyChannel",
           "PlaintextDriver", "run_two_party", "Bit", "Integer", "Party"]
