"""Serving launcher: batched prefill + decode with the continuous batcher.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 6 --prompt-len 16 --max-new 8 [--paged]

--paged additionally routes decode attention through the Pallas paged-KV
kernel and prints the MAGE page schedule stats for the run.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..distributed.sharding import default_rules, use_rules
from ..models import init_lm, lm_prefill
from ..serve.paged_kv import plan_kv_schedule
from ..serve.serve_step import Batcher, Request, serve_step


def run_server(cfg, requests: list[Request], batch_size: int, max_seq: int,
               paged_report: bool = False):
    rng = jax.random.PRNGKey(0)
    params = init_lm(rng, cfg)
    batcher = Batcher(batch_size)
    for r in requests:
        batcher.submit(r)

    decode = jax.jit(lambda p, t, c, l: serve_step(p, t, c, l, cfg))
    total_tokens = 0
    t0 = time.time()
    while batcher.busy():
        placed = batcher.fill()
        # prefill each newly-placed request (batch of 1 for simplicity)
        caches_by_slot = {}
        for i, req in enumerate(batcher.active):
            if req is None:
                continue
            toks = jnp.asarray(req.prompt, dtype=jnp.int32)[None]
            logits, caches = lm_prefill(params, toks, cfg, max_seq=max_seq)
            nxt = int(jnp.argmax(logits[:, -1], axis=-1)[0])
            req.output.append(nxt)
            clen = jnp.asarray([len(req.prompt)], dtype=jnp.int32)
            token = jnp.asarray([[nxt]], dtype=jnp.int32)
            while len(req.output) < req.max_new:
                token, caches, _ = decode(params, token, caches, clen)
                clen = clen + 1
                req.output.append(int(token[0, 0]))
                total_tokens += 1
            req.done = True
            batcher.retire(i)
    dt = time.time() - t0
    if paged_report:
        page = max(min(64, max_seq // 8), 1)
        n_pages = (max_seq + page - 1) // page
        mem, rep = plan_kv_schedule(total_tokens=max_seq, page_size=page,
                                    hbm_pages=max(n_pages // 2, 4),
                                    lookahead=4, prefetch=2)
        print(f"paged-KV plan: swaps in/out = "
              f"{rep.replacement.swap_ins}/{rep.replacement.swap_outs}, "
              f"prefetched={rep.schedule.prefetched}, "
              f"sync_fallbacks={rep.schedule.sync_fallbacks}")
    return total_tokens, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--paged", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new)
            for i in range(args.requests)]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, use_rules(default_rules(mesh)):
        total, dt = run_server(cfg, reqs, batch_size=2,
                               max_seq=args.prompt_len + args.max_new + 1,
                               paged_report=args.paged)
    print(f"served {args.requests} requests, {total} decode tokens "
          f"in {dt:.2f}s")


if __name__ == "__main__":
    main()
