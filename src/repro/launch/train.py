"""Training launcher: end-to-end fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 50 --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

--reduced runs the smoke-scale config on CPU; on a real cluster the same
loop runs the full config under the production mesh (launch/mesh.py).
The loop wires together: deterministic step-indexed data (exact resume),
async atomic checkpoints, NaN rollback, straggler detection, preemption
checkpointing.
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config, reduced_config
from ..data.pipeline import DataConfig, Prefetcher
from ..distributed.sharding import default_rules, use_rules
from ..models import ModelConfig
from ..train import checkpoint as ckpt
from ..train.fault import FaultConfig, Preemption, RunReport, StepTimer, is_bad
from ..train.optimizer import OptConfig
from ..train.train_step import TrainConfig, make_train_state, train_step


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, dcfg: DataConfig,
               fcfg: FaultConfig, steps: int, ckpt_dir: str | None = None,
               preemption: Preemption | None = None,
               inject_nan_at: int | None = None,
               log_every: int = 10) -> RunReport:
    report = RunReport()
    preemption = preemption or Preemption()

    rng = jax.random.PRNGKey(0)
    params, opt_state = make_train_state(rng, cfg)
    start = 0
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            params, opt_state, _ = ckpt.restore(ckpt_dir, last, params,
                                                opt_state)
            start = last
            print(f"resumed from step {last}")

    step_fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, tcfg),
                      donate_argnums=(0, 1))
    timer = StepTimer(fcfg)
    pf = Prefetcher(dcfg, start)
    rollbacks = 0
    step = start
    pending_save = None
    try:
        while step < steps:
            s, host_batch = pf.next()
            if s != step:
                continue  # skip stale prefetches after rollback
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
            new_params, new_opt, metrics = step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            if inject_nan_at is not None and step == inject_nan_at:
                metrics["loss"] = float("nan")
                inject_nan_at = None
            if is_bad(metrics):
                # rollback: reload last checkpoint, skip the bad batch
                rollbacks += 1
                report.rollbacks += 1
                if ckpt_dir is None or rollbacks > fcfg.max_rollbacks:
                    raise RuntimeError("unrecoverable divergence")
                if pending_save is not None:
                    pending_save.join()  # roll back to the newest checkpoint
                    pending_save = None
                last = ckpt.latest_step(ckpt_dir)
                params, opt_state = make_train_state(rng, cfg)
                if last is not None:
                    params, opt_state, _ = ckpt.restore(ckpt_dir, last,
                                                        params, opt_state)
                pf.close()
                step = (last or 0) + 1  # deterministic skip past the bad batch
                pf = Prefetcher(dcfg, step)
                print(f"rollback -> step {step}")
                continue
            params, opt_state = new_params, new_opt
            dt = time.time() - t0
            if timer.record(step, dt):
                report.stragglers += 1
            step += 1
            report.steps_run += 1
            if step % log_every == 0 or step == steps:
                print(f"step {step:5d} loss={metrics['loss']:.4f} "
                      f"lr={metrics.get('lr', 0):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            want_ckpt = ckpt_dir and (step % fcfg.checkpoint_every == 0
                                      or preemption.requested
                                      or step == steps)
            if want_ckpt:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save_async(ckpt_dir, step, params,
                                               opt_state)
                report.checkpoints += 1
                if preemption.requested:
                    break
    finally:
        if pending_save is not None:
            pending_save.join()
        pf.close()
    report.final_step = step
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainConfig(microbatches=args.microbatches,
                       opt=OptConfig(peak_lr=args.lr, warmup_steps=5,
                                     stable_steps=max(args.steps - 10, 5),
                                     decay_steps=5))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size,
                      frames_dim=cfg.d_model if cfg.is_encdec else 0)
    fcfg = FaultConfig(checkpoint_every=max(args.steps // 4, 5))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, use_rules(default_rules(mesh)):
        report = train_loop(cfg, tcfg, dcfg, fcfg, args.steps,
                            ckpt_dir=args.ckpt_dir)
    print(f"done: {report}")


if __name__ == "__main__":
    main()
