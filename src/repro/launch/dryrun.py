import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k [--multi-pod] [--seq-shard] [--pipeline]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md.
"""  # noqa: E402

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, all_cells, get_config
from ..distributed.sharding import (params_pspecs, rules_for, use_rules,
                                    zero_pspecs)
from ..models import ModelConfig, encdec_init_caches
from ..train.train_step import TrainConfig, train_step
from . import specs as S
from .analysis import (Roofline, analytic_roofline, collective_bytes,
                       model_flops)
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def build_step(cfg: ModelConfig, cell, mesh, rules, microbatches=None,
               extra_opts=None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    params_shape = S.params_struct(cfg)
    pspecs = params_pspecs(params_shape, rules)
    params_sh = S.named(pspecs, mesh)
    batch_shapes, batch_pspecs, shardable = S.batch_specs(
        cfg, cell, rules, _dp_size(mesh))
    batch_sh = S.named(batch_pspecs, mesh)
    bspec = rules.axis("batch") if shardable else None

    if cell.kind == "train":
        opt_shape = S.opt_struct(params_shape)
        # ZeRO-1: moments shard over the data axes on top of TP
        zero_specs = zero_pspecs(params_shape, rules, mesh)
        opt_pspecs = {"mu": zero_specs, "nu": zero_specs, "step": P()}
        opt_sh = S.named(opt_pspecs, mesh)
        dp = _dp_size(mesh)
        mb = microbatches or max(1, min(16, cell.global_batch // dp))
        tcfg = TrainConfig(microbatches=mb)

        def fn(params, opt_state, batch):
            with use_rules(rules):
                return train_step(params, opt_state, batch, cfg, tcfg,
                                  grad_pspecs=zero_specs)

        jf = jax.jit(fn, in_shardings=(params_sh, opt_sh, batch_sh),
                     donate_argnums=(0, 1))
        return jf, (params_shape, opt_shape, batch_shapes)

    if cell.kind == "prefill":
        if cfg.is_encdec:
            def fn(params, batch):
                with use_rules(rules):
                    from ..models.encdec import encdec_prefill
                    return encdec_prefill(params, batch["frames"],
                                          batch["tokens"], cfg)
            jf = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            return jf, (params_shape, batch_shapes)

        def fn(params, batch):
            with use_rules(rules):
                from ..models import lm_prefill
                logits, caches = lm_prefill(params, batch["tokens"], cfg,
                                            max_seq=cell.seq_len)
                return logits
        jf = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        return jf, (params_shape, batch_shapes)

    # decode: one new token against a seq_len cache
    b = cell.global_batch
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    clen = jax.ShapeDtypeStruct((b,), jnp.int32)
    token_sh = NamedSharding(mesh, P(bspec, None))
    clen_sh = NamedSharding(mesh, P(bspec))
    if cfg.is_encdec:
        caches_shape = jax.eval_shape(
            lambda: encdec_init_caches(cfg, b, cell.seq_len))
        kv = rules.axis("kv_heads")
        caches_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(None, bspec, None, kv, None)),
            caches_shape)
        mem_shape = jax.ShapeDtypeStruct((b, 1024, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        mem_sh = NamedSharding(mesh, P(bspec, None, None))

        def fn(params, token, memory, caches, cache_len):
            with use_rules(rules):
                from ..serve.serve_step import serve_step_encdec
                nxt, caches, _ = serve_step_encdec(params, token, memory,
                                                   caches, cache_len, cfg)
                return nxt, caches
        jf = jax.jit(fn, in_shardings=(params_sh, token_sh, mem_sh,
                                       caches_sh, clen_sh),
                     donate_argnums=(3,))
        return jf, (params_shape, token, mem_shape, caches_shape, clen)

    caches_shape = S.cache_struct(cfg, b, cell.seq_len)
    caches_sh = S.named(S.cache_pspecs(cfg, rules, shardable), mesh)

    def fn(params, token, caches, cache_len):
        with use_rules(rules):
            from ..serve.serve_step import serve_step
            nxt, caches, _ = serve_step(params, token, caches, cache_len,
                                        cfg)
            return nxt, caches
    jf = jax.jit(fn, in_shardings=(params_sh, token_sh, caches_sh, clen_sh),
                 donate_argnums=(2,))
    return jf, (params_shape, token, caches_shape, clen)


def run_cell(arch: str, shape: str, multi_pod: bool, seq_shard: bool = False,
             save: bool = True, microbatches=None, dp_over_model: bool = False,
             grad_compression: str | None = None,
             kv_dtype: str | None = None, variant: str = "",
             remat_policy: str = "full") -> dict:
    from ..models.lm import set_remat_policy
    set_remat_policy(remat_policy)
    cfg = get_config(arch)
    if kv_dtype:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, seq_sharding=seq_shard,
                      dp_over_model=dp_over_model)
    rules.grad_compression = grad_compression
    mesh_name = "pod512" if multi_pod else "pod256"
    t0 = time.time()
    result = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "mesh_shape": dict(mesh.shape), "ok": False,
              "seq_shard": seq_shard, "variant": variant}
    try:
        with mesh:
            jf, args = build_step(cfg, cell, mesh, rules,
                                  microbatches=microbatches)
            lowered = jf.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            # jax returned [{...}] per device before 0.4.35ish, a flat dict
            # after; normalize so both shapes work
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            coll = collective_bytes(compiled.as_text())
        n_chips = 1
        for v in mesh.shape.values():
            n_chips *= v
        flops = float(cost.get("flops", 0.0))
        hbm = float(cost.get("bytes accessed", 0.0))
        cbytes = float(sum(v for k, v in coll.items() if k != "count"))
        hlo_roof = Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=cbytes,
                            model_flops_per_chip=model_flops(cfg, cell,
                                                             n_chips))
        dp = _dp_size(mesh)
        mb = microbatches or max(1, min(16, cell.global_batch // dp))
        roof = analytic_roofline(cfg, cell, mesh, rules, microbatches=mb,
                                 remat_policy=remat_policy)
        result.update({
            "ok": True,
            "lower_s": t1 - t0, "compile_s": t2 - t1,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            "collectives": coll,
            "roofline": roof,
            "roofline_hlo_raw": hlo_roof.as_dict(),
        })
    except Exception as e:  # noqa: BLE001 — failures are the experiment
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fname = f"{arch}__{shape}__{mesh_name}"
        if seq_shard:
            fname += "__sp"
        if variant:
            fname += f"__{variant}"
        with open(os.path.join(OUT_DIR, fname + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--dp-over-model", action="store_true")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in cells:
        r = run_cell(arch, shape, args.multi_pod, seq_shard=args.seq_shard,
                     microbatches=args.microbatches,
                     dp_over_model=args.dp_over_model,
                     grad_compression=args.grad_compression,
                     kv_dtype=args.kv_dtype,
                     remat_policy=args.remat_policy,
                     variant=args.variant)
        if r["ok"]:
            roof = r["roofline"]
            print(f"[OK ] {arch:24s} {shape:12s} {r['mesh']} "
                  f"compile={r['compile_s']:6.1f}s "
                  f"dom={roof['dominant']:10s} "
                  f"roofline={roof['roofline_fraction']:.3f} "
                  f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB",
                  flush=True)
        else:
            failures += 1
            print(f"[FAIL] {arch:24s} {shape:12s}: {r['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
