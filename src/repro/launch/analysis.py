"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / peak_FLOPs            (per chip)
  memory term     = HLO_bytes / HBM_bw                (per chip)
  collective term = collective_bytes / ICI_bw         (per chip)

cost_analysis() supplies FLOPs/bytes for the per-device SPMD module;
collective bytes are parsed out of the compiled HLO text (result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op).
"""

from __future__ import annotations

import dataclasses
import re

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match '= <shape> kind(' — result shape precedes the op name
            marker = f" {kind}("
            if marker in ls and "=" in ls:
                lhs, rhs = ls.split(marker, 1)
                shape_part = lhs.split("=", 1)[1]
                out[kind] += _shape_bytes(shape_part)
                out["count"] += 1
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device collective bytes
    model_flops_per_chip: float   # 6ND (or 2ND) / chips
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is 'useful'
        (catches remat/redundancy waste)."""
        return (self.model_flops_per_chip / self.flops) if self.flops else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Roofline fraction: useful FLOPs / (peak * bound time)."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops_per_chip / (PEAK_FLOPS_BF16 * self.bound_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "model_flops_per_chip": self.model_flops_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.mfu_upper_bound,
        }


def model_flops(cfg, cell, n_chips: int) -> float:
    """6*N*D for training, 2*N*D for forward-only (per whole step)."""
    n_active = cfg.active_param_estimate()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    mult = 6 if cell.kind == "train" else 2
    return mult * n_active * tokens / n_chips


# ---------------------------------------------------------------------------
# analytic roofline model
#
# XLA's cost_analysis on the CPU backend counts while-loop (scan) bodies
# ONCE, so HLO-derived flops/bytes undercount by the trip counts of the
# microbatch/layer scans.  The analytic model below prices the step from
# the program structure we built (it knows every scan's trip count) and is
# cross-checked against the HLO collective inventory (ops that appear in
# the entry computation, e.g. the DP gradient all-reduce, match exactly).
# Both sets of numbers are reported; §Roofline uses the analytic terms.
# ---------------------------------------------------------------------------


def analytic_roofline(cfg, cell, mesh, rules, microbatches: int = 1,
                      remat_policy: str = "full") -> dict:
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    dp = 1
    for a in ("pod", "data"):
        if a in dict(mesh.shape):
            dp *= dict(mesh.shape)[a]
    tp = dict(mesh.shape).get("model", 1)

    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    n_active = cfg.active_param_estimate()
    dtype_b = 2  # bf16

    batch_shardable = B % dp == 0 and B >= dp
    b_local = B // dp if batch_shardable else B

    # ---- FLOPs per chip ----------------------------------------------------
    no_recompute = remat_policy in ("dots", "block_outs")
    passes = 2 if no_recompute else 3  # fwd(+recompute)+bwd collect. passes
    if cell.kind == "train":
        tokens = B * S
        # fwd 2N + bwd 4N (+ recompute 2N under full remat; block_outs
        # still recomputes the intra-block math, but that re-issues no
        # collectives — only the flops term keeps the recompute share)
        lin = (6.0 if remat_policy == "dots" else 8.0) * n_active * tokens
        attn_layers = L if cfg.family not in ("ssm", "hybrid") else \
            (L // cfg.shared_attn_every if cfg.shared_attn_every else 0)
        quad_mult = 12.0 if no_recompute and remat_policy == "dots" else 16.0
        attn = quad_mult * B * S * S * cfg.n_heads * cfg.head_dim \
            * attn_layers
        if cfg.family in ("ssm", "hybrid"):
            # SSD intra-chunk quadratic + xLSTM D-matrix quadratic
            from ..models.xlstm import MLSTM_CHUNK, MLSTM_CHUNK_THRESHOLD
            if cfg.family == "hybrid":
                q = cfg.ssm_chunk
            else:
                q = MLSTM_CHUNK if S >= MLSTM_CHUNK_THRESHOLD else S
            heads = (cfg.ssm_expand * d // cfg.ssm_head_dim
                     if cfg.family == "hybrid" else cfg.n_heads)
            hd = (cfg.ssm_head_dim if cfg.family == "hybrid"
                  else d // cfg.n_heads)
            attn += quad_mult * B * S * q * heads * hd * L
        flops = (lin + attn) / n_chips
    elif cell.kind == "prefill":
        tokens = B * S
        lin = 2.0 * n_active * tokens
        attn_layers = L if cfg.family not in ("ssm", "hybrid") else \
            (L // cfg.shared_attn_every if cfg.shared_attn_every else 0)
        attn = 4.0 * B * S * S * cfg.n_heads * cfg.head_dim * attn_layers
        if cfg.family in ("ssm", "hybrid"):
            from ..models.xlstm import MLSTM_CHUNK, MLSTM_CHUNK_THRESHOLD
            q = cfg.ssm_chunk if cfg.family == "hybrid" else \
                (MLSTM_CHUNK if S >= MLSTM_CHUNK_THRESHOLD else S)
            heads = (cfg.ssm_expand * d // cfg.ssm_head_dim
                     if cfg.family == "hybrid" else cfg.n_heads)
            hd = (cfg.ssm_head_dim if cfg.family == "hybrid"
                  else d // cfg.n_heads)
            attn += 4.0 * B * S * q * heads * hd * L
        flops = (lin + attn) / n_chips
    else:  # decode: one token over the whole batch
        lin = 2.0 * n_active * B
        attn_layers = L if cfg.family not in ("ssm", "hybrid") else \
            (L // cfg.shared_attn_every if cfg.shared_attn_every else 0)
        attn = 4.0 * B * S * cfg.n_heads * cfg.head_dim * attn_layers
        flops = (lin + attn) / n_chips

    # ---- HBM bytes per chip --------------------------------------------------
    params_local = n_active * dtype_b / tp  # active weights, TP-sharded
    if cell.kind == "train":
        # per microbatch: read weights fwd + recompute + bwd grad writes;
        # optimizer: read/write mu, nu (f32) + params
        weight_traffic = 3.0 * microbatches * params_local
        opt_traffic = (4 + 4 + 4 + 4 + 2 + 2) * n_active / tp
        act_traffic = 12.0 * B * S * d * L * dtype_b / n_chips
        hbm = weight_traffic + opt_traffic + act_traffic
    elif cell.kind == "prefill":
        hbm = params_local + 8.0 * B * S * d * L * dtype_b / n_chips
    else:
        kv_byte = 1 + 2 / cfg.head_dim if cfg.kv_cache_dtype == "int8" \
            else dtype_b
        kv_bytes = (2 * attn_layers * cfg.n_kv_heads * cfg.head_dim
                    * S * B * kv_byte) if cfg.family not in ("ssm",) else 0
        if cfg.family in ("ssm", "hybrid"):
            d_in = cfg.ssm_expand * d
            state = (d_in // max(cfg.ssm_head_dim, 1)) * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
            kv_bytes += L * B * state
        hbm = params_local + kv_bytes / n_chips * 1.0

    # ---- collective bytes per chip -------------------------------------------
    # family-aware TP all-reduce counts: an attention block has 2 row-
    # parallel matmuls (attn-out, mlp-down); a Mamba2 block 1 (out_proj);
    # an mLSTM block 1 (m_out) — and only when the corresponding logical
    # axis actually maps onto the model mesh axis for this config.
    ring = lambda p: 2.0 * (p - 1) / max(p, 1)  # noqa: E731
    attn_tp = 2 if (rules.axis("heads") or rules.axis("kv_heads")
                    or rules.axis("ff")) else 0
    if cfg.moe:
        attn_tp += 2 if rules.axis("experts") else 0  # dispatch/combine
    mamba_tp = 1 if rules.axis("ff") else 0
    mlstm_tp = 1 if rules.axis("heads") else 0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        ars = attn_tp * L
    elif cfg.family == "hybrid":
        n_attn = L // cfg.shared_attn_every if cfg.shared_attn_every else 0
        ars = mamba_tp * L + attn_tp * n_attn
    else:  # ssm / xlstm
        n_slstm = L // cfg.slstm_every if cfg.slstm_every else 0
        ars = mlstm_tp * (L - n_slstm)
    if rules.axis("vocab"):
        ars += 1  # unembed boundary

    coll = 0.0
    if cell.kind == "train":
        grad_local = 4.0 * n_active / tp          # f32 grads, TP-sharded
        if getattr(rules, "grad_compression", None) == "int8":
            grad_local /= 4.0                     # int8 payload (+scales)
        coll += ring(dp) * grad_local             # DP all-reduce
        # x (fwd, bwd [, recompute]) passes over b_local total rows
        if tp > 1:
            coll += ring(tp) * b_local * S * d * dtype_b * passes * ars
    elif cell.kind == "prefill":
        if tp > 1:
            coll += ring(tp) * b_local * S * d * dtype_b * ars
    else:
        if tp > 1:
            coll += ring(tp) * b_local * 1 * d * dtype_b * ars

    roof = Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                    model_flops_per_chip=model_flops(cfg, cell, n_chips))
    out = roof.as_dict()
    out["source"] = "analytic"
    return out
