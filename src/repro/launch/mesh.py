"""Production mesh definition (multi-pod dry-run contract).

A FUNCTION, not a module constant, so importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips; multi-pod:
(pod=2, data=16, model=16) = 512 chips.  TPU v5e constants for the roofline
live here too.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
HBM_BYTES = 16 * 1024 ** 3
