"""input_specs(): ShapeDtypeStruct stand-ins for every model input, plus the
matching PartitionSpecs — weak-type-correct, shardable, no allocation.

Covers: params + optimizer state (train), tokens/frames batches, KV caches
and recurrent states (decode), encoder memory (enc-dec decode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ShapeCell
from ..distributed.sharding import AxisRules
from ..models import (ModelConfig, grouped_layout,
                      init_caches, init_encdec, init_lm)
from ..models.config import BlockKind
from ..models.mamba2 import dims as mamba_dims
from ..train.optimizer import init_opt_state


def shape_structs(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def params_struct(cfg: ModelConfig, rng=None):
    rng = jax.random.PRNGKey(0) if rng is None else rng
    init = init_encdec if cfg.is_encdec else init_lm
    return jax.eval_shape(lambda r: init(r, cfg), rng)


def opt_struct(params_shape):
    return jax.eval_shape(init_opt_state, params_shape)


def _data_spec(rules: AxisRules, batch_shardable: bool) -> P:
    return P(rules.axis("batch") if batch_shardable else None)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, rules: AxisRules,
                n_batch_shards: int):
    """Token (and frame) batch ShapeDtypeStructs + PartitionSpecs."""
    b, s = cell.global_batch, cell.seq_len
    shardable = b % max(n_batch_shards, 1) == 0 and b >= n_batch_shards
    bspec = rules.axis("batch") if shardable else None
    out_shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    out_specs = {"tokens": P(bspec, None)}
    if cfg.is_encdec:
        out_shapes["frames"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        out_specs["frames"] = P(bspec, None, None)
    return out_shapes, out_specs, shardable


def _block_cache_spec(cfg: ModelConfig, kind: BlockKind, rules: AxisRules,
                      bspec) -> object:
    kv = rules.axis("kv_heads")
    kv_seq = rules.axis("kv_seq")
    if kv_seq is None and bspec is None:
        # batch unshardable (e.g. long_500k batch=1): the data axes are idle
        # — shard the cache's sequence dim over them instead (fixes the
        # zamba2 long_500k 16.2 GiB marginal fit; §Perf)
        kv_seq = rules.axis("batch")
    ff = rules.axis("ff")
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        if cfg.kv_cache_dtype == "int8":
            return (P(bspec, kv_seq, kv, None), P(bspec, kv_seq, kv, None),
                    P(bspec, kv_seq, kv), P(bspec, kv_seq, kv))
        return (P(bspec, kv_seq, kv, None), P(bspec, kv_seq, kv, None))
    if kind == BlockKind.MAMBA2:
        d_in, nh, n = mamba_dims(cfg)
        msize = 1
        return {"h": P(bspec, ff, None, None),
                "conv": P(bspec, None, None)}
    if kind == BlockKind.MLSTM:
        h = rules.axis("heads")
        return {"C": P(bspec, h, None, None), "n": P(bspec, h, None),
                "m": P(bspec, h)}
    if kind == BlockKind.SLSTM:
        return {"c": P(bspec), "n": P(bspec), "h": P(bspec),
                "m": P(bspec)}
    raise ValueError(kind)


def _prepend(spec_tree, n_extra: int):
    def fn(p):
        return P(*([None] * n_extra + list(p)))
    return jax.tree_util.tree_map(fn, spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg: ModelConfig, rules: AxisRules, batch_shardable: bool):
    """PartitionSpec tree matching models.init_caches structure."""
    bspec = rules.axis("batch") if batch_shardable else None
    out = []
    for g in grouped_layout(cfg):
        if g[0] == "scan":
            _, kind, count = g
            out.append(_prepend(_block_cache_spec(cfg, kind, rules, bspec),
                                1))
        else:
            _, inner, n_rep = g
            gc = {}
            for j, (kind, count) in enumerate(inner):
                gc[f"seg{j}"] = _prepend(
                    _block_cache_spec(cfg, kind, rules, bspec), 2)
            out.append(gc)
    return out


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_seq))


def named(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def mamba_nh_shardable(cfg: ModelConfig, rules: AxisRules) -> bool:
    return rules.axis("ff") is not None
