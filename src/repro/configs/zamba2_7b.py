"""zamba2-7b [arXiv:2411.15242]: Mamba2 backbone + shared attention blocks.

81 Mamba2 layers with ONE shared attention+MLP block applied every 6 layers
(weight sharing — each application has its own KV cache).  hybrid family ->
long_500k eligible.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, shared_attn_every=6,
)
