"""minicpm-2b [arXiv:2404.06395]: llama-like dense; WSD schedule is wired in
train/optimizer.py.  vocab padded 122753 -> 122880 (multiple of 256) for TP
divisibility (Megatron-style padding)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
    n_heads=36, n_kv_heads=36, d_ff=5760, vocab_size=122880,
    tie_embeddings=True,
)
