"""Architecture registry + assigned input shapes (40 cells).

``--arch <id>`` everywhere resolves through get_config(); reduced_config()
returns the same family scaled down for CPU smoke tests.  Shape cells follow
the assignment: train_4k / prefill_32k / decode_32k lower train_step /
prefill / serve_step; long_500k (decode with a 512k context) runs only for
sub-quadratic families (zamba2, xlstm) — see DESIGN.md §5 for the skip list.
"""

from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "minicpm-2b": "minicpm_2b",
    "internlm2-20b": "internlm2_20b",
    "stablelm-3b": "stablelm_3b",
    "qwen2-1.5b": "qwen2_1_5b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-1.3b": "xlstm_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCHS = sorted(ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    changes: dict = dict(
        n_layers=max(2, (cfg.shared_attn_every or cfg.slstm_every or 1) + 1),
        d_model=128, n_heads=4, d_ff=256 if cfg.d_ff else 0,
        vocab_size=512, head_dim=0,
    )
    changes["n_kv_heads"] = min(cfg.n_kv_heads, 2) if \
        cfg.n_kv_heads < cfg.n_heads else 4
    if cfg.moe:
        changes.update(n_experts=4, top_k=min(cfg.top_k, 2),
                       router_group_size=64)
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.slstm_every:
        changes.update(slstm_every=2, n_layers=4)
    if cfg.shared_attn_every:
        changes.update(shared_attn_every=2, n_layers=5)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, n_layers=2)
    return dataclasses.replace(cfg, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")   # skipped for pure full-attention archs
    return out


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in applicable_shapes(cfg):
            cells.append((a, s))
    return cells
