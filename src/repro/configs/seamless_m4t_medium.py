"""seamless-m4t-medium [arXiv:2308.11596]: encoder-decoder backbone; audio
frontend STUB (input_specs() provides precomputed frame embeddings).
vocab padded 256206 -> 256256 for TP divisibility."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=256256,
    encoder_layers=12, frontend="audio_frames",
)
