"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed experts, top-6."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=102400,
    moe=True, n_experts=64, n_shared_experts=2, top_k=6,
)
