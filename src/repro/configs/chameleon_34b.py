"""chameleon-34b [arXiv:2405.09818]: early-fusion VLM — VQ image tokens are
ordinary vocab entries, so the backbone is a dense decoder; the image
tokenizer frontend is a STUB (input_specs() supplies token ids that may
fall in the image-token vocab range)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab_size=65536,
    frontend="vq_image",
)
