"""xlstm-1.3b [arXiv:2405.04517]: mLSTM/sLSTM mix (7:1), attention-free ->
long_500k eligible.  d_ff=0: xLSTM blocks carry their own projections."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304, slstm_every=8,
)
