"""``python -m repro``: the deployment CLI (paper §6 — ``mage plan`` then
execute; §8.2 — the scenario benchmarks).

    python -m repro plan  --workload merge -n 4096 --budget 0.25 --out job/
    python -m repro run   job/ --check [--storage memmap] [--real]
    python -m repro bench [--tiny] [--streaming] [--json out.json]

``plan`` writes memory-program files through the out-of-core streaming
pipeline plus a ``job.json`` manifest; the spec hash is stamped into every
program's header so ``run`` validates artifacts before executing them and
rejects stale or tampered plans (SpecMismatchError, exit code 2).
"""

from __future__ import annotations

import argparse
import json
import sys

from .api import JobSpec, Session, SpecMismatchError, run_job


def _parse_budget(text: str) -> int | float:
    """``12`` → 12 frames; ``0.25`` → fraction of the working set."""
    if any(c in text for c in ".eE"):
        return float(text)
    return int(text)


def _add_spec_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--workload", required=True,
                    help="workload name (see repro.workloads.all_names())")
    ap.add_argument("-n", type=int, default=None,
                    help="problem size (default: workload default)")
    ap.add_argument("--workers", type=int, default=1,
                    help="workers per party (§5.1)")
    ap.add_argument("--budget", type=_parse_budget, default=None,
                    help="memory budget: frames (int) or working-set "
                         "fraction (float); omit for unbounded")
    ap.add_argument("--lookahead", type=int, default=10_000)
    ap.add_argument("--prefetch", type=int, default=0,
                    help="prefetch buffer pages B (0 = replacement only)")
    ap.add_argument("--policy", default="min",
                    help="eviction policy (min, min_clean, lru, fifo)")
    ap.add_argument("--mode", default=None,
                    choices=("memory", "streaming", "unbounded"),
                    help="plan mode (default: streaming for plan, "
                         "memory for exec)")
    ap.add_argument("--parallel", default="serial",
                    choices=("serial", "thread", "process"),
                    help="per-worker planning executor")
    ap.add_argument("--ckks-ring", type=int, default=None)
    ap.add_argument("--ckks-levels", type=int, default=None)


def _spec_from_args(args, default_mode: str) -> JobSpec:
    mode = args.mode or (default_mode if args.budget is not None
                         else "unbounded")
    return JobSpec(workload=args.workload, n=args.n,
                   num_workers=args.workers, memory_budget=args.budget,
                   lookahead=args.lookahead, prefetch_pages=args.prefetch,
                   policy=args.policy, plan_mode=mode,
                   parallel_plan=args.parallel,
                   ckks_ring=args.ckks_ring, ckks_levels=args.ckks_levels)


def cmd_plan(args) -> int:
    spec = _spec_from_args(args, default_mode="streaming")
    with Session(spec) as s:
        manifest = s.save_plan(args.out)
        planned = s.plan()
        for i, p in enumerate(planned):
            print(f"worker{i}: {len(p)} instructions -> "
                  f"{getattr(p, 'path', '(in-memory)')}")
    print(f"spec hash {spec.plan_hash()}; manifest: {manifest}")
    return 0


def cmd_run(args) -> int:
    sess = Session.from_plan(args.jobdir, storage=args.storage,
                             driver=args.driver)
    with sess:
        outputs = sess.execute(real=args.real or None, check=args.check)
    for tag in sorted(outputs):
        v = outputs[tag]
        head = ", ".join(str(x) for x in list(v.flat[:4]))
        print(f"output[{tag}]: shape={getattr(v, 'shape', ())} "
              f"[{head}{', ...' if v.size > 4 else ''}]")
    if args.check:
        print("oracle check OK")
    return 0


def cmd_exec(args) -> int:
    spec = _spec_from_args(args, default_mode="memory")
    outputs = run_job(spec, real=args.real or None, check=args.check)
    print(f"{len(outputs)} outputs"
          + (", oracle check OK" if args.check else ""))
    return 0


def cmd_bench(args) -> int:
    from .scenarios import (BENCH_CASES, STREAMING_CASE, TINY_BENCH_CASES,
                            TINY_STREAMING_CASE, run_bench)
    if args.cases:
        cases = []
        for item in args.cases.split(","):
            name, _, n = item.partition("=")
            if not name or not n.isdigit():
                raise SystemExit(
                    f"error: bad --cases entry {item!r} (want workload=n, "
                    f"e.g. merge=16384)")
            cases.append((name, int(n)))
    else:
        cases = TINY_BENCH_CASES if args.tiny else BENCH_CASES
    streaming_case = None
    if args.streaming or args.tiny:
        streaming_case = TINY_STREAMING_CASE if args.tiny else STREAMING_CASE
    rows = run_bench(cases=cases, budget_frac=args.budget_frac,
                     check=not args.no_check and not args.tiny,
                     streaming_case=streaming_case)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro", description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="plan memory programs to a directory")
    _add_spec_args(p)
    p.add_argument("--out", required=True, help="output directory")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("run", help="execute a planned job directory")
    p.add_argument("jobdir")
    p.add_argument("--check", action="store_true",
                   help="verify outputs against the numpy oracle")
    p.add_argument("--real", action="store_true",
                   help="GC: run real two-party crypto")
    p.add_argument("--storage", default=None, choices=("ram", "memmap"))
    p.add_argument("--driver", default=None)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("exec", help="trace+plan+execute in one shot")
    _add_spec_args(p)
    p.add_argument("--check", action="store_true")
    p.add_argument("--real", action="store_true")
    p.set_defaults(fn=cmd_exec)

    p = sub.add_parser("bench", help="drive the §8.2 scenario benchmarks")
    p.add_argument("--cases", default=None,
                   help="comma list of workload=n (default: fig8 sweep)")
    p.add_argument("--budget-frac", type=float, default=0.4)
    p.add_argument("--tiny", action="store_true",
                   help="small sizes + no claim assertions (CI smoke)")
    p.add_argument("--streaming", action="store_true",
                   help="add a past-planner-cap case via the file pipeline")
    p.add_argument("--no-check", action="store_true")
    p.add_argument("--json", metavar="PATH",
                   help="write rows as JSON (CI artifact)")
    p.set_defaults(fn=cmd_bench)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except SpecMismatchError as e:
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(2)
    except (ValueError, KeyError) as e:
        # predictable spec/registry errors: clean CLI message, not a trace
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    sys.exit(main())
