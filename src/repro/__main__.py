"""``python -m repro``: the deployment CLI (paper §6 — ``mage plan`` then
execute; §8.2 — the scenario benchmarks).

    python -m repro plan  --workload merge -n 4096 --budget 0.25 --out job/
    python -m repro run   job/ --check [--storage memmap] [--real]
    python -m repro run   job/ --worker 1 --peers h0:9000,h1:9001 [--json o.json]
    python -m repro fabric job/ [--check] [--real] [--json merged.json]
    python -m repro bench [--tiny] [--streaming] [--json out.json]
    python -m repro serve  --cache ~/.cache/mage --socket /tmp/mage.sock
    python -m repro submit --connect /tmp/mage.sock --workload merge \
                           -n 4096 --budget 64 --execute

``plan`` writes memory-program files through the out-of-core streaming
pipeline plus a ``job.json`` manifest; the spec hash is stamped into every
program's header so ``run`` validates artifacts before executing them and
rejects stale or tampered plans (SpecMismatchError, exit code 2).

``run --worker K`` is the §5.2 deployment unit: ONE engine (global rank K =
party*num_workers + worker) against remote peers over the TCP transport
fabric; ``fabric`` launches the whole fleet as N localhost processes,
merges their outputs, and can check them against the oracle.

``serve`` runs the multi-tenant plan-cache daemon and ``submit`` sends it
jobs (docs/SERVE.md).  Every ``--json`` output is wrapped as
``{"schema_version": N, ...}``; stage cores are selected uniformly with
``--plan-core`` / ``--sim-core`` on every subcommand (``--core`` is a
deprecated alias for ``--plan-core``).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

from .api import (SCHEMA_VERSION, FabricSpec, JobSpec, Session,
                  SpecMismatchError, check_outputs, driver_parties, run_job)
from .core.transport import TransportError, pick_free_ports
from .workloads import get as get_workload


def _parse_budget(text: str) -> int | float:
    """``12`` → 12 frames; ``0.25`` → fraction of the working set."""
    if any(c in text for c in ".eE"):
        return float(text)
    return int(text)


class _DeprecatedCore(argparse.Action):
    """``--core`` → ``--plan-core`` rename shim (kept one release)."""

    def __call__(self, parser, namespace, values, option_string=None):
        print(f"warning: {option_string} is deprecated, use --plan-core",
              file=sys.stderr)
        setattr(namespace, self.dest, values)


def _add_core_args(ap: argparse.ArgumentParser, default="array") -> None:
    """The uniform stage-core knobs every subcommand takes.

    ``default=None`` (run/serve) means "keep what the manifest/spec says"
    instead of forcing the array cores."""
    ap.add_argument("--plan-core", dest="plan_core", default=default,
                    choices=("array", "scalar"),
                    help="planner core: vectorized record arrays (default) "
                         "or the scalar reference; outputs are identical")
    ap.add_argument("--core", dest="plan_core", action=_DeprecatedCore,
                    choices=("array", "scalar"), help=argparse.SUPPRESS)
    ap.add_argument("--sim-core", dest="sim_core", default=default,
                    choices=("array", "scalar"),
                    help="timing-simulator core: vectorized record-chunk "
                         "replay (default) or the scalar reference; results "
                         "are identical (docs/SIMULATOR.md)")


def _add_cache_arg(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--cache", metavar="DIR", default=None,
                    help="artifact-cache root: reuse traced bytecode and "
                         "plans across invocations (docs/SERVE.md)")


def _add_spec_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--workload", default=None,
                    help="workload name (see repro.list_workloads())")
    ap.add_argument("-n", type=int, default=None,
                    help="problem size (default: workload default)")
    ap.add_argument("--workers", type=int, default=1,
                    help="workers per party (§5.1)")
    ap.add_argument("--budget", type=_parse_budget, default=None,
                    help="memory budget: frames (int) or working-set "
                         "fraction (float); omit for unbounded")
    ap.add_argument("--lookahead", type=int, default=10_000)
    ap.add_argument("--prefetch", type=int, default=0,
                    help="prefetch buffer pages B (0 = replacement only)")
    ap.add_argument("--policy", default="min",
                    help="eviction policy (min, min_clean, lru, fifo)")
    _add_core_args(ap)
    ap.add_argument("--mode", default=None,
                    choices=("memory", "streaming", "unbounded"),
                    help="plan mode (default: streaming for plan, "
                         "memory for exec)")
    ap.add_argument("--parallel", default="serial",
                    choices=("serial", "thread", "process"),
                    help="per-worker planning executor")
    ap.add_argument("--ckks-ring", type=int, default=None)
    ap.add_argument("--ckks-levels", type=int, default=None)
    ap.add_argument("--exec-backend", dest="exec_backend", default="scalar",
                    choices=("scalar", "batched", "overlap"),
                    help="engine backend: per-instruction reference loop, "
                         "plan-derived batched dispatch (docs/ENGINE.md), or "
                         "planned out-of-order NET overlap (docs/OVERLAP.md); "
                         "outputs are identical")


def _spec_from_args(args, default_mode: str) -> JobSpec:
    if args.workload is None:
        raise SystemExit("error: --workload is required")
    mode = args.mode or (default_mode if args.budget is not None
                         else "unbounded")
    return JobSpec(workload=args.workload, n=args.n,
                   num_workers=args.workers, memory_budget=args.budget,
                   lookahead=args.lookahead, prefetch_pages=args.prefetch,
                   policy=args.policy, plan_mode=mode,
                   plan_core=args.plan_core, sim_core=args.sim_core,
                   parallel_plan=args.parallel,
                   exec_backend=args.exec_backend,
                   ckks_ring=args.ckks_ring, ckks_levels=args.ckks_levels)


def cmd_plan(args) -> int:
    spec = _spec_from_args(args, default_mode="streaming")
    with Session(spec, cache=args.cache) as s:
        manifest = s.save_plan(args.out)
        planned = s.plan()
        for i, p in enumerate(planned):
            print(f"worker{i}: {len(p)} instructions -> "
                  f"{getattr(p, 'path', '(in-memory)')}")
        if s.cache_events:
            print(f"cache: {s.cache_events}")
    print(f"spec hash {spec.plan_hash()}; manifest: {manifest}")
    return 0


def cmd_run(args) -> int:
    transport = args.transport
    fabric = None
    if transport in ("shaped", "shaped+tcp"):
        fabric = FabricSpec(latency_s=args.latency,
                            bandwidth=args.bandwidth)
    elif args.latency or args.bandwidth:
        raise SystemExit("error: --latency/--bandwidth need "
                         "--transport shaped or shaped+tcp")
    if args.worker is not None:
        if not args.peers:
            raise SystemExit("error: --worker needs --peers host:port,... "
                             "(one address per global rank)")
        if args.check:
            raise SystemExit("error: --check needs the full outputs; a "
                             "--worker rank only holds its own (use "
                             "`python -m repro fabric` instead)")
        transport = transport or "tcp"
        fabric = FabricSpec(rank=args.worker,
                            peers=tuple(args.peers.split(",")),
                            latency_s=args.latency,
                            bandwidth=args.bandwidth)
    sess = Session.from_plan(args.jobdir, storage=args.storage,
                             driver=args.driver, transport=transport,
                             fabric=fabric)
    # core/backend knobs never change outputs (and are not plan-hashed),
    # so they may be overridden on an already-planned job
    import dataclasses
    overrides = {k: v for k, v in (("plan_core", args.plan_core),
                                   ("sim_core", args.sim_core),
                                   ("exec_backend", args.exec_backend))
                 if v is not None}
    if overrides:
        sess.spec = dataclasses.replace(sess.spec, **overrides)
    with sess:
        outputs = sess.execute(real=args.real or None, check=args.check)
    for tag in sorted(outputs):
        v = outputs[tag]
        head = ", ".join(str(x) for x in list(v.flat[:4]))
        print(f"output[{tag}]: shape={getattr(v, 'shape', ())} "
              f"[{head}{', ...' if v.size > 4 else ''}]")
    if args.json:
        _dump_outputs(args.json, outputs)
        print(f"wrote {args.json}")
    if args.check:
        print("oracle check OK")
    return 0


def _dump_outputs(path: str, outputs: dict) -> None:
    with open(path, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION,
                   "outputs": {str(tag): np.asarray(v).tolist()
                               for tag, v in outputs.items()}}, f)


def _load_outputs(path: str, protocol: str) -> dict:
    dtype = np.uint64 if protocol in ("gc", "shamir") else np.float64
    with open(path) as f:
        doc = json.load(f)
    if "schema_version" in doc:          # v1 envelope
        doc = doc["outputs"]
    return {int(tag): np.asarray(v, dtype=dtype)
            for tag, v in doc.items()}


def cmd_fabric(args) -> int:
    """Launch one `run --worker K` process per global rank on localhost."""
    with open(os.path.join(args.jobdir, "job.json")) as f:
        spec = JobSpec.from_dict(json.load(f)["spec"]).normalized()
    w = get_workload(spec.workload)
    driver = args.driver or spec.driver
    if args.real and w.protocol == "gc":
        driver = "gc-2party"
    n_ranks = driver_parties(driver) * spec.num_workers
    peers = ",".join(f"127.0.0.1:{p}" for p in pick_free_ports(n_ranks))
    print(f"fabric: {n_ranks} ranks ({driver}) over {peers}")

    env = dict(os.environ)
    pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    outputs: dict = {}
    with tempfile.TemporaryDirectory(prefix="mage_fabric_") as outdir:
        procs = []
        for rank in range(n_ranks):
            out_json = os.path.join(outdir, f"rank{rank}.json")
            cmd = [sys.executable, "-m", "repro", "run", args.jobdir,
                   "--worker", str(rank), "--peers", peers,
                   "--json", out_json]
            if driver != spec.driver:
                cmd += ["--driver", driver]
            if args.storage:
                cmd += ["--storage", args.storage]
            procs.append((rank, out_json,
                          subprocess.Popen(cmd, env=env)))
        failed = []
        try:
            for rank, _, proc in procs:
                try:
                    rc = proc.wait(timeout=args.timeout)
                except subprocess.TimeoutExpired:
                    failed.append((rank, f"timeout after {args.timeout}s"))
                    # peers block on the stuck rank's traffic: kill the
                    # whole fleet now, not after n_ranks x timeout
                    for _, _, p in procs:
                        if p.poll() is None:
                            p.kill()
                    continue
                if rc != 0:
                    failed.append((rank, rc))
        finally:
            for rank, _, proc in procs:  # don't leak ranks on error/timeout
                if proc.poll() is None:
                    proc.kill()
        if failed:
            raise SystemExit(f"error: fabric ranks failed: {failed}")
        for rank, out_json, _ in procs:
            outputs.update(_load_outputs(out_json, w.protocol))
    print(f"fabric: merged {len(outputs)} outputs from {n_ranks} ranks")
    if args.json:
        _dump_outputs(args.json, outputs)
        print(f"wrote {args.json}")
    if args.check:
        check_outputs(w, spec.n, outputs)
        print("oracle check OK")
    return 0


def cmd_exec(args) -> int:
    spec = _spec_from_args(args, default_mode="memory")
    outputs = run_job(spec, real=args.real or None, check=args.check,
                      cache=args.cache)
    print(f"{len(outputs)} outputs"
          + (", oracle check OK" if args.check else ""))
    return 0


def cmd_bench(args) -> int:
    from .scenarios import (BENCH_CASES, STREAMING_CASE, SWEEP_BUDGETS,
                            SWEEP_LOOKAHEADS, TINY_BENCH_CASES,
                            TINY_STREAMING_CASE, run_bench, run_sweep)
    if args.cases:
        cases = []
        for item in args.cases.split(","):
            name, _, n = item.partition("=")
            if not name or not n.isdigit():
                raise SystemExit(
                    f"error: bad --cases entry {item!r} (want workload=n, "
                    f"e.g. merge=16384)")
            cases.append((name, int(n)))
    else:
        cases = TINY_BENCH_CASES if args.tiny else BENCH_CASES
    if args.sweep:
        budgets = tuple(float(b) for b in args.budgets.split(",")) \
            if args.budgets else SWEEP_BUDGETS
        lookaheads = tuple(int(x) for x in args.lookaheads.split(",")) \
            if args.lookaheads else SWEEP_LOOKAHEADS
        rows = run_sweep(cases=cases, budgets=budgets,
                         lookaheads=lookaheads, sim_core=args.sim_core,
                         plan_core=args.plan_core, cache_dir=args.cache)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"schema_version": SCHEMA_VERSION,
                           "benchmark": "bench_sweep",
                           "sweep": {"budgets": list(budgets),
                                     "lookaheads": list(lookaheads)},
                           "rows": rows}, f, indent=2)
            print(f"wrote {args.json}")
        return 0
    streaming_case = None
    if args.streaming or args.tiny:
        streaming_case = TINY_STREAMING_CASE if args.tiny else STREAMING_CASE
    rows = run_bench(cases=cases, budget_frac=args.budget_frac,
                     check=not args.no_check and not args.tiny,
                     streaming_case=streaming_case, sim_core=args.sim_core,
                     plan_core=args.plan_core, cache_dir=args.cache)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION, "rows": rows},
                      f, indent=2)
        print(f"wrote {args.json}")
    return 0


def _parse_drop(items) -> list[tuple[int, int]]:
    """``--drop R:c1,c2`` → [(R, c1), (R, c2)] straggler pairs."""
    out: list[tuple[int, int]] = []
    for item in items or ():
        rnd, sep, rest = item.partition(":")
        if not sep or not rnd.isdigit():
            raise SystemExit(f"error: bad --drop entry {item!r} "
                             f"(want ROUND:client,client,...)")
        for c in rest.split(","):
            if not c.isdigit():
                raise SystemExit(f"error: bad --drop client {c!r} in "
                                 f"{item!r}")
            out.append((int(rnd), int(c)))
    return out


def cmd_agg(args) -> int:
    """Secure aggregation: N input-only clients → a small compute fleet
    (docs/AGGREGATE.md)."""
    from .aggregate import AggSpec, run_aggregation, verify_aggregates
    spec = AggSpec(clients=args.clients, vec_len=args.vec_len,
                   rounds=args.rounds, servers=args.servers,
                   gateways=args.gateways, seed=args.seed,
                   max_inflight_msgs=args.max_inflight_msgs,
                   max_inflight_bytes=args.max_inflight_bytes,
                   round_timeout_s=args.round_timeout)
    transport = args.transport
    if args.rank is not None:
        if not args.peers:
            raise SystemExit("error: --rank needs --peers host:port,... "
                             "(one address per fabric rank: servers then "
                             "gateways)")
        transport = transport or "tcp"
        fabric = FabricSpec(rank=args.rank,
                            peers=tuple(args.peers.split(",")),
                            latency_s=args.latency,
                            bandwidth=args.bandwidth)
    else:
        transport = transport or "inproc"
        fabric = FabricSpec(latency_s=args.latency,
                            bandwidth=args.bandwidth)
    cache = None
    if args.cache:
        from .serve_daemon.cache import ArtifactCache
        cache = ArtifactCache(args.cache)
    res = run_aggregation(spec, transport=transport, fabric_spec=fabric,
                          cache=cache, drop=_parse_drop(args.drop))
    for r in res.rounds:
        head = ", ".join(str(int(v)) for v in r.total[:4])
        note = (f" DEGRADED ({spec.clients - len(r.survivors)} dropped)"
                if r.degraded else "")
        print(f"round {r.rnd}: {len(r.survivors)}/{spec.clients} clients, "
              f"aggregate [{head}{', ...' if len(r.total) > 4 else ''}]"
              f"{note}")
    if res.rounds:
        print(f"{res.clients_per_s:.0f} clients/s over {res.seconds:.3f}s; "
              f"plan events: {res.plan_events}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION, **res.to_doc()}, f)
        print(f"wrote {args.json}")
    if args.check and res.rounds:
        try:
            verify_aggregates(res)
        except AssertionError as e:
            print(f"error: {e}", file=sys.stderr)
            return 1
        print("aggregate check OK")
    return 0


def cmd_serve(args) -> int:
    from .serve_daemon.server import ServeDaemon
    d = ServeDaemon(args.cache, socket_path=args.socket,
                    host=args.host, port=args.port,
                    frame_pool=args.frame_pool,
                    memory_bytes=args.memory_bytes,
                    cache_bytes=args.cache_bytes,
                    max_queue=args.max_queue,
                    plan_core=args.plan_core, sim_core=args.sim_core)
    addr = d.address if isinstance(d.address, str) \
        else f"{d.address[0]}:{d.address[1]}"
    print(f"serving on {addr} (cache: {d.cache.root}, "
          f"frame pool: {d.admission.frame_pool})", flush=True)
    try:
        d.serve_forever()
    except KeyboardInterrupt:
        d.shutdown()
    return 0


def cmd_submit(args) -> int:
    from .serve_daemon.client import ServeError, serve_client
    with serve_client(args.connect, timeout=args.timeout) as c:
        if args.status:
            resp = c.status()
        elif args.shutdown:
            resp = c.shutdown()
        else:
            spec = _spec_from_args(args, default_mode="streaming")
            try:
                resp = c.submit(spec, execute=args.execute,
                                check=args.check,
                                queue=not args.no_queue,
                                timeout=args.timeout,
                                use_cache=not args.no_cache)
            except ServeError as e:
                print(f"error: {e}", file=sys.stderr)
                return 3 if e.rejected else 1
    text = json.dumps(resp, indent=2)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro", description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="plan memory programs to a directory")
    _add_spec_args(p)
    _add_cache_arg(p)
    p.add_argument("--out", required=True, help="output directory")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("run", help="execute a planned job directory")
    p.add_argument("jobdir")
    p.add_argument("--check", action="store_true",
                   help="verify outputs against the numpy oracle")
    p.add_argument("--real", action="store_true",
                   help="GC: run real two-party crypto")
    p.add_argument("--storage", default=None, choices=("ram", "memmap"))
    p.add_argument("--driver", default=None)
    p.add_argument("--worker", type=int, default=None, metavar="K",
                   help="distributed mode: run ONLY global rank K "
                        "(party*workers + worker) against --peers")
    p.add_argument("--peers", default=None,
                   help="comma list of host:port, one per global rank")
    p.add_argument("--transport", default=None,
                   choices=("inproc", "tcp", "shaped", "shaped+tcp"),
                   help="transport backend (default: inproc; "
                        "--worker defaults to tcp)")
    p.add_argument("--latency", type=float, default=0.0,
                   help="shaped: per-link one-way latency (s)")
    p.add_argument("--bandwidth", type=float, default=None,
                   help="shaped: per-link bandwidth (bytes/s)")
    p.add_argument("--json", metavar="PATH",
                   help="write this process's outputs as JSON")
    p.add_argument("--exec-backend", dest="exec_backend", default=None,
                   choices=("scalar", "batched", "overlap"),
                   help="override the engine backend for this run "
                        "(docs/ENGINE.md, docs/OVERLAP.md); outputs are "
                        "identical")
    _add_core_args(p, default=None)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("fabric", help="run a planned job as an N-process "
                                      "localhost TCP fleet")
    p.add_argument("jobdir")
    p.add_argument("--check", action="store_true",
                   help="verify the merged outputs against the oracle")
    p.add_argument("--real", action="store_true",
                   help="GC: run real two-party crypto (2x the ranks)")
    p.add_argument("--storage", default=None, choices=("ram", "memmap"))
    p.add_argument("--driver", default=None)
    p.add_argument("--json", metavar="PATH",
                   help="write the merged outputs as JSON")
    p.add_argument("--timeout", type=float, default=300.0,
                   help="per-rank process timeout (s)")
    p.set_defaults(fn=cmd_fabric)

    p = sub.add_parser("exec", help="trace+plan+execute in one shot")
    _add_spec_args(p)
    _add_cache_arg(p)
    p.add_argument("--check", action="store_true")
    p.add_argument("--real", action="store_true")
    p.set_defaults(fn=cmd_exec)

    p = sub.add_parser("bench", help="drive the §8.2 scenario benchmarks")
    p.add_argument("--cases", default=None,
                   help="comma list of workload=n (default: fig8 sweep)")
    p.add_argument("--budget-frac", type=float, default=0.4)
    p.add_argument("--tiny", action="store_true",
                   help="small sizes + no claim assertions (CI smoke)")
    p.add_argument("--streaming", action="store_true",
                   help="add a past-planner-cap case via the file pipeline")
    p.add_argument("--sweep", action="store_true",
                   help="budget x lookahead grid instead of the fixed "
                        "scenario run (rows carry both knob values)")
    p.add_argument("--budgets", default=None,
                   help="comma list of budget fractions for --sweep")
    p.add_argument("--lookaheads", default=None,
                   help="comma list of planner lookaheads for --sweep")
    _add_core_args(p)
    _add_cache_arg(p)
    p.add_argument("--no-check", action="store_true")
    p.add_argument("--json", metavar="PATH",
                   help="write rows as JSON (CI artifact)")
    p.set_defaults(fn=cmd_bench)

    p = sub.add_parser("agg", help="secure aggregation: many input-only "
                                   "clients stream additive shares to a "
                                   "compute fleet (docs/AGGREGATE.md)")
    p.add_argument("--clients", type=int, required=True,
                   help="number of simulated input-only clients")
    p.add_argument("--rounds", type=int, default=1)
    p.add_argument("--vec-len", type=int, default=64,
                   help="per-client uint64 vector length")
    p.add_argument("--servers", type=int, default=2,
                   help="compute-fleet size (fabric ranks [0, S))")
    p.add_argument("--gateways", type=int, default=2,
                   help="client-side fabric endpoints (ranks [S, S+G))")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--transport", default=None,
                   choices=("inproc", "tcp", "shaped", "shaped+tcp"),
                   help="transport backend (default: inproc; "
                        "--rank defaults to tcp)")
    p.add_argument("--rank", type=int, default=None, metavar="K",
                   help="distributed mode: host ONLY fabric rank K "
                        "against --peers")
    p.add_argument("--peers", default=None,
                   help="comma list of host:port, one per fabric rank")
    p.add_argument("--latency", type=float, default=0.0,
                   help="shaped: per-link one-way latency (s)")
    p.add_argument("--bandwidth", type=float, default=None,
                   help="shaped: per-link bandwidth (bytes/s)")
    p.add_argument("--max-inflight-msgs", type=int, default=0,
                   help="per-link reorder-buffer message bound (0 = off)")
    p.add_argument("--max-inflight-bytes", type=int, default=1 << 20,
                   help="per-link reorder-buffer byte bound (backpressure)")
    p.add_argument("--round-timeout", type=float, default=30.0,
                   help="straggler timeout per round (s); late clients "
                        "degrade the round to the surviving subset")
    p.add_argument("--drop", action="append", metavar="R:c1,c2",
                   help="simulate stragglers: these clients never send in "
                        "round R (repeatable)")
    _add_cache_arg(p)
    p.add_argument("--check", action="store_true",
                   help="verify every revealed aggregate against the "
                        "oracle over its surviving subset")
    p.add_argument("--json", metavar="PATH",
                   help="write the full result envelope as JSON")
    p.set_defaults(fn=cmd_agg)

    p = sub.add_parser("serve", help="run the multi-tenant plan-cache "
                                     "daemon (docs/SERVE.md)")
    p.add_argument("--cache", required=True, metavar="DIR",
                   help="artifact-cache root the daemon owns")
    p.add_argument("--socket", default=None, metavar="PATH",
                   help="unix socket path to listen on (default: TCP)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=None,
                   help="TCP port (default: OS-assigned, printed on start)")
    p.add_argument("--frame-pool", type=int, default=1 << 16,
                   help="shared frame budget across concurrent jobs")
    p.add_argument("--memory-bytes", type=int, default=None,
                   help="optional cap on summed per-job memory estimates")
    p.add_argument("--cache-bytes", type=int, default=None,
                   help="LRU-evict cache entries beyond this many bytes")
    p.add_argument("--max-queue", type=int, default=64,
                   help="max jobs waiting for admission before rejecting")
    _add_core_args(p, default=None)
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("submit", help="submit a job to a serve daemon")
    p.add_argument("--connect", required=True, metavar="ADDR",
                   help="daemon address: unix socket path or host:port")
    _add_spec_args(p)
    p.add_argument("--execute", action="store_true",
                   help="also execute the planned job on the daemon")
    p.add_argument("--check", action="store_true",
                   help="with --execute: verify against the oracle")
    p.add_argument("--no-queue", action="store_true",
                   help="reject (exit 3) instead of waiting for admission")
    p.add_argument("--no-cache", action="store_true",
                   help="bypass the daemon's artifact cache (cold run)")
    p.add_argument("--timeout", type=float, default=None,
                   help="admission + socket timeout (s)")
    p.add_argument("--status", action="store_true",
                   help="just print the daemon's status JSON")
    p.add_argument("--shutdown", action="store_true",
                   help="ask the daemon to shut down")
    p.add_argument("--json", metavar="PATH",
                   help="also write the response JSON here")
    p.set_defaults(fn=cmd_submit)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except SpecMismatchError as e:
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(2)
    except (ValueError, KeyError, TransportError) as e:
        # predictable spec/registry/fabric errors: clean CLI message,
        # not a trace
        print(f"error: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    sys.exit(main())
