"""The multi-tenant serving daemon behind ``python -m repro serve``.

One long-running process owns an :class:`~.cache.ArtifactCache` and an
:class:`~.admission.AdmissionController` and answers line-delimited JSON
requests over a local socket (unix path, or TCP on localhost):

    {"op": "submit", "spec": {...}, "execute": true, ...}\\n
    {"op": "status"}\\n | {"op": "ping"}\\n | {"op": "shutdown"}\\n

Each connection is served by its own thread and may pipeline many
requests; a ``submit`` runs the staged Session pipeline with the shared
cache, so a repeated job shape is served from cached artifacts with
zero tracing and zero planning (the whole point — tracing is the
slowest §8.2 stage).  The expensive stages (planning + execution) only
run under an admission reservation sized by the job's resolved frame
count, so concurrent tenants cannot overcommit the shared frame pool.

See docs/SERVE.md for the full protocol.
"""

from __future__ import annotations

import json
import os
import socket
import threading
import time
import traceback

from ..api import (SCHEMA_VERSION, JobSpec, Session, SpecMismatchError,
                   estimate_job_resources)
from ..core.bytecode import ProgramFile, iter_record_chunks
from ..core.liveness import file_digest, records_digest
from .admission import AdmissionController, AdmissionError
from .cache import ArtifactCache

#: request fields a submit accepts (anything else is rejected — the
#: protocol is versioned via schema_version, not silently lenient)
_SUBMIT_FIELDS = {"op", "spec", "execute", "check", "queue", "timeout",
                  "use_cache", "return_outputs"}


def program_digest(p) -> str:
    """Chunk-size-independent record digest of a planned program, hex.

    Equal iff the programs are bitwise-identical record streams — the
    hot-vs-cold acceptance check of the cache."""
    if isinstance(p, ProgramFile):
        return f"{file_digest(p) & (1 << 64) - 1:016x}"
    d = 0
    for s, rec, _instrs in iter_record_chunks(p):
        d = records_digest(d, rec, s)
    return f"{d & (1 << 64) - 1:016x}"


def _outputs_digest(outputs) -> str:
    import hashlib
    import numpy as np
    h = hashlib.sha256()
    for tag in sorted(outputs):
        h.update(str(tag).encode())
        h.update(np.ascontiguousarray(outputs[tag]).tobytes())
    return h.hexdigest()[:16]


class ServeDaemon:
    """Accept loop + per-connection request threads over one cache."""

    def __init__(self, cache_dir: str | os.PathLike,
                 socket_path: str | None = None,
                 host: str = "127.0.0.1", port: int | None = None,
                 frame_pool: int = 1 << 16,
                 memory_bytes: int | None = None,
                 cache_bytes: int | None = None,
                 max_queue: int = 64,
                 plan_core: str | None = None,
                 sim_core: str | None = None):
        self.cache = ArtifactCache(cache_dir, max_bytes=cache_bytes)
        self.admission = AdmissionController(frame_pool,
                                             memory_bytes=memory_bytes,
                                             max_queue=max_queue)
        self._core_overrides = {}
        if plan_core is not None:
            self._core_overrides["plan_core"] = plan_core
        if sim_core is not None:
            self._core_overrides["sim_core"] = sim_core
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._jobs = {"submitted": 0, "completed": 0, "failed": 0,
                      "rejected": 0}
        self._job_seq = 0
        self._stop = threading.Event()
        if socket_path is not None:
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            if os.path.exists(socket_path):
                os.unlink(socket_path)      # stale socket from a dead daemon
            self._sock.bind(socket_path)
            self.address: str | tuple[str, int] = socket_path
        else:
            self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._sock.bind((host, port or 0))
            self.address = self._sock.getsockname()
        self._sock.listen(64)

    # -- lifecycle -----------------------------------------------------------

    def serve_forever(self) -> None:
        """Accept connections until :meth:`shutdown`; blocks the caller."""
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                break                      # listener closed by shutdown()
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
        self._sock.close()

    def start(self) -> threading.Thread:
        """Run :meth:`serve_forever` on a background thread (tests/bench)."""
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        return t

    def shutdown(self) -> None:
        self._stop.set()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        if isinstance(self.address, str) and os.path.exists(self.address):
            os.unlink(self.address)

    # -- request handling ----------------------------------------------------

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn, conn.makefile("r", encoding="utf-8") as rf:
            for line in rf:
                line = line.strip()
                if not line:
                    continue
                try:
                    resp = self._dispatch(json.loads(line))
                except Exception as e:     # noqa: BLE001 — protocol boundary
                    resp = {"ok": False, "error": f"{type(e).__name__}: {e}",
                            "trace": traceback.format_exc(limit=4)}
                resp.setdefault("schema_version", SCHEMA_VERSION)
                try:
                    conn.sendall((json.dumps(resp) + "\n").encode())
                except OSError:
                    return
                if resp.get("op") == "shutdown":
                    self.shutdown()
                    return

    def _dispatch(self, req: dict) -> dict:
        op = req.get("op")
        if op == "ping":
            return {"ok": True, "op": "ping"}
        if op == "shutdown":
            return {"ok": True, "op": "shutdown"}
        if op == "status":
            return self.status()
        if op == "submit":
            return self._submit(req)
        return {"ok": False, "error": f"unknown op {op!r} (expected "
                                      f"submit|status|ping|shutdown)"}

    def status(self) -> dict:
        with self._lock:
            jobs = dict(self._jobs)
        return {"ok": True, "op": "status",
                "uptime_s": time.monotonic() - self._t0,
                "jobs": jobs, "cache": self.cache.status(),
                "admission": self.admission.status()}

    def _count(self, key: str) -> None:
        with self._lock:
            self._jobs[key] += 1

    def _submit(self, req: dict) -> dict:
        unknown = set(req) - _SUBMIT_FIELDS
        if unknown:
            return {"ok": False,
                    "error": f"unknown submit fields {sorted(unknown)}"}
        if not isinstance(req.get("spec"), dict):
            return {"ok": False, "error": "submit needs a 'spec' object"}
        spec = JobSpec.from_dict(req["spec"])
        if self._core_overrides:
            import dataclasses
            spec = dataclasses.replace(spec, **self._core_overrides)
        with self._lock:
            self._job_seq += 1
            job_id = self._job_seq
        self._count("submitted")
        t_start = time.perf_counter()
        cache = self.cache if req.get("use_cache", True) else None
        try:
            with Session(spec, cache=cache) as sess:
                frames, mem_bytes = estimate_job_resources(sess)
                t_admit = time.perf_counter()
                try:
                    grant = self.admission.admit(
                        frames, mem_bytes, queue=req.get("queue", True),
                        timeout=req.get("timeout"))
                except AdmissionError as e:
                    self._count("rejected")
                    return {"ok": False, "op": "submit", "job_id": job_id,
                            "rejected": True, "error": str(e)}
                queued_s = time.perf_counter() - t_admit
                with grant:
                    t_plan = time.perf_counter()
                    planned = sess.plan()
                    plan_s = time.perf_counter() - t_plan
                    digests = [program_digest(p) for p in planned]
                    resp = {
                        "ok": True, "op": "submit", "job_id": job_id,
                        "spec_hash": sess.spec.plan_hash(sess.workload),
                        "trace_hash": sess.spec.trace_hash(sess.workload),
                        "cache": {"trace": sess.cache_events.get(
                                      "trace", "skipped"),
                                  "plan": sess.cache_events.get(
                                      "plan", "skipped")},
                        "frames": frames,
                        "memory_estimate_bytes": mem_bytes,
                        "digests": {"plan": digests},
                        "timings": {"queued_s": queued_s,
                                    "plan_s": plan_s},
                    }
                    if req.get("execute", False):
                        t_exec = time.perf_counter()
                        outputs = sess.execute(
                            check=req.get("check", False))
                        resp["timings"]["execute_s"] = \
                            time.perf_counter() - t_exec
                        resp["outputs_digest"] = _outputs_digest(outputs)
                        if sess.spec.exec_backend == "batched":
                            # batch-schedule sidecar cache outcome; only
                            # batched executes consult that cache kind
                            resp["cache"]["batch"] = \
                                sess.cache_events.get("batch", "skipped")
                        if sess.spec.exec_backend == "overlap":
                            resp["cache"]["overlap"] = \
                                sess.cache_events.get("overlap", "skipped")
                        if req.get("return_outputs", False):
                            resp["outputs"] = {
                                str(t): v.tolist()
                                for t, v in outputs.items()}
            resp["timings"]["total_s"] = time.perf_counter() - t_start
            self._count("completed")
            return resp
        except (SpecMismatchError, ValueError, KeyError,
                AssertionError) as e:
            self._count("failed")
            return {"ok": False, "op": "submit", "job_id": job_id,
                    "error": f"{type(e).__name__}: {e}"}
