"""Client helper for the serving daemon's line-delimited JSON protocol.

    client = repro.serve_client("/tmp/mage.sock")      # or "host:port"
    resp = client.submit(JobSpec(workload="merge", n=4096,
                                 memory_budget=64), execute=True)
    assert resp["cache"]["plan"] in ("hit", "miss")
    client.status(); client.close()

One client holds one connection and may pipeline many requests; it is
what ``python -m repro submit`` and ``benchmarks/serve_bench.py`` use.
"""

from __future__ import annotations

import dataclasses
import json
import socket


class ServeError(RuntimeError):
    """The daemon answered ``ok: false`` (``.response`` has the detail)."""

    def __init__(self, response: dict):
        super().__init__(response.get("error", "daemon request failed"))
        self.response = response
        self.rejected = bool(response.get("rejected"))


def _connect(address) -> socket.socket:
    if isinstance(address, tuple):
        return socket.create_connection(address)
    address = str(address)
    if ":" in address and "/" not in address:
        host, _, port = address.rpartition(":")
        return socket.create_connection((host, int(port)))
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.connect(address)
    return s


class ServeClient:
    """One connection to a :class:`~repro.serve_daemon.ServeDaemon`."""

    def __init__(self, address, timeout: float | None = None):
        self.address = address
        self._sock = _connect(address)
        if timeout is not None:
            self._sock.settimeout(timeout)
        self._rf = self._sock.makefile("r", encoding="utf-8")

    # -- plumbing ------------------------------------------------------------

    def request(self, req: dict) -> dict:
        """Send one request line, read one response line; raises
        :class:`ServeError` on ``ok: false`` responses."""
        self._sock.sendall((json.dumps(req) + "\n").encode())
        line = self._rf.readline()
        if not line:
            raise ConnectionError("daemon closed the connection")
        resp = json.loads(line)
        if not resp.get("ok", False):
            raise ServeError(resp)
        return resp

    def close(self) -> None:
        try:
            self._rf.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- operations ----------------------------------------------------------

    def ping(self) -> dict:
        return self.request({"op": "ping"})

    def status(self) -> dict:
        return self.request({"op": "status"})

    def shutdown(self) -> dict:
        return self.request({"op": "shutdown"})

    def submit(self, spec, execute: bool = False, check: bool = False,
               queue: bool = True, timeout: float | None = None,
               use_cache: bool = True,
               return_outputs: bool = False) -> dict:
        """Submit one job spec (a ``JobSpec`` or a plain spec dict)."""
        if dataclasses.is_dataclass(spec):
            spec = spec.to_dict()
        req = {"op": "submit", "spec": spec, "execute": execute,
               "check": check, "queue": queue, "use_cache": use_cache,
               "return_outputs": return_outputs}
        if timeout is not None:
            req["timeout"] = timeout
        return self.request(req)


def serve_client(address, timeout: float | None = None) -> ServeClient:
    """Connect to a serving daemon: a unix-socket path or ``host:port``."""
    return ServeClient(address, timeout=timeout)
