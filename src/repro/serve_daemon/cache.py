"""On-disk artifact cache: traced bytecode, next-use sidecars, plans.

Planned memory programs are deterministic functions of the plan-relevant
spec fields (``JobSpec.plan_hash``) and traced bytecode of the trace-
relevant subset (``JobSpec.trace_hash``), so both are cacheable across
sessions, processes and daemon restarts.  Layout under one cache root::

    <root>/trace/<trace_hash>/manifest.json
                              worker0.virtual.bc    FREE-stripped bytecode
                              worker0.ann           next-use sidecar
    <root>/plan/<plan_hash>/manifest.json
                            worker0.memory.bc       planned memory program
    <root>/batch/<plan_hash>/manifest.json
                             worker0.batch.npz      exec/ batch schedule
    <root>/overlap/<plan_hash>/manifest.json
                               worker0.overlap.npz  exec/ overlap schedule

Every entry's manifest records the sha256 + byte size of each artifact
file, the spec that produced it, and (for plans) the resolved per-worker
``PlanConfig`` and ``PlanReport`` so a cache-hit Session can still
``simulate()``.  A hit is validated exactly like ``Session.from_plan``
— the key is recomputed from the manifest's spec, the stamped hash in
every program header must agree, and additionally every file must match
its recorded digest — so a tampered or truncated entry is *rejected and
deleted* (counted in ``stats.invalid``) and the caller transparently
re-traces/re-plans.

Eviction is LRU by entry (manifest mtime; hits ``os.utime`` it) and
runs after each put until the root is back under ``max_bytes``.  The
entry being written is never the victim.  Counters (hits, misses,
invalid, evictions, bytes) feed the daemon's ``status`` response.

Concurrency: one in-process lock serializes counter updates and
eviction; file writes build the entry under a temporary name and
``os.rename`` it into place, so readers only ever see complete entries
(a lost race to publish the same key keeps the winner's files).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading

from ..core.bytecode import ProgramFile, strip_frees, writer_like
from ..core.liveness import annotate_next_use
from ..core.planner import PlanConfig, PlanReport
from ..core.replacement import ReplacementStats
from ..core.scheduling import ScheduleStats

MANIFEST = "manifest.json"
CACHE_FORMAT = 1
#: Session.trace stamps these per-spec; the cached trace is the pure
#: function of the trace fields, so they are stripped before writing and
#: re-stamped by the consuming Session on a hit.
_SPEC_META_KEYS = ("spec_hash", "job_spec")


@dataclasses.dataclass
class CacheStats:
    """Hit/miss/byte counters, exposed by the daemon's JSON status."""
    trace_hits: int = 0
    trace_misses: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    batch_hits: int = 0
    batch_misses: int = 0
    overlap_hits: int = 0
    overlap_misses: int = 0
    agg_hits: int = 0
    agg_misses: int = 0
    invalid: int = 0          # tampered/truncated entries rejected + deleted
    evictions: int = 0
    bytes_read: int = 0       # validated artifact bytes served from cache
    bytes_written: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _report_to_dict(rep: PlanReport) -> dict:
    return dataclasses.asdict(rep)


def _report_from_dict(d: dict) -> PlanReport:
    rep = dict(d)
    r, s = rep.pop("replacement", None), rep.pop("schedule", None)
    return PlanReport(
        replacement=ReplacementStats(**r) if r else None,
        schedule=ScheduleStats(**s) if s else None, **rep)


class CacheEntryError(ValueError):
    """A cache entry failed validation (tampered, truncated, or stale)."""


class ArtifactCache:
    """Spec-hash-keyed cache of traced bytecode and planned programs."""

    def __init__(self, root: str | os.PathLike,
                 max_bytes: int | None = None):
        self.root = os.fspath(root)
        self.max_bytes = max_bytes
        self.stats = CacheStats()
        self._lock = threading.Lock()
        os.makedirs(os.path.join(self.root, "trace"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "plan"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "batch"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "overlap"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "agg"), exist_ok=True)

    # -- bookkeeping ---------------------------------------------------------

    def _entries(self) -> list[tuple[float, int, str]]:
        """(mtime, bytes, dir) per complete entry, oldest first."""
        out = []
        for kind in ("trace", "plan", "batch", "overlap", "agg"):
            base = os.path.join(self.root, kind)
            for name in os.listdir(base):
                d = os.path.join(base, name)
                man = os.path.join(d, MANIFEST)
                if not os.path.isfile(man):
                    continue          # incomplete / mid-publish
                size = sum(e.stat().st_size for e in os.scandir(d)
                           if e.is_file())
                out.append((os.stat(man).st_mtime, size, d))
        out.sort()
        return out

    def total_bytes(self) -> int:
        return sum(size for _, size, _ in self._entries())

    def entry_count(self) -> int:
        return len(self._entries())

    def status(self) -> dict:
        return {"root": self.root, "max_bytes": self.max_bytes,
                "entries": self.entry_count(),
                "total_bytes": self.total_bytes(),
                **self.stats.to_dict()}

    def _evict(self, keep: str) -> None:
        """LRU-evict until under ``max_bytes``; never evicts ``keep``."""
        if self.max_bytes is None:
            return
        entries = self._entries()
        total = sum(size for _, size, _ in entries)
        keep = os.path.abspath(keep)
        for _, size, d in entries:
            if total <= self.max_bytes:
                break
            if os.path.abspath(d) == keep:
                continue
            shutil.rmtree(d, ignore_errors=True)
            total -= size
            self.stats.evictions += 1

    def _drop(self, entry_dir: str) -> None:
        with self._lock:
            self.stats.invalid += 1
        shutil.rmtree(entry_dir, ignore_errors=True)

    def _publish(self, tmp: str, entry_dir: str) -> None:
        """Atomically move a fully-built entry into place."""
        try:
            os.rename(tmp, entry_dir)
        except OSError:
            # lost a publish race (or a stale entry exists): keep theirs
            # if complete, replace if it is junk without a manifest
            if os.path.isfile(os.path.join(entry_dir, MANIFEST)):
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                shutil.rmtree(entry_dir, ignore_errors=True)
                os.rename(tmp, entry_dir)
        with self._lock:
            self._evict(keep=entry_dir)

    def _load(self, kind: str, key: str) -> tuple[str, dict] | None:
        """Validate and return (entry_dir, manifest) or None (= miss).

        Validation mirrors ``Session.from_plan``: the key is recomputed
        from the manifest's spec and every program's stamped hash must
        agree; additionally every artifact file must match its recorded
        sha256/size, so bit-level tampering is caught too."""
        entry_dir = os.path.join(self.root, kind, key)
        man_path = os.path.join(entry_dir, MANIFEST)
        if not os.path.isfile(man_path):
            return None
        try:
            with open(man_path) as f:
                manifest = json.load(f)
            if manifest.get("format") != CACHE_FORMAT or \
                    manifest.get("kind") != kind:
                raise CacheEntryError("wrong manifest format")
            if kind == "agg":
                from ..aggregate.offline import AggSpec
                expect = AggSpec.from_dict(manifest["spec"]).plan_key()
            else:
                from ..api import JobSpec
                spec = JobSpec.from_dict(manifest["spec"])
                expect = spec.trace_hash() if kind == "trace" \
                    else spec.plan_hash()
            if manifest.get("key") != key or expect != key:
                raise CacheEntryError(
                    f"manifest spec hashes to {expect}, entry claims "
                    f"{manifest.get('key')} ({key})")
            nread = 0
            for name, rec in manifest["files"].items():
                path = os.path.join(entry_dir, name)
                if not os.path.isfile(path) or \
                        os.path.getsize(path) != rec["bytes"] or \
                        _sha256(path) != rec["sha256"]:
                    raise CacheEntryError(f"{name} does not match its "
                                          f"recorded digest")
                nread += rec["bytes"]
            for name in manifest["programs"]:
                pf = ProgramFile(os.path.join(entry_dir, name))
                stamped = pf.meta.get("trace_hash") if kind == "trace" \
                    else pf.meta.get("spec_hash")
                if stamped != key:
                    raise CacheEntryError(
                        f"{name} was produced for {stamped}, entry says "
                        f"{key} — artifact and spec disagree")
        except (OSError, ValueError, KeyError, TypeError):
            # CacheEntryError is a ValueError: tampered/truncated/stale
            # entries are deleted so the caller re-traces/re-plans
            self._drop(entry_dir)
            return None
        os.utime(man_path)            # LRU touch
        with self._lock:
            self.stats.bytes_read += nread
        return entry_dir, manifest

    def _write_manifest(self, tmp: str, manifest: dict) -> None:
        files = {}
        nbytes = 0
        for e in os.scandir(tmp):
            if e.is_file():
                files[e.name] = {"sha256": _sha256(e.path),
                                 "bytes": e.stat().st_size}
                nbytes += e.stat().st_size
        manifest["format"] = CACHE_FORMAT
        manifest["files"] = files
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2)
        with self._lock:
            self.stats.bytes_written += nbytes

    def _tmpdir(self, kind: str) -> str:
        return tempfile.mkdtemp(prefix=".tmp-", dir=os.path.join(self.root,
                                                                 kind))

    # -- traced bytecode + next-use sidecars ---------------------------------

    def get_trace(self, spec, workload=None
                  ) -> tuple[list[ProgramFile], list[str]] | None:
        """Cached (programs, sidecar paths) for the spec's trace shape."""
        key = spec.trace_hash(workload)
        got = self._load("trace", key)
        with self._lock:
            if got is None:
                self.stats.trace_misses += 1
            else:
                self.stats.trace_hits += 1
        if got is None:
            return None
        entry_dir, manifest = got
        progs = [ProgramFile(os.path.join(entry_dir, n))
                 for n in manifest["programs"]]
        anns = [os.path.join(entry_dir, n)
                for n in manifest["annotations"]]
        return progs, anns

    def put_trace(self, spec, workload, progs,
                  chunk_instrs: int = 8192
                  ) -> tuple[list[ProgramFile], list[str]]:
        """Cache freshly traced programs; returns the cache-resident
        (FREE-stripped) files + sidecars, which the session adopts so
        the cold path annotates exactly once."""
        key = spec.trace_hash(workload)
        entry_dir = os.path.join(self.root, "trace", key)
        tmp = self._tmpdir("trace")
        try:
            names, ann_names = [], []
            for i, prog in enumerate(progs):
                name, ann = f"worker{i}.virtual.bc", f"worker{i}.ann"
                meta = {k: v for k, v in prog.meta.items()
                        if k not in _SPEC_META_KEYS}
                meta["trace_hash"] = key
                w = writer_like(prog, os.path.join(tmp, name), meta=meta,
                                chunk_instrs=chunk_instrs)
                w.extend(strip_frees(prog.instrs))
                pf = w.close()
                annotate_next_use(pf, os.path.join(tmp, ann), chunk_instrs)
                names.append(name)
                ann_names.append(ann)
            self._write_manifest(tmp, {
                "kind": "trace", "key": key,
                "spec": spec.normalized(workload).to_dict(),
                "programs": names, "annotations": ann_names})
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._publish(tmp, entry_dir)
        progs = [ProgramFile(os.path.join(entry_dir, n)) for n in names]
        anns = [os.path.join(entry_dir, n) for n in ann_names]
        return progs, anns

    # -- planned memory programs ---------------------------------------------

    def get_plan(self, spec, workload=None
                 ) -> tuple[list[ProgramFile], list[PlanConfig],
                            list[PlanReport]] | None:
        """Cached (memory programs, resolved configs, plan reports)."""
        key = spec.plan_hash(workload)
        got = self._load("plan", key)
        with self._lock:
            if got is None:
                self.stats.plan_misses += 1
            else:
                self.stats.plan_hits += 1
        if got is None:
            return None
        entry_dir, manifest = got
        progs = [ProgramFile(os.path.join(entry_dir, n))
                 for n in manifest["programs"]]
        cfgs = [PlanConfig(**d) for d in manifest["plan_configs"]]
        reports = [_report_from_dict(d) for d in manifest["reports"]]
        return progs, cfgs, reports

    # -- batch schedules (exec/ backend sidecars) ----------------------------

    def get_batch(self, spec, workload=None):
        """Cached per-worker :class:`~repro.exec.batching.BatchSchedule`
        sidecars for the spec's plan shape, or None.  Keyed by
        ``plan_hash``: the schedule is a deterministic function of the
        planned memory program, which is itself keyed the same way."""
        from ..exec.batching import BatchSchedule
        key = spec.plan_hash(workload)
        got = self._load("batch", key)
        with self._lock:
            if got is None:
                self.stats.batch_misses += 1
            else:
                self.stats.batch_hits += 1
        if got is None:
            return None
        entry_dir, manifest = got
        try:
            return [BatchSchedule.load(os.path.join(entry_dir, n))
                    for n in manifest["schedules"]]
        except (OSError, ValueError, KeyError):
            self._drop(entry_dir)
            return None

    def put_batch(self, spec, workload, schedules) -> None:
        """Cache freshly built batch schedules (one npz per worker)."""
        key = spec.plan_hash(workload)
        entry_dir = os.path.join(self.root, "batch", key)
        tmp = self._tmpdir("batch")
        try:
            names = []
            for i, sched in enumerate(schedules):
                name = f"worker{i}.batch.npz"
                sched.save(os.path.join(tmp, name))
                names.append(name)
            # "programs" is always present (entry validation iterates it);
            # batch entries carry sidecars, not bytecode
            self._write_manifest(tmp, {
                "kind": "batch", "key": key,
                "spec": spec.normalized(workload).to_dict(),
                "programs": [], "schedules": names})
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._publish(tmp, entry_dir)

    # -- overlap schedules (exec/ overlap backend sidecars) ------------------

    def get_overlap(self, spec, workload=None):
        """Cached per-worker :class:`~repro.exec.overlap.OverlapSchedule`
        sidecars for the spec's plan shape, or None.  Keyed by
        ``plan_hash`` like the batch sidecars: the schedule is a
        deterministic function of the planned memory program."""
        from ..exec.overlap import OverlapSchedule
        key = spec.plan_hash(workload)
        got = self._load("overlap", key)
        with self._lock:
            if got is None:
                self.stats.overlap_misses += 1
            else:
                self.stats.overlap_hits += 1
        if got is None:
            return None
        entry_dir, manifest = got
        try:
            return [OverlapSchedule.load(os.path.join(entry_dir, n))
                    for n in manifest["schedules"]]
        except (OSError, ValueError, KeyError):
            self._drop(entry_dir)
            return None

    def put_overlap(self, spec, workload, schedules) -> None:
        """Cache freshly built overlap schedules (one npz per worker)."""
        key = spec.plan_hash(workload)
        entry_dir = os.path.join(self.root, "overlap", key)
        tmp = self._tmpdir("overlap")
        try:
            names = []
            for i, sched in enumerate(schedules):
                name = f"worker{i}.overlap.npz"
                sched.save(os.path.join(tmp, name))
                names.append(name)
            # "programs" is always present (entry validation iterates it)
            self._write_manifest(tmp, {
                "kind": "overlap", "key": key,
                "spec": spec.normalized(workload).to_dict(),
                "programs": [], "schedules": names})
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._publish(tmp, entry_dir)

    # -- secure-aggregation round plans (docs/AGGREGATE.md) ------------------

    def get_agg(self, spec) -> dict | None:
        """Cached round-plan document for an ``AggSpec``, or None.  Keyed
        by ``AggSpec.plan_key()``: the plan is a pure function of the
        plan-relevant spec fields (the aggregation schedule is oblivious,
        so it is derived entirely ahead of time)."""
        key = spec.plan_key()
        got = self._load("agg", key)
        with self._lock:
            if got is None:
                self.stats.agg_misses += 1
            else:
                self.stats.agg_hits += 1
        if got is None:
            return None
        entry_dir, manifest = got
        try:
            with open(os.path.join(entry_dir, "roundplan.json")) as f:
                return json.load(f)
        except (OSError, ValueError):
            self._drop(entry_dir)
            return None

    def put_agg(self, spec, plan_doc: dict) -> None:
        """Cache a freshly derived round plan (one JSON sidecar)."""
        key = spec.plan_key()
        entry_dir = os.path.join(self.root, "agg", key)
        tmp = self._tmpdir("agg")
        try:
            with open(os.path.join(tmp, "roundplan.json"), "w") as f:
                json.dump(plan_doc, f, indent=2)
            # "programs" is always present (entry validation iterates it)
            self._write_manifest(tmp, {
                "kind": "agg", "key": key, "spec": spec.to_dict(),
                "programs": [], "artifacts": ["roundplan.json"]})
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._publish(tmp, entry_dir)

    def put_plan(self, spec, workload, planned, cfgs, reports) -> None:
        """Cache planned memory programs (files are copied, the session
        keeps executing its own artifacts)."""
        from ..core.bytecode import write_program
        key = spec.plan_hash(workload)
        entry_dir = os.path.join(self.root, "plan", key)
        tmp = self._tmpdir("plan")
        try:
            names = []
            for i, p in enumerate(planned):
                name = f"worker{i}.memory.bc"
                dst = os.path.join(tmp, name)
                if isinstance(p, ProgramFile):
                    shutil.copyfile(p.path, dst)
                else:
                    write_program(p, dst)
                names.append(name)
            self._write_manifest(tmp, {
                "kind": "plan", "key": key,
                "spec": spec.normalized(workload).to_dict(),
                "programs": names,
                "plan_configs": [dataclasses.asdict(c) for c in cfgs],
                "reports": [_report_to_dict(r) for r in reports]})
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._publish(tmp, entry_dir)
