"""Plan-cache serving daemon (`python -m repro serve`).

MAGE's central artifact — the memory program — is a deterministic,
spec-hash-stamped function of the job spec (§5–§7), so a production
service should never re-trace or re-plan a repeated job shape.  This
package is the serving layer built on that observation:

  cache.py      on-disk :class:`ArtifactCache` of traced bytecode,
                next-use sidecars and memory-program plans, keyed by
                spec hash, validated on hit exactly like
                ``Session.from_plan`` (tampered entries are rejected
                and transparently re-planned), LRU size-capped;
  admission.py  :class:`AdmissionController` — a shared frame-pool
                budget plus planner/engine memory estimates bound how
                many tenants plan/execute concurrently;
  server.py     :class:`ServeDaemon` — a line-delimited JSON request
                protocol over a local (unix or TCP) socket;
  client.py     :class:`ServeClient` / :func:`serve_client` — the
                matching helper `python -m repro submit` and the
                benchmarks use.

See docs/SERVE.md for the protocol, the cache layout and the admission
semantics.
"""

from .admission import AdmissionController, AdmissionError
from .cache import ArtifactCache, CacheStats
from .client import ServeClient, serve_client
from .server import ServeDaemon

__all__ = ["AdmissionController", "AdmissionError", "ArtifactCache",
           "CacheStats", "ServeClient", "ServeDaemon", "serve_client"]
