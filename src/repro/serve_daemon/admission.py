"""Admission control: a shared frame pool + memory budget across tenants.

The planner's and engine's footprints are O(frames) (docs/PLANNER.md,
``repro.core.planner.plan_memory_estimate``), so the daemon bounds
concurrent sessions by the frames they will pin: a job needs
``sum(cfg.num_frames)`` frames across its workers, and the controller
admits jobs only while the running total stays within ``frame_pool``
(and, when configured, their memory estimates within ``memory_bytes``).

Jobs that do not fit *right now* wait on a FIFO ticket queue (so a
stream of small jobs cannot starve a large one) unless they asked not
to queue, in which case — and whenever a job could *never* fit — an
:class:`AdmissionError` with the concrete numbers is raised for the
protocol layer to surface.
"""

from __future__ import annotations

import collections
import dataclasses
import threading


class AdmissionError(RuntimeError):
    """The job would overcommit the shared frame pool / memory budget."""


@dataclasses.dataclass
class _Ticket:
    frames: int
    mem_bytes: int
    granted: bool = False


class AdmissionController:
    """Bounds concurrent jobs by frames (and optionally bytes)."""

    def __init__(self, frame_pool: int, memory_bytes: int | None = None,
                 max_queue: int = 64):
        if frame_pool <= 0:
            raise ValueError("frame_pool must be positive")
        self.frame_pool = frame_pool
        self.memory_bytes = memory_bytes
        self.max_queue = max_queue
        self._cv = threading.Condition()
        self._queue: collections.deque[_Ticket] = collections.deque()
        self.frames_in_use = 0
        self.bytes_in_use = 0
        self.active = 0
        self.admitted = 0
        self.rejected = 0
        self.peak_frames = 0
        self.queued_peak = 0

    # -- core ----------------------------------------------------------------

    def _fits(self, t: _Ticket) -> bool:
        if self.frames_in_use + t.frames > self.frame_pool:
            return False
        if self.memory_bytes is not None and \
                self.bytes_in_use + t.mem_bytes > self.memory_bytes:
            return False
        return True

    def _check_possible(self, frames: int, mem_bytes: int) -> None:
        if frames > self.frame_pool:
            raise AdmissionError(
                f"job needs {frames} frames but the shared frame pool is "
                f"{self.frame_pool}; it can never be admitted — lower the "
                f"memory_budget or raise the daemon's --frame-pool")
        if self.memory_bytes is not None and mem_bytes > self.memory_bytes:
            raise AdmissionError(
                f"job's estimated {mem_bytes} bytes exceed the daemon's "
                f"memory budget of {self.memory_bytes} bytes")

    def admit(self, frames: int, mem_bytes: int = 0, queue: bool = True,
              timeout: float | None = None) -> "Admission":
        """Block until the job fits (FIFO), then reserve its resources.

        ``queue=False`` turns a would-wait into an immediate
        :class:`AdmissionError`; a job larger than the whole pool is
        always an error.  Returns a context manager releasing the
        reservation on exit."""
        frames = max(int(frames), 0)
        t = _Ticket(frames, max(int(mem_bytes), 0))
        with self._cv:
            self._check_possible(t.frames, t.mem_bytes)
            if not self._fits(t) or self._queue:
                if not queue:
                    self.rejected += 1
                    raise AdmissionError(
                        f"admission would overcommit: {frames} frames "
                        f"requested, {self.frames_in_use}/{self.frame_pool} "
                        f"in use and the job declined to queue")
                if len(self._queue) >= self.max_queue:
                    self.rejected += 1
                    raise AdmissionError(
                        f"admission queue is full ({self.max_queue} jobs "
                        f"waiting)")
                self._queue.append(t)
                self.queued_peak = max(self.queued_peak, len(self._queue))
                ok = self._cv.wait_for(lambda: t.granted, timeout)
                if not ok:
                    self._queue.remove(t)
                    self.rejected += 1
                    self._pump()
                    raise AdmissionError(
                        f"timed out after {timeout}s waiting for "
                        f"{frames} frames")
            else:
                self._grant(t)
            return Admission(self, t)

    def _grant(self, t: _Ticket) -> None:
        t.granted = True
        self.frames_in_use += t.frames
        self.bytes_in_use += t.mem_bytes
        self.active += 1
        self.admitted += 1
        self.peak_frames = max(self.peak_frames, self.frames_in_use)

    def _pump(self) -> None:
        """Grant queued tickets in FIFO order while they fit."""
        granted = False
        while self._queue and self._fits(self._queue[0]):
            self._grant(self._queue.popleft())
            granted = True
        if granted:
            self._cv.notify_all()

    def release(self, t: _Ticket) -> None:
        with self._cv:
            self.frames_in_use -= t.frames
            self.bytes_in_use -= t.mem_bytes
            self.active -= 1
            self._pump()

    def status(self) -> dict:
        with self._cv:
            return {"frame_pool": self.frame_pool,
                    "memory_bytes": self.memory_bytes,
                    "frames_in_use": self.frames_in_use,
                    "bytes_in_use": self.bytes_in_use,
                    "active": self.active, "waiting": len(self._queue),
                    "admitted": self.admitted, "rejected": self.rejected,
                    "peak_frames": self.peak_frames,
                    "queued_peak": self.queued_peak}


class Admission:
    """A granted reservation; release by exiting the ``with`` block."""

    def __init__(self, ctl: AdmissionController, ticket: _Ticket):
        self._ctl = ctl
        self._ticket = ticket
        self.frames = ticket.frames

    def __enter__(self) -> "Admission":
        return self

    def __exit__(self, *exc) -> None:
        self._ctl.release(self._ticket)
