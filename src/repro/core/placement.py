"""MAGE planner stage 1: placement (§6.2).

A page-aware slab allocator for the DSL: each MAGE-virtual page holds values
of a single size class, values never straddle pages, and among pages of the
right class with free slots we pick the one with the FEWEST free slots
(§6.2.2's effective-fragmentation heuristic: give whole pages a chance to
die).  Page-sized values get dedicated pages.
"""

from __future__ import annotations

import dataclasses
import heapq


@dataclasses.dataclass
class _SlabClass:
    size: int                      # slots per value
    capacity: int                  # values per page
    # page -> sorted free slot indices (list used as LIFO for locality)
    free_slots: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    # lazy min-heap of (free_count, page) candidates; stale entries skipped
    heap: list[tuple[int, int]] = dataclasses.field(default_factory=list)


class PageAllocator:
    """Slab allocator over the MAGE-virtual address space (slot-addressed)."""

    def __init__(self, page_shift: int):
        self.page_shift = page_shift
        self.page_slots = 1 << page_shift
        self._next_page = 0
        self._classes: dict[int, _SlabClass] = {}
        self._span_size: dict[int, int] = {}   # base addr -> n_slots
        self._page_class: dict[int, int] = {}  # page -> size class
        self.stats = {"allocs": 0, "frees": 0, "pages": 0,
                      "slab_wasted_slots": 0}

    # -- helpers -------------------------------------------------------------

    def _new_page(self) -> int:
        p = self._next_page
        self._next_page += 1
        self.stats["pages"] += 1
        return p

    def num_pages(self) -> int:
        return self._next_page

    @property
    def vspace_slots(self) -> int:
        return self._next_page << self.page_shift

    def size_of(self, addr: int) -> int:
        return self._span_size[addr]

    # -- alloc/free ------------------------------------------------------------

    def alloc(self, n_slots: int) -> int:
        if n_slots <= 0:
            raise ValueError(f"alloc of {n_slots} slots")
        if n_slots > self.page_slots:
            raise ValueError(
                f"value of {n_slots} slots exceeds the page size "
                f"({self.page_slots} slots); values must not straddle pages — "
                f"chunk the value at the DSL/library level")
        self.stats["allocs"] += 1
        if n_slots == self.page_slots:
            page = self._new_page()
            addr = page << self.page_shift
            self._span_size[addr] = n_slots
            return addr

        cls = self._classes.get(n_slots)
        if cls is None:
            cap = self.page_slots // n_slots
            cls = _SlabClass(size=n_slots, capacity=cap)
            self._classes[n_slots] = cls
            self.stats["slab_wasted_slots"] += 0

        # fewest-free-slots page with a free slot (lazy heap)
        page = None
        while cls.heap:
            cnt, cand = cls.heap[0]
            cur = cls.free_slots.get(cand)
            if cur is None or len(cur) != cnt or len(cur) == 0:
                heapq.heappop(cls.heap)  # stale
                continue
            page = cand
            break
        if page is None:
            page = self._new_page()
            self._page_class[page] = n_slots
            cls.free_slots[page] = list(range(cls.capacity - 1, -1, -1))
            self.stats["slab_wasted_slots"] += (
                self.page_slots - cls.capacity * n_slots)
        slots = cls.free_slots[page]
        idx = slots.pop()
        if slots:
            heapq.heappush(cls.heap, (len(slots), page))
        addr = (page << self.page_shift) + idx * n_slots
        self._span_size[addr] = n_slots
        return addr

    def free(self, addr: int) -> None:
        n = self._span_size.pop(addr, None)
        if n is None:
            raise KeyError(f"double free or bad free at {addr}")
        self.stats["frees"] += 1
        if n == self.page_slots:
            return  # dedicated page simply dies
        page = addr >> self.page_shift
        cls = self._classes[n]
        idx = (addr - (page << self.page_shift)) // n
        slots = cls.free_slots[page]
        slots.append(idx)
        heapq.heappush(cls.heap, (len(slots), page))

    def live_slots(self) -> int:
        return sum(self._span_size.values())
