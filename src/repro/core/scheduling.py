"""MAGE planner stage 3: scheduling (§6.4).

Transforms the 'physical' program (synchronous SWAP_IN/SWAP_OUT) into the
final memory program: every swap-in is split into an ISSUE_SWAP_IN hoisted up
to ``lookahead`` instructions earlier — into a slot of a ``prefetch_pages``-
sized prefetch buffer — and a FINISH_SWAP_IN at the use site that waits and
copies the page into its destination frame.  Evictions become COPY_OUT (frame
→ buffer) + ISSUE_SWAP_OUT, with FINISH_SWAP_OUT deferred until a buffer slot
must be reclaimed (oldest-first), exactly as in the paper.

Hazards handled:
  * read-after-write: an ISSUE_SWAP_IN for page p never overtakes an
    outstanding ISSUE_SWAP_OUT of p — we force a FINISH_SWAP_OUT first
    (or, with ``swap_bypass`` — beyond-paper — serve the read straight from
    the write's buffer slot with zero I/O);
  * buffer pressure: if no slot is free we first retire the oldest write;
    if none exists we cancel the youngest not-yet-needed prefetch; as a last
    resort the swap-in degrades to a synchronous issue+finish at the use site
    (the paper's FINISH-SWAP-IN fallback).

The replacement stage must have been run with T - B frames; the planner
pipeline (planner.py) owns that arithmetic.
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict, deque
from typing import Callable, Iterable

from .bytecode import (DEFAULT_CHUNK_INSTRS, Instr, Op, Program, ProgramFile,
                       writer_like)


@dataclasses.dataclass
class ScheduleStats:
    prefetched: int = 0          # swap-ins issued ahead of use
    sync_fallbacks: int = 0      # swap-ins issued at the use site
    canceled_prefetches: int = 0
    forced_write_finishes: int = 0
    bypass_hits: int = 0         # reads served from a pending write's slot
    swap_outs: int = 0
    lookahead: int = 0
    prefetch_pages: int = 0


@dataclasses.dataclass
class _PendingWrite:
    vpage: int
    slot: int
    order: int


def _schedule_core(src: Iterable[Instr], lookahead: int, B: int,
                   swap_bypass: bool, reserve: int,
                   emit: Callable[[Instr], None],
                   stats: ScheduleStats) -> None:
    """Streaming prefetch transducer: O(lookahead + B) state.

    Instead of pre-scanning the whole program for upcoming swap-ins (which
    would materialize it), the core keeps a sliding window of the next
    ``lookahead`` instructions — by construction the only ones an
    ISSUE_SWAP_IN may be hoisted across — and discovers reads as the window
    advances.  A read of page p must not be issued before p's latest
    preceding SWAP_OUT site (the page is not on storage yet before that
    point); ``last_out`` tracks those sites as they are scanned.
    """
    it = iter(src)
    window: deque[Instr] = deque()          # instructions [pos, scanned)
    reads: deque[tuple[int, int, tuple, int]] = deque()
    last_out: dict[int, int] = {}
    scanned = 0
    exhausted = False

    def scan_to(limit: int) -> None:
        # ensure every position <= limit has been scanned into the window
        nonlocal scanned, exhausted
        while not exhausted and scanned <= limit:
            nxt = next(it, None)
            if nxt is None:
                exhausted = True
                return
            if nxt.op == Op.SWAP_OUT:
                last_out[nxt.imm[0]] = scanned
            elif nxt.op == Op.SWAP_IN:
                p = nxt.imm[0]
                reads.append((scanned, p, nxt.outs[0],
                              last_out.get(p, -1) + 1))
            window.append(nxt)
            scanned += 1

    free_slots = list(range(B - 1, -1, -1))
    # issued reads keyed by their USE SITE position (unique — a page can
    # have several in-flight reads when clean evictions skip write-backs)
    read_slot: dict[int, int] = {}             # use_pos -> slot
    issue_order: list[int] = []                # use_pos, youngest last
    writes: OrderedDict[int, _PendingWrite] = OrderedDict()  # vpage -> pending
    bypass_ready: dict[int, int] = {}          # use_pos -> slot
    wcount = 0

    def finish_oldest_write() -> bool:
        if not writes:
            return False
        vp, pw = writes.popitem(last=False)
        emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
        free_slots.append(pw.slot)
        stats.forced_write_finishes += 1
        return True

    def cancel_youngest_read() -> bool:
        # cancel an issued-but-unused prefetch to reclaim its slot; its use
        # site then takes the sync-fallback path
        while issue_order:
            up = issue_order.pop()
            if up in read_slot:
                slot = read_slot.pop(up)
                # engine must still drain the in-flight DMA before reuse:
                emit(Instr(Op.FINISH_SWAP_OUT, imm=(slot,)))  # wait
                free_slots.append(slot)
                stats.canceled_prefetches += 1
                return True
        return False

    def get_slot(allow_cancel: bool) -> int | None:
        if free_slots:
            return free_slots.pop()
        if finish_oldest_write():
            return free_slots.pop()
        if allow_cancel and cancel_youngest_read():
            return free_slots.pop()
        return None

    def try_issue_read(pos_now: int) -> None:
        while reads and reads[0][0] - lookahead <= pos_now:
            if len(read_slot) >= B - reserve:
                break  # keep `reserve` slots available for evictions
            use_pos, vpage, frame_span, min_issue = reads[0]
            if use_pos <= pos_now:
                break  # its own use site handles it (sync fallback)
            if min_issue > pos_now:
                break  # page not on storage yet: wait for its swap-out site
            if vpage in writes:
                pw = writes[vpage]
                if swap_bypass:
                    # serve the future read straight from the write's slot
                    del writes[vpage]
                    bypass_ready[use_pos] = pw.slot
                    stats.bypass_hits += 1
                    reads.popleft()
                    continue
                emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
                free_slots.append(pw.slot)
                del writes[vpage]
                stats.forced_write_finishes += 1
            slot = get_slot(allow_cancel=False)
            if slot is None:
                break  # buffer full of useful work; retry next step
            emit(Instr(Op.ISSUE_SWAP_IN, imm=(vpage, slot)))
            read_slot[use_pos] = slot
            issue_order.append(use_pos)
            stats.prefetched += 1
            reads.popleft()

    pos = 0
    while True:
        scan_to(pos + lookahead)
        if not window:
            break
        ins = window.popleft()
        try_issue_read(pos)
        if ins.op == Op.SWAP_IN:
            vpage = ins.imm[0]
            if reads and reads[0][0] == pos:
                reads.popleft()  # this site was not prefetched
            if pos in bypass_ready:
                slot = bypass_ready.pop(pos)
                # data already sits in the buffer: plain copy, no wait
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 1)))
                free_slots.append(slot)
            elif pos in read_slot:
                slot = read_slot.pop(pos)
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 0)))
                free_slots.append(slot)
            else:
                # sync fallback at the use site
                if vpage in writes:
                    pw = writes.pop(vpage)
                    emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
                    free_slots.append(pw.slot)
                    stats.forced_write_finishes += 1
                slot = get_slot(allow_cancel=True)
                if slot is None:
                    raise RuntimeError("prefetch buffer unusable (B too small)")
                emit(Instr(Op.ISSUE_SWAP_IN, imm=(vpage, slot)))
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 0)))
                free_slots.append(slot)
                stats.sync_fallbacks += 1
        elif ins.op == Op.SWAP_OUT:
            vpage = ins.imm[0]
            # paper §6.4: reclaim only the oldest *write* slot; never steal a
            # prefetched read for an eviction — degrade to sync swap-out.
            slot = get_slot(allow_cancel=False)
            if slot is None:
                emit(ins)  # degraded: synchronous swap-out
                stats.swap_outs += 1
                pos += 1
                continue
            emit(Instr(Op.COPY_OUT, ins=ins.ins, imm=(slot,)))
            emit(Instr(Op.ISSUE_SWAP_OUT, imm=(vpage, slot)))
            writes[vpage] = _PendingWrite(vpage, slot, wcount)
            wcount += 1
            stats.swap_outs += 1
        else:
            emit(ins)
        pos += 1

    for vp, pw in writes.items():
        emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))


def _reserve_for(B: int, write_reserve: int | None) -> int:
    # Reserve a slice of the buffer for eviction traffic: if prefetched
    # reads may occupy every slot, each eviction degrades to a synchronous
    # (blocking) swap-out — measured to dominate MAGE's stall time on
    # sort/merge (see EXPERIMENTS.md §Perf).
    return (max(B // 4, 1) if write_reserve is None else write_reserve) \
        if B > 1 else 0


def plan_schedule(prog: Program, lookahead: int, prefetch_pages: int,
                  swap_bypass: bool = False,
                  write_reserve: int | None = None
                  ) -> tuple[Program, ScheduleStats]:
    assert prog.phase == "physical", prog.phase
    stats = ScheduleStats(lookahead=lookahead, prefetch_pages=prefetch_pages)
    B = prefetch_pages
    if B <= 0:  # degenerate: scheduling disabled, keep sync directives
        out_prog = dataclasses.replace(prog, phase="memory", prefetch_slots=0)
        return out_prog, stats
    out: list[Instr] = []
    _schedule_core(prog.instrs, lookahead, B, swap_bypass,
                   _reserve_for(B, write_reserve), out.append, stats)
    res = dataclasses.replace(prog, instrs=out, phase="memory",
                              prefetch_slots=B)
    return res, stats


def plan_schedule_file(pf: ProgramFile, out_path: str | os.PathLike,
                       lookahead: int, prefetch_pages: int,
                       swap_bypass: bool = False,
                       write_reserve: int | None = None,
                       chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                       meta: dict | None = None,
                       ) -> tuple[ProgramFile, ScheduleStats]:
    """Stage 3, out-of-core: stream a 'physical' bytecode file into the
    final memory-program file, holding O(lookahead + B) state."""
    assert pf.phase == "physical", pf.phase
    stats = ScheduleStats(lookahead=lookahead, prefetch_pages=prefetch_pages)
    B = prefetch_pages
    with writer_like(pf, out_path, phase="memory", prefetch_slots=max(B, 0),
                     meta=meta, chunk_instrs=chunk_instrs) as w:
        if B <= 0:
            # records are unchanged; copy raw chunks instead of paying the
            # per-instruction decode/encode cost just to rewrite the header
            for _, arr in pf.iter_chunks(chunk_instrs):
                w.append_records(arr)
        else:
            _schedule_core(pf.iter_instrs(chunk_instrs), lookahead, B,
                           swap_bypass, _reserve_for(B, write_reserve),
                           w.append, stats)
    return ProgramFile(os.fspath(out_path)), stats
