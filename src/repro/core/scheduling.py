"""MAGE planner stage 3: scheduling (§6.4).

Transforms the 'physical' program (synchronous SWAP_IN/SWAP_OUT) into the
final memory program: every swap-in is split into an ISSUE_SWAP_IN hoisted up
to ``lookahead`` instructions earlier — into a slot of a ``prefetch_pages``-
sized prefetch buffer — and a FINISH_SWAP_IN at the use site that waits and
copies the page into its destination frame.  Evictions become COPY_OUT (frame
→ buffer) + ISSUE_SWAP_OUT, with FINISH_SWAP_OUT deferred until a buffer slot
must be reclaimed (oldest-first), exactly as in the paper.

Hazards handled:
  * read-after-write: an ISSUE_SWAP_IN for page p never overtakes an
    outstanding ISSUE_SWAP_OUT of p — we force a FINISH_SWAP_OUT first
    (or, with ``swap_bypass`` — beyond-paper — serve the read straight from
    the write's buffer slot with zero I/O);
  * buffer pressure: if no slot is free we first retire the oldest write;
    if none exists we cancel the youngest not-yet-needed prefetch; as a last
    resort the swap-in degrades to a synchronous issue+finish at the use site
    (the paper's FINISH-SWAP-IN fallback).

The replacement stage must have been run with T - B frames; the planner
pipeline (planner.py) owns that arithmetic.
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict, deque
from typing import Callable, Iterable, Iterator

import numpy as np

from .bytecode import (DEFAULT_CHUNK_INSTRS, RECORD_WORDS, _IMM_OFF, _IN_OFF,
                       _OUT_OFF, Instr, Op, Program, ProgramFile,
                       decode_chunk, encode_chunk, pack_row, unpack_heads,
                       writer_like)


@dataclasses.dataclass
class ScheduleStats:
    prefetched: int = 0          # swap-ins issued ahead of use
    sync_fallbacks: int = 0      # swap-ins issued at the use site
    canceled_prefetches: int = 0
    forced_write_finishes: int = 0
    bypass_hits: int = 0         # reads served from a pending write's slot
    swap_outs: int = 0
    lookahead: int = 0
    prefetch_pages: int = 0


@dataclasses.dataclass
class _PendingWrite:
    vpage: int
    slot: int
    order: int


def _schedule_core(src: Iterable[Instr], lookahead: int, B: int,
                   swap_bypass: bool, reserve: int,
                   emit: Callable[[Instr], None],
                   stats: ScheduleStats) -> None:
    """Streaming prefetch transducer: O(lookahead + B) state.

    Instead of pre-scanning the whole program for upcoming swap-ins (which
    would materialize it), the core keeps a sliding window of the next
    ``lookahead`` instructions — by construction the only ones an
    ISSUE_SWAP_IN may be hoisted across — and discovers reads as the window
    advances.  A read of page p must not be issued before p's latest
    preceding SWAP_OUT site (the page is not on storage yet before that
    point); ``last_out`` tracks those sites as they are scanned.
    """
    it = iter(src)
    window: deque[Instr] = deque()          # instructions [pos, scanned)
    reads: deque[tuple[int, int, tuple, int]] = deque()
    last_out: dict[int, int] = {}
    scanned = 0
    exhausted = False

    def scan_to(limit: int) -> None:
        # ensure every position <= limit has been scanned into the window
        nonlocal scanned, exhausted
        while not exhausted and scanned <= limit:
            nxt = next(it, None)
            if nxt is None:
                exhausted = True
                return
            if nxt.op == Op.SWAP_OUT:
                last_out[nxt.imm[0]] = scanned
            elif nxt.op == Op.SWAP_IN:
                p = nxt.imm[0]
                reads.append((scanned, p, nxt.outs[0],
                              last_out.get(p, -1) + 1))
            window.append(nxt)
            scanned += 1

    free_slots = list(range(B - 1, -1, -1))
    # issued reads keyed by their USE SITE position (unique — a page can
    # have several in-flight reads when clean evictions skip write-backs)
    read_slot: dict[int, int] = {}             # use_pos -> slot
    issue_order: list[int] = []                # use_pos, youngest last
    writes: OrderedDict[int, _PendingWrite] = OrderedDict()  # vpage -> pending
    bypass_ready: dict[int, int] = {}          # use_pos -> slot
    wcount = 0

    def finish_oldest_write() -> bool:
        if not writes:
            return False
        vp, pw = writes.popitem(last=False)
        emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
        free_slots.append(pw.slot)
        stats.forced_write_finishes += 1
        return True

    def cancel_youngest_read() -> bool:
        # cancel an issued-but-unused prefetch to reclaim its slot; its use
        # site then takes the sync-fallback path
        while issue_order:
            up = issue_order.pop()
            if up in read_slot:
                slot = read_slot.pop(up)
                # engine must still drain the in-flight DMA before reuse:
                emit(Instr(Op.FINISH_SWAP_OUT, imm=(slot,)))  # wait
                free_slots.append(slot)
                stats.canceled_prefetches += 1
                return True
        return False

    def get_slot(allow_cancel: bool) -> int | None:
        if free_slots:
            return free_slots.pop()
        if finish_oldest_write():
            return free_slots.pop()
        if allow_cancel and cancel_youngest_read():
            return free_slots.pop()
        return None

    def try_issue_read(pos_now: int) -> None:
        while reads and reads[0][0] - lookahead <= pos_now:
            if len(read_slot) >= B - reserve:
                break  # keep `reserve` slots available for evictions
            use_pos, vpage, frame_span, min_issue = reads[0]
            if use_pos <= pos_now:
                break  # its own use site handles it (sync fallback)
            if min_issue > pos_now:
                break  # page not on storage yet: wait for its swap-out site
            if vpage in writes:
                pw = writes[vpage]
                if swap_bypass:
                    # serve the future read straight from the write's slot
                    del writes[vpage]
                    bypass_ready[use_pos] = pw.slot
                    stats.bypass_hits += 1
                    reads.popleft()
                    continue
                emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
                free_slots.append(pw.slot)
                del writes[vpage]
                stats.forced_write_finishes += 1
            slot = get_slot(allow_cancel=False)
            if slot is None:
                break  # buffer full of useful work; retry next step
            emit(Instr(Op.ISSUE_SWAP_IN, imm=(vpage, slot)))
            read_slot[use_pos] = slot
            issue_order.append(use_pos)
            stats.prefetched += 1
            reads.popleft()

    pos = 0
    while True:
        scan_to(pos + lookahead)
        if not window:
            break
        ins = window.popleft()
        try_issue_read(pos)
        if ins.op == Op.SWAP_IN:
            vpage = ins.imm[0]
            if reads and reads[0][0] == pos:
                reads.popleft()  # this site was not prefetched
            if pos in bypass_ready:
                slot = bypass_ready.pop(pos)
                # data already sits in the buffer: plain copy, no wait
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 1)))
                free_slots.append(slot)
            elif pos in read_slot:
                slot = read_slot.pop(pos)
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 0)))
                free_slots.append(slot)
            else:
                # sync fallback at the use site
                if vpage in writes:
                    pw = writes.pop(vpage)
                    emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
                    free_slots.append(pw.slot)
                    stats.forced_write_finishes += 1
                slot = get_slot(allow_cancel=True)
                if slot is None:
                    raise RuntimeError("prefetch buffer unusable (B too small)")
                emit(Instr(Op.ISSUE_SWAP_IN, imm=(vpage, slot)))
                emit(Instr(Op.FINISH_SWAP_IN, outs=ins.outs,
                           imm=(vpage, slot, 0)))
                free_slots.append(slot)
                stats.sync_fallbacks += 1
        elif ins.op == Op.SWAP_OUT:
            vpage = ins.imm[0]
            # paper §6.4: reclaim only the oldest *write* slot; never steal a
            # prefetched read for an eviction — degrade to sync swap-out.
            slot = get_slot(allow_cancel=False)
            if slot is None:
                emit(ins)  # degraded: synchronous swap-out
                stats.swap_outs += 1
                pos += 1
                continue
            emit(Instr(Op.COPY_OUT, ins=ins.ins, imm=(slot,)))
            emit(Instr(Op.ISSUE_SWAP_OUT, imm=(vpage, slot)))
            writes[vpage] = _PendingWrite(vpage, slot, wcount)
            wcount += 1
            stats.swap_outs += 1
        else:
            emit(ins)
        pos += 1

    for vp, pw in writes.items():
        emit(Instr(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))


# ---------------------------------------------------------------------------
# The record-array core (core="array", the default).
#
# Scheduling is event-sparse: only SWAP_IN/SWAP_OUT rows and prefetch-issue
# points mutate state; every other instruction passes through verbatim.  The
# array core therefore scans chunks once to extract the swap rows (a
# vectorized op-mask), computes the next event position (head swap row, or
# the head read's earliest legal issue point max(use - lookahead,
# min_issue)), block-copies the records in between untouched, and runs the
# scalar event logic only at event positions.  A forced retry one position
# after any state change reproduces the scalar core's emission order
# exactly: the scalar loop calls try_issue_read at *every* position, but
# between state changes those calls are provably no-ops.  State is
# O(lookahead + B + chunk); outputs are instruction-identical to
# ``_schedule_core`` (tested bitwise).
# ---------------------------------------------------------------------------

_OP_SWAP_IN = int(Op.SWAP_IN)
_OP_SWAP_OUT = int(Op.SWAP_OUT)


class _ArraySchedule:
    """Event-driven prefetch transducer over record chunks."""

    def __init__(self, lookahead: int, B: int, swap_bypass: bool,
                 reserve: int, sink: Callable[[np.ndarray], None],
                 stats: ScheduleStats,
                 flush_rows: int = DEFAULT_CHUNK_INSTRS):
        self.lookahead = lookahead
        self.B = B
        self.swap_bypass = swap_bypass
        self.reserve = reserve
        self.sink = sink
        self.stats = stats
        self.flush_rows = flush_rows

        self.buf: deque[tuple[int, np.ndarray]] = deque()
        self.scanned = 0
        self.exhausted = False
        self.upcoming: deque[int] = deque()       # positions of swap rows
        self.reads: deque[tuple[int, int, tuple, int]] = deque()
        self.last_out: dict[int, int] = {}

        self.free_slots = list(range(B - 1, -1, -1))
        self.read_slot: dict[int, int] = {}
        self.issue_order: list[int] = []
        self.writes: OrderedDict[int, _PendingWrite] = OrderedDict()
        self.bypass_ready: dict[int, int] = {}
        self.wcount = 0

        # flat output buffer: single rows and verbatim ranges both land
        # here, so dense directive interleaves don't churn tiny arrays
        self.obuf = np.empty((flush_rows + 8, RECORD_WORDS), dtype=np.int64)
        self.on = 0
        self.changed = False     # any state mutation since the last event
        self._cur: tuple[int, np.ndarray] | None = None   # _row_at cache

    # -- output assembly ------------------------------------------------------

    def _emit_row(self, row: list[int]) -> None:
        self.obuf[self.on] = row
        self.on += 1
        self.changed = True
        if self.on >= self.flush_rows:
            self._flush(force=True)

    def _emit_arr(self, arr: np.ndarray) -> None:
        m = arr.shape[0]
        lo = 0
        while m - lo > 0:
            take = min(m - lo, self.obuf.shape[0] - self.on)
            self.obuf[self.on:self.on + take] = arr[lo:lo + take]
            self.on += take
            lo += take
            if self.on >= self.flush_rows:
                self._flush(force=True)

    def _flush(self, force: bool = False) -> None:
        if self.on and (force or self.on >= self.flush_rows):
            self.sink(self.obuf[:self.on].copy())
            self.on = 0

    # -- scanning -------------------------------------------------------------

    def _pull(self, chunks: Iterator[tuple[int, np.ndarray]]) -> None:
        nxt = next(chunks, None)
        if nxt is None:
            self.exhausted = True
            return
        s, rec = nxt
        self.buf.append((s, rec))
        ops = unpack_heads(rec[:, 0])[0]
        for r in np.nonzero((ops == _OP_SWAP_IN)
                            | (ops == _OP_SWAP_OUT))[0].tolist():
            p = s + r
            row = rec[r]
            vp = int(row[_IMM_OFF])
            if int(row[0]) & 0xFFFF == _OP_SWAP_OUT:
                self.last_out[vp] = p
            else:
                self.reads.append((p, vp,
                                   (int(row[_OUT_OFF]),
                                    int(row[_OUT_OFF + 1])),
                                   self.last_out.get(vp, -1) + 1))
            self.upcoming.append(p)
        self.scanned = s + rec.shape[0]

    def _trim(self, pos: int) -> None:
        buf = self.buf
        while buf and buf[0][0] + buf[0][1].shape[0] <= pos:
            buf.popleft()

    def _copy(self, a: int, b: int) -> None:
        """Pass rows [a, b) through verbatim."""
        for s, rec in self.buf:
            if s >= b:
                break
            lo, hi = max(a - s, 0), min(b - s, rec.shape[0])
            if lo < hi:
                self._emit_arr(rec[lo:hi])
        self._trim(b)
        self._flush()

    def _row_at(self, pos: int) -> np.ndarray:
        cur = self._cur
        if cur is not None and cur[0] <= pos < cur[0] + cur[1].shape[0]:
            return cur[1][pos - cur[0]]
        for s, rec in self.buf:
            if s <= pos < s + rec.shape[0]:
                self._cur = (s, rec)
                return rec[pos - s]
        raise AssertionError(f"position {pos} not buffered")

    # -- slot management (scalar logic, row emission) -------------------------

    def _finish_oldest_write(self) -> bool:
        if not self.writes:
            return False
        _vp, pw = self.writes.popitem(last=False)
        self._emit_row(pack_row(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
        self.free_slots.append(pw.slot)
        self.stats.forced_write_finishes += 1
        return True

    def _cancel_youngest_read(self) -> bool:
        while self.issue_order:
            up = self.issue_order.pop()
            if up in self.read_slot:
                slot = self.read_slot.pop(up)
                # engine must still drain the in-flight DMA before reuse:
                self._emit_row(pack_row(Op.FINISH_SWAP_OUT, imm=(slot,)))
                self.free_slots.append(slot)
                self.stats.canceled_prefetches += 1
                return True
        return False

    def _get_slot(self, allow_cancel: bool) -> int | None:
        if self.free_slots:
            return self.free_slots.pop()
        if self._finish_oldest_write():
            return self.free_slots.pop()
        if allow_cancel and self._cancel_youngest_read():
            return self.free_slots.pop()
        return None

    def _try_issue(self, pos_now: int) -> None:
        reads = self.reads
        while reads and reads[0][0] - self.lookahead <= pos_now:
            if len(self.read_slot) >= self.B - self.reserve:
                break
            use_pos, vpage, span, min_issue = reads[0]
            if use_pos <= pos_now:
                break
            if min_issue > pos_now:
                break
            if vpage in self.writes:
                pw = self.writes[vpage]
                if self.swap_bypass:
                    del self.writes[vpage]
                    self.bypass_ready[use_pos] = pw.slot
                    self.stats.bypass_hits += 1
                    reads.popleft()
                    self.changed = True   # the only mutation with no emit
                    continue
                self._emit_row(pack_row(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
                self.free_slots.append(pw.slot)
                del self.writes[vpage]
                self.stats.forced_write_finishes += 1
            slot = self._get_slot(allow_cancel=False)
            if slot is None:
                break
            self._emit_row(pack_row(Op.ISSUE_SWAP_IN, imm=(vpage, slot)))
            self.read_slot[use_pos] = slot
            self.issue_order.append(use_pos)
            self.stats.prefetched += 1
            reads.popleft()

    # -- event handling -------------------------------------------------------

    def _handle(self, pos: int) -> bool:
        """Process position ``pos`` exactly like one scalar loop step;
        returns whether scheduler state changed (→ retry at pos + 1)."""
        self.changed = False
        row = self._row_at(pos)
        op = int(row[0]) & 0xFFFF
        self._try_issue(pos)
        if op == _OP_SWAP_IN:
            vpage = int(row[_IMM_OFF])
            span = (int(row[_OUT_OFF]), int(row[_OUT_OFF + 1]))
            if self.reads and self.reads[0][0] == pos:
                self.reads.popleft()   # this site was not prefetched
            if pos in self.bypass_ready:
                slot = self.bypass_ready.pop(pos)
                # data already sits in the buffer: plain copy, no wait
                self._emit_row(pack_row(Op.FINISH_SWAP_IN, outs=(span,),
                                        imm=(vpage, slot, 1)))
                self.free_slots.append(slot)
            elif pos in self.read_slot:
                slot = self.read_slot.pop(pos)
                self._emit_row(pack_row(Op.FINISH_SWAP_IN, outs=(span,),
                                        imm=(vpage, slot, 0)))
                self.free_slots.append(slot)
            else:
                # sync fallback at the use site
                if vpage in self.writes:
                    pw = self.writes.pop(vpage)
                    self._emit_row(pack_row(Op.FINISH_SWAP_OUT,
                                            imm=(pw.slot,)))
                    self.free_slots.append(pw.slot)
                    self.stats.forced_write_finishes += 1
                slot = self._get_slot(allow_cancel=True)
                if slot is None:
                    raise RuntimeError("prefetch buffer unusable "
                                       "(B too small)")
                self._emit_row(pack_row(Op.ISSUE_SWAP_IN,
                                        imm=(vpage, slot)))
                self._emit_row(pack_row(Op.FINISH_SWAP_IN, outs=(span,),
                                        imm=(vpage, slot, 0)))
                self.free_slots.append(slot)
                self.stats.sync_fallbacks += 1
        elif op == _OP_SWAP_OUT:
            vpage = int(row[_IMM_OFF])
            span = (int(row[_IN_OFF]), int(row[_IN_OFF + 1]))
            # paper §6.4: reclaim only the oldest *write* slot; never steal
            # a prefetched read for an eviction — degrade to sync swap-out.
            slot = self._get_slot(allow_cancel=False)
            if slot is None:
                self._emit_arr(row.reshape(1, RECORD_WORDS))  # degraded
                self.stats.swap_outs += 1
            else:
                self._emit_row(pack_row(Op.COPY_OUT, ins=(span,),
                                        imm=(slot,)))
                self._emit_row(pack_row(Op.ISSUE_SWAP_OUT,
                                        imm=(vpage, slot)))
                self.writes[vpage] = _PendingWrite(vpage, slot, self.wcount)
                self.wcount += 1
                self.stats.swap_outs += 1
        else:
            self._emit_arr(row.reshape(1, RECORD_WORDS))
        if self.upcoming and self.upcoming[0] == pos:
            self.upcoming.popleft()
        return self.changed

    # -- the drive loop -------------------------------------------------------

    def run(self, chunks: Iterator[tuple[int, np.ndarray]],
            total: int) -> None:
        pos = 0
        retry_at: int | None = 0   # attempt issuance at program start
        while pos < total:
            while not self.exhausted and self.scanned <= pos + self.lookahead:
                self._pull(chunks)
            e = total
            if self.upcoming:
                e = min(e, self.upcoming[0])
            if retry_at is not None and retry_at >= pos:
                e = min(e, retry_at)
            if self.reads:
                r0 = self.reads[0]
                # the head read's earliest legal issue point; if it is
                # already behind us the read is state-blocked and a retry
                # event (or the next swap site) will pick it up
                cand = max(r0[0] - self.lookahead, r0[3])
                if cand >= pos:
                    e = min(e, cand)
            if not self.exhausted:
                # never step past scan coverage; copy up to it and rescan
                cover = self.scanned - self.lookahead - 1
                if e > cover:
                    if cover + 1 > pos:
                        self._copy(pos, cover + 1)
                        pos = cover + 1
                    continue
            if e > pos:
                self._copy(pos, e)
                pos = e
                if pos >= total:
                    break
            changed = self._handle(pos)
            self._trim(pos + 1)
            self._flush()
            retry_at = pos + 1 if changed else None
            pos += 1
        for _vp, pw in self.writes.items():
            self._emit_row(pack_row(Op.FINISH_SWAP_OUT, imm=(pw.slot,)))
        self._flush(force=True)


def _schedule_core_array(chunks: Iterator[tuple[int, np.ndarray]],
                         total: int, lookahead: int, B: int,
                         swap_bypass: bool, reserve: int,
                         sink: Callable[[np.ndarray], None],
                         stats: ScheduleStats) -> None:
    _ArraySchedule(lookahead, B, swap_bypass, reserve, sink,
                   stats).run(chunks, total)


def schedule_records(chunks: list[np.ndarray], lookahead: int,
                     prefetch_pages: int,
                     sink: Callable[[np.ndarray], None],
                     swap_bypass: bool = False,
                     write_reserve: int | None = None) -> ScheduleStats:
    """Stage 3 over in-memory record chunks (records in → records out via
    ``sink``): the fused ``plan()`` pipeline's scheduling entry.  Owns the
    B<=0 pass-through, the write-reserve default and the stats
    construction, so the fused and staged paths cannot diverge."""
    B = prefetch_pages
    stats = ScheduleStats(lookahead=lookahead, prefetch_pages=B)
    if B <= 0:
        for c in chunks:
            sink(c)
        return stats

    def _gen():
        s = 0
        for c in chunks:
            yield s, c
            s += c.shape[0]

    _schedule_core_array(_gen(), sum(c.shape[0] for c in chunks),
                         lookahead, B, swap_bypass,
                         _reserve_for(B, write_reserve), sink, stats)
    return stats


def _reserve_for(B: int, write_reserve: int | None) -> int:
    # Reserve a slice of the buffer for eviction traffic: if prefetched
    # reads may occupy every slot, each eviction degrades to a synchronous
    # (blocking) swap-out — measured to dominate MAGE's stall time on
    # sort/merge (see EXPERIMENTS.md §Perf).
    return (max(B // 4, 1) if write_reserve is None else write_reserve) \
        if B > 1 else 0


def plan_schedule(prog: Program, lookahead: int, prefetch_pages: int,
                  swap_bypass: bool = False,
                  write_reserve: int | None = None,
                  core: str = "scalar",
                  chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                  ) -> tuple[Program, ScheduleStats]:
    """Stage 3 over an in-memory 'physical' Program.

    Defaults to the scalar core: for Instr-list inputs the
    encode/decode round-trip costs more than the event loop saves, so
    the array core only pays off where records already exist —
    ``plan()``'s fused pipeline and :func:`plan_schedule_file` (both
    default to it).  ``core="array"`` here is for equivalence testing;
    outputs are identical either way."""
    from .replacement import _check_core
    _check_core(core)
    assert prog.phase == "physical", prog.phase
    stats = ScheduleStats(lookahead=lookahead, prefetch_pages=prefetch_pages)
    B = prefetch_pages
    if B <= 0:  # degenerate: scheduling disabled, keep sync directives
        out_prog = dataclasses.replace(prog, phase="memory", prefetch_slots=0)
        return out_prog, stats
    out: list[Instr] = []
    rec = None
    if core == "array":
        try:
            rec = encode_chunk(prog.instrs)
        except (TypeError, ValueError):
            rec = None                # unencodable program: scalar reference
    if rec is not None:
        chunks = ((s, rec[s:s + chunk_instrs])
                  for s in range(0, rec.shape[0], chunk_instrs))
        _schedule_core_array(chunks, rec.shape[0], lookahead, B, swap_bypass,
                             _reserve_for(B, write_reserve),
                             lambda arr: out.extend(decode_chunk(arr)), stats)
    else:
        _schedule_core(prog.instrs, lookahead, B, swap_bypass,
                       _reserve_for(B, write_reserve), out.append, stats)
    res = dataclasses.replace(prog, instrs=out, phase="memory",
                              prefetch_slots=B)
    return res, stats


def plan_schedule_file(pf: ProgramFile, out_path: str | os.PathLike,
                       lookahead: int, prefetch_pages: int,
                       swap_bypass: bool = False,
                       write_reserve: int | None = None,
                       chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                       meta: dict | None = None,
                       core: str = "array",
                       ) -> tuple[ProgramFile, ScheduleStats]:
    """Stage 3, out-of-core: stream a 'physical' bytecode file into the
    final memory-program file, holding O(lookahead + B + chunk) state.
    With the default ``core="array"`` the no-hazard fast path block-copies
    record chunks without ever decoding an instruction."""
    from .replacement import _check_core
    _check_core(core)
    assert pf.phase == "physical", pf.phase
    stats = ScheduleStats(lookahead=lookahead, prefetch_pages=prefetch_pages)
    B = prefetch_pages
    with writer_like(pf, out_path, phase="memory", prefetch_slots=max(B, 0),
                     meta=meta, chunk_instrs=chunk_instrs) as w:
        if B <= 0:
            # records are unchanged; copy raw chunks instead of paying the
            # per-instruction decode/encode cost just to rewrite the header
            for _, arr in pf.iter_chunks(chunk_instrs):
                w.append_records(arr)
        elif core == "array":
            _schedule_core_array(pf.iter_chunks(chunk_instrs),
                                 pf.num_records, lookahead, B, swap_bypass,
                                 _reserve_for(B, write_reserve),
                                 w.append_records, stats)
        else:
            _schedule_core(pf.iter_instrs(chunk_instrs), lookahead, B,
                           swap_bypass, _reserve_for(B, write_reserve),
                           w.append, stats)
    return ProgramFile(os.fspath(out_path)), stats
