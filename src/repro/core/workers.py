"""Parallel/distributed MAGE execution (§5.1–§5.2, §6 "per-worker planning").

Workers follow the paper's distributed-memory model: each worker is one
thread of computation with its own MAGE-physical address space; DSL programs
are parameterized by (worker_id, num_workers) and express data movement with
explicit network directives.  Planning is run once per worker, independently
— each worker's accesses touch only its own region, so the memory programs
are generated in isolation, in parallel threads, or in parallel *processes*
(programs and plan artifacts are picklable; processes dodge the GIL for the
Python-heavy planner cores).

``run_engines`` is the single worker-orchestration core: every execution
path in the repo (plaintext oracle runs, real two-party GC, CKKS, the
``repro.api.Session`` facade) builds a list of :class:`EngineJob` and hands
it here, so thread spawning and error collection live in exactly one place.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import itertools
import os
import threading
from typing import Any, Callable, Sequence

import numpy as np

from .bytecode import Op, Program, ProgramFile
from .dsl import Value, trace
from .engine import Engine, EngineStats, ProtocolDriver
from .planner import PlanConfig, PlanReport, plan, plan_streaming
from .storage import StorageBackend
from .transport import InprocTransport, PartyView


@dataclasses.dataclass
class ProgramOptions:
    """Mirrors the paper's ProgramOptions: worker identity + problem params."""
    worker: int = 0
    num_workers: int = 1
    problem_size: int = 0
    extra: dict = dataclasses.field(default_factory=dict)


def send_value(v: Value, dst: int, tag: int | None = None) -> int:
    b = v.builder
    tag = b.fresh_tag() if tag is None else tag
    b.emit(Op.NET_SEND, ins=(v.span,), imm=(dst, tag))
    return tag


def recv_into(v: Value, src: int, tag: int) -> None:
    v.builder.emit(Op.NET_RECV, outs=(v.span,), imm=(src, tag))


def trace_workers(fn: Callable[[ProgramOptions], None], *, protocol: str,
                  page_shift: int, num_workers: int,
                  problem_size: int = 0, extra: dict | None = None,
                  meta: dict | None = None) -> list[Program]:
    progs = []
    for w in range(num_workers):
        opts = ProgramOptions(worker=w, num_workers=num_workers,
                              problem_size=problem_size,
                              extra=dict(extra or {}))
        progs.append(trace(fn, protocol=protocol, page_shift=page_shift,
                           worker=w, num_workers=num_workers,
                           args=(opts,),
                           meta={"problem_size": problem_size,
                                 **(meta or {})}))
    return progs


# ---------------------------------------------------------------------------
# per-worker planning
# ---------------------------------------------------------------------------

PARALLEL_MODES = ("serial", "thread", "process")


def _plan_one(w: int, prog: Program | ProgramFile, cfg: PlanConfig,
              streaming: bool, workdir: str | None, track_memory: bool,
              chunk_instrs: int, annotation: str | None,
              ) -> tuple[Program | ProgramFile, PlanReport]:
    """Module-level so ``parallel="process"`` can pickle it."""
    if streaming:
        wd = os.path.join(workdir, f"worker{w}") if workdir else None
        return plan_streaming(prog, cfg, workdir=wd,
                              track_memory=track_memory,
                              chunk_instrs=chunk_instrs,
                              annotations=annotation)
    return plan(prog, cfg, track_memory=track_memory)


def plan_workers(progs: Sequence[Program], cfg: PlanConfig | Sequence[PlanConfig],
                 parallel: bool | str = False, streaming: bool = False,
                 workdir: str | None = None, track_memory: bool = False,
                 chunk_instrs: int = 8192,
                 annotations: Sequence[str] | None = None,
                 ) -> tuple[list[Program | ProgramFile], list[PlanReport]]:
    """Plan each worker's program independently (§6.1).

    Worker programs only touch their own address space, so planning them is
    embarrassingly parallel.  ``parallel`` selects the executor: ``False`` /
    ``"serial"`` plans in-line, ``True`` / ``"thread"`` runs one planner
    thread per worker, and ``"process"`` uses a ``ProcessPoolExecutor`` to
    dodge the GIL for the Python-heavy planner cores (programs, configs and
    ProgramFiles are all picklable).  ``streaming=True`` uses the out-of-core
    file pipeline (one subdirectory per worker) and returns ProgramFiles the
    engine executes directly from disk.  ``cfg`` may be a single PlanConfig
    or one per worker (budgets can differ per working set).

    ``track_memory=True`` with ``parallel="thread"`` plans serially instead:
    tracemalloc is process-global, so concurrent planner threads would reset
    each other's measurement (``"process"`` keeps both parallelism and
    per-worker peaks).

    ``annotations`` — optional per-worker pre-computed next-use sidecar
    paths (streaming only), e.g. from the artifact cache; the annotation
    pass is skipped for workers that have one.
    """
    cfgs = list(cfg) if isinstance(cfg, (list, tuple)) else [cfg] * len(progs)
    if len(cfgs) != len(progs):
        raise ValueError(f"{len(cfgs)} configs for {len(progs)} workers")
    anns = list(annotations) if annotations is not None \
        else [None] * len(progs)
    if len(anns) != len(progs):
        raise ValueError(f"{len(anns)} annotations for {len(progs)} workers")
    mode = {False: "serial", True: "thread"}.get(parallel, parallel)
    if mode not in PARALLEL_MODES:
        raise ValueError(f"parallel must be one of {PARALLEL_MODES}, "
                         f"got {parallel!r}")
    if track_memory and mode == "thread":
        # tracemalloc is process-global: concurrent start/stop from planner
        # threads would reset each other's measurement. Processes are fine.
        mode = "serial"
    args = (range(len(progs)), progs, cfgs, itertools.repeat(streaming),
            itertools.repeat(workdir), itertools.repeat(track_memory),
            itertools.repeat(chunk_instrs), anns)
    if mode == "serial" or len(progs) <= 1:
        results = list(map(_plan_one, *args))
    elif mode == "thread":
        with cf.ThreadPoolExecutor(max_workers=len(progs),
                                   thread_name_prefix="mage-plan") as ex:
            results = list(ex.map(_plan_one, *args))
    else:
        with cf.ProcessPoolExecutor(max_workers=len(progs)) as ex:
            results = list(ex.map(_plan_one, *args))
    return [r[0] for r in results], [r[1] for r in results]


# ---------------------------------------------------------------------------
# the worker-orchestration core
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineJob:
    """One engine to run: a (program, driver) pair plus its fabric/storage.

    ``net`` is the engine's party-scoped window onto the transport fabric
    (NET_* directives); ``tag`` is only used to label failures (e.g.
    ``"garbler/worker1"``).
    """
    program: Program | ProgramFile
    driver: ProtocolDriver
    net: PartyView | None = None
    storage: StorageBackend | None = None
    use_memmap: bool = False
    on_output: Callable | None = None
    tag: Any = None
    #: optional exec/ batch schedule (see repro.exec.batching); engines run
    #: the batched fast path when both this and a batch-capable driver are
    #: present, the scalar reference loop otherwise
    batch_schedule: Any = None
    #: optional exec/ overlap schedule (see repro.exec.overlap); takes
    #: precedence over batch_schedule — the overlap loop batches local
    #: groups itself when the driver is batch-capable
    overlap_schedule: Any = None


def run_engines(jobs: Sequence[EngineJob],
                io_threads: int = 2) -> list[EngineStats]:
    """Run one Engine per job, concurrently; THE thread-spawn/error-collect
    loop (every other runner is a wrapper over this)."""
    results: list[EngineStats | None] = [None] * len(jobs)
    errors: list[tuple[Any, Exception]] = []

    def _run(k: int, job: EngineJob) -> None:
        try:
            eng = Engine(job.program, job.driver, storage=job.storage,
                         net=job.net, io_threads=io_threads,
                         use_memmap=job.use_memmap,
                         batch_schedule=job.batch_schedule,
                         overlap_schedule=job.overlap_schedule)
            results[k] = eng.run(on_output=job.on_output)
        except Exception as e:  # surfaced below
            errors.append((job.tag if job.tag is not None else k, e))

    if len(jobs) == 1:
        _run(0, jobs[0])
    else:
        threads = [threading.Thread(target=_run, args=(k, job), daemon=True)
                   for k, job in enumerate(jobs)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    if len(errors) == 1:
        raise errors[0][1]          # sole failure: original exception type
    if errors:
        tags = [t for t, _ in errors]
        raise RuntimeError(f"engine failures in {tags}: {errors}") \
            from errors[0][1]
    return results


def run_workers(progs: Sequence[Program | ProgramFile],
                driver_factory: Callable[[int], ProtocolDriver],
                use_memmap: bool = False,
                on_output: Callable[[int, Any, list[np.ndarray]], None] | None = None,
                ) -> list:
    """Run one engine per worker on threads sharing an inproc fabric."""
    net = PartyView(InprocTransport(len(progs)), 0, len(progs))
    jobs = []
    for w, p in enumerate(progs):
        cb = (lambda i, v, _w=w: on_output(_w, i, v)) if on_output else None
        jobs.append(EngineJob(p, driver_factory(w), net=net,
                              use_memmap=use_memmap, on_output=cb,
                              tag=f"worker{w}"))
    return run_engines(jobs)
