"""Parallel/distributed MAGE execution (§5.1–§5.2, §6 "per-worker planning").

Workers follow the paper's distributed-memory model: each worker is one
thread of computation with its own MAGE-physical address space; DSL programs
are parameterized by (worker_id, num_workers) and express data movement with
explicit network directives.  Planning is run once per worker, independently
— each worker's accesses touch only its own region, so the memory programs
are generated in isolation (and could be generated in parallel).
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import os
import threading
from typing import Any, Callable, Sequence

import numpy as np

from .bytecode import Op, Program, ProgramFile
from .dsl import Value, trace
from .engine import Channels, Engine, ProtocolDriver
from .planner import PlanConfig, PlanReport, plan, plan_streaming


@dataclasses.dataclass
class ProgramOptions:
    """Mirrors the paper's ProgramOptions: worker identity + problem params."""
    worker: int = 0
    num_workers: int = 1
    problem_size: int = 0
    extra: dict = dataclasses.field(default_factory=dict)


def send_value(v: Value, dst: int, tag: int | None = None) -> int:
    b = v.builder
    tag = b.fresh_tag() if tag is None else tag
    b.emit(Op.NET_SEND, ins=(v.span,), imm=(dst, tag))
    return tag


def recv_into(v: Value, src: int, tag: int) -> None:
    v.builder.emit(Op.NET_RECV, outs=(v.span,), imm=(src, tag))


def trace_workers(fn: Callable[[ProgramOptions], None], *, protocol: str,
                  page_shift: int, num_workers: int,
                  problem_size: int = 0, extra: dict | None = None,
                  ) -> list[Program]:
    progs = []
    for w in range(num_workers):
        opts = ProgramOptions(worker=w, num_workers=num_workers,
                              problem_size=problem_size,
                              extra=dict(extra or {}))
        progs.append(trace(fn, protocol=protocol, page_shift=page_shift,
                           worker=w, num_workers=num_workers,
                           args=(opts,),
                           meta={"problem_size": problem_size}))
    return progs


def plan_workers(progs: Sequence[Program], cfg: PlanConfig,
                 parallel: bool = False, streaming: bool = False,
                 workdir: str | None = None,
                 ) -> tuple[list[Program | ProgramFile], list[PlanReport]]:
    """Plan each worker's program independently (§6.1).

    Worker programs only touch their own address space, so planning them is
    embarrassingly parallel: ``parallel=True`` runs one planner per worker
    concurrently.  ``streaming=True`` uses the out-of-core file pipeline
    (one subdirectory per worker) and returns ProgramFiles the engine
    executes directly from disk.
    """
    def _one(w: int, p: Program) -> tuple[Program | ProgramFile, PlanReport]:
        if streaming:
            wd = os.path.join(workdir, f"worker{w}") if workdir else None
            return plan_streaming(p, cfg, workdir=wd)
        return plan(p, cfg)

    if parallel and len(progs) > 1:
        with cf.ThreadPoolExecutor(max_workers=len(progs),
                                   thread_name_prefix="mage-plan") as ex:
            results = list(ex.map(_one, range(len(progs)), progs))
    else:
        results = [_one(w, p) for w, p in enumerate(progs)]
    return [r[0] for r in results], [r[1] for r in results]


def run_workers(progs: Sequence[Program | ProgramFile],
                driver_factory: Callable[[int], ProtocolDriver],
                use_memmap: bool = False,
                on_output: Callable[[int, Any, list[np.ndarray]], None] | None = None,
                ) -> list:
    """Run one engine per worker on threads sharing a Channels fabric."""
    channels = Channels(len(progs))
    results: list = [None] * len(progs)
    errors: list = []

    def _run(w: int, prog: Program | ProgramFile):
        try:
            eng = Engine(prog, driver_factory(w), channels=channels,
                         use_memmap=use_memmap)
            cb = (lambda i, v: on_output(w, i, v)) if on_output else None
            results[w] = eng.run(on_output=cb)
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append((w, e))

    threads = [threading.Thread(target=_run, args=(w, p), daemon=True)
               for w, p in enumerate(progs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise RuntimeError(f"worker failures: {errors}") from errors[0][1]
    return results
