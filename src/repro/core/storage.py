"""Storage backends + async I/O for MAGE's engine (§7.1).

The paper swaps via Linux `aio` with O_DIRECT.  Our analogue is a
thread-pool async layer over a page-granular backend: a file-backed
``np.memmap`` (real execution under a memory budget) or an in-RAM dict
(tests).  Byte/op counters feed the benchmarks.
"""

from __future__ import annotations

import concurrent.futures as cf
import os
import tempfile
import threading

import numpy as np


class StorageBackend:
    page_bytes: int

    def read(self, page_id: int, out: np.ndarray) -> None:
        raise NotImplementedError

    def write(self, page_id: int, data: np.ndarray) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class RamStorage(StorageBackend):
    def __init__(self, page_shape: tuple[int, ...], dtype):
        self._pages: dict[int, np.ndarray] = {}
        self.page_shape = page_shape
        self.dtype = np.dtype(dtype)
        self.page_bytes = int(np.prod(page_shape)) * self.dtype.itemsize

    def read(self, page_id: int, out: np.ndarray) -> None:
        out[...] = self._pages[page_id]

    def write(self, page_id: int, data: np.ndarray) -> None:
        self._pages[page_id] = np.array(data, copy=True)


class MemmapStorage(StorageBackend):
    """Swap file: one slot per MAGE-virtual page, grown on demand."""

    GROW = 256  # pages per growth step

    def __init__(self, page_shape: tuple[int, ...], dtype,
                 path: str | None = None):
        self.page_shape = tuple(page_shape)
        self.dtype = np.dtype(dtype)
        self.page_bytes = int(np.prod(page_shape)) * self.dtype.itemsize
        if path is None:
            fd, path = tempfile.mkstemp(prefix="mage_swap_", suffix=".bin")
            os.close(fd)
            self._unlink = True
        else:
            self._unlink = False
        self.path = path
        self._capacity = 0
        self._mm: np.memmap | None = None
        self._lock = threading.Lock()

    def _ensure(self, page_id: int) -> None:
        if page_id < self._capacity:
            return
        with self._lock:
            if page_id < self._capacity:
                return
            new_cap = max(page_id + 1, self._capacity + self.GROW)
            if self._mm is not None:
                self._mm.flush()
                del self._mm
            with open(self.path, "ab") as f:
                f.truncate(new_cap * self.page_bytes)
            self._mm = np.memmap(self.path, dtype=self.dtype, mode="r+",
                                 shape=(new_cap, *self.page_shape))
            self._capacity = new_cap

    def read(self, page_id: int, out: np.ndarray) -> None:
        self._ensure(page_id)
        out[...] = self._mm[page_id]

    def write(self, page_id: int, data: np.ndarray) -> None:
        self._ensure(page_id)
        self._mm[page_id] = data

    def close(self) -> None:
        if self._mm is not None:
            self._mm.flush()
            del self._mm
            self._mm = None
        if self._unlink and os.path.exists(self.path):
            os.unlink(self.path)


class AsyncIO:
    """The engine's `aio` analogue: page reads/writes on worker threads."""

    def __init__(self, backend: StorageBackend, threads: int = 2):
        self.backend = backend
        self.pool = cf.ThreadPoolExecutor(max_workers=threads,
                                          thread_name_prefix="mage-io")
        self.bytes_read = 0
        self.bytes_written = 0
        self.reads = 0
        self.writes = 0

    def issue_read(self, page_id: int, out: np.ndarray) -> cf.Future:
        self.reads += 1
        self.bytes_read += self.backend.page_bytes
        return self.pool.submit(self.backend.read, page_id, out)

    def issue_write(self, page_id: int, data: np.ndarray) -> cf.Future:
        self.writes += 1
        self.bytes_written += self.backend.page_bytes
        return self.pool.submit(self.backend.write, page_id, data)

    def close(self) -> None:
        self.pool.shutdown(wait=True)
        self.backend.close()
