"""MAGE's execution engine (§5, §7.1).

An interpreter for memory programs: program data lives in a flat array (the
MAGE-physical address space); each instruction's operands are views into that
array; swap directives are handled by the engine itself via async I/O, and
everything else is delegated to the protocol driver.  Network directives move
spans between workers of the same party over the transport fabric
(``core.transport``): the engine addresses peers by worker id through a
:class:`~repro.core.transport.PartyView`, so the same bytecode runs over
in-process queues, localhost TCP, or a WAN-shaped link unmodified.

The engine runs programs in any phase:
  * 'virtual'  — Unbounded scenario: memory sized to the whole vspace;
  * 'physical' — replacement only (synchronous swaps);
  * 'memory'   — the full scheduled memory program (async swaps).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from .bytecode import (_IMM_OFF, _IN_OFF, _OUT_OFF, Instr, Op, Program,
                       ProgramFile, decode_chunk, iter_instructions,
                       iter_record_chunks, unpack_heads)
from .storage import AsyncIO, MemmapStorage, RamStorage, StorageBackend
from .transport import PartyView, TransportError


class ProtocolDriver:
    """Lower layer of the interpreter (§4.3): executes ops with the SC scheme.

    ``lane``/``dtype`` define the engine array's slot layout; e.g. the garbled
    circuit driver uses lane=2, uint64 (one 128-bit wire label per slot).
    Drivers must keep all state *inside the spans* they are handed — no
    pointers to driver-owned memory may live in the array (§7.1), which is
    what makes engine-level swapping sound.
    """

    lane: int = 1
    dtype: Any = np.uint64
    name: str = "abstract"

    def execute(self, op: Op, imm: tuple, outs: list[np.ndarray],
                ins: list[np.ndarray]) -> None:
        raise NotImplementedError

    def cost(self, instr: Instr) -> float:
        """Estimated compute seconds (feeds the timing simulator)."""
        raise NotImplementedError

    def finalize(self) -> None:
        pass


@dataclasses.dataclass
class EngineStats:
    instructions: int = 0
    directives: int = 0
    io_read_bytes: int = 0
    io_write_bytes: int = 0
    finish_in_waits: int = 0
    finish_out_waits: int = 0
    net_messages: int = 0
    net_sent_bytes: int = 0
    net_recv_bytes: int = 0
    #: instructions executed through driver.execute_batch (exec/ backend)
    batched_instructions: int = 0
    #: number of execute_batch calls those instructions collapsed into
    batches: int = 0
    #: NET_RECVs posted as deferred completion handles (overlap backend)
    posted_recvs: int = 0
    #: peak simultaneously outstanding recv handles (overlap backend)
    max_inflight_recvs: int = 0
    #: per-link totals, (src_worker, dst_worker) -> [messages, bytes]; a key
    #: with src == this worker is outgoing traffic, dst == this worker
    #: incoming.  Counted by the engine thread itself (thread-confined, so
    #: no races even when many engines share one transport).
    net_links: dict = dataclasses.field(default_factory=dict)

    def _net_count(self, src: int, dst: int, nbytes: int) -> None:
        link = self.net_links.setdefault((src, dst), [0, 0])
        link[0] += 1
        link[1] += nbytes


class Engine:
    """Interprets a memory program — in-memory ``Program`` or on-disk
    ``ProgramFile``.  With a ProgramFile the engine is a *streaming
    executor*: instructions are decoded chunk-by-chunk straight from the
    file, so executing a paper-scale memory program costs O(chunk) planner-
    side memory on top of the engine's own frames (§7.1)."""

    def __init__(self, program: Program | ProgramFile, driver: ProtocolDriver,
                 storage: StorageBackend | None = None,
                 net: PartyView | None = None,
                 io_threads: int = 2,
                 use_memmap: bool = False,
                 batch_schedule: Any = None,
                 overlap_schedule: Any = None):
        self.prog = program
        self.driver = driver
        self.batch_schedule = batch_schedule
        self.overlap_schedule = overlap_schedule
        psize = program.page_slots
        page_shape = (psize, driver.lane)
        if program.phase == "virtual":
            n_slots = max(program.vspace_slots, 1)
        else:
            n_slots = max(program.num_frames, 1) * psize
        self.memory = np.zeros((n_slots, driver.lane), dtype=driver.dtype)
        B = program.prefetch_slots
        self.pf = np.zeros((max(B, 1), psize, driver.lane), dtype=driver.dtype)
        if storage is None:
            storage = (MemmapStorage(page_shape, driver.dtype) if use_memmap
                       else RamStorage(page_shape, driver.dtype))
        self.io = AsyncIO(storage, threads=io_threads)
        self.net = net
        self._slot_future: dict[int, Any] = {}
        self.stats = EngineStats()
        self._page_shape = page_shape

    # -- helpers ---------------------------------------------------------------

    def _view(self, span) -> np.ndarray:
        addr, n = span
        return self.memory[addr:addr + n]

    def _frame_page(self, span) -> np.ndarray:
        # a directive frame span always covers exactly one page
        return self._view(span)

    def _wait_slot(self, slot: int) -> None:
        fut = self._slot_future.pop(slot, None)
        if fut is not None:
            fut.result()

    def _instructions(self):
        return iter_instructions(self.prog)

    def _net(self) -> PartyView:
        if self.net is None:
            raise TransportError(
                "program has NET_* directives but the engine has no fabric "
                "attached (pass net=PartyView(...))")
        return self.net

    # -- main loop ---------------------------------------------------------------

    def run(self, on_output: Callable[[Instr, list[np.ndarray]], None] | None = None
            ) -> EngineStats:
        # try/finally: a mid-run driver/storage exception must not leak the
        # AsyncIO thread pool or an open (possibly temp-file) backend.
        try:
            if self.overlap_schedule is not None:
                self._run_loop_overlap(on_output)
            elif self.batch_schedule is not None \
                    and hasattr(self.driver, "execute_batch"):
                self._run_loop_batched(on_output)
            else:
                self._run_loop(on_output)
        finally:
            self.stats.io_read_bytes = self.io.bytes_read
            self.stats.io_write_bytes = self.io.bytes_written
            self.io.close()
        return self.stats

    def _run_loop(self, on_output) -> None:
        exec_one = self._exec_one
        for instr in self._instructions():
            exec_one(instr, on_output)
        self.driver.finalize()

    def _run_loop_batched(self, on_output) -> None:
        """The exec/ fast path: walk the precomputed batch schedule.

        Batchable groups (same op, uniform shape, mutually independent;
        see exec/batching.py) go through ``driver.execute_batch`` as
        gathered span columns; everything else — barriers, ops outside
        the driver's ``batch_ops``, singleton groups — replays through
        the scalar ``_exec_one`` reference path in schedule order."""
        drv = self.driver
        sched = self.batch_schedule
        sched.validate_for(self.prog)
        batch_ops = getattr(drv, "batch_ops", frozenset())
        order, bounds = sched.order, sched.bounds
        group_op, chunk_groups = sched.group_op, sched.chunk_groups
        ci = 0
        for start, rec, instrs in iter_record_chunks(self.prog,
                                                     sched.chunk_instrs,
                                                     cache=True):
            for g in range(chunk_groups[ci], chunk_groups[ci + 1]):
                rows = order[bounds[g]:bounds[g + 1]]
                gop = int(group_op[g])
                if gop >= 0 and len(rows) >= 2 and rec is not None \
                        and Op(gop) in batch_ops:
                    self._exec_batch(Op(gop), rec, rows)
                elif instrs is not None:
                    for r in rows:
                        self._exec_one(instrs[r], on_output)
                else:
                    for ins in decode_chunk(rec[rows]):
                        self._exec_one(ins, on_output)
            ci += 1
        drv.finalize()

    def _net_row(self, rec, instrs, r: int, is_send: bool):
        """(peer, tag, span-view) for a NET_SEND/NET_RECV row, straight
        from the record columns when no decoded Instr list is around."""
        if instrs is not None:
            ins = instrs[r]
            span = ins.ins[0] if is_send else ins.outs[0]
            return int(ins.imm[0]), int(ins.imm[1]), self._view(span)
        row = rec[r]
        off = _IN_OFF if is_send else _OUT_OFF
        return (int(row[_IMM_OFF]), int(row[_IMM_OFF + 1]),
                self._view((int(row[off]), int(row[off + 1]))))

    def _run_loop_overlap(self, on_output) -> None:
        """The planned out-of-order issue path (exec/overlap.py): walk the
        OverlapSchedule's groups — NET_SENDs issued at their hoisted
        position, NET_RECVs posted as deferred completion handles
        (``recv_async``) and completed only at their K_RECV_WAIT group,
        with independent local work (batched where the driver allows)
        filling the latency gap.  Dataflow order is schedule-enforced, so
        results are bitwise-identical to the scalar reference."""
        from ..exec.overlap import K_LOCAL, K_RECV_WAIT, K_SEND
        drv = self.driver
        sched = self.overlap_schedule
        sched.validate_for(self.prog)
        batch_ops = (getattr(drv, "batch_ops", frozenset())
                     if hasattr(drv, "execute_batch") else frozenset())
        order, bounds = sched.order, sched.bounds
        group_kind, group_op = sched.group_kind, sched.group_op
        chunk_groups = sched.chunk_groups
        stats = self.stats
        w = self.prog.worker
        ci = 0
        for start, rec, instrs in iter_record_chunks(self.prog,
                                                     sched.chunk_instrs,
                                                     cache=True):
            handles: dict[int, tuple] = {}
            for g in range(chunk_groups[ci], chunk_groups[ci + 1]):
                rows = order[bounds[g]:bounds[g + 1]]
                kind = int(group_kind[g])
                if kind == K_LOCAL:
                    gop = int(group_op[g])
                    if gop >= 0 and len(rows) >= 2 and rec is not None \
                            and Op(gop) in batch_ops:
                        self._exec_batch(Op(gop), rec, rows)
                    elif instrs is not None:
                        for r in rows:
                            self._exec_one(instrs[r], on_output)
                    else:
                        for ins in decode_chunk(rec[rows]):
                            self._exec_one(ins, on_output)
                elif kind == K_SEND:
                    net = self._net()
                    for r in rows:
                        dst, tag, view = self._net_row(rec, instrs, r, True)
                        net.send_async(w, dst, tag, view)
                        stats.directives += 1
                        stats.net_messages += 1
                        stats.net_sent_bytes += view.nbytes
                        stats._net_count(w, dst, view.nbytes)
                elif kind == K_RECV_WAIT:
                    for r in rows:
                        h, src, nbytes = handles.pop(int(r))
                        h.wait()
                        stats.directives += 1
                        stats.net_messages += 1
                        stats.net_recv_bytes += nbytes
                        stats._net_count(src, w, nbytes)
                else:  # K_RECV_POST
                    net = self._net()
                    for r in rows:
                        src, tag, view = self._net_row(rec, instrs, r, False)
                        handles[int(r)] = (
                            net.recv_async(src, w, tag, out=view),
                            src, view.nbytes)
                        stats.posted_recvs += 1
                    if len(handles) > stats.max_inflight_recvs:
                        stats.max_inflight_recvs = len(handles)
            if handles:  # pragma: no cover - builder waits inside the chunk
                raise AssertionError(
                    f"{len(handles)} recv handles leaked past chunk {ci}")
            ci += 1
        drv.finalize()

    def _exec_batch(self, op: Op, rec: np.ndarray, rows: np.ndarray) -> None:
        r0 = rec[rows[0]]
        _, n_outs, n_ins, n_imm = unpack_heads(r0[0])
        imm = tuple(int(r0[_IMM_OFF + j]) for j in range(n_imm))
        out_idx = [(rec[rows, _OUT_OFF + 2 * j],
                    int(r0[_OUT_OFF + 1 + 2 * j])) for j in range(n_outs)]
        in_idx = [(rec[rows, _IN_OFF + 2 * j],
                   int(r0[_IN_OFF + 1 + 2 * j])) for j in range(n_ins)]
        self.driver.execute_batch(op, imm, out_idx, in_idx, self.memory)
        self.stats.instructions += len(rows)
        self.stats.batched_instructions += len(rows)
        self.stats.batches += 1

    def _exec_one(self, instr: Instr, on_output) -> None:
        drv = self.driver
        w = self.prog.worker
        if True:
            op = instr.op
            if op == Op.SWAP_IN:
                self.stats.directives += 1
                self.io.issue_read(instr.imm[0],
                                   self._frame_page(instr.outs[0])).result()
            elif op == Op.SWAP_OUT:
                self.stats.directives += 1
                self.io.issue_write(instr.imm[0],
                                    np.array(self._frame_page(instr.ins[0]),
                                             copy=True)).result()
            elif op == Op.ISSUE_SWAP_IN:
                self.stats.directives += 1
                vpage, slot = instr.imm
                self._wait_slot(slot)
                self._slot_future[slot] = self.io.issue_read(
                    vpage, self.pf[slot])
            elif op == Op.FINISH_SWAP_IN:
                self.stats.directives += 1
                vpage, slot = instr.imm[0], instr.imm[1]
                self._wait_slot(slot)
                self.stats.finish_in_waits += 1
                self._frame_page(instr.outs[0])[...] = self.pf[slot]
            elif op == Op.COPY_OUT:
                self.stats.directives += 1
                slot = instr.imm[0]
                self._wait_slot(slot)
                self.pf[slot][...] = self._frame_page(instr.ins[0])
            elif op == Op.ISSUE_SWAP_OUT:
                self.stats.directives += 1
                vpage, slot = instr.imm
                self._slot_future[slot] = self.io.issue_write(
                    vpage, self.pf[slot])
            elif op == Op.FINISH_SWAP_OUT:
                self.stats.directives += 1
                self._wait_slot(instr.imm[0])
                self.stats.finish_out_waits += 1
            elif op == Op.NET_SEND:
                self.stats.directives += 1
                dst, tag = instr.imm[0], instr.imm[1]
                view = self._view(instr.ins[0])
                self._net().send(w, dst, tag, view)
                self.stats.net_messages += 1
                self.stats.net_sent_bytes += view.nbytes
                self.stats._net_count(w, dst, view.nbytes)
            elif op == Op.NET_RECV:
                self.stats.directives += 1
                src, tag = instr.imm[0], instr.imm[1]
                view = self._view(instr.outs[0])
                self._net().recv(src, w, tag, out=view)
                self.stats.net_messages += 1
                self.stats.net_recv_bytes += view.nbytes
                self.stats._net_count(src, w, view.nbytes)
            elif op == Op.NET_BARRIER:
                # documented as "wait until posted send/recv with tag done"
                # (bytecode.py) — this engine's NET ops are synchronous, so
                # the completion wait is a no-op.  Collective sync is the
                # fabric's job (PartyView.barrier / Fabric.barrier), not an
                # instruction semantic.
                self.stats.directives += 1
            elif op == Op.FREE:
                pass
            elif op == Op.OUTPUT:
                self.stats.instructions += 1
                views = [self._view(s) for s in instr.ins]
                drv.execute(op, instr.imm, [], views)
                if on_output is not None:
                    on_output(instr, views)
            else:
                self.stats.instructions += 1
                drv.execute(op, instr.imm,
                            [self._view(s) for s in instr.outs],
                            [self._view(s) for s in instr.ins])
