"""Memory programming for JAX computations (DESIGN.md §3.2).

A jaxpr is oblivious by construction — no data-dependent memory accesses —
which is exactly the property MAGE exploits for SC.  This module runs the
MAGE planning pipeline over a jaxpr's buffer trace:

  * each equation is an instruction; each intermediate value a (variable-
    sized) page;
  * a backward pass annotates next uses; Belady MIN evicts under an HBM
    byte budget; lookahead prefetch hoists reload issues;
  * the output is an *offload plan* — which buffers to move to host memory
    when, and what traffic/stall that costs under an HBM<->host bandwidth
    model.

Used two ways: (1) as the analysis behind activation-offload decisions for
train_step (reported in EXPERIMENTS.md §Dry-run), and (2) as a standalone
planner for the paged-KV serving schedule (serve/paged_kv.py builds the
trace directly instead).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable

import jax
import numpy as np

from .bytecode import INF


@dataclasses.dataclass
class BufferTrace:
    sizes: list[int]                  # bytes per buffer id
    reads: list[list[int]]            # per instruction: buffer ids read
    writes: list[list[int]]           # per instruction: buffer ids written
    names: list[str]                  # per instruction: primitive name


def jaxpr_trace(fn: Callable, *example_args, **kw) -> BufferTrace:
    from jax.extend.core import Literal
    closed = jax.make_jaxpr(fn, **kw)(*example_args)
    jaxpr = closed.jaxpr
    ids: dict[Any, int] = {}
    sizes: list[int] = []

    def bid(v) -> int | None:
        if not hasattr(v, "aval") or isinstance(v, Literal):
            return None
        if v not in ids:
            ids[v] = len(sizes)
            aval = v.aval
            sizes.append(int(np.prod(aval.shape)) * aval.dtype.itemsize
                         if aval.shape else aval.dtype.itemsize)
        return ids[v]

    reads, writes, names = [], [], []
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        bid(v)
    for eqn in jaxpr.eqns:
        r = [bid(v) for v in eqn.invars]
        w = [bid(v) for v in eqn.outvars]
        reads.append([x for x in r if x is not None])
        writes.append([x for x in w if x is not None])
        names.append(eqn.primitive.name)
    return BufferTrace(sizes, reads, writes, names)


@dataclasses.dataclass
class OffloadPlan:
    budget_bytes: int
    peak_unbounded: int               # live bytes at the worst instruction
    bytes_out: int = 0                # HBM -> host
    bytes_in: int = 0                 # host -> HBM
    n_offloads: int = 0
    n_reloads: int = 0
    moves: list[tuple[int, str, int, int]] = dataclasses.field(
        default_factory=list)         # (instr, 'out'|'in', buffer, bytes)
    feasible: bool = True

    def est_overhead(self, hbm_host_bw: float = 50e9,
                     compute_s: float | None = None) -> float:
        """Transfer seconds; with compute_s, fraction of step time assuming
        perfect overlap of issue (the prefetch schedule's goal)."""
        xfer = (self.bytes_in + self.bytes_out) / hbm_host_bw
        if compute_s:
            return max(0.0, xfer - compute_s) / compute_s
        return xfer


def plan_offload(trace: BufferTrace, budget_bytes: int) -> OffloadPlan:
    """Belady MIN over the buffer trace with a byte budget."""
    n = len(trace.reads)
    touch = [sorted(set(trace.reads[i]) | set(trace.writes[i]))
             for i in range(n)]
    # next-use annotation (backward pass)
    next_use: list[dict[int, int]] = [dict() for _ in range(n)]
    last: dict[int, int] = {}
    for i in range(n - 1, -1, -1):
        for b in touch[i]:
            next_use[i][b] = last.get(b, INF)
            last[b] = i

    # peak live bytes (for the report)
    first_seen: dict[int, int] = {}
    last_seen: dict[int, int] = {}
    for i in range(n):
        for b in touch[i]:
            first_seen.setdefault(b, i)
            last_seen[b] = i
    delta = np.zeros(n + 1, dtype=np.int64)
    for b, f in first_seen.items():
        delta[f] += trace.sizes[b]
        delta[last_seen[b] + 1] -= trace.sizes[b]
    peak = int(np.max(np.cumsum(delta))) if n else 0

    plan = OffloadPlan(budget_bytes=budget_bytes, peak_unbounded=peak)
    resident: dict[int, int] = {}     # buffer -> bytes
    on_host: set[int] = set()
    cur_bytes = 0
    heap: list[tuple[int, int]] = []  # (-next_use, buffer) lazy
    cur_nu: dict[int, int] = {}

    def pop_victim(pinned: set[int]) -> int | None:
        stash = []
        found = None
        while heap:
            negnu, v = heapq.heappop(heap)
            if v not in resident or cur_nu.get(v) != -negnu:
                continue  # stale
            if v in pinned:
                stash.append((negnu, v))
                continue
            found = v
            break
        for e in stash:
            heapq.heappush(heap, e)
        return found

    for i in range(n):
        pinned = set(touch[i])
        if sum(trace.sizes[b] for b in pinned) > budget_bytes:
            plan.feasible = False  # one instruction exceeds the budget
        for b in pinned:
            if b not in resident:
                sz = trace.sizes[b]
                while cur_bytes + sz > budget_bytes:
                    victim = pop_victim(pinned)
                    if victim is None:
                        break
                    cur_bytes -= resident.pop(victim)
                    if cur_nu.get(victim, INF) < INF:
                        plan.bytes_out += trace.sizes[victim]
                        plan.n_offloads += 1
                        plan.moves.append((i, "out", victim,
                                           trace.sizes[victim]))
                        on_host.add(victim)
                if b in on_host:
                    plan.bytes_in += sz
                    plan.n_reloads += 1
                    plan.moves.append((i, "in", b, sz))
                    on_host.discard(b)
                resident[b] = sz
                cur_bytes += sz
            nu = next_use[i][b]
            cur_nu[b] = nu
            heapq.heappush(heap, (-nu, b))
    return plan
