"""The tracing DSL core (§6.2.1).

MAGE's DSLs are "internal" languages: the user writes an ordinary function;
executing it does NOT perform secure computation, it *emits bytecode*.  Our
analogue is a Python tracing context: protocol packages define value types
(garbled ``Integer`` vectors, CKKS ``Batch``es) whose overloaded operators
call ``Builder.emit``.  Deallocation requests reach the placement allocator
when a value's refcount drops (CPython destructors — the analogue of C++
destructors in the paper) or via explicit ``free()``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable, Sequence

from .bytecode import Instr, Op, Program, Span
from .placement import PageAllocator

_tls = threading.local()


def current_builder() -> "Builder":
    b = getattr(_tls, "builder", None)
    if b is None:
        raise RuntimeError("no active Builder; use `with Builder(...)`")
    return b


class Builder:
    """Accumulates bytecode for ONE worker while the DSL program executes."""

    def __init__(self, protocol: str, page_shift: int,
                 worker: int = 0, num_workers: int = 1):
        self.protocol = protocol
        self.page_shift = page_shift
        self.worker = worker
        self.num_workers = num_workers
        self.alloc = PageAllocator(page_shift)
        self.instrs: list[Instr] = []
        self._closed = False
        self._net_tag = 0

    # -- context management ---------------------------------------------------

    def __enter__(self) -> "Builder":
        if getattr(_tls, "builder", None) is not None:
            raise RuntimeError("Builder contexts do not nest")
        _tls.builder = self
        return self

    def __exit__(self, *exc) -> None:
        _tls.builder = None

    # -- emission ---------------------------------------------------------------

    def emit(self, op: Op, outs: Sequence[Span] = (), ins: Sequence[Span] = (),
             imm: tuple = ()) -> None:
        if self._closed:
            raise RuntimeError("builder already finished")
        self.instrs.append(Instr(op, tuple(outs), tuple(ins), tuple(imm)))

    def new_span(self, n_slots: int) -> Span:
        return (self.alloc.alloc(n_slots), n_slots)

    def free_span(self, span: Span) -> None:
        if self._closed:
            return  # program over; allocator bookkeeping no longer matters
        self.alloc.free(span[0])
        self.emit(Op.FREE, ins=(span,))

    def fresh_tag(self) -> int:
        self._net_tag += 1
        return self._net_tag

    # -- finish -----------------------------------------------------------------

    def finish(self, meta: dict | None = None) -> Program:
        self._closed = True
        return Program(
            instrs=self.instrs,
            page_shift=self.page_shift,
            protocol=self.protocol,
            phase="virtual",
            worker=self.worker,
            num_workers=self.num_workers,
            vspace_slots=self.alloc.vspace_slots,
            meta=dict(meta or {}),
        )


class Value:
    """Base class for DSL values: owns one ≤page-sized span of slots."""

    __slots__ = ("builder", "span", "_freed", "__weakref__")

    def __init__(self, n_slots: int, builder: Builder | None = None):
        self.builder = builder or current_builder()
        self.span = self.builder.new_span(n_slots)
        self._freed = False

    @property
    def addr(self) -> int:
        return self.span[0]

    @property
    def n_slots(self) -> int:
        return self.span[1]

    def free(self) -> None:
        if not self._freed:
            self._freed = True
            self.builder.free_span(self.span)

    def __del__(self):
        with contextlib.suppress(Exception):
            self.free()


def trace(fn: Callable[..., None], *, protocol: str, page_shift: int,
          worker: int = 0, num_workers: int = 1,
          args: tuple = (), kwargs: dict | None = None,
          meta: dict | None = None) -> Program:
    """Run a DSL program function and return its virtual-address bytecode."""
    import gc
    b = Builder(protocol, page_shift, worker=worker, num_workers=num_workers)
    with b:
        fn(*args, **(kwargs or {}))
        gc.collect()  # flush destructor-driven FREEs before closing
    return b.finish(meta)
