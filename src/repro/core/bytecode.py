"""MAGE bytecode: the instruction stream the planner operates on.

Following §4.2 of the paper, each instruction is a *high-level* DSL operation
(integer add, ciphertext multiply, ...), not a gate and not a raw memory
access.  Operands are spans in a MAGE-virtual (during placement) or
MAGE-physical (after replacement) address space measured in *slots* — the
protocol driver defines what a slot is (a 128-bit wire label for garbled
circuits; an 8-byte word for CKKS).

Invariant inherited from the paper (§6.2.2): a value never straddles a page
boundary, so every operand span touches exactly one page.  The planner code
nevertheless computes page ranges generally, so relaxing the invariant later
only costs planner generality, not correctness.
"""

from __future__ import annotations

import dataclasses
import enum
import json
import os
import struct
from typing import Iterable, Iterator, Sequence

import numpy as np

INF = 1 << 62  # "never used again" sentinel for next-use times


class Op(enum.IntEnum):
    # ---- generic data movement -------------------------------------------
    INPUT = 1          # obtain (secret) input into outs[0]
    OUTPUT = 2         # reveal / externalize ins[0]
    COPY = 3           # outs[0] = ins[0]

    # ---- garbled-circuit style integer ops (AND-XOR engine) ---------------
    ADD = 10           # outs[0] = ins[0] + ins[1]      (ripple-carry subcircuit)
    SUB = 11
    MUL = 12           # shift-add subcircuit
    CMP_GE = 13        # outs[0](1-bit lanes) = ins[0] >= ins[1]
    CMP_EQ = 14
    SELECT = 15        # outs[0] = ins[0] ? ins[1] : ins[2]   (bitwise mux)
    XOR = 16
    AND = 17
    OR = 18
    NOT = 19
    MINMAX = 20        # (outs[0], outs[1]) = key-wise (min, max) of ins[0], ins[1]
    SORT_LOCAL = 21    # outs[0] = bitonic-sorted ins[0] (within-value network)
    PAIR_JOIN = 22     # outs[0] = equi-flagged pairs of ins[0] x ins[1] (loop join cell)
    MAC8 = 23          # outs[0] = ins[0] (acc) + ins[1] (8-bit ints) * imm scalar-vec
    XNOR_POP_SIGN = 24 # binary fc layer: sign(popcount(xnor(row, vec)) * 2 - n)
    REDUCE_ADD = 25    # outs[0](width lanes) = tree-sum of ins[0] vector
    REVERSE = 26       # outs[0] = ins[0] with element order reversed (free)

    # ---- Shamir secret-sharing field ops (n-party engine) ------------------
    # Shares live in GF(p), p = 2^61 - 1; one uint64 slot per element.
    # Linear ops are share-local; degree reduction after F_MUL_LOCAL is
    # expressed IN the trace as F_EVAL + NET_SEND/NET_RECV + an
    # F_MULC/F_MULC_ADD recombine chain, so the planner and the overlap
    # pass see every resharing round (see docs/SHAMIR.md).
    F_ADD = 50         # outs[0] = (ins[0] + ins[1]) mod p;          imm=(count,)
    F_SUB = 51         # outs[0] = (ins[0] - ins[1]) mod p;          imm=(count,)
    F_MULC = 52        # outs[0] = (c * ins[0]) mod p;               imm=(count, c)
    F_ADDC = 53        # outs[0] = (ins[0] + c) mod p;               imm=(count, c)
    F_MUL_LOCAL = 54   # outs[0] = (ins[0] * ins[1]) mod p (share-wise product;
                       # the share degree doubles);                  imm=(count,)
    F_EVAL = 55        # outs[0] = q(alpha_{j+1}) where q is this party's
                       # deterministic degree-t resharing polynomial of ins[0]
                       # for round rid;                    imm=(count, j, t, rid)
    F_MULC_ADD = 56    # outs[0] = (ins[0] + c * ins[1]) mod p;      imm=(count, c)

    # ---- CKKS style ops (Add-Multiply engine) ------------------------------
    CT_ADD = 40        # ciphertext + ciphertext
    CT_MUL = 41        # ciphertext * ciphertext (+ relinearize + rescale)
    CT_MUL_NR = 42     # multiply WITHOUT relinearization (for lazy-relin sums)
    CT_RELIN = 43      # relinearize + rescale an un-relinearized product
    CT_ADD_PLAIN = 44
    CT_MUL_PLAIN = 45

    # ---- placement-internal pseudo instructions ----------------------------
    FREE = 60          # operand span is dead (emitted by the DSL allocator)

    # ---- swap directives (inserted by replacement/scheduling stages) -------
    SWAP_IN = 70          # imm=(vpage,); outs[0]=frame span         [synchronous]
    SWAP_OUT = 71         # imm=(vpage,); ins[0]=frame span          [synchronous]
    ISSUE_SWAP_IN = 72    # imm=(vpage, pf_slot)                     [async read]
    FINISH_SWAP_IN = 73   # imm=(vpage, pf_slot); outs[0]=frame span [wait+copy]
    COPY_OUT = 74         # imm=(pf_slot,); ins[0]=frame span        [frame -> pf]
    ISSUE_SWAP_OUT = 75   # imm=(vpage, pf_slot)                     [async write]
    FINISH_SWAP_OUT = 76  # imm=(pf_slot,)                           [wait]

    # ---- network directives (distributed-memory model, §5.1) ---------------
    NET_SEND = 80      # imm=(dst_worker, tag); ins[0]=span
    NET_RECV = 81      # imm=(src_worker, tag); outs[0]=span
    NET_BARRIER = 82   # imm=(tag,) wait until posted recv/send with tag done


DIRECTIVES = frozenset({
    Op.SWAP_IN, Op.SWAP_OUT, Op.ISSUE_SWAP_IN, Op.FINISH_SWAP_IN,
    Op.COPY_OUT, Op.ISSUE_SWAP_OUT, Op.FINISH_SWAP_OUT,
    Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER,
})

NET_DIRECTIVES = frozenset({Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER})


Span = tuple[int, int]  # (start_slot_addr, n_slots)


@dataclasses.dataclass(frozen=True, slots=True)
class Instr:
    """One bytecode instruction.

    outs/ins are tuples of (addr, n_slots) spans.  ``imm`` carries op-specific
    immediates the planner does not interpret (widths, plaintext constants,
    worker ids, ...).  The planner only needs to know which spans are read and
    which are written — exactly the extensibility argument of §4.3.
    """
    op: Op
    outs: tuple[Span, ...] = ()
    ins: tuple[Span, ...] = ()
    imm: tuple = ()

    def spans(self) -> Iterator[tuple[Span, bool]]:
        for s in self.ins:
            yield s, False
        for s in self.outs:
            yield s, True


@dataclasses.dataclass
class Program:
    """A bytecode program for ONE worker.

    ``phase`` distinguishes the three §6.1 pipeline artifacts:
      'virtual'  — operands are MAGE-virtual addresses (placement output)
      'physical' — operands are MAGE-physical addresses + sync swap directives
      'memory'   — final memory program (scheduled, async directives)
    """
    instrs: list[Instr]
    page_shift: int
    protocol: str
    phase: str = "virtual"
    worker: int = 0
    num_workers: int = 1
    vspace_slots: int = 0        # extent of the MAGE-virtual address space
    num_frames: int = 0          # physical frames (phase >= physical)
    prefetch_slots: int = 0      # prefetch buffer pages (phase == memory)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def page_slots(self) -> int:
        return 1 << self.page_shift

    def pages_of(self, span: Span) -> range:
        lo = span[0] >> self.page_shift
        hi = (span[0] + span[1] - 1) >> self.page_shift
        return range(lo, hi + 1)

    def num_vpages(self) -> int:
        return (self.vspace_slots + self.page_slots - 1) >> self.page_shift

    def op_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ins in self.instrs:
            out[ins.op.name] = out.get(ins.op.name, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.instrs)


def strip_frees(instrs: Sequence[Instr]) -> list[Instr]:
    return [i for i in instrs if i.op != Op.FREE]


def iter_instructions(prog) -> Iterator[Instr]:
    """Instruction stream of an in-memory Program or an on-disk ProgramFile
    (chunk-decoded, so consumers of a paper-scale file stay O(chunk))."""
    instrs = getattr(prog, "instrs", None)
    return iter(instrs) if instrs is not None else prog.iter_instrs()


def iter_record_chunks(prog, chunk_instrs: int | None = None, *,
                       cache: bool = False
                       ) -> "Iterator[tuple[int, np.ndarray | None, list]]":
    """Yield ``(start, rec, instrs)`` chunks of a Program or ProgramFile.

    THE shared chunk iteration for record-consuming replay paths (the
    array simulator cores): ``rec`` is the [m, RECORD_WORDS] record array
    (``None`` for an in-memory chunk the record format cannot express —
    wide arity or non-scalar immediates), ``instrs`` the instruction list
    (``None`` for file chunks, which consumers decode on demand).

    ``cache=True`` memoizes the encoded chunks on an in-memory Program so
    repeated replays (the batched engine loop, benchmarks) do not pay the
    Python-side encode again; ~152 bytes/record of extra memory."""
    if chunk_instrs is None:
        chunk_instrs = DEFAULT_CHUNK_INSTRS
    instrs = getattr(prog, "instrs", None)
    if instrs is None:
        for s, rec in prog.iter_chunks(chunk_instrs):
            yield s, rec, None
        return
    memo = None
    if cache:
        memo = getattr(prog, "_rec_chunk_cache", None)
        if memo is not None and memo[0] == chunk_instrs:
            for i, s in enumerate(range(0, len(instrs), chunk_instrs)):
                yield s, memo[1][i], instrs[s:s + chunk_instrs]
            return
        memo = (chunk_instrs, [])
    for s in range(0, len(instrs), chunk_instrs):
        sub = instrs[s:s + chunk_instrs]
        try:
            rec = encode_chunk(sub)
        except (TypeError, ValueError):
            rec = None
        if memo is not None:
            memo[1].append(rec)
        yield s, rec, sub
    if memo is not None:
        prog._rec_chunk_cache = memo


# ---------------------------------------------------------------------------
# On-disk chunked bytecode format (§6.1: the planner is out-of-core).
#
# A program file is a small self-describing header followed by fixed-width
# 152-byte instruction records.  Fixed width is what makes every pipeline
# stage streamable: forward and *reverse* chunk iteration are both a seek
# plus one contiguous read, and record k of the annotation sidecar can be
# written at offset k while scanning the program backward.
#
#   header:  MAGIC(8) | u32 json_len | json (page_shift, protocol, phase, ...)
#   records: n x RECORD_WORDS little-endian int64
#
# Record layout (int64 words):
#   word 0          op | n_outs<<16 | n_ins<<20 | n_imm<<24 | float_mask<<28
#   1 .. 4          outs[0..MAX_OUTS): (addr, n_slots) pairs
#   5 .. 12         ins[0..MAX_INS):   (addr, n_slots) pairs
#   13 .. 18        imm values; float64 immediates are stored bit-exactly via
#                   their IEEE-754 pattern, flagged in float_mask
# ---------------------------------------------------------------------------

FILE_MAGIC = b"MAGEBC01"
MAX_OUTS = 2
MAX_INS = 4
MAX_IMM = 6
_OUT_OFF = 1
_IN_OFF = _OUT_OFF + 2 * MAX_OUTS
_IMM_OFF = _IN_OFF + 2 * MAX_INS
RECORD_WORDS = _IMM_OFF + MAX_IMM
RECORD_BYTES = RECORD_WORDS * 8
DEFAULT_CHUNK_INSTRS = 8192

_REC_DTYPE = np.dtype("<i8")

#: structured view of one record: the same 19 int64 words, addressable by
#: field.  ``decode_chunk_array`` / ``encode_chunk_array`` reinterpret
#: between this and the flat [n, RECORD_WORDS] chunk layout with zero
#: copies — the named-field API for external record-chunk consumers.  The
#: in-tree planner cores (replacement.py / scheduling.py) index the flat
#: word columns directly (via _OUT_OFF/_IN_OFF/_IMM_OFF and
#: ``unpack_heads``) and only materialize an ``Instr`` on event-time slow
#: paths.
REC_STRUCT = np.dtype([
    ("head", "<i8"),                       # op | arities | float_mask
    ("outs", "<i8", (MAX_OUTS, 2)),        # (addr, n_slots) pairs
    ("ins", "<i8", (MAX_INS, 2)),
    ("imm", "<i8", (MAX_IMM,)),
])
assert REC_STRUCT.itemsize == RECORD_BYTES

_HEADER_FIELDS = ("page_shift", "protocol", "phase", "worker", "num_workers",
                  "vspace_slots", "num_frames", "prefetch_slots")


def decode_chunk_array(arr: np.ndarray) -> np.ndarray:
    """Zero-copy: view an [n, RECORD_WORDS] int64 chunk as a structured
    record array with named ``head`` / ``outs`` / ``ins`` / ``imm`` fields."""
    if arr.ndim != 2 or arr.shape[1] != RECORD_WORDS:
        raise ValueError(f"bad record chunk shape {arr.shape}")
    if not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr)
    return arr.view(REC_STRUCT).reshape(arr.shape[0])


def encode_chunk_array(rec: np.ndarray) -> np.ndarray:
    """Zero-copy inverse of :func:`decode_chunk_array`: back to the flat
    [n, RECORD_WORDS] layout ``ProgramWriter.append_records`` accepts."""
    if rec.dtype != REC_STRUCT:
        raise ValueError(f"expected {REC_STRUCT}, got {rec.dtype}")
    return rec.view(_REC_DTYPE).reshape(rec.shape[0], RECORD_WORDS)


def unpack_heads(w0: np.ndarray) -> tuple[np.ndarray, ...]:
    """Split a vector of record head words into (op, n_outs, n_ins, n_imm)."""
    return (w0 & 0xFFFF, (w0 >> 16) & 0xF, (w0 >> 20) & 0xF,
            (w0 >> 24) & 0xF)


def pack_row(op: Op, outs: Sequence[Span] = (), ins: Sequence[Span] = (),
             imm: Sequence[int] = ()) -> list[int]:
    """Pack one all-int instruction into a raw record row (a Python list of
    RECORD_WORDS ints).  This is the planner cores' directive emitter: it
    produces exactly what ``encode_chunk([Instr(op, outs, ins, imm)])``
    would, without constructing the Instr."""
    row = [0] * RECORD_WORDS
    k = _OUT_OFF
    for a, n in outs:
        row[k] = a
        row[k + 1] = n
        k += 2
    k = _IN_OFF
    for a, n in ins:
        row[k] = a
        row[k + 1] = n
        k += 2
    for j, v in enumerate(imm):
        row[_IMM_OFF + j] = v
    row[0] = int(op) | len(outs) << 16 | len(ins) << 20 | len(imm) << 24
    return row


def _float_to_bits(v: float) -> int:
    return struct.unpack("<q", struct.pack("<d", v))[0]


def _bits_to_float(x: int) -> float:
    return struct.unpack("<d", struct.pack("<q", x))[0]


def encode_chunk(instrs: Sequence[Instr]) -> np.ndarray:
    """Encode instructions into an [n, RECORD_WORDS] int64 record array.

    Field packing happens in plain Python lists with one bulk np.array
    conversion at the end — per-element assignment into a NumPy array is
    ~10x slower and this is the writer's hot path.
    """
    rows: list[list[int]] = []
    for ins in instrs:
        outs, inss, imm = ins.outs, ins.ins, ins.imm
        if len(outs) > MAX_OUTS or len(inss) > MAX_INS or len(imm) > MAX_IMM:
            raise ValueError(
                f"instruction exceeds record arity "
                f"(outs<={MAX_OUTS}, ins<={MAX_INS}, imm<={MAX_IMM}): {ins}")
        row = [0] * RECORD_WORDS
        k = _OUT_OFF
        for a, n in outs:
            row[k] = a
            row[k + 1] = n
            k += 2
        k = _IN_OFF
        for a, n in inss:
            row[k] = a
            row[k + 1] = n
            k += 2
        fmask = 0
        for j, v in enumerate(imm):
            if isinstance(v, float):
                fmask |= 1 << j
                row[_IMM_OFF + j] = _float_to_bits(v)
            elif isinstance(v, (int, np.integer)):
                row[_IMM_OFF + j] = int(v)
            else:
                raise TypeError(
                    f"imm values must be int or float for the on-disk "
                    f"format, got {type(v).__name__}: {ins}")
        row[0] = (int(ins.op) | len(outs) << 16 | len(inss) << 20
                  | len(imm) << 24 | fmask << 28)
        rows.append(row)
    if not rows:
        return np.zeros((0, RECORD_WORDS), dtype=_REC_DTYPE)
    return np.array(rows, dtype=_REC_DTYPE)


def decode_chunk(arr: np.ndarray) -> list[Instr]:
    """Decode an [n, RECORD_WORDS] record array back into instructions."""
    out: list[Instr] = []
    ops = Op._value2member_map_
    for row in arr.tolist():              # bulk convert: python ints are fast
        w0 = row[0]
        n_outs = (w0 >> 16) & 0xF
        n_ins = (w0 >> 20) & 0xF
        n_imm = (w0 >> 24) & 0xF
        fmask = (w0 >> 28) & 0x3F
        out.append(Instr(
            ops[w0 & 0xFFFF],
            tuple((row[_OUT_OFF + 2 * j], row[_OUT_OFF + 2 * j + 1])
                  for j in range(n_outs)),
            tuple((row[_IN_OFF + 2 * j], row[_IN_OFF + 2 * j + 1])
                  for j in range(n_ins)),
            tuple(_bits_to_float(row[_IMM_OFF + j]) if fmask >> j & 1
                  else row[_IMM_OFF + j] for j in range(n_imm))))
    return out


class ProgramWriter:
    """Append-only writer for a bytecode program file.

    Records are buffered and flushed as encoded chunks; ``meta`` must be
    JSON-serializable (the planner only stores plain config dicts there).
    """

    def __init__(self, path: str | os.PathLike, *, page_shift: int,
                 protocol: str, phase: str = "virtual", worker: int = 0,
                 num_workers: int = 1, vspace_slots: int = 0,
                 num_frames: int = 0, prefetch_slots: int = 0,
                 meta: dict | None = None,
                 chunk_instrs: int = DEFAULT_CHUNK_INSTRS):
        self.path = os.fspath(path)
        self.chunk_instrs = chunk_instrs
        self.num_records = 0
        self._buf: list[Instr] = []
        header = {"page_shift": page_shift, "protocol": protocol,
                  "phase": phase, "worker": worker,
                  "num_workers": num_workers, "vspace_slots": vspace_slots,
                  "num_frames": num_frames, "prefetch_slots": prefetch_slots,
                  "record_words": RECORD_WORDS}
        header["meta"] = meta or {}
        payload = json.dumps(header).encode()
        self._f = open(self.path, "wb")
        self._f.write(FILE_MAGIC)
        self._f.write(struct.pack("<I", len(payload)))
        self._f.write(payload)

    def append(self, instr: Instr) -> None:
        self._buf.append(instr)
        if len(self._buf) >= self.chunk_instrs:
            self._flush()

    def extend(self, instrs: Iterable[Instr]) -> None:
        for i in instrs:
            self.append(i)

    def append_records(self, arr: np.ndarray) -> None:
        """Pass already-encoded records through without a decode/encode."""
        if arr.ndim != 2 or arr.shape[1] != RECORD_WORDS:
            raise ValueError(f"bad record array shape {arr.shape}")
        self._flush()
        self._f.write(np.ascontiguousarray(arr, dtype=_REC_DTYPE).tobytes())
        self.num_records += arr.shape[0]

    def _flush(self) -> None:
        if self._buf:
            self._f.write(encode_chunk(self._buf).tobytes())
            self.num_records += len(self._buf)
            self._buf.clear()

    def close(self) -> "ProgramFile":
        self._flush()
        self._f.close()
        return ProgramFile(self.path)

    def __enter__(self) -> "ProgramWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:
            self.close()
        else:
            self._f.close()


class ProgramFile:
    """A bytecode program on disk: Program-compatible header attributes plus
    chunked forward/reverse record iteration.

    The engine and every planner stage accept this in place of an in-memory
    ``Program``; only a chunk of instructions is ever materialized.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        with open(self.path, "rb") as f:
            if f.read(8) != FILE_MAGIC:
                raise ValueError(f"not a MAGE bytecode file: {self.path}")
            (jlen,) = struct.unpack("<I", f.read(4))
            header = json.loads(f.read(jlen))
        self._data_off = 12 + jlen
        data_bytes = os.path.getsize(self.path) - self._data_off
        if header.get("record_words") != RECORD_WORDS:
            raise ValueError(
                f"record width mismatch: file has {header.get('record_words')}"
                f" words, reader expects {RECORD_WORDS}")
        if data_bytes % RECORD_BYTES:
            raise ValueError(f"truncated bytecode file: {self.path}")
        self.num_records = data_bytes // RECORD_BYTES
        for k in _HEADER_FIELDS:
            setattr(self, k, header[k])
        self.meta: dict = header.get("meta", {})

    # -- Program-compatible surface ------------------------------------------

    @property
    def page_slots(self) -> int:
        return 1 << self.page_shift

    def pages_of(self, span: Span) -> range:
        lo = span[0] >> self.page_shift
        hi = (span[0] + span[1] - 1) >> self.page_shift
        return range(lo, hi + 1)

    def num_vpages(self) -> int:
        return (self.vspace_slots + self.page_slots - 1) >> self.page_shift

    def __len__(self) -> int:
        return self.num_records

    # -- chunked access -------------------------------------------------------

    def iter_chunks(self, chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                    reverse: bool = False
                    ) -> Iterator[tuple[int, np.ndarray]]:
        """Yield (start_record_index, [m, RECORD_WORDS] array) windows."""
        n = self.num_records
        starts = range(0, n, chunk_instrs)
        if reverse:
            starts = reversed(starts)
        with open(self.path, "rb") as f:
            for s in starts:
                m = min(chunk_instrs, n - s)
                f.seek(self._data_off + s * RECORD_BYTES)
                raw = f.read(m * RECORD_BYTES)
                yield s, np.frombuffer(raw, dtype=_REC_DTYPE).reshape(
                    m, RECORD_WORDS)

    def iter_instrs(self, chunk_instrs: int = DEFAULT_CHUNK_INSTRS
                    ) -> Iterator[Instr]:
        for _, arr in self.iter_chunks(chunk_instrs):
            yield from decode_chunk(arr)

    def read_program(self) -> Program:
        """Materialize the whole file (tests / small programs only)."""
        prog = Program(instrs=list(self.iter_instrs()),
                       page_shift=self.page_shift, protocol=self.protocol,
                       phase=self.phase, worker=self.worker,
                       num_workers=self.num_workers,
                       vspace_slots=self.vspace_slots,
                       num_frames=self.num_frames,
                       prefetch_slots=self.prefetch_slots,
                       meta=dict(self.meta))
        return prog


def writer_like(src: Program | ProgramFile, path: str | os.PathLike, *,
                phase: str | None = None, num_frames: int | None = None,
                prefetch_slots: int | None = None, meta: dict | None = None,
                chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> ProgramWriter:
    """A ProgramWriter inheriting header fields from ``src`` with overrides."""
    return ProgramWriter(
        path, page_shift=src.page_shift, protocol=src.protocol,
        phase=src.phase if phase is None else phase,
        worker=src.worker, num_workers=src.num_workers,
        vspace_slots=src.vspace_slots,
        num_frames=src.num_frames if num_frames is None else num_frames,
        prefetch_slots=(src.prefetch_slots if prefetch_slots is None
                        else prefetch_slots),
        meta=dict(src.meta) if meta is None else meta,
        chunk_instrs=chunk_instrs)


def write_program(prog: Program, path: str | os.PathLike,
                  strip_free: bool = False,
                  chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> ProgramFile:
    """Serialize an in-memory Program.  ``strip_free=True`` drops FREE
    pseudo-instructions, matching what the planner stages expect."""
    w = writer_like(prog, path, chunk_instrs=chunk_instrs)
    w.extend(strip_frees(prog.instrs) if strip_free else prog.instrs)
    return w.close()
