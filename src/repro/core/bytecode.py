"""MAGE bytecode: the instruction stream the planner operates on.

Following §4.2 of the paper, each instruction is a *high-level* DSL operation
(integer add, ciphertext multiply, ...), not a gate and not a raw memory
access.  Operands are spans in a MAGE-virtual (during placement) or
MAGE-physical (after replacement) address space measured in *slots* — the
protocol driver defines what a slot is (a 128-bit wire label for garbled
circuits; an 8-byte word for CKKS).

Invariant inherited from the paper (§6.2.2): a value never straddles a page
boundary, so every operand span touches exactly one page.  The planner code
nevertheless computes page ranges generally, so relaxing the invariant later
only costs planner generality, not correctness.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Sequence

INF = 1 << 62  # "never used again" sentinel for next-use times


class Op(enum.IntEnum):
    # ---- generic data movement -------------------------------------------
    INPUT = 1          # obtain (secret) input into outs[0]
    OUTPUT = 2         # reveal / externalize ins[0]
    COPY = 3           # outs[0] = ins[0]

    # ---- garbled-circuit style integer ops (AND-XOR engine) ---------------
    ADD = 10           # outs[0] = ins[0] + ins[1]      (ripple-carry subcircuit)
    SUB = 11
    MUL = 12           # shift-add subcircuit
    CMP_GE = 13        # outs[0](1-bit lanes) = ins[0] >= ins[1]
    CMP_EQ = 14
    SELECT = 15        # outs[0] = ins[0] ? ins[1] : ins[2]   (bitwise mux)
    XOR = 16
    AND = 17
    OR = 18
    NOT = 19
    MINMAX = 20        # (outs[0], outs[1]) = key-wise (min, max) of ins[0], ins[1]
    SORT_LOCAL = 21    # outs[0] = bitonic-sorted ins[0] (within-value network)
    PAIR_JOIN = 22     # outs[0] = equi-flagged pairs of ins[0] x ins[1] (loop join cell)
    MAC8 = 23          # outs[0] = ins[0] (acc) + ins[1] (8-bit ints) * imm scalar-vec
    XNOR_POP_SIGN = 24 # binary fc layer: sign(popcount(xnor(row, vec)) * 2 - n)
    REDUCE_ADD = 25    # outs[0](width lanes) = tree-sum of ins[0] vector
    REVERSE = 26       # outs[0] = ins[0] with element order reversed (free)

    # ---- CKKS style ops (Add-Multiply engine) ------------------------------
    CT_ADD = 40        # ciphertext + ciphertext
    CT_MUL = 41        # ciphertext * ciphertext (+ relinearize + rescale)
    CT_MUL_NR = 42     # multiply WITHOUT relinearization (for lazy-relin sums)
    CT_RELIN = 43      # relinearize + rescale an un-relinearized product
    CT_ADD_PLAIN = 44
    CT_MUL_PLAIN = 45

    # ---- placement-internal pseudo instructions ----------------------------
    FREE = 60          # operand span is dead (emitted by the DSL allocator)

    # ---- swap directives (inserted by replacement/scheduling stages) -------
    SWAP_IN = 70          # imm=(vpage,); outs[0]=frame span         [synchronous]
    SWAP_OUT = 71         # imm=(vpage,); ins[0]=frame span          [synchronous]
    ISSUE_SWAP_IN = 72    # imm=(vpage, pf_slot)                     [async read]
    FINISH_SWAP_IN = 73   # imm=(vpage, pf_slot); outs[0]=frame span [wait+copy]
    COPY_OUT = 74         # imm=(pf_slot,); ins[0]=frame span        [frame -> pf]
    ISSUE_SWAP_OUT = 75   # imm=(vpage, pf_slot)                     [async write]
    FINISH_SWAP_OUT = 76  # imm=(pf_slot,)                           [wait]

    # ---- network directives (distributed-memory model, §5.1) ---------------
    NET_SEND = 80      # imm=(dst_worker, tag); ins[0]=span
    NET_RECV = 81      # imm=(src_worker, tag); outs[0]=span
    NET_BARRIER = 82   # imm=(tag,) wait until posted recv/send with tag done


DIRECTIVES = frozenset({
    Op.SWAP_IN, Op.SWAP_OUT, Op.ISSUE_SWAP_IN, Op.FINISH_SWAP_IN,
    Op.COPY_OUT, Op.ISSUE_SWAP_OUT, Op.FINISH_SWAP_OUT,
    Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER,
})

NET_DIRECTIVES = frozenset({Op.NET_SEND, Op.NET_RECV, Op.NET_BARRIER})


Span = tuple[int, int]  # (start_slot_addr, n_slots)


@dataclasses.dataclass(frozen=True, slots=True)
class Instr:
    """One bytecode instruction.

    outs/ins are tuples of (addr, n_slots) spans.  ``imm`` carries op-specific
    immediates the planner does not interpret (widths, plaintext constants,
    worker ids, ...).  The planner only needs to know which spans are read and
    which are written — exactly the extensibility argument of §4.3.
    """
    op: Op
    outs: tuple[Span, ...] = ()
    ins: tuple[Span, ...] = ()
    imm: tuple = ()

    def spans(self) -> Iterator[tuple[Span, bool]]:
        for s in self.ins:
            yield s, False
        for s in self.outs:
            yield s, True


@dataclasses.dataclass
class Program:
    """A bytecode program for ONE worker.

    ``phase`` distinguishes the three §6.1 pipeline artifacts:
      'virtual'  — operands are MAGE-virtual addresses (placement output)
      'physical' — operands are MAGE-physical addresses + sync swap directives
      'memory'   — final memory program (scheduled, async directives)
    """
    instrs: list[Instr]
    page_shift: int
    protocol: str
    phase: str = "virtual"
    worker: int = 0
    num_workers: int = 1
    vspace_slots: int = 0        # extent of the MAGE-virtual address space
    num_frames: int = 0          # physical frames (phase >= physical)
    prefetch_slots: int = 0      # prefetch buffer pages (phase == memory)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def page_slots(self) -> int:
        return 1 << self.page_shift

    def pages_of(self, span: Span) -> range:
        lo = span[0] >> self.page_shift
        hi = (span[0] + span[1] - 1) >> self.page_shift
        return range(lo, hi + 1)

    def num_vpages(self) -> int:
        return (self.vspace_slots + self.page_slots - 1) >> self.page_shift

    def op_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ins in self.instrs:
            out[ins.op.name] = out.get(ins.op.name, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.instrs)


def strip_frees(instrs: Sequence[Instr]) -> list[Instr]:
    return [i for i in instrs if i.op != Op.FREE]
