"""MAGE core: memory programming for oblivious computations.

Pipeline (paper §6): DSL trace → placement → replacement (Belady MIN) →
scheduling (lookahead prefetch) → memory program → engine.
"""

from .bytecode import (DIRECTIVES, INF, Instr, Op, Program, ProgramFile,
                       ProgramWriter, decode_chunk_array, encode_chunk_array,
                       iter_instructions, write_program)
from .dsl import Builder, Value, current_builder, trace
from .engine import Engine, EngineStats, ProtocolDriver
from .liveness import (AnnotationReader, annotate_next_use, file_digest,
                       iter_touch_chunks, stripped_touches,
                       touches_from_records, working_set_pages_stream)
from .placement import PageAllocator
from .planner import (PlanConfig, PlanReport, plan, plan_streaming,
                      plan_unbounded)
from .replacement import (POLICIES, MinCleanPolicy, MinPolicy,
                          ReplacementStats, plan_replacement,
                          plan_replacement_file)
from .scheduling import ScheduleStats, plan_schedule, plan_schedule_file
from .simulator import (DeviceModel, SimResult, simulate_memory_program,
                        simulate_os_paging, simulate_unbounded)
from .storage import AsyncIO, MemmapStorage, RamStorage
from .transport import (Fabric, FabricSpec, InprocTransport, LinkStats,
                        PartyView, ShapedTransport, TcpTransport, Transport,
                        TransportError, aggregate_links, build_fabric,
                        pick_free_ports, register_transport)
from .workers import (EngineJob, ProgramOptions, plan_workers, recv_into,
                      run_engines, run_workers, send_value, trace_workers)

__all__ = [
    "DIRECTIVES", "INF", "Instr", "Op", "Program", "ProgramFile",
    "ProgramWriter", "decode_chunk_array", "encode_chunk_array",
    "iter_instructions", "iter_touch_chunks", "stripped_touches",
    "touches_from_records", "working_set_pages_stream", "write_program",
    "Builder", "Value", "current_builder", "trace",
    "Engine", "EngineStats", "ProtocolDriver",
    "Fabric", "FabricSpec", "InprocTransport", "LinkStats", "PartyView",
    "ShapedTransport", "TcpTransport", "Transport", "TransportError",
    "aggregate_links", "build_fabric", "pick_free_ports",
    "register_transport",
    "AnnotationReader", "annotate_next_use", "file_digest",
    "PageAllocator",
    "PlanConfig", "PlanReport", "plan", "plan_streaming", "plan_unbounded",
    "POLICIES", "MinCleanPolicy", "MinPolicy", "ReplacementStats",
    "plan_replacement", "plan_replacement_file",
    "ScheduleStats", "plan_schedule", "plan_schedule_file",
    "DeviceModel", "SimResult", "simulate_memory_program",
    "simulate_os_paging", "simulate_unbounded",
    "AsyncIO", "MemmapStorage", "RamStorage",
    "EngineJob", "ProgramOptions", "plan_workers", "recv_into",
    "run_engines", "run_workers", "send_value", "trace_workers",
]
