"""MAGE core: memory programming for oblivious computations.

Pipeline (paper §6): DSL trace → placement → replacement (Belady MIN) →
scheduling (lookahead prefetch) → memory program → engine.
"""

from .bytecode import DIRECTIVES, INF, Instr, Op, Program
from .dsl import Builder, Value, current_builder, trace
from .engine import Channels, Engine, EngineStats, ProtocolDriver
from .placement import PageAllocator
from .planner import PlanConfig, PlanReport, plan, plan_unbounded
from .replacement import (POLICIES, MinCleanPolicy, MinPolicy,
                          ReplacementStats, plan_replacement)
from .scheduling import ScheduleStats, plan_schedule
from .simulator import (DeviceModel, SimResult, simulate_memory_program,
                        simulate_os_paging, simulate_unbounded)
from .storage import AsyncIO, MemmapStorage, RamStorage
from .workers import (ProgramOptions, plan_workers, recv_into, run_workers,
                      send_value, trace_workers)

__all__ = [
    "DIRECTIVES", "INF", "Instr", "Op", "Program",
    "Builder", "Value", "current_builder", "trace",
    "Channels", "Engine", "EngineStats", "ProtocolDriver",
    "PageAllocator",
    "PlanConfig", "PlanReport", "plan", "plan_unbounded",
    "POLICIES", "MinCleanPolicy", "MinPolicy", "ReplacementStats",
    "plan_replacement",
    "ScheduleStats", "plan_schedule",
    "DeviceModel", "SimResult", "simulate_memory_program",
    "simulate_os_paging", "simulate_unbounded",
    "AsyncIO", "MemmapStorage", "RamStorage",
    "ProgramOptions", "plan_workers", "recv_into", "run_workers",
    "send_value", "trace_workers",
]
