"""Deterministic storage-timing simulator.

The paper's headline numbers (Fig. 8–10) are wall-clock on Azure VMs with a
local SSD.  This container is a single CPU, so we validate the *memory
management* content with a calibrated replay: per-instruction compute cost
from the protocol driver's cost model, and a single-queue storage device with
latency + bandwidth (§6.4 uses 10 GB/s and 1 ms for the Little's-law sizing
of the prefetch buffer; we default to a cloud-SSD-flavored 1 GB/s / 200 us,
both configurable).

Three scenarios, matching §8.2:
  * Unbounded — sum of compute costs;
  * OS        — demand paging over the *virtual* trace: reactive (a fault
                blocks for the whole transfer), LRU/CLOCK-style eviction,
                optional sequential readahead, asynchronous write-back that
                contends for device bandwidth; per-fault CPU overhead;
  * MAGE      — replay of the planned memory program: ISSUE_* overlap with
                compute; FINISH_* block only until the transfer completes.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable

from .bytecode import (DEFAULT_CHUNK_INSTRS, Instr, Op, Program, ProgramFile,
                       iter_instructions)
from .liveness import W_WRITE, iter_touch_chunks


@dataclasses.dataclass
class DeviceModel:
    bandwidth: float = 1.0e9       # bytes/s
    latency: float = 200e-6        # seconds per I/O op (pipelined: adds to
    #                                completion delay, not device occupancy)
    fault_overhead: float = 5e-6   # OS page-fault CPU cost (trap+map+TLB)
    readahead: int = 8             # OS sequential readahead window (pages)
    os_writeback_throttle_s: float = 0.02  # direct-reclaim blocking point


@dataclasses.dataclass
class SimResult:
    total: float = 0.0
    compute: float = 0.0
    stall: float = 0.0
    reads: int = 0
    writes: int = 0
    read_bytes: int = 0
    write_bytes: int = 0
    net_msgs: int = 0          # NET_SEND directives replayed
    net_bytes: int = 0         # bytes those sends would move on the fabric

    @property
    def overhead(self) -> float:
        return self.total / self.compute if self.compute else 1.0


CostFn = Callable[[Instr], float]


class _Device:
    """Single in-order I/O channel."""

    def __init__(self, model: DeviceModel, page_bytes: int):
        self.m = model
        self.page_bytes = page_bytes
        self.free_at = 0.0
        self.xfer = page_bytes / model.bandwidth

    def submit(self, now: float, pages: int = 1,
               nbytes: int | None = None) -> float:
        """Queue an I/O; returns completion time.  The device pipelines:
        occupancy grows by transfer time only; per-op latency delays the
        completion (queue-depth > 1, as `aio` exploits)."""
        start = max(now, self.free_at)
        xfer = (nbytes / self.m.bandwidth if nbytes is not None
                else pages * self.xfer)
        self.free_at = start + xfer
        return start + xfer + self.m.latency


def simulate_unbounded(prog: Program | ProgramFile, cost: CostFn) -> SimResult:
    r = SimResult()
    for ins in iter_instructions(prog):
        if ins.op not in (Op.FREE,):
            r.compute += cost(ins)
    r.total = r.compute
    return r


def simulate_memory_program(prog: Program | ProgramFile, cost: CostFn,
                            page_bytes: int,
                            model: DeviceModel | None = None) -> SimResult:
    """Replay a 'physical' or 'memory' phase program."""
    model = model or DeviceModel()
    dev = _Device(model, page_bytes)
    r = SimResult()
    t = 0.0
    slot_done: dict[int, float] = {}
    slot_bytes = max(page_bytes // max(prog.page_slots, 1), 1)
    for ins in iter_instructions(prog):
        op = ins.op
        if op == Op.SWAP_IN:
            done = dev.submit(t)
            r.stall += done - t
            t = done
            r.reads += 1
        elif op == Op.SWAP_OUT:
            done = dev.submit(t)
            r.stall += done - t
            t = done
            r.writes += 1
        elif op == Op.ISSUE_SWAP_IN:
            slot_done[ins.imm[1]] = dev.submit(t)
            r.reads += 1
        elif op == Op.ISSUE_SWAP_OUT:
            slot_done[ins.imm[1]] = dev.submit(t)
            r.writes += 1
        elif op in (Op.FINISH_SWAP_IN, Op.FINISH_SWAP_OUT):
            slot = ins.imm[1] if op == Op.FINISH_SWAP_IN else ins.imm[0]
            done = slot_done.pop(slot, t)
            if done > t:
                r.stall += done - t
                t = done
            if op == Op.FINISH_SWAP_IN:
                t += page_bytes / 50e9  # pf->frame memcpy (~DRAM bw)
        elif op == Op.COPY_OUT:
            t += page_bytes / 50e9
        elif op == Op.NET_SEND:
            # accounted like the transport fabric does (send side): the
            # span's slots at the protocol's slot width
            r.net_msgs += 1
            r.net_bytes += ins.ins[0][1] * slot_bytes
        elif op in (Op.NET_RECV, Op.NET_BARRIER, Op.FREE):
            continue
        else:
            c = cost(ins)
            r.compute += c
            t += c
    r.read_bytes = r.reads * page_bytes
    r.write_bytes = r.writes * page_bytes
    r.total = t
    return r


def simulate_os_paging(virtual_prog: Program | ProgramFile, cost: CostFn,
                       num_frames: int, page_bytes: int,
                       model: DeviceModel | None = None,
                       os_page_bytes: int | None = None,
                       chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> SimResult:
    """Demand paging over the virtual trace: the OS-swapping baseline.

    Reactive LRU with blocking major faults.  The OS works at its own page
    granularity (``os_page_bytes``, default = MAGE page size): faulting one
    MAGE-page worth of data costs ceil(page/os_page/readahead) blocking I/O
    clusters (Linux swap readahead) plus a per-OS-page fault overhead
    (trap + map + TLB).  Dirty evictions write back asynchronously but
    contend for the device.  No future knowledge (no dead-page drop, no
    planned prefetch) — that is exactly what MAGE adds.

    Streaming-capable: the trace is consumed as chunks (a ``ProgramFile``
    is never materialized, and in-memory programs no longer grow a
    program-length touch sidecar), so the full §8.2 scenario path is
    O(frames + chunk) in simulator memory.
    """
    model = model or DeviceModel()
    dev = _Device(model, page_bytes)
    os_page = os_page_bytes or page_bytes
    os_pages_per = max(page_bytes // os_page, 1)
    clusters = max((os_pages_per + model.readahead - 1) // model.readahead, 1)
    cluster_bytes = min(model.readahead * os_page, page_bytes)

    r = SimResult()
    t = 0.0
    lru: OrderedDict[int, None] = OrderedDict()    # resident pages, LRU order
    dirty: set[int] = set()
    stored: set[int] = set()

    def evict_one(now: float) -> float:
        page, _ = lru.popitem(last=False)
        if page in dirty:
            dirty.discard(page)
            stored.add(page)
            dev.submit(now, nbytes=page_bytes)  # async write-back: contends
            r.writes += 1
            # direct-reclaim throttling: once the write-back queue is deep,
            # the faulting process blocks until it drains below the mark
            lag = dev.free_at - now
            if lag > model.os_writeback_throttle_s:
                blocked = lag - model.os_writeback_throttle_s
                r.stall += blocked
                return now + blocked
        return now

    for instrs, offs, pg, fl in iter_touch_chunks(virtual_prog, chunk_instrs):
        offs_l = offs.tolist()
        pg_l = pg.tolist()
        fl_l = fl.tolist()
        for i, ins in enumerate(instrs):
            for k in range(offs_l[i], offs_l[i + 1]):
                p = pg_l[k]
                f = fl_l[k]
                if p in lru:
                    lru.move_to_end(p)
                else:
                    if p in stored:
                        # major fault: blocking reads at OS granularity
                        t += model.fault_overhead * os_pages_per
                        for _ in range(clusters):
                            done = dev.submit(t, nbytes=cluster_bytes)
                            r.stall += done - t
                            t = done
                        r.reads += 1
                    # else: first touch, anonymous page, no I/O
                    while len(lru) >= num_frames:
                        t = evict_one(t)
                    lru[p] = None
                if f & W_WRITE:
                    dirty.add(p)
            c = cost(ins)
            r.compute += c
            t += c
    r.read_bytes = r.reads * page_bytes
    r.write_bytes = r.writes * page_bytes
    r.total = t
    return r
