"""Deterministic storage-timing simulator.

The paper's headline numbers (Fig. 8–10) are wall-clock on Azure VMs with a
local SSD.  This container is a single CPU, so we validate the *memory
management* content with a calibrated replay: per-instruction compute cost
from the protocol driver's cost model, and a single-queue storage device with
latency + bandwidth (§6.4 uses 10 GB/s and 1 ms for the Little's-law sizing
of the prefetch buffer; we default to a cloud-SSD-flavored 1 GB/s / 200 us,
both configurable).

Three scenarios, matching §8.2:
  * Unbounded — sum of compute costs;
  * OS        — demand paging over the *virtual* trace: reactive (a fault
                blocks for the whole transfer), LRU/CLOCK-style eviction,
                optional sequential readahead, asynchronous write-back that
                contends for device bandwidth; per-fault CPU overhead;
  * MAGE      — replay of the planned memory program: ISSUE_* overlap with
                compute; FINISH_* block only until the transfer completes.

Each simulator has TWO cores behind a ``core="array"|"scalar"`` knob
(array is the default; the scalar loops are kept as the reference):

  * the array cores consume record chunks and price each chunk with ONE
    vectorized ``cost_chunk`` call (see ``GCCostModel.cost_chunk`` /
    ``CkksCostModel.cost_chunk`` and the rec-level wrapper the scenarios
    harness provides), dropping to scalar handlers only at *events* —
    swap/NET directives in the memory-program replay, residency misses in
    the OS baseline (found by a vectorized probe over the touch arrays,
    the same adaptive-window pattern as replacement's ``_ArrayCore``);

  * results are EXACTLY equal to the scalar cores for any chunk size:
    per-instruction costs are bitwise-identical by the cost models'
    chunk contract, and both cores accumulate compute sequentially
    between events, folding it into the clock at the same points
    (asserted in tests/test_array_sim.py).

Costs: ``cost`` is a per-instruction callable; if it also exposes
``cost_chunk(rec) -> float64[m]`` over raw record chunks (the scenarios
harness's cost objects do), the array cores use it — otherwise they fall
back to calling the scalar cost per instruction, keeping results
identical but losing the speed edge.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable

import numpy as np

from .bytecode import (DEFAULT_CHUNK_INSTRS, INF, _IMM_OFF, _IN_OFF, Instr,
                       Op, Program, ProgramFile, decode_chunk,
                       iter_instructions, iter_record_chunks, unpack_heads)
from .liveness import W_WRITE, iter_touch_chunks
from .replacement import ARRAY_MAX_VPAGES, _check_core


@dataclasses.dataclass
class DeviceModel:
    bandwidth: float = 1.0e9       # bytes/s
    latency: float = 200e-6        # seconds per I/O op (pipelined: adds to
    #                                completion delay, not device occupancy)
    fault_overhead: float = 5e-6   # OS page-fault CPU cost (trap+map+TLB)
    readahead: int = 8             # OS sequential readahead window (pages)
    os_writeback_throttle_s: float = 0.02  # direct-reclaim blocking point


@dataclasses.dataclass
class SimResult:
    total: float = 0.0
    compute: float = 0.0
    stall: float = 0.0
    reads: int = 0
    writes: int = 0
    read_bytes: int = 0        # bytes the device actually transferred
    write_bytes: int = 0
    net_msgs: int = 0          # NET_SEND directives replayed
    net_bytes: int = 0         # bytes those sends would move on the fabric
    net_stall: float = 0.0     # seconds the clock waited on the network

    @property
    def overhead(self) -> float:
        return self.total / self.compute if self.compute else 1.0


CostFn = Callable[[Instr], float]


def _chunk_costs(cost: CostFn, rec: np.ndarray | None, instrs,
                 skip: frozenset) -> list[float]:
    """Per-instruction seconds for one chunk, as a Python float list.

    Prefers the cost object's vectorized ``cost_chunk(rec)``; otherwise
    prices instructions with the scalar callable (ops in ``skip`` — rows
    the scalar reference never prices — get 0.0, which is what the array
    cores' sequential sums need: adding 0.0 is exact)."""
    ck = getattr(cost, "cost_chunk", None)
    if ck is not None and rec is not None:
        costs = np.asarray(ck(rec), dtype=np.float64)
        if skip:
            ops = unpack_heads(rec[:, 0])[0]
            costs = np.where(np.isin(ops, list(skip)), 0.0, costs)
        return costs.tolist()
    if instrs is None:
        instrs = decode_chunk(rec)
    return [0.0 if int(i.op) in skip else cost(i) for i in instrs]


class _Device:
    """Single in-order I/O channel."""

    def __init__(self, model: DeviceModel, page_bytes: int):
        self.m = model
        self.page_bytes = page_bytes
        self.free_at = 0.0
        self.xfer = page_bytes / model.bandwidth

    def submit(self, now: float, pages: int = 1,
               nbytes: int | None = None) -> float:
        """Queue an I/O; returns completion time.  The device pipelines:
        occupancy grows by transfer time only; per-op latency delays the
        completion (queue-depth > 1, as `aio` exploits)."""
        start = max(now, self.free_at)
        xfer = (nbytes / self.m.bandwidth if nbytes is not None
                else pages * self.xfer)
        self.free_at = start + xfer
        return start + xfer + self.m.latency


# ---------------------------------------------------------------------------
# Unbounded
# ---------------------------------------------------------------------------

_SKIP_FREE = frozenset({int(Op.FREE)})


def simulate_unbounded(prog: Program | ProgramFile, cost: CostFn,
                       core: str = "array",
                       chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> SimResult:
    _check_core(core)
    r = SimResult()
    if core == "scalar":
        for ins in iter_instructions(prog):
            if ins.op not in (Op.FREE,):
                r.compute += cost(ins)
    else:
        comp = 0.0
        for _s, rec, instrs in iter_record_chunks(prog, chunk_instrs):
            comp = sum(_chunk_costs(cost, rec, instrs, _SKIP_FREE), comp)
        r.compute = comp
    r.total = r.compute
    return r


# ---------------------------------------------------------------------------
# MAGE: memory-program replay
# ---------------------------------------------------------------------------

_E_SWAP_IN = int(Op.SWAP_IN)
_E_SWAP_OUT = int(Op.SWAP_OUT)
_E_ISSUE_IN = int(Op.ISSUE_SWAP_IN)
_E_FINISH_IN = int(Op.FINISH_SWAP_IN)
_E_COPY_OUT = int(Op.COPY_OUT)
_E_ISSUE_OUT = int(Op.ISSUE_SWAP_OUT)
_E_FINISH_OUT = int(Op.FINISH_SWAP_OUT)
_E_NET_SEND = int(Op.NET_SEND)

_MEM_EVENTS = frozenset({_E_SWAP_IN, _E_SWAP_OUT, _E_ISSUE_IN, _E_FINISH_IN,
                         _E_COPY_OUT, _E_ISSUE_OUT, _E_FINISH_OUT,
                         _E_NET_SEND})
_MEM_EVENTS_ARR = np.array(sorted(_MEM_EVENTS), dtype=np.int64)
_MEM_SKIP = frozenset({int(Op.NET_RECV), int(Op.NET_BARRIER), int(Op.FREE)})
_MEM_NONCOMPUTE = _MEM_EVENTS | _MEM_SKIP


class _MemoryReplay:
    """Event-time state of the memory-program replay, shared by both cores:
    the simulated clock, the device, and the in-flight pf-slot completions.
    Pending compute is folded in via :meth:`flush` only at events (and once
    at the end), so both cores add the same floats in the same order."""

    def __init__(self, model: DeviceModel, page_bytes: int, slot_bytes: int,
                 r: SimResult, net_latency_s: float = 0.0,
                 net_bandwidth: float | None = None,
                 net_mode: str = "inorder"):
        self.dev = _Device(model, page_bytes)
        self.page_bytes = page_bytes
        self.slot_bytes = slot_bytes
        self.r = r
        self.t = 0.0
        self.slot_done: dict[int, float] = {}
        self.net_lat = net_latency_s
        self.net_bw = net_bandwidth
        self.net_overlap = net_mode == "overlap"
        # overlap mode: the one-deep latency window of the last message
        # still in flight; local compute between sends hides it
        self.net_due = 0.0

    def settle_net(self) -> None:
        """Charge any still-hidden latency residue (the trailing recv)."""
        if self.net_due > self.t:
            self.r.net_stall += self.net_due - self.t
            self.t = self.net_due

    def flush(self, sub: float) -> None:
        self.t += sub
        self.r.compute += sub

    def event(self, op: int, a: int, b: int, n0: int) -> None:
        """One directive: ``a``/``b`` are imm[0]/imm[1], ``n0`` is
        ins[0]'s slot count (NET_SEND accounting)."""
        r, dev, t = self.r, self.dev, self.t
        if self.net_overlap and self.net_due > 0.0 and op != _E_NET_SEND:
            # swap directives are reorder barriers for NET (the planned
            # scheduler never moves a send/recv across one — residency):
            # every posted recv window must settle before the swap
            if self.net_due > t:
                r.net_stall += self.net_due - t
                t = self.net_due
            self.net_due = 0.0
        if op == _E_SWAP_IN or op == _E_SWAP_OUT:
            done = dev.submit(t)
            r.stall += done - t
            t = done
            if op == _E_SWAP_IN:
                r.reads += 1
                r.read_bytes += self.page_bytes
            else:
                r.writes += 1
                r.write_bytes += self.page_bytes
        elif op == _E_ISSUE_IN:
            self.slot_done[b] = dev.submit(t)
            r.reads += 1
            r.read_bytes += self.page_bytes
        elif op == _E_ISSUE_OUT:
            self.slot_done[b] = dev.submit(t)
            r.writes += 1
            r.write_bytes += self.page_bytes
        elif op == _E_FINISH_IN or op == _E_FINISH_OUT:
            slot = b if op == _E_FINISH_IN else a
            done = self.slot_done.pop(slot, t)
            if done > t:
                r.stall += done - t
                t = done
            if op == _E_FINISH_IN:
                t += self.page_bytes / 50e9  # pf->frame memcpy (~DRAM bw)
        elif op == _E_COPY_OUT:
            t += self.page_bytes / 50e9
        elif op == _E_NET_SEND:
            # accounted like the transport fabric does (send side): the
            # span's slots at the protocol's slot width
            nbytes = n0 * self.slot_bytes
            r.net_msgs += 1
            r.net_bytes += nbytes
            if self.net_lat or self.net_bw:
                xfer = nbytes / self.net_bw if self.net_bw else 0.0
                if self.net_overlap:
                    # sends are hoisted and recv waits deferred, so the
                    # latency windows of every exchange in the barrier
                    # window run concurrently; only the residue of the
                    # latest one past the local work stalls (at the next
                    # barrier or at the end of the program)
                    t += xfer
                    due = t + self.net_lat
                    if due > self.net_due:
                        self.net_due = due
                else:
                    # in-order issue: every exchange is a blocking round
                    r.net_stall += self.net_lat
                    t += xfer + self.net_lat
        self.t = t


def _mem_walk(instrs, cost: CostFn, rp: _MemoryReplay, sub: float) -> float:
    """The scalar reference walk (also prices array-core fallback chunks):
    accumulate compute sequentially, fold at events."""
    for ins in instrs:
        op = int(ins.op)
        if op in _MEM_SKIP:
            continue
        if op in _MEM_EVENTS:
            rp.flush(sub)
            sub = 0.0
            imm = ins.imm
            rp.event(op, imm[0] if imm else 0,
                     imm[1] if len(imm) > 1 else 0,
                     ins.ins[0][1] if ins.ins else 0)
        else:
            sub += cost(ins)
    return sub


def simulate_memory_program(prog: Program | ProgramFile, cost: CostFn,
                            page_bytes: int,
                            model: DeviceModel | None = None,
                            core: str = "array",
                            chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                            net_latency_s: float = 0.0,
                            net_bandwidth: float | None = None,
                            net_mode: str = "inorder") -> SimResult:
    """Replay a 'physical' or 'memory' phase program.

    ``net_latency_s``/``net_bandwidth`` price NET_SEND exchanges on a
    modelled link (both default off — NET then costs nothing, as before).
    ``net_mode`` selects the issue discipline being predicted:

    * ``"inorder"`` — every exchange is a blocking round:
      ``t += xfer + latency`` at each NET_SEND.
    * ``"overlap"`` — the planned out-of-order engine (docs/OVERLAP.md):
      sends are hoisted and recv waits deferred, so the latency windows
      of every exchange between two swap barriers run concurrently and
      hide behind local compute; only the residue of the latest window
      still open at the next barrier (or program end) stalls.
    """
    _check_core(core)
    if net_mode not in ("inorder", "overlap"):
        raise ValueError(f"net_mode must be 'inorder' or 'overlap', "
                         f"got {net_mode!r}")
    model = model or DeviceModel()
    r = SimResult()
    slot_bytes = max(page_bytes // max(prog.page_slots, 1), 1)
    rp = _MemoryReplay(model, page_bytes, slot_bytes, r,
                       net_latency_s=net_latency_s,
                       net_bandwidth=net_bandwidth, net_mode=net_mode)
    if core == "scalar":
        rp.flush(_mem_walk(iter_instructions(prog), cost, rp, 0.0))
    else:
        sub = 0.0
        for _s, rec, instrs in iter_record_chunks(prog, chunk_instrs):
            if rec is None:
                sub = _mem_walk(instrs, cost, rp, sub)
                continue
            costs = _chunk_costs(cost, rec, instrs, _MEM_NONCOMPUTE)
            ops = unpack_heads(rec[:, 0])[0]
            prev = 0
            for e in np.nonzero(np.isin(ops, _MEM_EVENTS_ARR))[0].tolist():
                rp.flush(sum(costs[prev:e], sub))
                sub = 0.0
                row = rec[e]
                rp.event(int(ops[e]), int(row[_IMM_OFF]),
                         int(row[_IMM_OFF + 1]), int(row[_IN_OFF + 1]))
                prev = e + 1
            sub = sum(costs[prev:], sub)
        rp.flush(sub)
    rp.settle_net()
    r.total = rp.t
    return r


# ---------------------------------------------------------------------------
# OS demand paging
# ---------------------------------------------------------------------------


class _OsReplay:
    """Event-time state of the OS-paging baseline, shared by both cores:
    the clock, the device, the OS-granularity fault-cluster geometry and
    the write-back throttle.  Residency structures stay core-specific
    (dict/LRU list vs. flat arrays); only the event arithmetic lives here,
    so the two cores cannot drift."""

    def __init__(self, model: DeviceModel, page_bytes: int,
                 os_page_bytes: int | None, r: SimResult):
        self.m = model
        self.dev = _Device(model, page_bytes)
        os_page = os_page_bytes or page_bytes
        self.os_pages_per = max(page_bytes // os_page, 1)
        self.clusters = max(
            (self.os_pages_per + model.readahead - 1) // model.readahead, 1)
        self.cluster_bytes = min(model.readahead * os_page, page_bytes)
        self.page_bytes = page_bytes
        self.r = r
        self.t = 0.0

    def flush(self, sub: float) -> None:
        self.t += sub
        self.r.compute += sub

    def major_fault(self) -> None:
        """Blocking reads at OS granularity: ceil(page/os_page/readahead)
        I/O clusters (Linux swap readahead) plus per-OS-page trap cost."""
        r = self.r
        t = self.t + self.m.fault_overhead * self.os_pages_per
        for _ in range(self.clusters):
            done = self.dev.submit(t, nbytes=self.cluster_bytes)
            r.stall += done - t
            t = done
            r.read_bytes += self.cluster_bytes
        r.reads += 1
        self.t = t

    def writeback(self) -> None:
        """Async write-back of a dirty victim: contends for the device;
        direct-reclaim throttling blocks the faulting process once the
        write-back queue is deep."""
        r = self.r
        now = self.t
        self.dev.submit(now, nbytes=self.page_bytes)
        r.writes += 1
        r.write_bytes += self.page_bytes
        lag = self.dev.free_at - now
        if lag > self.m.os_writeback_throttle_s:
            blocked = lag - self.m.os_writeback_throttle_s
            r.stall += blocked
            self.t = now + blocked

    def fault_run(self, flushes: list, majors: list, wbs: list) -> None:
        """Replay one fault run's per-touch (flush?, major_fault?,
        writeback?) event sequence in a single loop with all clock/device
        state hoisted to locals — arithmetic, operation order and float
        associativity identical to calling ``flush``/``major_fault``/
        ``writeback`` one by one, minus three attribute-dispatched calls
        per faulting touch.  This is the array core's batched thrash
        path; the per-call methods stay the reference (and the scalar
        core's only) entry points."""
        m, dev, r = self.m, self.dev, self.r
        t = self.t
        free_at = dev.free_at
        compute, stall = r.compute, r.stall
        reads, writes = r.reads, r.writes
        read_b, write_b = r.read_bytes, r.write_bytes
        ov = m.fault_overhead * self.os_pages_per
        lat = m.latency
        bw = m.bandwidth
        cb = self.cluster_bytes
        xc = cb / bw
        clusters = self.clusters
        pb = self.page_bytes
        xp = pb / bw
        thr = m.os_writeback_throttle_s
        for j in range(len(majors)):
            f = flushes[j]
            if f is not None:
                t += f
                compute += f
            if majors[j]:
                tt = t + ov
                for _ in range(clusters):
                    start = tt if tt > free_at else free_at
                    free_at = start + xc
                    done = free_at + lat
                    stall += done - tt
                    tt = done
                    read_b += cb
                reads += 1
                t = tt
            if wbs[j]:
                start = t if t > free_at else free_at
                free_at = start + xp
                writes += 1
                write_b += pb
                lag = free_at - t
                if lag > thr:
                    blocked = lag - thr
                    stall += blocked
                    t = t + blocked
        self.t = t
        dev.free_at = free_at
        r.compute, r.stall = compute, stall
        r.reads, r.writes = reads, writes
        r.read_bytes, r.write_bytes = read_b, write_b


def _os_scalar(prog, cost: CostFn, num_frames: int, rp: _OsReplay,
               chunk_instrs: int) -> None:
    """The scalar reference: reactive LRU with blocking major faults."""
    lru: OrderedDict[int, None] = OrderedDict()    # resident pages, LRU order
    dirty: set[int] = set()
    stored: set[int] = set()
    sub = 0.0
    for instrs, offs, pg, fl in iter_touch_chunks(prog, chunk_instrs):
        offs_l = offs.tolist()
        pg_l = pg.tolist()
        fl_l = fl.tolist()
        for i, ins in enumerate(instrs):
            for k in range(offs_l[i], offs_l[i + 1]):
                p = pg_l[k]
                if p in lru:
                    lru.move_to_end(p)
                else:
                    rp.flush(sub)
                    sub = 0.0
                    if p in stored:
                        rp.major_fault()
                    # else: first touch, anonymous page, no I/O
                    while len(lru) >= num_frames:
                        victim, _ = lru.popitem(last=False)
                        if victim in dirty:
                            dirty.discard(victim)
                            stored.add(victim)
                            rp.writeback()
                    lru[p] = None
                if fl_l[k] & W_WRITE:
                    dirty.add(p)
            sub += cost(ins)
    rp.flush(sub)


class _OsArrayCore:
    """Vectorized residency probe over the touch arrays; scalar fault /
    evict handling only on misses (the ``_ArrayCore`` adaptive-window
    pattern).  State: per-frame page/last-touch/dirty vectors plus
    growable per-page slot/stored vectors — array analogues of the
    scalar core's LRU dict, with the LRU order recovered exactly as the
    argmin of last-touch indices (touch indices are globally unique, so
    the victim matches the OrderedDict's pop order)."""

    def __init__(self, num_frames: int, rp: _OsReplay):
        self.nf = num_frames
        self.rp = rp
        self.slot_of = np.full(1024, -1, dtype=np.int64)
        self.stored = np.zeros(1024, dtype=bool)
        self.page_of = np.full(num_frames, -1, dtype=np.int64)
        self.last_touch = np.full(num_frames, INF, dtype=np.int64)
        self.dirty_of = np.zeros(num_frames, dtype=bool)
        self.free = list(range(num_frames - 1, -1, -1))
        self.used = 0
        self.base = 0                  # global touch index of chunk start
        self.win = _OS_PROBE_MAX
        self._cand: list[tuple[int, int]] = []   # LRU victim candidates
        self._ci = 0

    def _grow(self, max_page: int) -> None:
        if max_page < self.slot_of.shape[0]:
            return
        n = max(max_page + 1, 2 * self.slot_of.shape[0])
        s2 = np.full(n, -1, dtype=np.int64)
        s2[:self.slot_of.shape[0]] = self.slot_of
        self.slot_of = s2
        st2 = np.zeros(n, dtype=bool)
        st2[:self.stored.shape[0]] = self.stored
        self.stored = st2

    def _evict_frame(self) -> int:
        """The LRU victim: the frame with the globally smallest last-touch
        index.  Per-eviction argmin is O(frames) — too slow at fig9-scale
        working sets — so victims come from a snapshot of the 1024 smallest
        keys (one argpartition, amortized over the burst of evictions that
        follows).  Touch indices only ever grow, so a candidate whose key
        is unchanged since the snapshot is still the global minimum: every
        non-candidate exceeded the snapshot's largest key then and has only
        grown, and any candidate touched since (or any newly faulted-in
        page) carries a more recent — larger — index.  Stale entries are
        skipped; an exhausted queue re-snapshots.  Exactly the argmin (and
        the scalar OrderedDict pop order), tested bitwise."""
        lt = self.last_touch
        while True:
            while self._ci < len(self._cand):
                key, f = self._cand[self._ci]
                self._ci += 1
                if key < INF and lt[f] == key:
                    return f
            k = min(self.nf, 1024)
            if k == self.nf:
                idx = np.argsort(lt)
            else:
                part = np.argpartition(lt, k - 1)[:k]
                idx = part[np.argsort(lt[part])]
            self._cand = list(zip(lt[idx].tolist(), idx.tolist()))
            self._ci = 0
            if not self._cand:
                raise RuntimeError("no frame to evict (num_frames == 0)")

    def _take_victims(self, want: int) -> list[int]:
        """Up to ``want`` LRU victim frames from the candidate snapshot,
        in eviction order, WITHOUT booking the evictions — the batched
        fault-run path books them in one vectorized sweep.  Exactly the
        frames ``_evict_frame`` would return: candidate validity is
        static during a run (evicted frames go to ``INF``, and only
        already-consumed or free — ``INF``-keyed, hence invalid —
        candidates are ever reassigned), so the stale-check can run as
        one vectorized pass over the remaining snapshot.  May return
        fewer than ``want`` (snapshot exhausted): the caller shrinks the
        run and the scalar path re-snapshots."""
        out: list[int] = []
        lt = self.last_touch
        while len(out) < want and self._ci < len(self._cand):
            # bounded block scan: short runs must not pay a rescan of the
            # whole (possibly stale) remainder on every call
            blk = max(2 * (want - len(out)), 64)
            rem = self._cand[self._ci:self._ci + blk]
            keys = np.fromiter((c[0] for c in rem), np.int64, count=len(rem))
            frs = np.fromiter((c[1] for c in rem), np.int64, count=len(rem))
            vpos = np.flatnonzero((keys < INF) & (lt[frs] == keys))
            vpos = vpos[:want - len(out)]
            if vpos.size:
                out.extend(frs[vpos].tolist())
                self._ci += int(vpos[-1]) + 1
            else:
                self._ci += len(rem)
        return out

    def _fault_run(self, m0: int, stop: int, pg: np.ndarray, wm: np.ndarray,
                   rows_l: list, costs: list, ci: int, sub: float) -> int:
        """Batch one run of consecutive all-miss touches on pairwise-
        distinct pages (the thrash pattern: every touch faults, one page
        per touch).  Replay events — compute flushes at instruction
        boundaries, major faults, victim write-backs — fire one by one in
        exactly the scalar order (the device model is order-sensitive),
        but all residency bookkeeping (LRU stamps, slot/frame/dirty/
        stored vectors, victim selection) runs as vectorized sweeps.
        Returns the first unprocessed touch (== ``m0`` when the victim
        snapshot is empty and the caller should take the scalar path).

        Exactness: probe misses stay misses (evictions never make a page
        resident, and distinct pages rule out an earlier fault of the
        run resupplying a later touch), victim pages are resident and so
        disjoint from the run's pages (their ``stored`` promotion cannot
        retag a run page), and the touch→frame pairing replays the
        scalar free-list pops and candidate consumption in order."""
        rp = self.rp
        n = stop - m0
        pages = pg[m0:stop]
        stored_f = self.stored[pages]
        nf0 = min(n, self.nf - self.used)
        vf_list = self._take_victims(n - nf0) if n > nf0 else []
        if nf0 + len(vf_list) < n:
            n = nf0 + len(vf_list)
            if n < _OS_RUN_MIN:
                return m0
            stop = m0 + n
            pages = pages[:n]
            stored_f = stored_f[:n]
        nev = len(vf_list)
        vf = np.asarray(vf_list, dtype=np.int64)
        vdirty = self.dirty_of[vf] if nev else np.zeros(0, dtype=bool)
        # replay events in exact scalar order: per missing touch, flush
        # the compute accrued since the last fault, then the major fault,
        # then its eviction's write-back — one hoisted-locals loop
        st_l = stored_f.tolist()
        wb_l = [False] * nf0 + vdirty.tolist()
        flushes: list = [None] * n
        cur = ci
        for j in range(n):
            r = rows_l[m0 + j]
            if j == 0 or r > cur:
                flushes[j] = sum(costs[cur:r], sub)
                sub = 0.0
                cur = r
        rp.fault_run(flushes, st_l, wb_l)
        # vectorized bookkeeping: release victims, then assign frames in
        # scalar pairing order (free-list tail pops first, then victims)
        if nev:
            vq = self.page_of[vf]
            self.stored[vq[vdirty]] = True
            self.slot_of[vq] = -1
        if nf0:
            frames = self.free[-nf0:][::-1] + vf_list
            del self.free[-nf0:]
        else:
            frames = vf_list
        fr = np.asarray(frames, dtype=np.int64)
        self.slot_of[pages] = fr
        self.page_of[fr] = pages
        self.dirty_of[fr] = wm[m0:stop]
        self.last_touch[fr] = self.base + np.arange(m0, stop, dtype=np.int64)
        self.used += n - nev
        return stop

    def _touch(self, k: int, pg_l: list, fl_l: list) -> None:
        """One scalar touch: exactly ``_os_scalar``'s per-touch body."""
        p = pg_l[k]
        s = int(self.slot_of[p])
        if s < 0:
            rp = self.rp
            if self.stored[p]:
                rp.major_fault()
            while self.used >= self.nf:
                vf = self._evict_frame()
                vq = int(self.page_of[vf])
                if self.dirty_of[vf]:
                    self.dirty_of[vf] = False
                    self.stored[vq] = True
                    rp.writeback()
                self.slot_of[vq] = -1
                self.page_of[vf] = -1
                self.last_touch[vf] = INF
                self.free.append(vf)
                self.used -= 1
            s = self.free.pop()
            self.slot_of[p] = s
            self.page_of[s] = p
            self.dirty_of[s] = False
            self.used += 1
        self.last_touch[s] = self.base + k
        if fl_l[k] & W_WRITE:
            self.dirty_of[s] = True

    def process_chunk(self, m: int, offs: np.ndarray, pg: np.ndarray,
                      fl: np.ndarray, costs: list[float],
                      sub: float) -> float:
        """Transduce one chunk's touches; returns the pending compute."""
        T = pg.shape[0]
        if T:
            self._grow(int(pg.max()))
        rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(offs))
        wm = (fl & W_WRITE) != 0
        offs_l = offs.tolist()
        pg_l = pg.tolist()
        fl_l = fl.tolist()
        rows_l = rows.tolist()
        slot_of = self.slot_of
        ci = 0                     # first instruction not yet priced
        k = 0
        win = self.win
        while k < T:
            end = min(k + win, T)
            sl = slot_of[pg[k:end]]
            missrel = np.nonzero(sl < 0)[0]
            m0 = k + int(missrel[0]) if missrel.size else end
            if m0 > k:
                seg = slice(k, m0)
                ssl = sl[:m0 - k]
                # hits never evict, so the probe's verdict holds for the
                # whole clean prefix: batch the LRU/dirty bookkeeping
                self.last_touch[ssl] = self.base + np.arange(
                    k, m0, dtype=np.int64)
                self.dirty_of[ssl[wm[seg]]] = True
            if m0 < end:
                # maximal run of consecutive probe-miss touches with
                # pairwise-distinct pages: thrash traces fault on every
                # touch, and handling those runs one by one in Python is
                # what degenerated the array core to ~2-5x scalar — batch
                # them through _fault_run instead
                mrel = sl[m0 - k:]
                res = np.flatnonzero(mrel >= 0)
                stop = m0 + (int(res[0]) if res.size else len(mrel))
                if stop - m0 >= _OS_RUN_MIN:
                    stop = _unique_prefix(pg, m0, stop)
                done = m0
                if stop - m0 >= _OS_RUN_MIN:
                    done = self._fault_run(m0, stop, pg, wm, rows_l,
                                           costs, ci, sub)
                if done > m0:
                    ci = rows_l[done - 1]
                    sub = 0.0
                    if done == end:   # all-miss probe: thrash, widen
                        win = min(win * 2, _OS_PROBE_MAX)
                    else:
                        win = max(_OS_PROBE_MIN, min(win, 2 * (m0 - k + 8)))
                    k = done
                    continue
                i = rows_l[m0]
                self.rp.flush(sum(costs[ci:i], sub))
                sub = 0.0
                ci = i
                row_end = offs_l[i + 1]
                for kk in range(m0, row_end):
                    self._touch(kk, pg_l, fl_l)
                win = max(_OS_PROBE_MIN, min(win, 2 * (m0 - k + 8)))
                k = row_end
            else:
                k = end
                win = min(win * 2, _OS_PROBE_MAX)
        self.win = win
        self.base += T
        return sum(costs[ci:m], sub)


_OS_PROBE_MAX = 8192
_OS_PROBE_MIN = 32
#: below this, a fault run is not worth the vectorized setup
_OS_RUN_MIN = 8


def _unique_prefix(pg: np.ndarray, m0: int, stop: int) -> int:
    """Largest ``stop' <= stop`` such that ``pg[m0:stop']`` has pairwise
    distinct pages (the fault-run batcher's precondition: a duplicate
    would be a hit after its first occurrence faults the page in)."""
    run = pg[m0:stop]
    srt = np.argsort(run, kind="stable")
    v = run[srt]
    dup = v[1:] == v[:-1]
    if not dup.any():
        return stop
    # stable sort keeps equal pages in touch order, so srt[1:][dup] are
    # second-and-later occurrences; the earliest one ends the prefix
    return m0 + int(srt[1:][dup].min())


def simulate_os_paging(virtual_prog: Program | ProgramFile, cost: CostFn,
                       num_frames: int, page_bytes: int,
                       model: DeviceModel | None = None,
                       os_page_bytes: int | None = None,
                       chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                       core: str = "array") -> SimResult:
    """Demand paging over the virtual trace: the OS-swapping baseline.

    Reactive LRU with blocking major faults.  The OS works at its own page
    granularity (``os_page_bytes``, default = MAGE page size): faulting one
    MAGE-page worth of data costs ceil(page/os_page/readahead) blocking I/O
    clusters (Linux swap readahead) plus a per-OS-page fault overhead
    (trap + map + TLB).  Dirty evictions write back asynchronously but
    contend for the device.  No future knowledge (no dead-page drop, no
    planned prefetch) — that is exactly what MAGE adds.

    ``read_bytes``/``write_bytes`` report what the device actually
    transferred: fault clusters at OS readahead granularity (which can
    exceed the page size when the cluster count rounds up) and
    whole-page write-backs.

    Streaming-capable: the trace is consumed as chunks (a ``ProgramFile``
    is never materialized, and in-memory programs never grow a
    program-length touch sidecar), so the full §8.2 scenario path is
    O(frames + chunk) in simulator memory.
    """
    _check_core(core)
    if core == "array" and virtual_prog.num_vpages() >= ARRAY_MAX_VPAGES:
        # the array core keeps O(num_vpages) slot/stored vectors (the
        # analogue of replacement's per-page state); past this bound the
        # scalar core's dicts — O(touched pages) — are the leaner choice.
        # Results are identical either way.
        core = "scalar"
    model = model or DeviceModel()
    r = SimResult()
    rp = _OsReplay(model, page_bytes, os_page_bytes, r)
    if core == "scalar":
        _os_scalar(virtual_prog, cost, num_frames, rp, chunk_instrs)
    else:
        ac = _OsArrayCore(num_frames, rp)
        need_instrs = getattr(cost, "cost_chunk", None) is None
        sub = 0.0
        for head, offs, pg, fl, rec in iter_touch_chunks(
                virtual_prog, chunk_instrs, decode=need_instrs,
                records=True):
            if rec is not None and not need_instrs:
                m = head if isinstance(head, int) else len(head)
                costs = np.asarray(cost.cost_chunk(rec),
                                   dtype=np.float64).tolist()
            else:
                m = len(head)
                costs = [cost(i) for i in head]
            sub = ac.process_chunk(m, offs, pg, fl, costs, sub)
        rp.flush(sub)
    r.total = rp.t
    return r
