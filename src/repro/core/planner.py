"""MAGE's planner pipeline (§6.1): placement → replacement → scheduling.

``plan()`` turns a virtual-address bytecode into a memory program for a given
physical memory budget; ``PlanReport`` captures the Table-1 metrics (planning
time, planner peak memory) plus per-stage statistics.

Two execution modes share the same stage cores (so their outputs are
instruction-identical):

  * ``plan()``           — in-memory, for small programs and tests;
  * ``plan_streaming()`` — out-of-core: every stage reads the previous
    stage's bytecode file chunk-by-chunk and appends to the next, so planner
    peak memory is O(chunk + frames + lookahead) regardless of program
    length (the paper's Table-1 claim).
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import time
import tracemalloc

from .bytecode import Instr, Program, ProgramFile, decode_chunk, write_program
from .liveness import annotate_next_use
from .replacement import (ReplacementStats, plan_replacement,
                          plan_replacement_file, replacement_records)
from .scheduling import (ScheduleStats, plan_schedule, plan_schedule_file,
                         schedule_records)


@dataclasses.dataclass
class PlanConfig:
    """Memory budget + knobs (paper defaults: GC 64 KiB pages, l=10000, B=256
    pages; CKKS 2 MiB pages, l=100, B=16 — we express pages in slots).

    ``core`` selects the replacement/scheduling implementation: ``"array"``
    (default) runs the vectorized record-array cores, ``"scalar"`` the
    reference transducers.  Outputs are instruction-identical (tested
    bitwise), so the knob never changes a plan — only how fast it is made.
    """
    num_frames: int                 # T: physical frames incl. prefetch buffer
    lookahead: int = 10_000         # l
    prefetch_pages: int = 0         # B (0 = replacement-only planning)
    policy: str = "min"
    swap_bypass: bool = False       # beyond-paper read-from-write-buffer
    core: str = "array"             # array | scalar (same outputs)

    @property
    def replacement_frames(self) -> int:
        return self.num_frames - self.prefetch_pages


@dataclasses.dataclass
class PlanReport:
    placement_s: float = 0.0        # time spent tracing the DSL (if measured)
    annotate_s: float = 0.0         # streaming-only: backward next-use pass
    replacement_s: float = 0.0
    scheduling_s: float = 0.0
    peak_mem_bytes: int = 0
    replacement: ReplacementStats | None = None
    schedule: ScheduleStats | None = None

    @property
    def total_s(self) -> float:
        return (self.placement_s + self.annotate_s + self.replacement_s
                + self.scheduling_s)


def plan(virtual_prog: Program, cfg: PlanConfig,
         track_memory: bool = False) -> tuple[Program, PlanReport]:
    report = PlanReport()
    if cfg.prefetch_pages >= cfg.num_frames:
        raise ValueError("prefetch buffer must be smaller than the budget")
    if track_memory:
        tracemalloc.start()
    t0 = time.perf_counter()
    # Fused array pipeline: records chain between stages (one encode at
    # the front, one decode at the end).  Falls back to the staged path
    # when the array core cannot run this program/policy.
    fused = replacement_records(virtual_prog, cfg.replacement_frames,
                                cfg.policy) if cfg.core == "array" else None
    if fused is not None:
        phys_chunks, rstats = fused
        t1 = time.perf_counter()
        out: list[Instr] = []
        sstats = schedule_records(
            phys_chunks, cfg.lookahead, cfg.prefetch_pages,
            lambda c: out.extend(decode_chunk(c)),
            swap_bypass=cfg.swap_bypass)
        mem = Program(
            instrs=out, page_shift=virtual_prog.page_shift,
            protocol=virtual_prog.protocol, phase="memory",
            worker=virtual_prog.worker,
            num_workers=virtual_prog.num_workers,
            vspace_slots=virtual_prog.vspace_slots,
            num_frames=cfg.replacement_frames,
            prefetch_slots=max(cfg.prefetch_pages, 0),
            meta=dict(virtual_prog.meta))
        t2 = time.perf_counter()
    else:
        # the array core already proved it cannot run this program/policy
        # (or core="scalar" was asked for): the scalar stages are both
        # faster here and instruction-identical
        phys, rstats = plan_replacement(virtual_prog,
                                        cfg.replacement_frames,
                                        policy=cfg.policy, core="scalar")
        t1 = time.perf_counter()
        mem, sstats = plan_schedule(phys, cfg.lookahead,
                                    cfg.prefetch_pages,
                                    swap_bypass=cfg.swap_bypass,
                                    core="scalar")
        t2 = time.perf_counter()
    if track_memory:
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        report.peak_mem_bytes = peak
    report.replacement_s = t1 - t0
    report.scheduling_s = t2 - t1
    report.replacement = rstats
    report.schedule = sstats
    mem.meta["plan"] = dataclasses.asdict(cfg)
    return mem, report


def plan_unbounded(virtual_prog: Program) -> Program:
    """The Unbounded scenario: no budget, engine runs the virtual program."""
    return virtual_prog


def plan_memory_estimate(cfg: PlanConfig, chunk_instrs: int = 8192) -> int:
    """Upper-bound bytes of planner peak memory for one worker, O(frames).

    The streaming pipeline's state is the Table-1 bound
    O(chunk + frames + lookahead): per-frame bookkeeping (frame table,
    residency maps, MIN-policy heap entries — a few machine words each),
    the scheduler's lookahead window, and a handful of in-flight record
    chunks per stage.  The constants below are deliberately generous
    (~2x measured ``PlanReport.peak_mem_bytes``) so the admission
    controller errs toward under-, not over-, commitment."""
    from .bytecode import RECORD_BYTES
    per_frame = 128          # frame table + residency + heap, with slack
    chunks_in_flight = 8     # 3 stages x (read + write) + fused-core slack
    return (cfg.num_frames * per_frame
            + max(cfg.lookahead, 1) * 32
            + chunks_in_flight * chunk_instrs * RECORD_BYTES)


def plan_streaming(virtual: Program | ProgramFile, cfg: PlanConfig,
                   out_path: str | os.PathLike | None = None,
                   workdir: str | os.PathLike | None = None,
                   track_memory: bool = False,
                   chunk_instrs: int = 8192,
                   keep_intermediates: bool = False,
                   annotations: str | os.PathLike | None = None,
                   ) -> tuple[ProgramFile, PlanReport]:
    """Out-of-core planning: file-to-file stages, bounded planner memory.

    ``virtual`` is either an in-memory 'virtual' Program (serialized first,
    FREEs stripped) or an already-written 'virtual' ProgramFile.  Returns
    the memory program as a ProgramFile the streaming engine can execute
    directly.  Output is instruction-identical to ``plan()``.

    The caller owns the returned file: with ``workdir=None`` a fresh
    temporary directory is created to hold it (intermediates are always
    cleaned up, and the directory itself is removed if planning fails),
    but after a successful call it is the caller's to delete when done —
    the memory program can be far larger than RAM, so nothing here can
    decide its lifetime.  Pass ``out_path`` to place the result somewhere
    you already manage.

    ``annotations`` is an optional pre-computed next-use sidecar for
    ``virtual`` (as written by ``annotate_next_use``); when given, the
    backward annotation pass is skipped (``report.annotate_s == 0``) and
    the caller keeps ownership of the sidecar file — this is how the
    artifact cache replans a cached trace without re-annotating.
    """
    report = PlanReport()
    if cfg.prefetch_pages >= cfg.num_frames:
        raise ValueError("prefetch buffer must be smaller than the budget")
    made_workdir = workdir is None
    if made_workdir:
        workdir = tempfile.mkdtemp(prefix="mage_plan_")
    else:
        os.makedirs(workdir, exist_ok=True)
    vpath = os.path.join(workdir, "virtual.bc")
    apath = os.path.join(workdir, "virtual.ann")
    ppath = os.path.join(workdir, "physical.bc")
    mpath = os.fspath(out_path) if out_path is not None \
        else os.path.join(workdir, "memory.bc")

    if track_memory:
        tracemalloc.start()
    wrote_virtual = False
    done = False
    try:
        if isinstance(virtual, Program):
            virtual = write_program(virtual, vpath, strip_free=True,
                                    chunk_instrs=chunk_instrs)
            wrote_virtual = True
        assert virtual.phase == "virtual", virtual.phase

        t0 = time.perf_counter()
        if annotations is not None:
            apath = os.fspath(annotations)   # caller-owned: never unlinked
            t1 = t0                          # pass skipped: annotate_s == 0
        else:
            annotate_next_use(virtual, apath, chunk_instrs)
            t1 = time.perf_counter()
        phys, rstats = plan_replacement_file(
            virtual, ppath, cfg.replacement_frames, policy=cfg.policy,
            annotations=apath, chunk_instrs=chunk_instrs, core=cfg.core)
        t2 = time.perf_counter()
        mem, sstats = plan_schedule_file(
            phys, mpath, cfg.lookahead, cfg.prefetch_pages,
            swap_bypass=cfg.swap_bypass, chunk_instrs=chunk_instrs,
            meta={**dict(virtual.meta), "plan": dataclasses.asdict(cfg)},
            core=cfg.core)
        t3 = time.perf_counter()
        done = True
    finally:
        if track_memory:
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            report.peak_mem_bytes = peak
        if not done and made_workdir:
            shutil.rmtree(workdir, ignore_errors=True)
        elif not keep_intermediates:
            doomed = [vpath] if wrote_virtual else []
            if annotations is None:
                doomed.append(apath)
            doomed.append(ppath)
            for p in doomed:
                if os.path.exists(p):
                    os.unlink(p)
    report.annotate_s = t1 - t0
    report.replacement_s = t2 - t1
    report.scheduling_s = t3 - t2
    report.replacement = rstats
    report.schedule = sstats
    return mem, report
