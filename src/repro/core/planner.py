"""MAGE's planner pipeline (§6.1): placement → replacement → scheduling.

``plan()`` turns a virtual-address bytecode into a memory program for a given
physical memory budget; ``PlanReport`` captures the Table-1 metrics (planning
time, planner peak memory) plus per-stage statistics.
"""

from __future__ import annotations

import dataclasses
import time
import tracemalloc

from .bytecode import Program
from .replacement import ReplacementStats, plan_replacement
from .scheduling import ScheduleStats, plan_schedule


@dataclasses.dataclass
class PlanConfig:
    """Memory budget + knobs (paper defaults: GC 64 KiB pages, l=10000, B=256
    pages; CKKS 2 MiB pages, l=100, B=16 — we express pages in slots)."""
    num_frames: int                 # T: physical frames incl. prefetch buffer
    lookahead: int = 10_000         # l
    prefetch_pages: int = 0         # B (0 = replacement-only planning)
    policy: str = "min"
    swap_bypass: bool = False       # beyond-paper read-from-write-buffer

    @property
    def replacement_frames(self) -> int:
        return self.num_frames - self.prefetch_pages


@dataclasses.dataclass
class PlanReport:
    placement_s: float = 0.0        # time spent tracing the DSL (if measured)
    replacement_s: float = 0.0
    scheduling_s: float = 0.0
    peak_mem_bytes: int = 0
    replacement: ReplacementStats | None = None
    schedule: ScheduleStats | None = None

    @property
    def total_s(self) -> float:
        return self.placement_s + self.replacement_s + self.scheduling_s


def plan(virtual_prog: Program, cfg: PlanConfig,
         track_memory: bool = False) -> tuple[Program, PlanReport]:
    report = PlanReport()
    if cfg.prefetch_pages >= cfg.num_frames:
        raise ValueError("prefetch buffer must be smaller than the budget")
    if track_memory:
        tracemalloc.start()
    t0 = time.perf_counter()
    phys, rstats = plan_replacement(virtual_prog, cfg.replacement_frames,
                                    policy=cfg.policy)
    t1 = time.perf_counter()
    mem, sstats = plan_schedule(phys, cfg.lookahead, cfg.prefetch_pages,
                                swap_bypass=cfg.swap_bypass)
    t2 = time.perf_counter()
    if track_memory:
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        report.peak_mem_bytes = peak
    report.replacement_s = t1 - t0
    report.scheduling_s = t2 - t1
    report.replacement = rstats
    report.schedule = sstats
    mem.meta["plan"] = dataclasses.asdict(cfg)
    return mem, report


def plan_unbounded(virtual_prog: Program) -> Program:
    """The Unbounded scenario: no budget, engine runs the virtual program."""
    return virtual_prog
