"""MAGE planner: page-touch extraction and next-use annotation (§6.3).

The backward pass over the bytecode annotates, for every (instruction, page)
touch, when the page is touched next (``next_any``) and when it is next READ
(``next_read``).  Belady's MIN consumes ``next_any``; the write-back decision
consumes ``next_read``:

  * drop-on-evict is safe iff next_read == INF — no later instruction can
    observe the page, because any later read would have made next_read finite;
  * a swap-in on a residency miss is elided iff the touching instruction
    overwrites the whole page without reading it (write-allocate elision),
    or the page was previously dropped (in which case, by the argument above,
    its first later touch must be write-only).

Storage layout is CSR-style flat numpy arrays so the planner's own memory
stays linear in the bytecode with a small constant (§6.1: the planner cannot
benefit from MAGE's own techniques, so it must be lean).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bytecode import INF, Instr, Op, Program

W_WRITE = 1       # touch includes a write
W_READ = 2        # touch includes a read
W_FULL_WRITE = 4  # writes cover the whole page


@dataclasses.dataclass
class Touches:
    """Per-instruction page touches for a stripped (FREE-less) program."""
    offsets: np.ndarray    # [N+1] int64, CSR offsets into the arrays below
    pages: np.ndarray      # [T] int64
    flags: np.ndarray      # [T] int8 (W_* bits)
    next_any: np.ndarray   # [T] int64 (instruction index or INF)
    next_read: np.ndarray  # [T] int64
    num_pages: int

    def row(self, i: int) -> slice:
        return slice(int(self.offsets[i]), int(self.offsets[i + 1]))


def compute_touches(prog: Program, instrs: list[Instr]) -> Touches:
    shift = prog.page_shift
    psize = prog.page_slots

    offsets = [0]
    pages: list[int] = []
    flags: list[int] = []

    for ins in instrs:
        row: dict[int, int] = {}
        covered: dict[int, int] = {}
        for (addr, n), is_write in ins.spans():
            lo = addr >> shift
            hi = (addr + n - 1) >> shift
            for p in range(lo, hi + 1):
                f = row.get(p, 0)
                if is_write:
                    f |= W_WRITE
                    # slots of this page covered by the write
                    s = max(addr, p << shift)
                    e = min(addr + n, (p + 1) << shift)
                    covered[p] = covered.get(p, 0) + (e - s)
                else:
                    f |= W_READ
                row[p] = f
        for p, f in row.items():
            if (f & W_WRITE) and not (f & W_READ) and covered.get(p, 0) >= psize:
                f |= W_FULL_WRITE
            pages.append(p)
            flags.append(f)
        offsets.append(len(pages))

    offs = np.asarray(offsets, dtype=np.int64)
    pg = np.asarray(pages, dtype=np.int64)
    fl = np.asarray(flags, dtype=np.int8)

    # Backward pass: next touch / next read per (instruction, page).
    n_t = len(pg)
    next_any = np.full(n_t, INF, dtype=np.int64)
    next_read = np.full(n_t, INF, dtype=np.int64)
    last_any: dict[int, int] = {}
    last_read: dict[int, int] = {}
    for i in range(len(instrs) - 1, -1, -1):
        for k in range(int(offs[i]), int(offs[i + 1])):
            p = int(pg[k])
            next_any[k] = last_any.get(p, INF)
            next_read[k] = last_read.get(p, INF)
            last_any[p] = i
            if fl[k] & W_READ:
                last_read[p] = i

    num_pages = int(pg.max()) + 1 if n_t else 0
    return Touches(offs, pg, fl, next_any, next_read, num_pages)


def max_pages_per_instr(t: Touches) -> int:
    if len(t.offsets) <= 1:
        return 0
    return int(np.max(np.diff(t.offsets)))


def working_set_pages(t: Touches) -> int:
    """Peak number of simultaneously-live pages (w in §2.4.3, page units).

    A page is live between its first touch and its last touch.
    """
    if t.num_pages == 0:
        return 0
    first = np.full(t.num_pages, -1, dtype=np.int64)
    last = np.zeros(t.num_pages, dtype=np.int64)
    n_instr = len(t.offsets) - 1
    for i in range(n_instr):
        for k in range(int(t.offsets[i]), int(t.offsets[i + 1])):
            p = int(t.pages[k])
            if first[p] < 0:
                first[p] = i
            last[p] = i
    delta = np.zeros(n_instr + 1, dtype=np.int64)
    for p in range(t.num_pages):
        if first[p] >= 0:
            delta[first[p]] += 1
            delta[last[p] + 1] -= 1
    return int(np.max(np.cumsum(delta))) if n_instr else 0
