"""MAGE planner: page-touch extraction and next-use annotation (§6.3).

The backward pass over the bytecode annotates, for every (instruction, page)
touch, when the page is touched next (``next_any``) and when it is next READ
(``next_read``).  Belady's MIN consumes ``next_any``; the write-back decision
consumes ``next_read``:

  * drop-on-evict is safe iff next_read == INF — no later instruction can
    observe the page, because any later read would have made next_read finite;
  * a swap-in on a residency miss is elided iff the touching instruction
    overwrites the whole page without reading it (write-allocate elision),
    or the page was previously dropped (in which case, by the argument above,
    its first later touch must be write-only).

Storage layout is CSR-style flat numpy arrays so the planner's own memory
stays linear in the bytecode with a small constant (§6.1: the planner cannot
benefit from MAGE's own techniques, so it must be lean).
"""

from __future__ import annotations

import dataclasses
import os
import struct

import numpy as np

from .bytecode import (DEFAULT_CHUNK_INSTRS, INF, MAX_INS, MAX_OUTS,
                       _IN_OFF, _OUT_OFF, Instr, Op, Program, ProgramFile,
                       decode_chunk, encode_chunk, strip_frees, unpack_heads)

W_WRITE = 1       # touch includes a write
W_READ = 2        # touch includes a read
W_FULL_WRITE = 4  # writes cover the whole page


@dataclasses.dataclass
class Touches:
    """Per-instruction page touches for a stripped (FREE-less) program."""
    offsets: np.ndarray    # [N+1] int64, CSR offsets into the arrays below
    pages: np.ndarray      # [T] int64
    flags: np.ndarray      # [T] int8 (W_* bits)
    next_any: np.ndarray   # [T] int64 (instruction index or INF)
    next_read: np.ndarray  # [T] int64
    num_pages: int

    def row(self, i: int) -> slice:
        return slice(int(self.offsets[i]), int(self.offsets[i + 1]))


def compute_touches(prog: Program, instrs: list[Instr]) -> Touches:
    shift = prog.page_shift
    psize = prog.page_slots

    offsets = [0]
    pages: list[int] = []
    flags: list[int] = []

    for ins in instrs:
        row: dict[int, int] = {}
        covered: dict[int, int] = {}
        for (addr, n), is_write in ins.spans():
            lo = addr >> shift
            hi = (addr + n - 1) >> shift
            for p in range(lo, hi + 1):
                f = row.get(p, 0)
                if is_write:
                    f |= W_WRITE
                    # slots of this page covered by the write
                    s = max(addr, p << shift)
                    e = min(addr + n, (p + 1) << shift)
                    covered[p] = covered.get(p, 0) + (e - s)
                else:
                    f |= W_READ
                row[p] = f
        for p, f in row.items():
            if (f & W_WRITE) and not (f & W_READ) and covered.get(p, 0) >= psize:
                f |= W_FULL_WRITE
            pages.append(p)
            flags.append(f)
        offsets.append(len(pages))

    offs = np.asarray(offsets, dtype=np.int64)
    pg = np.asarray(pages, dtype=np.int64)
    fl = np.asarray(flags, dtype=np.int8)

    # Backward pass: next touch / next read per (instruction, page).
    n_t = len(pg)
    next_any = np.full(n_t, INF, dtype=np.int64)
    next_read = np.full(n_t, INF, dtype=np.int64)
    last_any: dict[int, int] = {}
    last_read: dict[int, int] = {}
    for i in range(len(instrs) - 1, -1, -1):
        for k in range(int(offs[i]), int(offs[i + 1])):
            p = int(pg[k])
            next_any[k] = last_any.get(p, INF)
            next_read[k] = last_read.get(p, INF)
            last_any[p] = i
            if fl[k] & W_READ:
                last_read[p] = i

    num_pages = int(pg.max()) + 1 if n_t else 0
    return Touches(offs, pg, fl, next_any, next_read, num_pages)


def stripped_touches(prog: Program, instrs: list[Instr] | None = None
                     ) -> tuple[list[Instr], Touches]:
    """THE strip-FREEs-then-extract-touches entry point.

    Every consumer that needs a program's page-touch structure
    (replacement, the OS-paging baseline, working-set sizing) goes through
    here instead of hand-rolling the ``strip_frees`` + ``compute_touches``
    pair."""
    if instrs is None:
        instrs = strip_frees(prog.instrs)
    return instrs, compute_touches(prog, instrs)


# ---------------------------------------------------------------------------
# Streaming annotation (§6.3's single backward pass, out-of-core).
#
# ``annotate_next_use`` scans a bytecode file's chunks in *reverse* file
# order and writes a fixed-width sidecar: for every instruction, its page
# touches with (page, flags, next_any, next_read).  Because the records are
# fixed width, the sidecar chunk for instructions [s, s+m) is written at
# offset s while the program is scanned backward — the planner never holds
# more than one chunk plus an O(live pages) carry dict.  The per-chunk math
# is vectorized NumPy (lexsort + segmented scans), replacing the
# per-instruction Python loop of ``compute_touches`` on the hot path.
# ---------------------------------------------------------------------------

ANN_MAGIC = b"MAGEAN01"
ANN_TOUCH_SLOTS = MAX_INS + MAX_OUTS
ANN_WORDS = 1 + 4 * ANN_TOUCH_SLOTS
ANN_BYTES = ANN_WORDS * 8
_ANN_HEADER = struct.Struct("<8s4qQ")


_DIGEST_MIX = np.uint64(0x9E3779B97F4A7C15)   # golden-ratio odd constant


def records_digest(acc: int, arr: np.ndarray, start: int) -> int:
    """XOR-combine per-record hashes of a record chunk into ``acc``.

    Each record hashes from its content and its *global* index only, and
    records combine by XOR — so the digest is independent of chunk size
    and of visit order.  That lets the reverse annotation scan and the
    forward replacement scan (possibly using different chunk_instrs)
    agree on it, which is how a stale sidecar is detected even when
    record counts happen to match (see plan_replacement_file)."""
    if arr.shape[0] == 0:
        return acc
    u = arr.view(np.uint64)
    w = (np.arange(1, arr.shape[1] + 1, dtype=np.uint64) * _DIGEST_MIX) | 1
    rows = (u * w).sum(axis=1, dtype=np.uint64)
    rows ^= np.arange(start, start + arr.shape[0],
                      dtype=np.uint64) * _DIGEST_MIX
    rows *= _DIGEST_MIX                      # finalize: mix high bits down
    rows ^= rows >> np.uint64(33)
    return acc ^ int(np.bitwise_xor.reduce(rows))


def file_digest(pf: ProgramFile,
                chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> int:
    """Fold :func:`records_digest` over a whole program file.  Chunk-size
    independent, so two files digest equal iff their records are
    bitwise-identical — the array-vs-scalar core gate in tests and
    ``table1_planning.py --cores``."""
    d = 0
    for s, arr in pf.iter_chunks(chunk_instrs):
        d = records_digest(d, arr, s)
    return d


@dataclasses.dataclass
class AnnotationInfo:
    path: str
    n_records: int
    num_pages: int
    max_touches: int
    prog_crc: int = 0


def _chunk_touches(rec: np.ndarray, shift: int, psize: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized page-touch extraction for one record chunk.

    Returns (pages, flags, present) of shape [m, ANN_TOUCH_SLOTS], slots
    ordered ins-then-outs with per-instruction duplicates merged into the
    first occurrence — byte-compatible with ``compute_touches``'s dict walk.
    """
    m = rec.shape[0]
    ops, n_outs, n_ins, _ = unpack_heads(rec[:, 0])
    if np.any(ops == int(Op.FREE)):
        raise ValueError(
            "bytecode file contains FREE pseudo-instructions; write it with "
            "write_program(..., strip_free=True) before planning")
    S = ANN_TOUCH_SLOTS
    pages = np.full((m, S), -1, dtype=np.int64)
    flags = np.zeros((m, S), dtype=np.int64)
    covered = np.zeros((m, S), dtype=np.int64)
    present = np.zeros((m, S), dtype=bool)

    def fill(slot: int, sel: np.ndarray, addr: np.ndarray, n: np.ndarray,
             is_write: bool) -> None:
        sel = sel & (n > 0)
        if not sel.any():
            return
        pg = addr >> shift
        hi = (addr + n - 1) >> shift
        if np.any(sel & (hi != pg)):
            raise ValueError(
                "operand span straddles a page boundary; the streaming "
                "planner requires the §6.2.2 invariant (use the in-memory "
                "planner for straddling spans)")
        pages[sel, slot] = pg[sel]
        flags[sel, slot] = W_WRITE if is_write else W_READ
        if is_write:
            covered[sel, slot] = n[sel]
        present[:, slot] |= sel

    for j in range(MAX_INS):
        fill(j, n_ins > j, rec[:, _IN_OFF + 2 * j],
             rec[:, _IN_OFF + 2 * j + 1], False)
    for j in range(MAX_OUTS):
        fill(MAX_INS + j, n_outs > j, rec[:, _OUT_OFF + 2 * j],
             rec[:, _OUT_OFF + 2 * j + 1], True)

    # merge duplicate pages within an instruction into the first slot
    for j in range(1, S):
        un = present[:, j].copy()
        if not un.any():
            continue
        for k in range(j):
            mm = un & present[:, k] & (pages[:, j] == pages[:, k])
            if mm.any():
                flags[mm, k] |= flags[mm, j]
                covered[mm, k] += covered[mm, j]
                un &= ~mm
        present[:, j] = un

    fw = (present & ((flags & W_WRITE) != 0) & ((flags & W_READ) == 0)
          & (covered >= psize))
    flags[fw] |= W_FULL_WRITE
    return pages, flags, present


def flat_touches(rec: np.ndarray, shift: int, psize: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """CSR-style touch extraction for one record chunk.

    Returns ``(counts, rows, pages, flags)``: per-instruction touch counts
    plus flat per-touch arrays in touch order (the order ``compute_touches``
    produces).  Shared by the annotation pass, the record-array replacement
    core, and the streaming OS-paging simulator."""
    pages, flags, present = _chunk_touches(rec, shift, psize)
    counts = present.sum(axis=1).astype(np.int64)
    rows, slots = np.nonzero(present)           # row-major: touch order
    return counts, rows.astype(np.int64), pages[rows, slots], \
        flags[rows, slots]


class _NextUseCarry:
    """Earliest known next-touch / next-read per page across the already-
    visited (later) chunks of the reverse scan.

    Dense grow-on-demand int64 arrays instead of int→int dicts: page ids
    are small consecutive integers here, so a direct gather/scatter
    replaces millions of boxed-int dict probes on paper-scale traces
    (same doubling pattern as ``working_set_pages_stream``)."""

    __slots__ = ("any", "read")

    def __init__(self, cap: int = 1024):
        self.any = np.full(cap, INF, dtype=np.int64)
        self.read = np.full(cap, INF, dtype=np.int64)

    def ensure(self, max_page: int) -> None:
        cap = self.any.shape[0]
        if max_page < cap:
            return
        grow = max(max_page + 1, 2 * cap)
        for name in self.__slots__:
            arr = np.full(grow, INF, dtype=np.int64)
            arr[:cap] = getattr(self, name)
            setattr(self, name, arr)


def _chunk_next_use(tl_page: np.ndarray, tl_flags: np.ndarray,
                    gi: np.ndarray, carry: _NextUseCarry
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized next_any/next_read for one chunk's flat touch list.

    ``gi`` is the global instruction index per touch; chunks must be
    visited in *reverse* program order — ``carry`` holds the earliest
    known next-touch / next-read per page across already-visited (later)
    chunks and is updated in place."""
    nt = len(gi)
    t_any = np.empty(nt, dtype=np.int64)
    t_read = np.empty(nt, dtype=np.int64)
    if nt == 0:
        return t_any, t_read
    order = np.lexsort((gi, tl_page))
    spage, sgi = tl_page[order], gi[order]
    sread = (tl_flags[order] & W_READ) != 0
    seg_start = np.empty(nt, dtype=bool)
    seg_start[0] = True
    seg_start[1:] = spage[1:] != spage[:-1]
    seg_id = np.cumsum(seg_start) - 1
    seg_first = np.where(seg_start)[0]
    upages = spage[seg_first]
    carry.ensure(int(upages[-1]))          # upages is sorted ascending

    has_next = np.zeros(nt, dtype=bool)
    has_next[:-1] = spage[:-1] == spage[1:]
    nxt_in_chunk = np.empty(nt, dtype=np.int64)
    nxt_in_chunk[:-1] = sgi[1:]
    nxt_in_chunk[-1] = INF
    s_any = np.where(has_next, nxt_in_chunk, carry.any[upages][seg_id])

    # suffix-min of read positions within each page segment
    sent = nt
    idx = np.arange(nt, dtype=np.int64)
    rd_pos = np.where(sread, idx, sent)
    big = nt + 2
    key = seg_id * big + rd_pos
    incl = np.minimum.accumulate(key[::-1])[::-1] - seg_id * big
    excl = np.full(nt, sent, dtype=np.int64)
    excl[:-1] = np.where(has_next[:-1], incl[1:], sent)
    s_read = np.where(excl < sent,
                      sgi[np.minimum(excl, nt - 1)],
                      carry.read[upages][seg_id])

    t_any[order] = s_any
    t_read[order] = s_read

    # carries: this chunk is *earlier* in the program than everything
    # processed so far
    first_rd = incl[seg_first]
    carry.any[upages] = sgi[seg_first]
    has_rd = first_rd < sent
    carry.read[upages[has_rd]] = sgi[first_rd[has_rd]]
    return t_any, t_read


def annotate_next_use(pf: ProgramFile, ann_path: str | os.PathLike,
                      chunk_instrs: int = DEFAULT_CHUNK_INSTRS
                      ) -> AnnotationInfo:
    """The streaming backward pass: write the next-use sidecar for ``pf``."""
    ann_path = os.fspath(ann_path)
    shift = pf.page_shift
    psize = pf.page_slots
    carry = _NextUseCarry()
    num_pages = 0
    max_touches = 0
    crc = 0
    with open(ann_path, "wb") as f:
        f.write(_ANN_HEADER.pack(ANN_MAGIC, 0, ANN_WORDS, 0, 0, 0))
        f.truncate(_ANN_HEADER.size + pf.num_records * ANN_BYTES)
        for start, rec in pf.iter_chunks(chunk_instrs, reverse=True):
            m = rec.shape[0]
            crc = records_digest(crc, rec, start)
            counts, rows, tl_page, tl_flags = flat_touches(rec, shift, psize)
            nt = len(rows)
            ann = np.zeros((m, ANN_WORDS), dtype=np.int64)
            ann[:, 0] = counts
            if nt:
                t_any, t_read = _chunk_next_use(tl_page, tl_flags,
                                                start + rows, carry)
                row_start = np.zeros(m, dtype=np.int64)
                np.cumsum(counts[:-1], out=row_start[1:])
                ordinal = np.arange(nt, dtype=np.int64) - \
                    np.repeat(row_start, counts)
                flat = ann.reshape(-1)
                base = rows * ANN_WORDS + 1 + ordinal * 4
                flat[base] = tl_page
                flat[base + 1] = tl_flags
                flat[base + 2] = t_any
                flat[base + 3] = t_read
                num_pages = max(num_pages, int(tl_page.max()) + 1)
                max_touches = max(max_touches, int(counts.max()))
            f.seek(_ANN_HEADER.size + start * ANN_BYTES)
            f.write(ann.tobytes())
        f.seek(0)
        f.write(_ANN_HEADER.pack(ANN_MAGIC, pf.num_records, ANN_WORDS,
                                 num_pages, max_touches, crc))
    return AnnotationInfo(ann_path, pf.num_records, num_pages, max_touches,
                          crc)


def touches_from_records(rec: np.ndarray, shift: int, psize: int,
                         chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> Touches:
    """Vectorized in-memory ``compute_touches`` over encoded records.

    Runs the exact per-chunk math of :func:`annotate_next_use` as a reverse
    scan over slices of an in-memory record array — same touch order, same
    next-use values, no sidecar file.  Raises ``ValueError`` on programs the
    record format cannot express (page-straddling spans, FREEs); callers
    fall back to the scalar :func:`compute_touches`."""
    n = rec.shape[0]
    carry = _NextUseCarry()
    parts = []
    for s in reversed(range(0, n, chunk_instrs)):
        sub = rec[s:s + chunk_instrs]
        counts, rows, pg, fl = flat_touches(sub, shift, psize)
        t_any, t_read = _chunk_next_use(pg, fl, s + rows, carry)
        parts.append((counts, pg, fl, t_any, t_read))
    parts.reverse()
    if parts:
        counts = np.concatenate([p[0] for p in parts])
        pg = np.concatenate([p[1] for p in parts])
        fl = np.concatenate([p[2] for p in parts])
        nxt = np.concatenate([p[3] for p in parts])
        nxr = np.concatenate([p[4] for p in parts])
    else:
        counts = np.zeros(0, dtype=np.int64)
        pg = np.zeros(0, dtype=np.int64)
        fl = np.zeros(0, dtype=np.int64)
        nxt = np.zeros(0, dtype=np.int64)
        nxr = np.zeros(0, dtype=np.int64)
    offs = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    num_pages = int(pg.max()) + 1 if len(pg) else 0
    return Touches(offs, pg, fl.astype(np.int8), nxt, nxr, num_pages)


def iter_touch_chunks(prog: Program | ProgramFile,
                      chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                      decode: bool = True, records: bool = False):
    """Yield ``(instrs, offsets, pages, flags)`` per chunk, FREE-stripped.

    THE shared touch-iteration helper for chunk-streaming consumers (the
    OS-paging simulator, working-set sizing): O(chunk) memory for a
    ProgramFile; in-memory Programs are encoded chunk-by-chunk (falling
    back to a ``compute_touches`` slice for chunks the record format
    cannot express, e.g. page-straddling spans).  ``decode=False`` yields
    the chunk's instruction COUNT in place of the instruction list, so
    touch-only consumers skip the per-instruction Instr construction.

    ``records=True`` appends the chunk's [m, RECORD_WORDS] record array
    as a fifth element (what the array simulator core prices with one
    ``cost_chunk`` call).  On an in-memory fallback chunk the record
    array is ``None`` and the instruction list is yielded regardless of
    ``decode`` — consumers price those chunks with the scalar cost."""
    shift, psize = prog.page_shift, prog.page_slots
    if not hasattr(prog, "instrs"):
        for _s, rec in prog.iter_chunks(chunk_instrs):
            counts, _rows, pg, fl = flat_touches(rec, shift, psize)
            offs = np.zeros(rec.shape[0] + 1, dtype=np.int64)
            np.cumsum(counts, out=offs[1:])
            head = decode_chunk(rec) if decode else rec.shape[0]
            yield (head, offs, pg, fl, rec) if records \
                else (head, offs, pg, fl)
        return
    instrs = strip_frees(prog.instrs)
    for s in range(0, len(instrs), chunk_instrs):
        sub = instrs[s:s + chunk_instrs]
        rec = None
        try:
            rec = encode_chunk(sub)
            counts, _rows, pg, fl = flat_touches(rec, shift, psize)
            offs = np.zeros(len(sub) + 1, dtype=np.int64)
            np.cumsum(counts, out=offs[1:])
        except (TypeError, ValueError):
            rec = None
            t = compute_touches(prog, sub)
            offs, pg, fl = t.offsets, t.pages, t.flags
        head = sub if (decode or (records and rec is None)) else len(sub)
        yield (head, offs, pg, fl, rec) if records else (head, offs, pg, fl)


class AnnotationReader:
    """Forward chunk reader for the next-use sidecar."""

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        with open(self.path, "rb") as f:
            magic, n, words, num_pages, max_touches, crc = \
                _ANN_HEADER.unpack(f.read(_ANN_HEADER.size))
        if magic != ANN_MAGIC or words != ANN_WORDS:
            raise ValueError(f"not a MAGE annotation file: {self.path}")
        self.n_records = n
        self.num_pages = num_pages
        self.max_touches = max_touches
        self.prog_crc = crc

    def iter_chunks(self, chunk_instrs: int = DEFAULT_CHUNK_INSTRS):
        with open(self.path, "rb") as f:
            for s in range(0, self.n_records, chunk_instrs):
                m = min(chunk_instrs, self.n_records - s)
                f.seek(_ANN_HEADER.size + s * ANN_BYTES)
                raw = f.read(m * ANN_BYTES)
                yield s, np.frombuffer(raw, dtype=np.int64).reshape(
                    m, ANN_WORDS)


def max_pages_per_instr(t: Touches) -> int:
    if len(t.offsets) <= 1:
        return 0
    return int(np.max(np.diff(t.offsets)))


def working_set_pages_stream(prog: Program | ProgramFile,
                             chunk_instrs: int = DEFAULT_CHUNK_INSTRS) -> int:
    """Peak simultaneously-live pages (w of §2.4.3), from chunked touches.

    The streaming counterpart of :func:`working_set_pages`: O(pages +
    chunk) memory and array-speed, so budget resolution stays cheap on
    paper-scale traces."""
    first = np.full(1024, INF, dtype=np.int64)
    last = np.full(1024, -1, dtype=np.int64)
    base = 0
    for m, offs, pages, _flags in iter_touch_chunks(prog, chunk_instrs,
                                                    decode=False):
        if len(pages):
            mp = int(pages.max())
            if mp >= first.shape[0]:
                grow = max(mp + 1, 2 * first.shape[0])
                f2 = np.full(grow, INF, dtype=np.int64)
                f2[:first.shape[0]] = first
                first = f2
                l2 = np.full(grow, -1, dtype=np.int64)
                l2[:last.shape[0]] = last
                last = l2
            gi = base + np.repeat(np.arange(m, dtype=np.int64),
                                  np.diff(offs))
            np.minimum.at(first, pages, gi)
            np.maximum.at(last, pages, gi)
        base += m
    valid = last >= 0
    if base == 0 or not valid.any():
        return 0
    delta = np.zeros(base + 1, dtype=np.int64)
    np.add.at(delta, first[valid], 1)
    np.add.at(delta, last[valid] + 1, -1)
    return int(np.cumsum(delta).max())


def working_set_pages(t: Touches) -> int:
    """Peak number of simultaneously-live pages (w in §2.4.3, page units).

    A page is live between its first touch and its last touch.
    """
    if t.num_pages == 0:
        return 0
    first = np.full(t.num_pages, -1, dtype=np.int64)
    last = np.zeros(t.num_pages, dtype=np.int64)
    n_instr = len(t.offsets) - 1
    for i in range(n_instr):
        for k in range(int(t.offsets[i]), int(t.offsets[i + 1])):
            p = int(t.pages[k])
            if first[p] < 0:
                first[p] = i
            last[p] = i
    delta = np.zeros(n_instr + 1, dtype=np.int64)
    for p in range(t.num_pages):
        if first[p] >= 0:
            delta[first[p]] += 1
            delta[last[p] + 1] -= 1
    return int(np.max(np.cumsum(delta))) if n_instr else 0
