"""The transport fabric: ONE communication API for every byte the repro
moves between engines (§5.2 deployment model).

MAGE deploys one engine per worker per party across machines; intra-party
network directives (NET_*) and inter-party protocol traffic (garbled
tables, OT messages) are both just tagged point-to-point transfers.  This
module is that abstraction: a :class:`Transport` carries numpy arrays
between integer-ranked *endpoints* over ``(src, dst, tag)`` links with
per-link byte/message accounting, and everything above it — the engine's
NET_* handling, the garbled protocol's party stream, the CLI's
multi-process fleet — is expressed against the same five calls::

    connect()  send(src, dst, tag, arr)  recv(src, dst, tag)  barrier()  close()

Three registered backends:

* ``inproc`` — per-link locked reorder buffers (the successor of the old
  ``Channels`` queues; out-of-order tags now buffer and match instead of
  raising, and byte accounting is lock-protected — safe across engine
  threads).
* ``tcp``    — length-prefixed frames over sockets, one outbound
  connection per peer plus a background reader thread per inbound
  connection feeding the same reorder buffers, so tags may arrive in any
  order and a blocked receiver never stops the wire (the reader keeps
  draining, which is what makes symmetric send-then-recv exchanges
  deadlock-free over real sockets).
* ``shaped`` — a decorator adding configurable per-link latency and
  bandwidth on top of another (same-process) transport: messages carry a
  virtual delivery time computed with pipelined link occupancy (serialize
  at ``bandwidth``, deliver ``latency`` later), and ``recv`` sleeps until
  that time.  This turns fig11's WAN model into *measured* traffic over a
  shaped link (§8.7).

Rank space: a fabric with P parties × W workers has ``P*W`` endpoints,
``rank = party * W + worker``.  :class:`PartyView` scopes a transport to
one party's contiguous rank block so the engine keeps addressing peers by
worker id.  Endpoint-to-process placement is a :class:`FabricSpec`:
``rank=None`` hosts every endpoint in this process (threads — today's
behavior); ``rank=k`` hosts exactly one endpoint and reaches the rest via
``peers`` addresses (``python -m repro run --worker k --peers ...``).

Message ordering contract: per ``(src, dst, tag)`` FIFO.  Distinct tags on
the same link may be consumed in any order (they buffer independently) —
both the bitonic exchanges and the garbled kind-streams rely only on
per-tag FIFO, so the contract is exactly as strong as the programs need.

Accounting contract: ``stats()`` records traffic at the *sending*
endpoint, keyed ``(src, dst, tag)`` — aggregate with
:func:`aggregate_links`.  Counters are mutated under a lock (engine
threads share one transport in-process).
"""

from __future__ import annotations

import dataclasses
import socket
import struct
import threading
import time
from collections import deque
from typing import Callable, Iterable, Sequence

import numpy as np

__all__ = [
    "Transport", "InprocTransport", "TcpTransport", "ShapedTransport",
    "Fabric", "FabricSpec", "PartyView", "LinkStats", "ReorderStats",
    "Completion", "TransportError", "TransportClosed", "build_fabric",
    "register_transport", "aggregate_links", "pick_free_ports",
    "TRANSPORTS",
]


class TransportError(RuntimeError):
    pass


class TransportClosed(TransportError):
    """The link closed (peer gone) with a receive still outstanding."""


@dataclasses.dataclass
class LinkStats:
    messages: int = 0
    bytes: int = 0


@dataclasses.dataclass
class ReorderStats:
    """One link's reorder-buffer occupancy snapshot (receive side)."""
    pending_msgs: int = 0
    pending_bytes: int = 0
    peak_msgs: int = 0
    peak_bytes: int = 0
    max_msgs: int = 0             # configured bound (0 = unbounded)
    max_bytes: int = 0


def _links_reorder_stats(links: dict, lock: threading.Lock
                         ) -> dict[tuple[int, int], ReorderStats]:
    with lock:
        items = list(links.items())
    out = {}
    for key, ln in items:
        with ln._cond:
            out[key] = ReorderStats(ln._pending_msgs, ln._pending_bytes,
                                    ln.peak_msgs, ln.peak_bytes,
                                    ln.max_msgs, ln.max_bytes)
    return out


#: reserved tag ranges (ordinary tags are small non-negative ints: the DSL's
#: fresh_tag counter and the garbled kind tags) — barriers use deeply
#: negative tags so they can never collide with data on the same link.
_ENGINE_BARRIER_BASE = -(1 << 40)
_FABRIC_BARRIER_BASE = -(1 << 50)


class _StatsBook:
    """Lock-protected (src, dst, tag) → LinkStats counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._m: dict[tuple[int, int, int], LinkStats] = {}

    def add(self, key: tuple[int, int, int], nbytes: int) -> None:
        with self._lock:
            s = self._m.get(key)
            if s is None:
                s = self._m[key] = LinkStats()
            s.messages += 1
            s.bytes += nbytes

    def snapshot(self) -> dict[tuple[int, int, int], LinkStats]:
        with self._lock:
            return {k: LinkStats(v.messages, v.bytes)
                    for k, v in self._m.items()}


def aggregate_links(stats: dict[tuple[int, int, int], LinkStats]
                    ) -> dict[tuple[int, int], LinkStats]:
    """(src, dst, tag) stats → per-(src, dst) link totals."""
    out: dict[tuple[int, int], LinkStats] = {}
    for (src, dst, _tag), s in stats.items():
        t = out.setdefault((src, dst), LinkStats())
        t.messages += s.messages
        t.bytes += s.bytes
    return out


class _Link:
    """One (src, dst) lane: a locked per-tag reorder buffer.

    Out-of-order tags buffer and match (the old ``Channels.recv`` raised on
    mismatch); ``max_msgs``/``max_bytes`` bound the pending set so a
    producer running far ahead blocks instead of materializing everything
    (§2.4.2 pipelining for the garbled stream, reader-thread backpressure
    for TCP)."""

    def __init__(self, max_msgs: int = 0, max_bytes: int = 0):
        self._cond = threading.Condition()
        self._by_tag: dict[int, deque] = {}
        self._pending_msgs = 0
        self._pending_bytes = 0
        self.peak_msgs = 0            # high-water marks: the counters that
        self.peak_bytes = 0           # *verify* the depth knobs bounded memory
        self.max_msgs = max_msgs
        self.max_bytes = max_bytes
        self.closed = False

    def _over(self) -> bool:
        return ((self.max_msgs and self._pending_msgs >= self.max_msgs) or
                (self.max_bytes and self._pending_bytes >= self.max_bytes))

    def put(self, tag: int, data: np.ndarray) -> None:
        with self._cond:
            while self._over() and not self.closed:
                self._cond.wait()
            if self.closed:
                raise TransportClosed("send on closed link")
            self._by_tag.setdefault(tag, deque()).append(data)
            self._pending_msgs += 1
            self._pending_bytes += data.nbytes
            self.peak_msgs = max(self.peak_msgs, self._pending_msgs)
            self.peak_bytes = max(self.peak_bytes, self._pending_bytes)
            self._cond.notify_all()

    def get(self, tag: int, timeout: float | None = None) -> np.ndarray:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                q = self._by_tag.get(tag)
                if q:
                    data = q.popleft()
                    if not q:
                        del self._by_tag[tag]
                    self._pending_msgs -= 1
                    self._pending_bytes -= data.nbytes
                    self._cond.notify_all()
                    return data
                if self.closed:
                    raise TransportClosed(
                        f"link closed with recv(tag={tag}) outstanding")
                if deadline is None:
                    self._cond.wait()
                else:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        raise TransportError(
                            f"recv(tag={tag}) timed out after {timeout}s")
                    self._cond.wait(left)

    def close(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()


class Completion:
    """Handle for an asynchronous transport operation.

    ``send_async`` returns an already-completed handle (sends hand their
    bytes to the fabric eagerly); ``recv_async`` returns a *deferred*
    receive: the message stays in the link's reorder buffer — and, on a
    shaped link, its virtual delivery-time sleep stays unpaid — until
    :meth:`wait` runs the underlying blocking ``recv``.  Deferring the
    completion to the instruction that actually needs the data is what
    lets the overlap engine hide WAN latency behind local compute.

    Ordering contract: handles for the same ``(src, dst, tag)`` channel
    must be waited in the order they were created (per-tag FIFO is
    resolved at wait time).  The planned overlap pass enforces this with
    channel-order edges; ad-hoc users must do the same.

    ``wait`` is idempotent (the payload is cached) but not safe to call
    from two threads at once — a handle belongs to its issuing engine."""

    __slots__ = ("_thunk", "_result", "_err_prefix")

    def __init__(self, thunk: Callable[[], "np.ndarray | None"] | None,
                 err_prefix: str = ""):
        self._thunk = thunk
        self._result: np.ndarray | None = None
        self._err_prefix = err_prefix

    @classmethod
    def completed(cls, result: "np.ndarray | None" = None) -> "Completion":
        c = cls(None)
        c._result = result
        return c

    def done(self) -> bool:
        """True once :meth:`wait` has completed (never before for a
        deferred receive — the payload is not consumed early)."""
        return self._thunk is None

    def wait(self) -> "np.ndarray | None":
        """Complete the operation; blocks (and, on shaped links, sleeps
        out the virtual delivery time) until the payload is available."""
        if self._thunk is not None:
            thunk, self._thunk = self._thunk, None
            try:
                self._result = thunk()
            except TransportError as e:
                if self._err_prefix:
                    raise TransportError(f"{self._err_prefix}{e}") from e
                raise
        return self._result


class Transport:
    """Abstract fabric: tagged point-to-point array transfer between
    integer-ranked endpoints."""

    name = "abstract"

    def connect(self) -> None:
        """Establish links; must be called before send/recv on distributed
        backends (no-op for in-process ones)."""

    def send(self, src: int, dst: int, tag: int, data: np.ndarray,
             copy: bool = True) -> None:
        """``copy=False`` lets a sender that never mutates ``data`` again
        (e.g. the garbled stream's freshly built tables) skip the
        defensive snapshot on in-process backends."""
        raise NotImplementedError

    def recv(self, src: int, dst: int, tag: int,
             out: np.ndarray | None = None,
             timeout: float | None = None) -> np.ndarray:
        """Blocking receive of the next (src → dst, tag) message.  With
        ``out``, the payload is written into it (reshaped) as well as
        returned."""
        raise NotImplementedError

    def send_async(self, src: int, dst: int, tag: int, data: np.ndarray,
                   copy: bool = True) -> Completion:
        """Issue a send and return a completion handle.  The base
        implementation hands the bytes to the fabric eagerly (sends only
        block on reorder-buffer depth bounds — backpressure the caller
        must feel anyway) and returns an already-done handle."""
        self.send(src, dst, tag, data, copy=copy)
        return Completion.completed()

    def recv_async(self, src: int, dst: int, tag: int,
                   out: np.ndarray | None = None,
                   timeout: float | None = None) -> Completion:
        """Post a deferred receive over the existing reorder buffers.

        Nothing is consumed until ``wait()``: the message (delivered by
        the sender, a TCP reader thread, or a shaped side table) keeps
        buffering in the per-tag deque, and ``wait()`` runs the blocking
        ``recv`` — including any shaped delivery-time sleep — writing
        into ``out`` at that point."""
        return Completion(
            lambda: self.recv(src, dst, tag, out=out, timeout=timeout))

    def barrier(self, rank: int, group: Sequence[int],
                _base: int = _ENGINE_BARRIER_BASE) -> None:
        """Token all-to-all within ``group``: rank sends one empty message
        to every other member, then collects one from each.  Built on
        send/recv, so it works identically on every backend; each rank
        keeps its own epoch counter per group (aligned by program order)."""
        key = (frozenset(group), _base)
        with self._epoch_lock:
            epoch = self._epochs.get((rank, key), 0)
            self._epochs[(rank, key)] = epoch + 1
        tag = _base - epoch
        token = np.zeros(0, dtype=np.uint8)
        for peer in group:
            if peer != rank:
                self.send(rank, peer, tag, token)
        for peer in group:
            if peer != rank:
                self.recv(peer, rank, tag)

    def close(self) -> None:
        pass

    def stats(self) -> dict[tuple[int, int, int], LinkStats]:
        """Per-(src, dst, tag) counters of traffic SENT from this endpoint
        (snapshot; thread-safe).  Reserved-tag barrier tokens are internal
        plumbing, not program traffic, and are filtered out."""
        return {k: v for k, v in self._book.snapshot().items()
                if k[2] > _ENGINE_BARRIER_BASE}

    def link_totals(self) -> dict[tuple[int, int], LinkStats]:
        return aggregate_links(self.stats())

    def reorder_stats(self) -> dict[tuple[int, int], "ReorderStats"]:
        """Receive-side reorder-buffer occupancy per (src, dst) link:
        current pending and the HIGH-WATER marks since creation, plus the
        configured bounds.  This is how a consumer *verifies* (not just
        assumes) that the depth knobs kept in-flight memory bounded."""
        return {}

    # shared plumbing used by barrier()/stats() implementations
    def _init_common(self) -> None:
        self._book = _StatsBook()
        self._epochs: dict = {}
        self._epoch_lock = threading.Lock()


class InprocTransport(Transport):
    """All endpoints in one process: per-link locked reorder buffers.

    The behavior-preserving successor of the old ``Channels`` queue pairs,
    with two fixes the old code lacked: out-of-order tags buffer instead
    of raising, and byte/message accounting happens under a lock."""

    name = "inproc"

    def __init__(self, num_endpoints: int, depth: int = 0):
        self.num_endpoints = num_endpoints
        self._default_depth = depth
        self._links: dict[tuple[int, int], _Link] = {}
        self._links_lock = threading.Lock()
        self._init_common()

    def _check(self, src: int, dst: int) -> None:
        n = self.num_endpoints
        if not (0 <= src < n and 0 <= dst < n) or src == dst:
            raise TransportError(f"bad link ({src} -> {dst}) for "
                                 f"{n}-endpoint fabric")

    def _link(self, src: int, dst: int) -> _Link:
        key = (src, dst)
        link = self._links.get(key)
        if link is None:
            with self._links_lock:
                link = self._links.setdefault(
                    key, _Link(max_msgs=self._default_depth))
        return link

    def set_depth(self, src: int, dst: int, max_msgs: int = 0,
                  max_bytes: int = 0) -> None:
        """Bound one link's pending set (senders block when full) — the
        garbled stream uses this so the full circuit never materializes."""
        link = self._link(src, dst)
        link.max_msgs = max_msgs
        link.max_bytes = max_bytes

    def reorder_stats(self):
        return _links_reorder_stats(self._links, self._links_lock)

    def send(self, src, dst, tag, data, copy=True):
        self._check(src, dst)
        data = np.array(data, copy=True) if copy else np.asarray(data)
        self._book.add((src, dst, tag), data.nbytes)
        self._link(src, dst).put(tag, data)

    def recv(self, src, dst, tag, out=None, timeout=None):
        self._check(src, dst)
        data = self._link(src, dst).get(tag, timeout=timeout)
        if out is not None:
            out[...] = data.reshape(out.shape)
        return data

    def close(self):
        with self._links_lock:
            for link in self._links.values():
                link.close()


# ---------------------------------------------------------------------------
# TCP backend
# ---------------------------------------------------------------------------

# frame := !I total_len | !B kind | body
#   kind 1 (hello): !q rank
#   kind 2 (data):  !qqq src dst tag | !B len(dtype) | dtype | !B ndim
#                   | !<ndim>q shape | payload
_K_HELLO, _K_DATA = 1, 2


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def _pack_data(src: int, dst: int, tag: int, arr: np.ndarray) -> bytes:
    dt = arr.dtype.str.encode()
    body = (struct.pack("!Bqqq", _K_DATA, src, dst, tag)
            + struct.pack("!B", len(dt)) + dt
            + struct.pack("!B", arr.ndim)
            + struct.pack(f"!{arr.ndim}q", *arr.shape)
            + arr.tobytes())
    return struct.pack("!I", len(body)) + body


def _unpack_data(body: bytes) -> tuple[int, int, int, np.ndarray]:
    src, dst, tag = struct.unpack_from("!qqq", body, 1)
    off = 1 + 24
    (dlen,) = struct.unpack_from("!B", body, off)
    off += 1
    dt = body[off:off + dlen].decode()
    off += dlen
    (ndim,) = struct.unpack_from("!B", body, off)
    off += 1
    shape = struct.unpack_from(f"!{ndim}q", body, off)
    off += 8 * ndim
    arr = np.frombuffer(body, dtype=np.dtype(dt), offset=off).reshape(shape)
    return src, dst, tag, np.array(arr)  # own the memory


def parse_addr(text: str) -> tuple[str, int]:
    host, _, port = text.rpartition(":")
    if not host or not port.isdigit():
        raise TransportError(f"bad peer address {text!r} (want host:port)")
    return host, int(port)


def pick_free_ports(n: int, host: str = "127.0.0.1") -> list[int]:
    """Reserve n distinct free TCP ports (bound sockets held until all
    are picked, then released — good enough for localhost fleets)."""
    socks, ports = [], []
    try:
        for _ in range(n):
            s = socket.socket()
            s.bind((host, 0))
            socks.append(s)
            ports.append(s.getsockname()[1])
    finally:
        for s in socks:
            s.close()
    return ports


class TcpTransport(Transport):
    """One endpoint of a multi-process fabric over sockets.

    ``addrs[k]`` is rank k's ``host:port``.  Each rank listens on its own
    port and dials one outbound (send-only) connection to every peer;
    inbound connections are receive-only, each drained by a background
    reader thread into the shared per-link reorder buffers.  Readers
    apply byte-bounded backpressure (``max_link_bytes``): a link whose
    receiver lags stops being read, which pushes back through TCP flow
    control to the sender — bounded memory without bounding the wire."""

    name = "tcp"

    def __init__(self, rank: int, addrs: Sequence[str],
                 connect_timeout: float = 30.0,
                 max_link_bytes: int = 64 << 20):
        self.rank = rank
        self.addrs = [parse_addr(a) for a in addrs]
        if not 0 <= rank < len(self.addrs):
            raise TransportError(f"rank {rank} outside {len(self.addrs)} "
                                 f"peer addresses")
        self.num_endpoints = len(self.addrs)
        self.connect_timeout = connect_timeout
        self.max_link_bytes = max_link_bytes
        self._links: dict[tuple[int, int], _Link] = {}
        self._links_lock = threading.Lock()
        self._dead_peers: set[int] = set()
        self._out: dict[int, socket.socket] = {}
        self._out_locks: dict[int, threading.Lock] = {}
        self._listener: socket.socket | None = None
        self._in: list[socket.socket] = []
        self._readers: list[threading.Thread] = []
        self._accept_thread: threading.Thread | None = None
        self._accepted = threading.Semaphore(0)
        self._accept_err: list[Exception] = []
        self._closed = False
        self._init_common()

    # -- wiring ----------------------------------------------------------------

    def _link(self, src: int, dst: int) -> _Link:
        key = (src, dst)
        link = self._links.get(key)
        if link is None:
            with self._links_lock:
                link = self._links.setdefault(
                    key, _Link(max_bytes=self.max_link_bytes))
                # a link first touched after its peer died (or after
                # close()) must be born closed, or the recv waits forever
                if self._closed or (dst == self.rank
                                    and src in self._dead_peers):
                    link.close()
        return link

    def listen(self):
        """Bind + start accepting inbound connections (idempotent).
        Split from :meth:`connect` so a fabric hosting several ranks in
        one process can open every listener before anyone dials."""
        n = self.num_endpoints
        if n == 1 or self._listener is not None:
            return
        host, port = self.addrs[self.rank]
        lsock = socket.socket()
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lsock.bind((host, port))
        lsock.listen(n)
        self._listener = lsock

        def _accept_loop():
            try:
                for _ in range(n - 1):
                    conn, _ = lsock.accept()
                    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                    hdr = _recv_exact(conn, 4)
                    if hdr is None:
                        raise TransportError("peer hung up during hello")
                    body = _recv_exact(conn, struct.unpack("!I", hdr)[0])
                    kind, peer = struct.unpack("!Bq", body)
                    if kind != _K_HELLO:
                        raise TransportError(f"expected hello, got kind "
                                             f"{kind}")
                    t = threading.Thread(target=self._read_loop,
                                         args=(conn, peer), daemon=True,
                                         name=f"tcp-read-{peer}->{self.rank}")
                    t.start()
                    self._in.append(conn)
                    self._readers.append(t)
                    self._accepted.release()
            except Exception as e:  # surfaced by connect()
                if not self._closed:
                    self._accept_err.append(e)
                self._accepted.release()

        self._accept_thread = threading.Thread(target=_accept_loop,
                                               daemon=True,
                                               name=f"tcp-accept-{self.rank}")
        self._accept_thread.start()

    def connect(self):
        n = self.num_endpoints
        if n == 1:
            return
        self.listen()
        deadline = time.monotonic() + self.connect_timeout
        for peer in range(n):
            if peer == self.rank:
                continue
            self._out[peer] = self._dial(peer, deadline)
            self._out_locks[peer] = threading.Lock()
        for _ in range(n - 1):
            left = deadline - time.monotonic()
            if not self._accepted.acquire(timeout=max(left, 0.01)):
                raise TransportError(
                    f"rank {self.rank}: timed out waiting for inbound "
                    f"connections")
            if self._accept_err:
                raise self._accept_err[0]

    def _dial(self, peer: int, deadline: float) -> socket.socket:
        host, port = self.addrs[peer]
        last: Exception | None = None
        while time.monotonic() < deadline:
            try:
                s = socket.create_connection((host, port), timeout=2.0)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                s.settimeout(None)
                hello = struct.pack("!Bq", _K_HELLO, self.rank)
                s.sendall(struct.pack("!I", len(hello)) + hello)
                return s
            except OSError as e:
                last = e
                time.sleep(0.05)
        raise TransportError(f"rank {self.rank}: cannot reach rank {peer} "
                             f"at {host}:{port}: {last}")

    def _read_loop(self, conn: socket.socket, peer: int) -> None:
        try:
            while True:
                hdr = _recv_exact(conn, 4)
                if hdr is None:
                    return
                body = _recv_exact(conn, struct.unpack("!I", hdr)[0])
                if body is None:
                    return
                if body[0] != _K_DATA:
                    raise TransportError(f"unexpected frame kind {body[0]}")
                src, dst, tag, arr = _unpack_data(body)
                if dst != self.rank:
                    raise TransportError(
                        f"rank {self.rank} got a frame for rank {dst}")
                self._link(src, dst).put(tag, arr)
        except (TransportClosed, OSError):
            pass
        finally:
            conn.close()
            # wake any recv still waiting on this peer; the dead-peer mark
            # (taken under the links lock) also closes links created later
            with self._links_lock:
                self._dead_peers.add(peer)
                links = list(self._links.items())
            for (src, _dst), link in links:
                if src == peer:
                    link.close()

    # -- data path ---------------------------------------------------------------

    def send(self, src, dst, tag, data, copy=True):
        # copy is irrelevant here: serialization owns the bytes
        if src != self.rank:
            raise TransportError(f"endpoint {self.rank} cannot send "
                                 f"as rank {src}")
        sock = self._out.get(dst)
        if sock is None:
            raise TransportError(f"no connection to rank {dst} "
                                 f"(connect() not run?)")
        frame = _pack_data(src, dst, tag, np.ascontiguousarray(data))
        with self._out_locks[dst]:
            sock.sendall(frame)
        self._book.add((src, dst, tag), data.nbytes)

    def recv(self, src, dst, tag, out=None, timeout=None):
        if dst != self.rank:
            raise TransportError(f"endpoint {self.rank} cannot recv "
                                 f"as rank {dst}")
        data = self._link(src, dst).get(tag, timeout=timeout)
        if out is not None:
            out[...] = data.reshape(out.shape)
        return data

    def set_depth(self, src: int, dst: int, max_msgs: int = 0,
                  max_bytes: int = 0) -> None:
        """Bound one inbound link's reorder buffer (parity with
        ``InprocTransport.set_depth``).  The reader thread blocks in
        ``put`` when the bound is hit, which stops draining the socket and
        pushes back to the sender through TCP flow control — the message
        -granular version of the coarse ``max_link_bytes`` default."""
        link = self._link(src, dst)
        link.max_msgs = max_msgs
        link.max_bytes = max_bytes

    def reorder_stats(self):
        return _links_reorder_stats(self._links, self._links_lock)

    def close(self):
        self._closed = True
        for sock in self._out.values():
            try:
                sock.shutdown(socket.SHUT_WR)
            except OSError:
                pass
            sock.close()
        if self._listener is not None:
            self._listener.close()
        # unblock our readers immediately: by close() time every hosted
        # engine has finished its program, so anything still in flight on
        # an inbound socket is stray — without this, readers sit in recv()
        # until the PEER closes its outbound side, and a fabric closing
        # several co-hosted ranks sequentially eats one join timeout per
        # reader thread
        for conn in self._in:
            try:
                conn.shutdown(socket.SHUT_RD)
            except OSError:
                pass
        for t in self._readers:
            t.join(timeout=5.0)
        with self._links_lock:
            for link in self._links.values():
                link.close()


# ---------------------------------------------------------------------------
# shaped decorator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinkShape:
    latency_s: float = 0.0          # one-way delivery delay
    bandwidth: float | None = None  # bytes/s (None = unconstrained)


class ShapedTransport(Transport):
    """Decorator adding latency/bandwidth per link to a same-process
    transport.

    The sender stamps each message with a virtual delivery time using
    pipelined link occupancy (a message serializes onto the link at
    ``bandwidth`` after the previous one clears; ``latency`` delays
    delivery, not occupancy — the same device model the storage simulator
    uses), and ``recv`` sleeps until that time.  Wall-clock through a
    shaped fabric therefore *measures* traffic under the configured WAN
    instead of modeling it.  Sender and receiver must share the process
    (delivery stamps ride in a side table, not on the wire); to shape a
    *cross-process* link (the ``shaped+tcp`` backend), pass
    ``paced_send=True``: the SENDER then sleeps until the message's
    virtual delivery time before handing it to the inner transport, so no
    side table must cross the process boundary.  Sender pacing charges
    the full latency serially at the sender instead of overlapping it
    with receiver compute — a conservative (upper-bound) approximation,
    exact for the bandwidth term and for ping-pong exchanges."""

    name = "shaped"

    def __init__(self, inner: Transport, default: LinkShape | None = None,
                 links: dict[tuple[int, int], LinkShape] | None = None,
                 paced_send: bool = False):
        self.inner = inner
        self.default = default or LinkShape()
        self.links = dict(links or {})
        self.paced_send = paced_send
        self._busy: dict[tuple[int, int], float] = {}
        self._deliver: dict[tuple[int, int, int], deque] = {}
        self._lock = threading.Lock()
        self.num_endpoints = getattr(inner, "num_endpoints", 0)
        self._init_common()  # barrier epochs (stats delegate to inner)

    def shape_for(self, src: int, dst: int) -> LinkShape:
        return self.links.get((src, dst), self.default)

    def send(self, src, dst, tag, data, copy=True):
        sh = self.shape_for(src, dst)
        now = time.monotonic()
        with self._lock:
            start = max(now, self._busy.get((src, dst), 0.0))
            xfer = (np.asarray(data).nbytes / sh.bandwidth
                    if sh.bandwidth else 0.0)
            self._busy[(src, dst)] = start + xfer
            due = start + xfer + sh.latency_s
            if not self.paced_send:
                self._deliver.setdefault((src, dst, tag), deque()).append(due)
        if self.paced_send:
            wait = due - time.monotonic()
            if wait > 0:
                time.sleep(wait)
        self.inner.send(src, dst, tag, data, copy=copy)

    def recv(self, src, dst, tag, out=None, timeout=None):
        data = self.inner.recv(src, dst, tag, out=None, timeout=timeout)
        if not self.paced_send:
            with self._lock:
                q = self._deliver.get((src, dst, tag))
                due = q.popleft() if q else 0.0
            wait = due - time.monotonic()
            if wait > 0:
                time.sleep(wait)
        if out is not None:
            out[...] = data.reshape(out.shape)
        return data

    def set_depth(self, src, dst, max_msgs=0, max_bytes=0):
        if hasattr(self.inner, "set_depth"):
            self.inner.set_depth(src, dst, max_msgs, max_bytes)

    def listen(self):
        if hasattr(self.inner, "listen"):
            self.inner.listen()

    def connect(self):
        self.inner.connect()

    def close(self):
        self.inner.close()

    def stats(self):
        return self.inner.stats()

    def reorder_stats(self):
        return self.inner.reorder_stats()


# ---------------------------------------------------------------------------
# fabric: endpoint placement + lifecycle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """Endpoint-to-process placement + link shaping for one job.

    ``rank=None`` hosts all endpoints in this process (today's threaded
    mode); ``rank=k`` hosts exactly endpoint k (distributed mode) and
    ``peers`` must list every rank's ``host:port`` in rank order.
    ``latency_s``/``bandwidth`` configure the ``shaped`` backend's
    default link shape."""

    rank: int | None = None
    peers: tuple[str, ...] = ()
    latency_s: float = 0.0
    bandwidth: float | None = None
    connect_timeout_s: float = 30.0

    def __post_init__(self):
        object.__setattr__(self, "peers", tuple(self.peers))


class PartyView:
    """One party's worker-id-addressed window onto the fabric.

    The engine speaks worker ids (NET_* immediates); the view offsets them
    into the global rank space (``rank = base + worker``) so the same
    bytecode runs unmodified on any backend/placement.

    ``recv_timeout`` bounds every NET_RECV: a mis-tagged send or a dead
    sibling engine raises a TransportError instead of hanging the run
    (the old ``Channels.recv`` failed fast on tag mismatch; reorder
    buffers cannot, so they fail bounded instead)."""

    RECV_TIMEOUT_S = 600.0

    def __init__(self, transport: Transport, base: int, num_workers: int,
                 recv_timeout: float | None = None):
        self.transport = transport
        self.base = base
        self.num_workers = num_workers
        self.recv_timeout = (self.RECV_TIMEOUT_S if recv_timeout is None
                             else recv_timeout)

    def send(self, src: int, dst: int, tag: int, data: np.ndarray) -> None:
        self.transport.send(self.base + src, self.base + dst, tag, data)

    def recv(self, src: int, dst: int, tag: int,
             out: np.ndarray | None = None) -> np.ndarray:
        try:
            return self.transport.recv(self.base + src, self.base + dst,
                                       tag, out=out,
                                       timeout=self.recv_timeout)
        except TransportError as e:
            raise TransportError(
                f"NET_RECV worker{src}->worker{dst} tag={tag}: {e}") from e

    def send_async(self, src: int, dst: int, tag: int,
                   data: np.ndarray) -> Completion:
        return self.transport.send_async(self.base + src, self.base + dst,
                                         tag, data)

    def recv_async(self, src: int, dst: int, tag: int,
                   out: np.ndarray | None = None) -> Completion:
        c = self.transport.recv_async(self.base + src, self.base + dst,
                                      tag, out=out,
                                      timeout=self.recv_timeout)
        c._err_prefix = f"NET_RECV worker{src}->worker{dst} tag={tag}: "
        return c

    def barrier(self, rank: int) -> None:
        group = range(self.base, self.base + self.num_workers)
        self.transport.barrier(self.base + rank, group)


class Fabric:
    """A set of endpoints (possibly a strict subset — distributed mode)
    plus their transports, with one connect/stats/barrier/close surface."""

    def __init__(self, name: str, num_endpoints: int,
                 transports: dict[int, Transport]):
        self.name = name
        self.num_endpoints = num_endpoints
        self.transports = transports
        self.hosted = sorted(transports)
        self._epoch = 0

    @property
    def distributed(self) -> bool:
        return len(self.hosted) < self.num_endpoints

    def connect(self) -> None:
        # open every hosted listener before anyone dials, then dial
        # concurrently: co-hosted TCP ranks block on each other's inbound
        # connections, so sequential connect() would deadlock
        uniq = self._unique()
        for t in uniq:
            if hasattr(t, "listen"):
                t.listen()
        if len(uniq) == 1:
            uniq[0].connect()
            return
        errs: list[Exception] = []

        def _c(t):
            try:
                t.connect()
            except Exception as e:  # re-raised below
                errs.append(e)

        threads = [threading.Thread(target=_c, args=(t,), daemon=True)
                   for t in uniq]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errs:
            raise errs[0]

    def close(self) -> None:
        for t in self._unique():
            t.close()

    def _unique(self) -> list[Transport]:
        seen: list[Transport] = []
        for t in self.transports.values():
            if all(t is not s for s in seen):
                seen.append(t)
        return seen

    def transport_for(self, rank: int) -> Transport:
        try:
            return self.transports[rank]
        except KeyError:
            raise TransportError(f"rank {rank} is not hosted by this "
                                 f"process (hosted: {self.hosted})") from None

    def view(self, rank: int, base: int, num_workers: int) -> PartyView:
        return PartyView(self.transport_for(rank), base, num_workers)

    def stats(self) -> dict[tuple[int, int, int], LinkStats]:
        """Sent-traffic stats merged across hosted endpoints (send-side
        accounting keeps the union disjoint)."""
        out: dict[tuple[int, int, int], LinkStats] = {}
        for t in self._unique():
            for k, s in t.stats().items():
                agg = out.setdefault(k, LinkStats())
                agg.messages += s.messages
                agg.bytes += s.bytes
        return out

    def link_totals(self) -> dict[tuple[int, int], LinkStats]:
        return aggregate_links(self.stats())

    def reorder_stats(self) -> dict[tuple[int, int], ReorderStats]:
        """Receive-side reorder occupancy merged across hosted endpoints
        (each link's buffer lives at its receiving endpoint, so hosted
        transports never disagree about a key)."""
        out: dict[tuple[int, int], ReorderStats] = {}
        for t in self._unique():
            out.update(t.reorder_stats())
        return out

    def barrier(self) -> None:
        """Full-fabric barrier across every endpoint (each hosted rank
        exchanges tokens with all ranks) — used to hold distributed
        processes open until every peer has drained its traffic."""
        group = list(range(self.num_endpoints))
        epoch = self._epoch
        self._epoch += 1
        tag = _FABRIC_BARRIER_BASE - epoch
        token = np.zeros(0, dtype=np.uint8)
        for r in self.hosted:
            t = self.transport_for(r)
            for peer in group:
                if peer != r:
                    t.send(r, peer, tag, token)
        for r in self.hosted:
            t = self.transport_for(r)
            for peer in group:
                if peer != r:
                    t.recv(peer, r, tag)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

TransportFactory = Callable[[int, FabricSpec, Iterable[int]],
                            dict[int, "Transport"]]

TRANSPORTS: dict[str, TransportFactory] = {}


def register_transport(name: str, factory: TransportFactory) -> None:
    TRANSPORTS[name] = factory


def _make_inproc(n: int, spec: FabricSpec, hosted) -> dict[int, Transport]:
    if spec.rank is not None:
        raise TransportError("inproc transport cannot host a single rank; "
                             "use tcp for distributed placement")
    t = InprocTransport(n)
    return {r: t for r in hosted}


def _make_tcp(n: int, spec: FabricSpec, hosted) -> dict[int, Transport]:
    if len(spec.peers) != n:
        raise TransportError(f"tcp fabric needs {n} peer addresses "
                             f"(one per rank), got {len(spec.peers)}")
    return {r: TcpTransport(r, spec.peers,
                            connect_timeout=spec.connect_timeout_s)
            for r in hosted}


def _make_shaped(n: int, spec: FabricSpec, hosted) -> dict[int, Transport]:
    if spec.rank is not None:
        raise TransportError("shaped transport is same-process only; shape "
                             "cross-process links with OS tooling")
    t = ShapedTransport(InprocTransport(n),
                        default=LinkShape(latency_s=spec.latency_s,
                                          bandwidth=spec.bandwidth))
    return {r: t for r in hosted}


def _make_shaped_tcp(n: int, spec: FabricSpec, hosted
                     ) -> dict[int, Transport]:
    """``shaped`` wrapping the tcp backend — cross-process WAN
    experiments.  Every hosted rank gets its own sender-paced decorator
    (no shared side table is needed: pacing happens entirely on the
    sending endpoint), so it composes with single-rank placement."""
    inner = _make_tcp(n, spec, hosted)
    shape = LinkShape(latency_s=spec.latency_s, bandwidth=spec.bandwidth)
    return {r: ShapedTransport(t, default=shape, paced_send=True)
            for r, t in inner.items()}


register_transport("inproc", _make_inproc)
register_transport("tcp", _make_tcp)
register_transport("shaped", _make_shaped)
register_transport("shaped+tcp", _make_shaped_tcp)


def build_fabric(name: str, num_endpoints: int,
                 spec: FabricSpec | None = None) -> Fabric:
    """Build (but do not connect) the fabric for one job."""
    spec = spec or FabricSpec()
    try:
        factory = TRANSPORTS[name]
    except KeyError:
        raise KeyError(f"unknown transport {name!r}; registered: "
                       f"{sorted(TRANSPORTS)}") from None
    if spec.rank is None:
        hosted: Iterable[int] = range(num_endpoints)
    else:
        if not 0 <= spec.rank < num_endpoints:
            raise TransportError(f"fabric rank {spec.rank} outside "
                                 f"{num_endpoints} endpoints")
        hosted = (spec.rank,)
    return Fabric(name, num_endpoints, factory(num_endpoints, spec, hosted))
