"""MAGE planner stage 2: replacement (§6.3).

Applies Belady's MIN directly — the clairvoyance that is unrealizable for an
OS is free here, because the bytecode *is* the access pattern.  Emits
synchronous SWAP_IN / SWAP_OUT directives and rewrites every operand from
MAGE-virtual to MAGE-physical addresses via a page table maintained in
software during planning (§4.1).

Write-back rule (see liveness.py): a dirty victim is written back only if its
next READ is finite; otherwise it is dropped — no later instruction can
observe it.  A swap-in is elided when the missing page is about to be fully
overwritten by the touching instruction (write-allocate elision).

Policies beyond MIN (LRU/FIFO/"farthest-clean") are pluggable; LRU/FIFO feed
the OS-baseline comparisons and MinClean is our beyond-paper dirty-aware
variant (§Perf).
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from typing import Callable, Iterable, Iterator

from .bytecode import (DEFAULT_CHUNK_INSTRS, INF, Instr, Op, Program,
                       ProgramFile, decode_chunk, strip_frees, writer_like)
from .liveness import (W_FULL_WRITE, W_WRITE, AnnotationReader, Touches,
                       annotate_next_use, compute_touches,
                       max_pages_per_instr, records_digest)


class EvictionPolicy:
    """Planner calls touch() on every page touch and evict() on frame need."""

    name = "abstract"

    def touch(self, page: int, next_use: int, now: int) -> None:
        raise NotImplementedError

    def evict(self, pinned: set[int], resident: dict[int, int],
              dirty: set[int]) -> int:
        raise NotImplementedError

    def remove(self, page: int) -> None:
        pass


class _HeapPolicy(EvictionPolicy):
    """Lazy-deletion heap over per-page keys (max-heap iff maximize)."""

    def __init__(self, maximize: bool):
        self._sign = -1 if maximize else 1
        self._heap: list[tuple[int, int]] = []
        self._cur: dict[int, int] = {}

    def _push(self, page: int, key: int) -> None:
        self._cur[page] = key
        heapq.heappush(self._heap, (self._sign * key, page))
        if len(self._heap) > 64 + 4 * len(self._cur):
            # compact stale lazy-deletion entries: without this the heap
            # grows with total touches, breaking the planner's O(frames)
            # memory bound.  Rebuilding from _cur keeps exactly the valid
            # entries; duplicates later re-pushed from an evict stash are
            # harmless (they turn stale once the page leaves _cur).
            self._heap = [(self._sign * k, p) for p, k in self._cur.items()]
            heapq.heapify(self._heap)

    def touch(self, page: int, next_use: int, now: int) -> None:
        self._push(page, next_use)

    def remove(self, page: int) -> None:
        self._cur.pop(page, None)

    def _pop_valid(self, pinned: set[int], resident: dict[int, int],
                   stash: list[tuple[int, int]]) -> tuple[int, int] | None:
        """Pop the best non-stale, non-pinned resident entry, or None."""
        while self._heap:
            k, p = heapq.heappop(self._heap)
            cur = self._cur.get(p)
            if cur is None or self._sign * cur != k or p not in resident:
                continue
            if p in pinned:
                stash.append((k, p))
                continue
            return (k, p)
        return None

    def _finish(self, chosen: int, stash: list[tuple[int, int]]) -> int:
        for e in stash:
            if e[1] != chosen:
                heapq.heappush(self._heap, e)
        del self._cur[chosen]
        return chosen

    def evict(self, pinned, resident, dirty) -> int:
        stash: list[tuple[int, int]] = []
        got = self._pop_valid(pinned, resident, stash)
        if got is None:
            for e in stash:
                heapq.heappush(self._heap, e)
            raise RuntimeError(
                "no evictable page: num_frames smaller than one instruction's "
                "working set — raise the memory budget or shrink DSL chunks")
        return self._finish(got[1], stash)


class MinPolicy(_HeapPolicy):
    """Belady's MIN: evict the resident page whose next use is farthest."""

    name = "min"

    def __init__(self):
        super().__init__(maximize=True)


class MinCleanPolicy(_HeapPolicy):
    """Beyond-paper: farthest-first, but among pages whose next use lies
    within a window of the farthest (or is also INF), prefer a CLEAN page —
    skipping a write-back.  Attacks the 2x write slack plain MIN concedes
    (§6.3 footnote 4; exact minimization is NP-hard, Farach & Liberatore)."""

    name = "min_clean"

    def __init__(self, rel_delta: float = 0.05, abs_delta: int = 256):
        super().__init__(maximize=True)
        self.rel_delta = rel_delta
        self.abs_delta = abs_delta

    def evict(self, pinned, resident, dirty) -> int:
        stash: list[tuple[int, int]] = []
        first = self._pop_valid(pinned, resident, stash)
        if first is None:
            for e in stash:
                heapq.heappush(self._heap, e)
            raise RuntimeError(
                "no evictable page: num_frames smaller than one instruction's "
                "working set — raise the memory budget or shrink DSL chunks")
        fk, fp = first
        far = self._sign * fk  # == -fk: the farthest next-use
        if fp not in dirty:
            return self._finish(fp, stash)
        if far >= INF:
            window_lo = INF
        else:
            window_lo = far - max(self.abs_delta, int(self.rel_delta * far))
        rejected: list[tuple[int, int]] = [first]
        chosen = None
        while True:
            nxt = self._pop_valid(pinned, resident, stash)
            if nxt is None:
                break
            key = self._sign * nxt[0]
            if key < window_lo:
                rejected.append(nxt)
                break
            if nxt[1] not in dirty:
                chosen = nxt[1]
                break
            rejected.append(nxt)
        if chosen is None:
            chosen = fp  # no clean page in window: plain MIN choice
        for e in rejected:
            if e[1] != chosen:
                stash.append(e)
        return self._finish(chosen, stash)


class LruPolicy(_HeapPolicy):
    name = "lru"

    def __init__(self):
        super().__init__(maximize=False)

    def touch(self, page: int, next_use: int, now: int) -> None:
        self._push(page, now)


class FifoPolicy(_HeapPolicy):
    name = "fifo"

    def __init__(self):
        super().__init__(maximize=False)

    def touch(self, page: int, next_use: int, now: int) -> None:
        if page not in self._cur:
            self._push(page, now)


POLICIES: dict[str, type[EvictionPolicy]] = {
    "min": MinPolicy,
    "min_clean": MinCleanPolicy,
    "lru": LruPolicy,
    "fifo": FifoPolicy,
}


@dataclasses.dataclass
class ReplacementStats:
    swap_ins: int = 0
    swap_outs: int = 0
    dropped_dirty: int = 0       # dirty pages dropped: never read again
    elided_swap_ins: int = 0     # write-allocate elisions
    num_frames: int = 0
    num_vpages: int = 0
    instructions: int = 0
    policy: str = "min"

    @property
    def total_swaps(self) -> int:
        return self.swap_ins + self.swap_outs


# One instruction plus its annotated page touches, in touch order:
# (instr, [(page, flags, next_any, next_read), ...]).  Both the in-memory
# and the file-streaming paths feed this shape to the same transducer core,
# which is what makes their outputs instruction-identical by construction.
_TouchRow = tuple[int, int, int, int]
_AnnotatedInstr = tuple[Instr, list[_TouchRow]]


def _replacement_core(items: Iterable[_AnnotatedInstr], num_frames: int,
                      pol: EvictionPolicy, shift: int, psize: int,
                      emit: Callable[[Instr], None],
                      stats: ReplacementStats) -> None:
    """Streaming Belady transducer: O(frames + pages-on-storage) state."""
    page_table: dict[int, int] = {}          # vpage -> frame
    free_frames = list(range(num_frames - 1, -1, -1))
    dirty: set[int] = set()
    stored: set[int] = set()                 # storage holds current content
    cur_next_read: dict[int, int] = {}       # resident pages only

    def acquire_frame(pinned: set[int]) -> int:
        if free_frames:
            return free_frames.pop()
        victim = pol.evict(pinned, page_table, dirty)
        frame = page_table.pop(victim)
        if victim in dirty:
            dirty.discard(victim)
            if cur_next_read.pop(victim, INF) < INF:
                emit(Instr(Op.SWAP_OUT,
                           ins=((frame << shift, psize),),
                           imm=(victim,)))
                stats.swap_outs += 1
                stored.add(victim)
            else:
                stats.dropped_dirty += 1
                stored.discard(victim)
        else:
            cur_next_read.pop(victim, None)
        # clean victim: storage copy (if any) is already current
        return frame

    def translate(span):
        addr, n = span
        vp = addr >> shift
        return ((page_table[vp] << shift) + (addr - (vp << shift)), n)

    for i, (ins, row) in enumerate(items):
        pinned = {p for p, _, _, _ in row}
        for p, f, nxt, nxr in row:
            if p not in page_table:
                frame = acquire_frame(pinned)
                if p in stored:
                    if f & W_FULL_WRITE:
                        stored.discard(p)
                        stats.elided_swap_ins += 1
                    else:
                        emit(Instr(Op.SWAP_IN,
                                   outs=((frame << shift, psize),),
                                   imm=(p,)))
                        stats.swap_ins += 1
                page_table[p] = frame
            if f & W_WRITE:
                dirty.add(p)
            cur_next_read[p] = nxr
            pol.touch(p, nxt, i)
        emit(Instr(ins.op,
                   tuple(translate(s) for s in ins.outs),
                   tuple(translate(s) for s in ins.ins),
                   ins.imm))
        stats.instructions += 1


def _items_from_touches(instrs: list[Instr], t: Touches
                        ) -> Iterator[_AnnotatedInstr]:
    offs, pg, fl = t.offsets, t.pages, t.flags
    nxt, nxr = t.next_any, t.next_read
    for i, ins in enumerate(instrs):
        yield ins, [(int(pg[k]), int(fl[k]), int(nxt[k]), int(nxr[k]))
                    for k in range(int(offs[i]), int(offs[i + 1]))]


def _items_from_files(pf: ProgramFile, ann: AnnotationReader,
                      chunk_instrs: int) -> Iterator[_AnnotatedInstr]:
    crc = 0
    for (s, rec), (s2, arr) in zip(pf.iter_chunks(chunk_instrs),
                                   ann.iter_chunks(chunk_instrs)):
        assert s == s2, "program/annotation chunking out of sync"
        crc = records_digest(crc, rec, s)
        for r, ins in enumerate(decode_chunk(rec)):
            yield ins, [(int(arr[r, 1 + 4 * j]), int(arr[r, 2 + 4 * j]),
                         int(arr[r, 3 + 4 * j]), int(arr[r, 4 + 4 * j]))
                        for j in range(int(arr[r, 0]))]
    if crc != ann.prog_crc:
        raise ValueError(
            "annotation sidecar does not match this program file "
            "(content checksum mismatch); regenerate it with "
            "annotate_next_use")


def _check_budget(num_frames: int, need: int) -> None:
    if num_frames < need:
        raise ValueError(
            f"num_frames={num_frames} < {need} pages touched by one "
            f"instruction; budget too small for this chunking")


def plan_replacement(prog: Program, num_frames: int,
                     policy: str | EvictionPolicy = "min",
                     ) -> tuple[Program, ReplacementStats]:
    """Stage 2: rewrite a 'virtual' program into a 'physical' one."""
    assert prog.phase == "virtual", prog.phase
    instrs = strip_frees(prog.instrs)
    touches = compute_touches(prog, instrs)
    _check_budget(num_frames, max_pages_per_instr(touches))
    pol = POLICIES[policy]() if isinstance(policy, str) else policy
    stats = ReplacementStats(num_frames=num_frames,
                             num_vpages=touches.num_pages,
                             policy=getattr(pol, "name", str(policy)))
    out: list[Instr] = []
    _replacement_core(_items_from_touches(instrs, touches), num_frames, pol,
                      prog.page_shift, prog.page_slots, out.append, stats)
    res = Program(
        instrs=out, page_shift=prog.page_shift, protocol=prog.protocol,
        phase="physical", worker=prog.worker, num_workers=prog.num_workers,
        vspace_slots=prog.vspace_slots, num_frames=num_frames,
        meta=dict(prog.meta),
    )
    return res, stats


def plan_replacement_file(pf: ProgramFile, out_path: str | os.PathLike,
                          num_frames: int,
                          policy: str | EvictionPolicy = "min",
                          annotations: AnnotationReader | str | None = None,
                          chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                          ) -> tuple[ProgramFile, ReplacementStats]:
    """Stage 2, out-of-core: stream a 'virtual' bytecode file (plus its
    next-use sidecar) into a 'physical' bytecode file."""
    assert pf.phase == "virtual", pf.phase
    out_path = os.fspath(out_path)
    own_ann = annotations is None
    if own_ann:
        annotations = annotate_next_use(pf, out_path + ".ann",
                                        chunk_instrs).path
    if not isinstance(annotations, AnnotationReader):
        annotations = AnnotationReader(annotations)
    try:
        if annotations.n_records != pf.num_records:
            raise ValueError(
                f"annotation sidecar has {annotations.n_records} records "
                f"but program has {pf.num_records}; stale sidecar?")
        _check_budget(num_frames, annotations.max_touches)
        pol = POLICIES[policy]() if isinstance(policy, str) else policy
        stats = ReplacementStats(num_frames=num_frames,
                                 num_vpages=annotations.num_pages,
                                 policy=getattr(pol, "name", str(policy)))
        with writer_like(pf, out_path, phase="physical",
                         num_frames=num_frames,
                         chunk_instrs=chunk_instrs) as w:
            _replacement_core(
                _items_from_files(pf, annotations, chunk_instrs),
                num_frames, pol, pf.page_shift, pf.page_slots,
                w.append, stats)
    finally:
        if own_ann and os.path.exists(annotations.path):
            os.unlink(annotations.path)
    return ProgramFile(out_path), stats
