"""MAGE planner stage 2: replacement (§6.3).

Applies Belady's MIN directly — the clairvoyance that is unrealizable for an
OS is free here, because the bytecode *is* the access pattern.  Emits
synchronous SWAP_IN / SWAP_OUT directives and rewrites every operand from
MAGE-virtual to MAGE-physical addresses via a page table maintained in
software during planning (§4.1).

Write-back rule (see liveness.py): a dirty victim is written back only if its
next READ is finite; otherwise it is dropped — no later instruction can
observe it.  A swap-in is elided when the missing page is about to be fully
overwritten by the touching instruction (write-allocate elision).

Policies beyond MIN (LRU/FIFO/"farthest-clean") are pluggable; LRU/FIFO feed
the OS-baseline comparisons and MinClean is our beyond-paper dirty-aware
variant (§Perf).
"""

from __future__ import annotations

import dataclasses
import heapq
import os
from typing import Callable, Iterable, Iterator

import numpy as np

from .bytecode import (DEFAULT_CHUNK_INSTRS, INF, MAX_INS, MAX_OUTS,
                       RECORD_WORDS, _IN_OFF, _OUT_OFF, Instr, Op, Program,
                       ProgramFile, decode_chunk, encode_chunk, pack_row,
                       strip_frees, unpack_heads, writer_like)
from .liveness import (ANN_TOUCH_SLOTS, ANN_WORDS, W_FULL_WRITE, W_WRITE,
                       AnnotationReader, Touches, annotate_next_use,
                       max_pages_per_instr, records_digest, stripped_touches,
                       touches_from_records)


class EvictionPolicy:
    """Planner calls touch() on every page touch and evict() on frame need.

    ``resident`` / ``dirty`` are mappings/sets of page ids — plain dict/set
    or the scalar core's dense-array equivalents (:class:`_DensePageMap` /
    :class:`_DensePageSet`); policies must only rely on membership,
    indexing and iteration."""

    name = "abstract"

    def touch(self, page: int, next_use: int, now: int) -> None:
        raise NotImplementedError

    def evict(self, pinned: set[int], resident, dirty) -> int:
        raise NotImplementedError

    def remove(self, page: int) -> None:
        pass


class _HeapPolicy(EvictionPolicy):
    """Lazy-deletion heap over per-page keys (max-heap iff maximize)."""

    def __init__(self, maximize: bool):
        self._sign = -1 if maximize else 1
        self._heap: list[tuple[int, int]] = []
        self._cur: dict[int, int] = {}

    def _push(self, page: int, key: int) -> None:
        self._cur[page] = key
        heapq.heappush(self._heap, (self._sign * key, page))
        if len(self._heap) > 64 + 4 * len(self._cur):
            # compact stale lazy-deletion entries: without this the heap
            # grows with total touches, breaking the planner's O(frames)
            # memory bound.  Rebuilding from _cur keeps exactly the valid
            # entries; duplicates later re-pushed from an evict stash are
            # harmless (they turn stale once the page leaves _cur).
            self._heap = [(self._sign * k, p) for p, k in self._cur.items()]
            heapq.heapify(self._heap)

    def touch(self, page: int, next_use: int, now: int) -> None:
        self._push(page, next_use)

    def remove(self, page: int) -> None:
        self._cur.pop(page, None)

    def _pop_valid(self, pinned: set[int], resident: dict[int, int],
                   stash: list[tuple[int, int]]) -> tuple[int, int] | None:
        """Pop the best non-stale, non-pinned resident entry, or None."""
        while self._heap:
            k, p = heapq.heappop(self._heap)
            cur = self._cur.get(p)
            if cur is None or self._sign * cur != k or p not in resident:
                continue
            if p in pinned:
                stash.append((k, p))
                continue
            return (k, p)
        return None

    def _finish(self, chosen: int, stash: list[tuple[int, int]]) -> int:
        for e in stash:
            if e[1] != chosen:
                heapq.heappush(self._heap, e)
        del self._cur[chosen]
        return chosen

    def evict(self, pinned, resident, dirty) -> int:
        stash: list[tuple[int, int]] = []
        got = self._pop_valid(pinned, resident, stash)
        if got is None:
            for e in stash:
                heapq.heappush(self._heap, e)
            raise RuntimeError(
                "no evictable page: num_frames smaller than one instruction's "
                "working set — raise the memory budget or shrink DSL chunks")
        return self._finish(got[1], stash)


class MinPolicy(_HeapPolicy):
    """Belady's MIN: evict the resident page whose next use is farthest."""

    name = "min"

    def __init__(self):
        super().__init__(maximize=True)


class MinCleanPolicy(_HeapPolicy):
    """Beyond-paper: farthest-first, but among pages whose next use lies
    within a window of the farthest (or is also INF), prefer a CLEAN page —
    skipping a write-back.  Attacks the 2x write slack plain MIN concedes
    (§6.3 footnote 4; exact minimization is NP-hard, Farach & Liberatore)."""

    name = "min_clean"

    def __init__(self, rel_delta: float = 0.05, abs_delta: int = 256):
        super().__init__(maximize=True)
        self.rel_delta = rel_delta
        self.abs_delta = abs_delta

    def evict(self, pinned, resident, dirty) -> int:
        stash: list[tuple[int, int]] = []
        first = self._pop_valid(pinned, resident, stash)
        if first is None:
            for e in stash:
                heapq.heappush(self._heap, e)
            raise RuntimeError(
                "no evictable page: num_frames smaller than one instruction's "
                "working set — raise the memory budget or shrink DSL chunks")
        fk, fp = first
        far = self._sign * fk  # == -fk: the farthest next-use
        if fp not in dirty:
            return self._finish(fp, stash)
        if far >= INF:
            window_lo = INF
        else:
            window_lo = far - max(self.abs_delta, int(self.rel_delta * far))
        rejected: list[tuple[int, int]] = [first]
        chosen = None
        while True:
            nxt = self._pop_valid(pinned, resident, stash)
            if nxt is None:
                break
            key = self._sign * nxt[0]
            if key < window_lo:
                rejected.append(nxt)
                break
            if nxt[1] not in dirty:
                chosen = nxt[1]
                break
            rejected.append(nxt)
        if chosen is None:
            chosen = fp  # no clean page in window: plain MIN choice
        for e in rejected:
            if e[1] != chosen:
                stash.append(e)
        return self._finish(chosen, stash)


class LruPolicy(_HeapPolicy):
    name = "lru"

    def __init__(self):
        super().__init__(maximize=False)

    def touch(self, page: int, next_use: int, now: int) -> None:
        self._push(page, now)


class FifoPolicy(_HeapPolicy):
    name = "fifo"

    def __init__(self):
        super().__init__(maximize=False)

    def touch(self, page: int, next_use: int, now: int) -> None:
        if page not in self._cur:
            self._push(page, now)


POLICIES: dict[str, type[EvictionPolicy]] = {
    "min": MinPolicy,
    "min_clean": MinCleanPolicy,
    "lru": LruPolicy,
    "fifo": FifoPolicy,
}


@dataclasses.dataclass
class ReplacementStats:
    swap_ins: int = 0
    swap_outs: int = 0
    dropped_dirty: int = 0       # dirty pages dropped: never read again
    elided_swap_ins: int = 0     # write-allocate elisions
    num_frames: int = 0
    num_vpages: int = 0
    instructions: int = 0
    policy: str = "min"

    @property
    def total_swaps(self) -> int:
        return self.swap_ins + self.swap_outs


# One instruction plus its annotated page touches, in touch order:
# (instr, [(page, flags, next_any, next_read), ...]).  Both the in-memory
# and the file-streaming paths feed this shape to the same transducer core,
# which is what makes their outputs instruction-identical by construction.
_TouchRow = tuple[int, int, int, int]
_AnnotatedInstr = tuple[Instr, list[_TouchRow]]

_MISSING = object()


class _DensePageMap:
    """int→int map over a grow-on-demand page-indexed int64 array.

    Drop-in for the dicts the scalar core keeps per page (software page
    table, per-page next-read): page ids are dense small integers here,
    so direct array indexing replaces boxed-int hashing on every touch.
    Values are non-negative (frames, instruction indices, INF); -1 marks
    absent.  Exposes the dict surface the eviction policies consume
    (membership, indexing, ``pop``, ``len``, iteration)."""

    __slots__ = ("_arr", "_n")

    def __init__(self, cap: int = 1024):
        self._arr = np.full(cap, -1, dtype=np.int64)
        self._n = 0

    def _ensure(self, p: int) -> None:
        cap = self._arr.shape[0]
        if p >= cap:
            arr = np.full(max(p + 1, 2 * cap), -1, dtype=np.int64)
            arr[:cap] = self._arr
            self._arr = arr

    def __contains__(self, p: int) -> bool:
        return 0 <= p < self._arr.shape[0] and self._arr[p] >= 0

    def __getitem__(self, p: int) -> int:
        if p not in self:
            raise KeyError(p)
        return int(self._arr[p])

    def __setitem__(self, p: int, v: int) -> None:
        self._ensure(p)
        if self._arr[p] < 0:
            self._n += 1
        self._arr[p] = v

    def pop(self, p: int, default=_MISSING) -> int:
        if p in self:
            v = int(self._arr[p])
            self._arr[p] = -1
            self._n -= 1
            return v
        if default is _MISSING:
            raise KeyError(p)
        return default

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        return iter(np.nonzero(self._arr >= 0)[0].tolist())

    def keys(self):
        return iter(self)


class _DensePageSet:
    """Set of page ids over a grow-on-demand boolean array (see
    :class:`_DensePageMap` for why arrays beat dict/set here)."""

    __slots__ = ("_arr", "_n")

    def __init__(self, cap: int = 1024):
        self._arr = np.zeros(cap, dtype=bool)
        self._n = 0

    def add(self, p: int) -> None:
        cap = self._arr.shape[0]
        if p >= cap:
            arr = np.zeros(max(p + 1, 2 * cap), dtype=bool)
            arr[:cap] = self._arr
            self._arr = arr
        if not self._arr[p]:
            self._n += 1
            self._arr[p] = True

    def discard(self, p: int) -> None:
        if p in self:
            self._arr[p] = False
            self._n -= 1

    def __contains__(self, p: int) -> bool:
        return 0 <= p < self._arr.shape[0] and bool(self._arr[p])

    def __len__(self) -> int:
        return self._n

    def __iter__(self):
        return iter(np.nonzero(self._arr)[0].tolist())


def _replacement_core(items: Iterable[_AnnotatedInstr], num_frames: int,
                      pol: EvictionPolicy, shift: int, psize: int,
                      emit: Callable[[Instr], None],
                      stats: ReplacementStats) -> None:
    """Streaming Belady transducer: O(frames + pages-on-storage) state
    (dense page-indexed arrays; see :class:`_DensePageMap`)."""
    page_table = _DensePageMap()             # vpage -> frame
    free_frames = list(range(num_frames - 1, -1, -1))
    dirty = _DensePageSet()
    stored = _DensePageSet()                 # storage holds current content
    cur_next_read = _DensePageMap()          # resident pages only

    def acquire_frame(pinned: set[int]) -> int:
        if free_frames:
            return free_frames.pop()
        victim = pol.evict(pinned, page_table, dirty)
        frame = page_table.pop(victim)
        if victim in dirty:
            dirty.discard(victim)
            if cur_next_read.pop(victim, INF) < INF:
                emit(Instr(Op.SWAP_OUT,
                           ins=((frame << shift, psize),),
                           imm=(victim,)))
                stats.swap_outs += 1
                stored.add(victim)
            else:
                stats.dropped_dirty += 1
                stored.discard(victim)
        else:
            cur_next_read.pop(victim, None)
        # clean victim: storage copy (if any) is already current
        return frame

    def translate(span):
        addr, n = span
        vp = addr >> shift
        return ((page_table[vp] << shift) + (addr - (vp << shift)), n)

    for i, (ins, row) in enumerate(items):
        pinned = {p for p, _, _, _ in row}
        for p, f, nxt, nxr in row:
            if p not in page_table:
                frame = acquire_frame(pinned)
                if p in stored:
                    if f & W_FULL_WRITE:
                        stored.discard(p)
                        stats.elided_swap_ins += 1
                    else:
                        emit(Instr(Op.SWAP_IN,
                                   outs=((frame << shift, psize),),
                                   imm=(p,)))
                        stats.swap_ins += 1
                page_table[p] = frame
            if f & W_WRITE:
                dirty.add(p)
            cur_next_read[p] = nxr
            pol.touch(p, nxt, i)
        emit(Instr(ins.op,
                   tuple(translate(s) for s in ins.outs),
                   tuple(translate(s) for s in ins.ins),
                   ins.imm))
        stats.instructions += 1


def _items_from_touches(instrs: list[Instr], t: Touches
                        ) -> Iterator[_AnnotatedInstr]:
    offs, pg, fl = t.offsets, t.pages, t.flags
    nxt, nxr = t.next_any, t.next_read
    for i, ins in enumerate(instrs):
        yield ins, [(int(pg[k]), int(fl[k]), int(nxt[k]), int(nxr[k]))
                    for k in range(int(offs[i]), int(offs[i + 1]))]


def _items_from_files(pf: ProgramFile, ann: AnnotationReader,
                      chunk_instrs: int) -> Iterator[_AnnotatedInstr]:
    crc = 0
    for (s, rec), (s2, arr) in zip(pf.iter_chunks(chunk_instrs),
                                   ann.iter_chunks(chunk_instrs)):
        assert s == s2, "program/annotation chunking out of sync"
        crc = records_digest(crc, rec, s)
        for r, ins in enumerate(decode_chunk(rec)):
            yield ins, [(int(arr[r, 1 + 4 * j]), int(arr[r, 2 + 4 * j]),
                         int(arr[r, 3 + 4 * j]), int(arr[r, 4 + 4 * j]))
                        for j in range(int(arr[r, 0]))]
    if crc != ann.prog_crc:
        raise ValueError(
            "annotation sidecar does not match this program file "
            "(content checksum mismatch); regenerate it with "
            "annotate_next_use")


def _check_budget(num_frames: int, need: int) -> None:
    if num_frames < need:
        raise ValueError(
            f"num_frames={num_frames} < {need} pages touched by one "
            f"instruction; budget too small for this chunking")


# ---------------------------------------------------------------------------
# The record-array core (core="array", the default).
#
# Same transducer semantics as ``_replacement_core``, restructured around a
# batched no-miss fast path: a vectorized residency probe over the chunk's
# touch list finds the first miss, everything before it is bookkept with
# array scatters (hits never evict, so the probe's verdict cannot go stale
# within the clean prefix), and only the instruction containing the miss is
# handled by scalar code — including the eviction decision, which replays
# each heap policy's exact (key, page) tie-breaking over per-frame key
# arrays.  Operand rewriting is one gather per chunk, and output records are
# assembled as arrays, so the streaming pipeline never decodes an ``Instr``
# off the fast path.  Outputs are instruction-identical to the scalar core
# (tested bitwise via records_digest).
# ---------------------------------------------------------------------------

ARRAY_POLICIES = ("min", "min_clean", "lru", "fifo")
#: the array core keeps O(num_vpages) int64 per-page vectors (frame_of,
#: stored) and composite (key * num_vpages + page) eviction keys need
#: key * P < 2^62; past this page count (64 Mi pages = 64 GiB of data at
#: GC's 64 KiB pages — ~0.5 GiB of planner state) the planner falls back
#: to the scalar core's dict-based O(resident + stored) state instead
ARRAY_MAX_VPAGES = 1 << 26
_PROBE_MAX = 8192
_PROBE_MIN = 32
_SMALL_SEG = 12          # below this, scalar-loop the clean prefix too
_MIN_SENTINEL = -(1 << 62)   # pinned-frame key for maximizing policies
_LRU_SENTINEL = (1 << 62) + 1  # pinned-frame key for minimizing policies

CORES = ("array", "scalar")


def _check_core(core: str) -> None:
    if core not in CORES:
        raise ValueError(f"core must be one of {CORES}, got {core!r}")


class _ArrayCore:
    """Streaming Belady transducer over record chunks (state: O(frames)
    vectors plus O(num_vpages) int64/bool per-page vectors — the array
    analogue of the scalar core's page-table/stored dicts, bounded by
    ARRAY_MAX_VPAGES — plus one chunk).

    Per-frame eviction keys are stored as an injective COMPOSITE,
    ``key * P ± page`` (P = num_vpages), so the heap policies' exact pop
    order — best key first, then smallest page — collapses into a single
    argmax/argmin.  Next-use keys are clamped to ``INF // P`` first; that
    only collapses INF (real keys are instruction indices, and any
    program with T instructions touches at most 6T pages, so
    T * P < 6T^2 << 2^62 for every feasible program — guarded by
    ARRAY_MAX_VPAGES)."""

    def __init__(self, num_frames: int, policy: str, shift: int, psize: int,
                 num_vpages: int, stats: ReplacementStats):
        if policy not in ARRAY_POLICIES:
            raise ValueError(f"array core supports {ARRAY_POLICIES}, "
                             f"got {policy!r}")
        self.nf = num_frames
        self.policy = policy
        self.maximize = policy in ("min", "min_clean")
        if policy == "min_clean":
            ref = MinCleanPolicy()
            self.rel_delta, self.abs_delta = ref.rel_delta, ref.abs_delta
        self.shift = shift
        self.psize = psize
        self.stats = stats
        n = max(num_vpages, 1)
        self.P = n
        self.clamp = INF // n
        self.frame_of = np.full(n, -1, dtype=np.int64)
        self.stored = np.zeros(n, dtype=bool)
        self.page_of = np.full(num_frames, -1, dtype=np.int64)
        self.key_of = np.zeros(num_frames, dtype=np.int64)
        self.dirty_of = np.zeros(num_frames, dtype=bool)
        self.nxr_of = np.full(num_frames, INF, dtype=np.int64)
        self.free_ptr = 0
        self.probe_win = _PROBE_MAX
        self._dir_rows: list[list[int]] = []
        self._dir_rel: list[int] = []

    # -- event-time slow path -------------------------------------------------

    def _evict(self, pinned_frames: list[int]) -> int:
        """One argmax/argmin over the composite per-frame keys replays the
        lazy-deletion heap's exact pop order."""
        key_of = self.key_of
        sentinel = _MIN_SENTINEL if self.maximize else _LRU_SENTINEL
        saved = [(f, int(key_of[f])) for f in pinned_frames]
        for f, _ in saved:
            key_of[f] = sentinel
        try:
            if self.maximize:
                vf = int(np.argmax(key_of))
                if key_of[vf] == _MIN_SENTINEL:
                    raise RuntimeError(
                        "no evictable page: num_frames smaller than one "
                        "instruction's working set — raise the memory "
                        "budget or shrink DSL chunks")
                if self.policy == "min_clean":
                    return self._evict_min_clean(vf)
            else:
                vf = int(np.argmin(key_of))
                if key_of[vf] == _LRU_SENTINEL:
                    raise RuntimeError(
                        "no evictable page: num_frames smaller than one "
                        "instruction's working set — raise the memory "
                        "budget or shrink DSL chunks")
            return vf
        finally:
            for f, k in saved:
                key_of[f] = k

    def _evict_min_clean(self, vf: int) -> int:
        """MinClean's scan order: the farthest (min-page) entry if clean,
        else the best CLEAN composite within the window, else the plain
        MIN choice.  Runs with pinned sentinels in place."""
        key_of, dirty_of = self.key_of, self.dirty_of
        if not dirty_of[vf]:
            return vf
        far = int(key_of[vf]) // self.P          # the clamped key
        if far >= self.clamp:                    # i.e. next use == INF
            window_lo = self.clamp
        else:
            window_lo = far - max(self.abs_delta, int(self.rel_delta * far))
        # pinned sentinels sit far below any window threshold
        masked = np.where(dirty_of, _MIN_SENTINEL, key_of)
        cf = int(np.argmax(masked))
        if masked[cf] >= window_lo * self.P:
            return cf
        return vf

    def _touch(self, k: int, pinned, gi: int, pages_l, flags_l, nxt_l,
               nxr_l, tframe) -> None:
        """One scalar touch: exactly ``_replacement_core``'s per-touch body.
        ``pinned`` is the owning instruction's page list (only consulted if
        this touch faults)."""
        p = pages_l[k]
        fl = flags_l[k]
        frame_of = self.frame_of
        f = int(frame_of[p])
        if f < 0:
            if self.free_ptr < self.nf:
                f = self.free_ptr
                self.free_ptr += 1
            else:
                pf = []
                for q in pinned:
                    fq = int(frame_of[q])
                    if fq >= 0:
                        pf.append(fq)
                f = self._evict(pf)
                self._reclaim(f)
            st = self.stats
            if self.stored[p]:
                if fl & W_FULL_WRITE:
                    self.stored[p] = False
                    st.elided_swap_ins += 1
                else:
                    self._dir_rows.append(pack_row(
                        Op.SWAP_IN, outs=((f << self.shift, self.psize),),
                        imm=(p,)))
                    self._dir_rel.append(self._cur_rel)
                    st.swap_ins += 1
            frame_of[p] = f
            self.page_of[f] = p
            if self.policy == "fifo":
                self.key_of[f] = gi * self.P + p
        if fl & W_WRITE:
            self.dirty_of[f] = True
        self.nxr_of[f] = nxr_l[k]
        if self.maximize:
            self.key_of[f] = min(nxt_l[k], self.clamp) * self.P \
                + (self.P - 1 - p)
        elif self.policy == "lru":
            self.key_of[f] = gi * self.P + p
        tframe[k] = f

    def _reclaim(self, victim_f: int) -> None:
        """Unmap the eviction victim, emitting its write-back if needed."""
        vq = int(self.page_of[victim_f])
        st = self.stats
        if self.dirty_of[victim_f]:
            self.dirty_of[victim_f] = False
            if self.nxr_of[victim_f] < INF:
                self._dir_rows.append(pack_row(
                    Op.SWAP_OUT,
                    ins=((victim_f << self.shift, self.psize),),
                    imm=(vq,)))
                self._dir_rel.append(self._cur_rel)
                st.swap_outs += 1
                self.stored[vq] = True
            else:
                st.dropped_dirty += 1
                self.stored[vq] = False
        self.frame_of[vq] = -1
        self.page_of[victim_f] = -1

    # -- per-chunk drive ------------------------------------------------------

    def process_chunk(self, start: int, rec: np.ndarray, offs: np.ndarray,
                      pages: np.ndarray, flags: np.ndarray,
                      nxt: np.ndarray, nxr: np.ndarray) -> np.ndarray:
        """Transduce one chunk; returns the output records (directives
        interleaved before their instruction, operands rewritten)."""
        m = rec.shape[0]
        T = pages.shape[0]
        self._dir_rows = []
        self._dir_rel = []
        tframe = np.empty(T, dtype=np.int64)
        counts = np.diff(offs)
        rows = np.repeat(np.arange(m, dtype=np.int64), counts)
        write_mask = (flags & W_WRITE) != 0
        pages_l = pages.tolist()
        flags_l = flags.tolist()
        nxt_l = nxt.tolist()
        nxr_l = nxr.tolist()
        rows_l = rows.tolist()
        offs_l = offs.tolist()
        frame_of, key_of = self.frame_of, self.key_of
        maximize, lru = self.maximize, self.policy == "lru"

        t = 0
        win = self.probe_win
        while t < T:
            end = min(t + win, T)
            fr = frame_of[pages[t:end]]
            missrel = np.nonzero(fr < 0)[0]
            m0 = t + int(missrel[0]) if missrel.size else end
            if m0 > t:
                if m0 - t < _SMALL_SEG:
                    for k in range(t, m0):
                        self._touch(k, (), start + rows_l[k], pages_l,
                                    flags_l, nxt_l, nxr_l, tframe)
                else:
                    seg = slice(t, m0)
                    sfr = fr[:m0 - t]
                    tframe[seg] = sfr
                    self.dirty_of[sfr[write_mask[seg]]] = True
                    self.nxr_of[sfr] = nxr[seg]
                    if maximize:
                        key_of[sfr] = np.minimum(nxt[seg], self.clamp) \
                            * self.P + (self.P - 1 - pages[seg])
                    elif lru:
                        key_of[sfr] = (start + rows[seg]) * self.P \
                            + pages[seg]
            if m0 < end:
                # event: scalar-handle the rest of the faulting instruction
                dist = m0 - t
                i = rows_l[m0]
                self._cur_rel = i
                row_end = offs_l[i + 1]
                pinned = pages_l[offs_l[i]:row_end]
                gi = start + i
                for k in range(m0, row_end):
                    self._touch(k, pinned, gi, pages_l, flags_l, nxt_l,
                                nxr_l, tframe)
                t = row_end
                win = max(_PROBE_MIN, min(win, 2 * (dist + 8)))
            else:
                t = end
                win = min(win * 2, _PROBE_MAX)
        self.probe_win = win
        self.stats.instructions += m
        return self._emit_chunk(rec, offs, rows, counts, pages, tframe)

    def _emit_chunk(self, rec, offs, rows, counts, pages, tframe):
        m = rec.shape[0]
        out = rec.copy()
        if len(pages):
            # per-instruction page -> frame maps, padded to the touch arity
            S = ANN_TOUCH_SLOTS
            pages_pad = np.full((m, S), -1, dtype=np.int64)
            frames_pad = np.zeros((m, S), dtype=np.int64)
            ordinal = np.arange(len(pages), dtype=np.int64) - \
                np.repeat(offs[:-1], counts)
            pages_pad[rows, ordinal] = pages
            frames_pad[rows, ordinal] = tframe
            _ops, n_outs, n_ins, _ = unpack_heads(rec[:, 0])
            shift = self.shift
            ar = np.arange(m)
            slots = [(_OUT_OFF + 2 * j, n_outs > j) for j in range(MAX_OUTS)]
            slots += [(_IN_OFF + 2 * j, n_ins > j) for j in range(MAX_INS)]
            for off, present in slots:
                sel = present & (rec[:, off + 1] > 0)
                if not sel.any():
                    continue
                addr = rec[:, off]
                vp = addr >> shift
                match = pages_pad == vp[:, None]
                if not match.any(axis=1)[sel].all():
                    raise KeyError(
                        "operand page missing from its instruction's touch "
                        "set — span straddles a page or arity is corrupt")
                frame = frames_pad[ar, np.argmax(match, axis=1)]
                out[sel, off] = addr[sel] + ((frame[sel] - vp[sel]) << shift)
        D = len(self._dir_rows)
        if D == 0:
            return out
        drel = np.asarray(self._dir_rel, dtype=np.int64)
        dcount = np.bincount(drel, minlength=m)
        ipos = np.arange(m, dtype=np.int64) + np.cumsum(dcount)
        full = np.empty((m + D, RECORD_WORDS), dtype=np.int64)
        full[ipos] = out
        hole = np.ones(m + D, dtype=bool)
        hole[ipos] = False
        full[hole] = np.asarray(self._dir_rows, dtype=np.int64)
        return full


def _array_chunks_from_files(pf: ProgramFile, ann: AnnotationReader,
                             chunk_instrs: int):
    """Yield (start, rec, offsets, pages, flags, next_any, next_read) per
    chunk from a program file + its sidecar, validating the content digest
    exactly like the scalar ``_items_from_files``."""
    crc = 0
    for (s, rec), (s2, arr) in zip(pf.iter_chunks(chunk_instrs),
                                   ann.iter_chunks(chunk_instrs)):
        assert s == s2, "program/annotation chunking out of sync"
        crc = records_digest(crc, rec, s)
        counts = arr[:, 0]
        m = len(counts)
        offs = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(counts, out=offs[1:])
        T = int(offs[-1])
        rows = np.repeat(np.arange(m, dtype=np.int64), counts)
        ordinal = np.arange(T, dtype=np.int64) - np.repeat(offs[:-1], counts)
        base = rows * ANN_WORDS + 1 + ordinal * 4
        flat = arr.reshape(-1)
        yield s, rec, offs, flat[base], flat[base + 1], flat[base + 2], \
            flat[base + 3]
    if crc != ann.prog_crc:
        raise ValueError(
            "annotation sidecar does not match this program file "
            "(content checksum mismatch); regenerate it with "
            "annotate_next_use")


def _use_array_core(core: str, policy: str | EvictionPolicy) -> bool:
    """The array core handles the registered policy names; custom
    EvictionPolicy instances keep the scalar reference core."""
    _check_core(core)
    return core == "array" and isinstance(policy, str) \
        and policy in ARRAY_POLICIES


def replacement_records(prog: Program, num_frames: int,
                        policy: str | EvictionPolicy = "min",
                        chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                        ) -> tuple[list[np.ndarray], ReplacementStats] | None:
    """Stage 2 over an in-memory program, producing OUTPUT RECORD CHUNKS.

    The fused ``plan()`` pipeline keeps chunks as arrays between stages
    (one encode at the front, one decode at the very end).  Returns None
    when the array core cannot run this program/policy (straddling spans,
    wide arity, custom EvictionPolicy instance) — callers fall back to
    the scalar reference."""
    assert prog.phase == "virtual", prog.phase
    if not _use_array_core("array", policy):
        return None
    instrs = strip_frees(prog.instrs)
    try:
        rec = encode_chunk(instrs)
        touches = touches_from_records(rec, prog.page_shift,
                                       prog.page_slots, chunk_instrs)
    except (TypeError, ValueError):
        return None
    if touches.num_pages >= ARRAY_MAX_VPAGES:
        return None
    _check_budget(num_frames, max_pages_per_instr(touches))
    stats = ReplacementStats(num_frames=num_frames,
                             num_vpages=touches.num_pages,
                             policy=policy)
    ac = _ArrayCore(num_frames, policy, prog.page_shift, prog.page_slots,
                    touches.num_pages, stats)
    offs = touches.offsets
    flags64 = touches.flags.astype(np.int64)
    chunks: list[np.ndarray] = []
    for s in range(0, len(instrs), chunk_instrs):
        e = min(s + chunk_instrs, len(instrs))
        lo, hi = int(offs[s]), int(offs[e])
        chunks.append(ac.process_chunk(
            s, rec[s:e], offs[s:e + 1] - lo,
            touches.pages[lo:hi], flags64[lo:hi],
            touches.next_any[lo:hi], touches.next_read[lo:hi]))
    return chunks, stats


def plan_replacement(prog: Program, num_frames: int,
                     policy: str | EvictionPolicy = "min",
                     core: str = "array",
                     chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                     ) -> tuple[Program, ReplacementStats]:
    """Stage 2: rewrite a 'virtual' program into a 'physical' one.

    ``core="array"`` (default) runs the vectorized record-array core;
    ``core="scalar"`` the reference transducer.  Outputs are
    instruction-identical."""
    assert prog.phase == "virtual", prog.phase
    _check_core(core)
    got = replacement_records(prog, num_frames, policy, chunk_instrs) \
        if core == "array" else None
    out: list[Instr] = []
    if got is not None:
        chunks, stats = got
        for c in chunks:
            out.extend(decode_chunk(c))
    else:
        instrs, touches = stripped_touches(prog)
        _check_budget(num_frames, max_pages_per_instr(touches))
        pol = POLICIES[policy]() if isinstance(policy, str) else policy
        stats = ReplacementStats(num_frames=num_frames,
                                 num_vpages=touches.num_pages,
                                 policy=getattr(pol, "name", str(policy)))
        _replacement_core(_items_from_touches(instrs, touches), num_frames,
                          pol, prog.page_shift, prog.page_slots, out.append,
                          stats)
    res = Program(
        instrs=out, page_shift=prog.page_shift, protocol=prog.protocol,
        phase="physical", worker=prog.worker, num_workers=prog.num_workers,
        vspace_slots=prog.vspace_slots, num_frames=num_frames,
        meta=dict(prog.meta),
    )
    return res, stats


def plan_replacement_file(pf: ProgramFile, out_path: str | os.PathLike,
                          num_frames: int,
                          policy: str | EvictionPolicy = "min",
                          annotations: AnnotationReader | str | None = None,
                          chunk_instrs: int = DEFAULT_CHUNK_INSTRS,
                          core: str = "array",
                          ) -> tuple[ProgramFile, ReplacementStats]:
    """Stage 2, out-of-core: stream a 'virtual' bytecode file (plus its
    next-use sidecar) into a 'physical' bytecode file.  With the default
    ``core="array"`` chunks stay record arrays end-to-end (no per-
    instruction decode/encode on the fast path)."""
    assert pf.phase == "virtual", pf.phase
    out_path = os.fspath(out_path)
    own_ann = annotations is None
    if own_ann:
        annotations = annotate_next_use(pf, out_path + ".ann",
                                        chunk_instrs).path
    if not isinstance(annotations, AnnotationReader):
        annotations = AnnotationReader(annotations)
    try:
        if annotations.n_records != pf.num_records:
            raise ValueError(
                f"annotation sidecar has {annotations.n_records} records "
                f"but program has {pf.num_records}; stale sidecar?")
        _check_budget(num_frames, annotations.max_touches)
        use_array = _use_array_core(core, policy) \
            and annotations.num_pages < ARRAY_MAX_VPAGES
        if use_array:
            stats = ReplacementStats(num_frames=num_frames,
                                     num_vpages=annotations.num_pages,
                                     policy=policy)
        else:
            pol = POLICIES[policy]() if isinstance(policy, str) else policy
            stats = ReplacementStats(num_frames=num_frames,
                                     num_vpages=annotations.num_pages,
                                     policy=getattr(pol, "name", str(policy)))
        with writer_like(pf, out_path, phase="physical",
                         num_frames=num_frames,
                         chunk_instrs=chunk_instrs) as w:
            if use_array:
                ac = _ArrayCore(num_frames, policy, pf.page_shift,
                                pf.page_slots, annotations.num_pages, stats)
                for (s, rec, offs, pg, fl, na, nr) in \
                        _array_chunks_from_files(pf, annotations,
                                                 chunk_instrs):
                    w.append_records(ac.process_chunk(s, rec, offs, pg, fl,
                                                      na, nr))
            else:
                _replacement_core(
                    _items_from_files(pf, annotations, chunk_instrs),
                    num_frames, pol, pf.page_shift, pf.page_slots,
                    w.append, stats)
    finally:
        if own_ann and os.path.exists(annotations.path):
            os.unlink(annotations.path)
    return ProgramFile(out_path), stats
