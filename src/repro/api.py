"""The deployment facade: a declarative ``JobSpec`` plus a staged ``Session``.

The paper's deployment unit is a config file plus two stages — ``mage plan``
produces on-disk memory programs, the engine executes them (§6, §8.1.3).
This module is that unit for the repro: a frozen :class:`JobSpec` names a
workload, a memory budget, a plan mode and a driver/storage pair, and a
:class:`Session` runs the staged pipeline

    trace() → plan() → execute(real=…) / simulate(cost_fn)

on top of the single worker-orchestration core in ``core.workers``.  Plans
can be saved to a directory (``save_plan``) and executed later or elsewhere
(``Session.from_plan`` / ``python -m repro run``); every planned program
carries the spec hash in its ``meta`` so stale or tampered artifacts are
rejected instead of silently executed.

Drivers, storage backends and transports are *registries* keyed by name
(``{"gc-plaintext", "gc-2party", "ckks"} × {"ram", "memmap"} ×
{"inproc", "tcp", "shaped"}`` in-tree), so call sites select protocols by
string instead of importing concrete classes; ``register_driver`` /
``register_storage`` / ``register_transport`` extend them (§4.3's
extensibility argument, surfaced at the API).

All communication — intra-party NET_* directives and inter-party garbled
traffic — rides one transport fabric (``core.transport``).  A spec's
``transport`` picks the backend and its ``fabric`` (:class:`FabricSpec`)
places endpoints: ``rank=None`` runs every engine in this process
(threads), ``rank=k`` runs exactly one engine against remote peers —
that is ``python -m repro run --worker k --peers ...`` (§5.2's
one-engine-per-worker-per-party deployment; see docs/DISTRIBUTED.md).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Callable

import numpy as np

from .core.bytecode import (Program, ProgramFile, write_program)
from .core.engine import EngineStats, ProtocolDriver
from .core.liveness import working_set_pages_stream
from .core.replacement import CORES
from .core.planner import PlanConfig, PlanReport
from .core.simulator import (DeviceModel, SimResult, simulate_memory_program,
                             simulate_os_paging, simulate_unbounded)
from .core.storage import MemmapStorage, RamStorage, StorageBackend
from .core.transport import Fabric, FabricSpec, LinkStats, build_fabric
from .core.transport import register_transport  # noqa: F401  (re-export)
from .core.workers import EngineJob, plan_workers, run_engines
from .protocols.ckks import CkksDriver, CkksParams
from .protocols.garbled.driver import (EvaluatorDriver, GarblerDriver,
                                       PlaintextDriver)
from .protocols.garbled.gates import PartyChannel
from .protocols.shamir.driver import ShamirDriver
from .workloads import Workload, get

PLAN_MODES = ("memory", "streaming", "unbounded")
#: Engine execution backends: "scalar" is the per-instruction reference
#: loop; "batched" precomputes a batch schedule from the plan's oblivious
#: instruction stream and executes uniform independent groups through
#: ``driver.execute_batch`` (see repro.exec and docs/ENGINE.md).
#: "overlap" additionally precomputes an out-of-order issue schedule that
#: hoists NET_SENDs, defers NET_RECV completions and fills the WAN
#: latency gap with independent local work (see repro.exec.overlap and
#: docs/OVERLAP.md).  Like plan_core/sim_core, all three are
#: output-identical by construction.
EXEC_BACKENDS = ("scalar", "batched", "overlap")

#: Version stamped into every machine-readable output (CLI ``--json``
#: files and the serving daemon's protocol responses) so consumers can
#: evolve with the formats.
SCHEMA_VERSION = 1

#: bytes per address-space slot, per protocol — a GC slot is one 128-bit
#: wire label, a CKKS or Shamir slot one 8-byte word (what the timing
#: simulator and the OS-paging baseline charge per page).
SLOT_BYTES = {"gc": 16, "ckks": 8, "shamir": 8}

#: JobSpec fields that determine the planned memory program.  Execution
#: details (driver, exec_backend, storage, workdir, parallelism, chunking)
#: are excluded:
#: a plan produced under any of them is valid under all of them, and
#: ``plan_mode`` / ``plan_core`` / ``sim_core`` are excluded because the
#: streaming and in-memory pipelines, the array and scalar planner cores,
#: and the array and scalar simulator cores are all output-identical by
#: construction (tested).
PLAN_HASH_FIELDS = ("workload", "n", "num_workers", "memory_budget",
                    "lookahead", "prefetch_pages", "policy", "swap_bypass",
                    "ckks_ring", "ckks_levels")

#: The subset of PLAN_HASH_FIELDS that determines the *traced* bytecode:
#: the DSL trace is a pure function of the workload shape, so traced
#: programs (and their next-use sidecars) are shared across every budget
#: / lookahead / policy variation of the same shape in the artifact
#: cache (``JobSpec.trace_hash``).
TRACE_HASH_FIELDS = ("workload", "n", "num_workers", "ckks_ring",
                     "ckks_levels")

JOB_FILE = "job.json"


class SpecMismatchError(ValueError):
    """A plan artifact does not match the spec that claims it."""


# ---------------------------------------------------------------------------
# driver / storage registries
# ---------------------------------------------------------------------------

# A driver factory builds ProtocolDrivers for the endpoints THIS process
# hosts: it gets the session and the connected Fabric and returns
# {global_rank: driver} for fabric.hosted only — so a distributed
# single-rank process constructs exactly its own driver.  Global rank =
# party * num_workers + worker; the registry records how many parties a
# driver deploys (gc-2party: 2, everything else: 1).  Outputs are
# collected from every hosted driver exposing a non-empty ``.outputs``
# (for two-party GC that is the evaluator side only, matching the
# protocol).

DriverFactory = Callable[["Session", Fabric], dict[int, ProtocolDriver]]
StorageFactory = Callable[[tuple, np.dtype], StorageBackend]


@dataclasses.dataclass(frozen=True)
class DriverDef:
    factory: DriverFactory
    parties: int = 1


DRIVERS: dict[str, DriverDef] = {}
STORAGE_BACKENDS: dict[str, StorageFactory] = {}


def register_driver(name: str, factory: DriverFactory,
                    parties: int = 1) -> None:
    DRIVERS[name] = DriverDef(factory, parties)


def driver_parties(name: str) -> int:
    """Number of parties (rank blocks) a registered driver deploys."""
    return _driver_def(name).parties


def _driver_def(name: str) -> DriverDef:
    try:
        return DRIVERS[name]
    except KeyError:
        raise KeyError(f"unknown driver {name!r}; registered: "
                       f"{sorted(DRIVERS)}") from None


def register_storage(name: str, factory: StorageFactory) -> None:
    STORAGE_BACKENDS[name] = factory


def _gc_plaintext_drivers(s: "Session", fx: Fabric
                          ) -> dict[int, ProtocolDriver]:
    w, n, p = s.workload, s.spec.n, s.spec.num_workers
    return {r: PlaintextDriver(w.inputs(n, r % p, p)) for r in fx.hosted}


def _gc_two_party_drivers(s: "Session", fx: Fabric
                          ) -> dict[int, ProtocolDriver]:
    # one cross-party link per worker pair: garbler rank wk sends to
    # evaluator rank p + wk (the one-to-one inter-party topology of Fig. 3)
    w, n, p = s.workload, s.spec.n, s.spec.num_workers
    out: dict[int, ProtocolDriver] = {}
    for r in fx.hosted:
        party, wk = divmod(r, p)
        link = PartyChannel(fx.transport_for(r), src=wk, dst=p + wk)
        if party == 0:
            out[r] = GarblerDriver(link, w.inputs(n, wk, p), seed=7)
        else:
            out[r] = EvaluatorDriver(link, w.inputs(n, wk, p))
    return out


def _ckks_drivers(s: "Session", fx: Fabric) -> dict[int, ProtocolDriver]:
    w, n, p = s.workload, s.spec.n, s.spec.num_workers
    params = s.ckks_params()
    return {r: CkksDriver(params, w.inputs(n, r % p, p), seed=0xCEC5)
            for r in fx.hosted}


def _shamir_drivers(s: "Session", fx: Fabric) -> dict[int, ProtocolDriver]:
    # the n Shamir parties ARE the n workers of one registry party: worker
    # rank == party index, MUL resharing rounds ride the all-to-all worker
    # links as ordinary NET_* directives (see docs/SHAMIR.md)
    w, n, p = s.workload, s.spec.n, s.spec.num_workers
    return {r: ShamirDriver(p, r % p, w.inputs(n, r % p, p))
            for r in fx.hosted}


def _shamir_fixed(n_parties: int) -> DriverFactory:
    def factory(s: "Session", fx: Fabric) -> dict[int, ProtocolDriver]:
        if s.spec.num_workers != n_parties:
            raise ValueError(
                f"driver shamir-{n_parties}party needs num_workers="
                f"{n_parties}, got {s.spec.num_workers}")
        return _shamir_drivers(s, fx)
    return factory


register_driver("gc-plaintext", _gc_plaintext_drivers)
register_driver("gc-2party", _gc_two_party_drivers, parties=2)
register_driver("ckks", _ckks_drivers)
register_driver("shamir", _shamir_drivers)
register_driver("shamir-3party", _shamir_fixed(3))
register_driver("shamir-5party", _shamir_fixed(5))
register_storage("ram", lambda shape, dtype: RamStorage(shape, dtype))
register_storage("memmap", lambda shape, dtype: MemmapStorage(shape, dtype))


# ---------------------------------------------------------------------------
# discovery: the stable way to enumerate what the registries offer
# ---------------------------------------------------------------------------


def list_workloads() -> list[str]:
    """Registered workload names (`JobSpec.workload` values)."""
    from .workloads import all_names
    return all_names()


def list_drivers() -> list[str]:
    """Registered protocol drivers (`JobSpec.driver` values)."""
    return sorted(DRIVERS)


def list_storages() -> list[str]:
    """Registered storage backends (`JobSpec.storage` values)."""
    return sorted(STORAGE_BACKENDS)


def list_transports() -> list[str]:
    """Registered transport fabrics (`JobSpec.transport` values)."""
    from .core.transport import TRANSPORTS
    return sorted(TRANSPORTS)


# ---------------------------------------------------------------------------
# JobSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """Declarative description of one trace→plan→execute job.

    ``memory_budget`` is the paper's T: an ``int`` is an absolute frame
    count used as-is; a ``float`` in (0, 1] is a fraction of the worker's
    working set, resolved per worker with the benchmark harness's clamping
    (floor of ``8 + prefetch_pages`` frames, capped below the working set
    so there is real memory pressure, prefetch buffer at most a quarter of
    the budget).  ``None`` requires ``plan_mode="unbounded"``.

    ``transport`` + ``fabric`` are execution details (never part of the
    plan hash): the transport registry name and the endpoint placement /
    link shaping (:class:`~repro.core.transport.FabricSpec`).
    """
    workload: str
    n: int | None = None                  # problem size (None → default_n)
    num_workers: int = 1
    memory_budget: int | float | None = None
    lookahead: int = 10_000               # plan knobs (paper l, B, policy)
    prefetch_pages: int = 0
    policy: str = "min"
    swap_bypass: bool = False
    plan_mode: str = "memory"             # memory | streaming | unbounded
    plan_core: str = "array"              # array | scalar (identical output)
    sim_core: str = "array"               # simulator core (identical results)
    parallel_plan: bool | str = "serial"  # serial | thread | process
    driver: str = "auto"                  # auto → protocol default
    exec_backend: str = "scalar"          # scalar | batched (see docs/ENGINE.md)
    storage: str = "ram"                  # ram | memmap
    transport: str = "inproc"             # inproc | tcp | shaped
    fabric: FabricSpec | None = None      # endpoint placement / shaping
    workdir: str | None = None            # streaming plan files live here
    chunk_instrs: int = 8192
    track_plan_memory: bool = False
    ckks_ring: int | None = None          # CKKS N override (benchmarks)
    ckks_levels: int | None = None

    def __post_init__(self):
        if self.plan_mode not in PLAN_MODES:
            raise ValueError(f"plan_mode must be one of {PLAN_MODES}, "
                             f"got {self.plan_mode!r}")
        if self.plan_core not in CORES:
            raise ValueError(f"plan_core must be one of {CORES}, "
                             f"got {self.plan_core!r}")
        if self.sim_core not in CORES:
            raise ValueError(f"sim_core must be one of {CORES}, "
                             f"got {self.sim_core!r}")
        if self.exec_backend not in EXEC_BACKENDS:
            raise ValueError(f"exec_backend must be one of {EXEC_BACKENDS}, "
                             f"got {self.exec_backend!r}")
        if self.plan_mode == "unbounded":
            if self.memory_budget is not None:
                raise ValueError("unbounded jobs take no memory_budget")
        elif self.memory_budget is None:
            raise ValueError(f"plan_mode={self.plan_mode!r} needs a "
                             f"memory_budget (frames or working-set fraction)")
        if isinstance(self.memory_budget, float) and \
                not 0.0 < self.memory_budget <= 1.0:
            raise ValueError("fractional memory_budget must be in (0, 1]")
        if isinstance(self.fabric, dict):  # from_dict / JSON round-trip
            object.__setattr__(self, "fabric", FabricSpec(**self.fabric))

    # -- derived / resolved ---------------------------------------------------

    def normalized(self, workload: "Workload | None" = None) -> "JobSpec":
        """Fill workload-dependent defaults (n, driver) in."""
        w = workload if workload is not None else get(self.workload)
        changes = {}
        if self.n is None:
            changes["n"] = w.default_n
        if self.driver == "auto":
            changes["driver"] = {"ckks": "ckks", "shamir": "shamir"}.get(
                w.protocol, "gc-plaintext")
        return dataclasses.replace(self, **changes) if changes else self

    def plan_hash(self, workload: "Workload | None" = None) -> str:
        """Digest of the plan-determining fields (see PLAN_HASH_FIELDS)."""
        return self._hash(PLAN_HASH_FIELDS, workload)

    def trace_hash(self, workload: "Workload | None" = None) -> str:
        """Digest of the trace-determining fields (see TRACE_HASH_FIELDS)."""
        return self._hash(TRACE_HASH_FIELDS, workload)

    def _hash(self, fields: tuple[str, ...],
              workload: "Workload | None" = None) -> str:
        spec = self.normalized(workload)
        payload = {k: getattr(spec, k) for k in fields}
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "JobSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown JobSpec fields: {sorted(unknown)}")
        return cls(**d)


def resolve_plan_config(spec: JobSpec, prog: Program,
                        working_set: int | None = None) -> PlanConfig:
    """Turn a spec's budget into a concrete per-worker PlanConfig."""
    b = spec.memory_budget
    prefetch = spec.prefetch_pages
    if isinstance(b, float):
        ws = working_set if working_set is not None \
            else working_set_pages_stream(prog)
        min_frames = 8 + prefetch
        budget = max(int(ws * b), min_frames)
        budget = min(budget, max(ws - 1, min_frames))
        prefetch = min(prefetch, max(budget // 4, 1))
    else:
        budget = int(b)
    return PlanConfig(num_frames=budget, lookahead=spec.lookahead,
                      prefetch_pages=prefetch, policy=spec.policy,
                      swap_bypass=spec.swap_bypass, core=spec.plan_core)


# ---------------------------------------------------------------------------
# simulate() result
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WorkerScenarios:
    """Per-worker §8.2 scenario timings + plan metadata."""
    unbounded: SimResult
    os: SimResult
    mage: SimResult
    report: PlanReport
    config: PlanConfig
    working_set_pages: int
    page_bytes: int
    instructions: int
    program_bytes: int                   # memory program size (file or est.)


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------


class Session:
    """Staged trace→plan→execute/simulate over one JobSpec.

    Stages cache: ``trace()`` and ``plan()`` are idempotent, ``execute()``
    and ``simulate()`` call them as needed.  Streaming plans with no
    explicit ``workdir`` live in a session-owned temp directory — use the
    session as a context manager (or call :meth:`close`) to clean it up,
    or :meth:`save_plan` to move the artifacts somewhere durable.
    """

    def __init__(self, spec: JobSpec, workload: Workload | None = None,
                 cache=None):
        """``workload`` overrides the registry lookup (e.g. an unregistered
        or parameter-adjusted Workload object); its name must match.

        ``cache`` — an :class:`~repro.serve_daemon.ArtifactCache` or a
        cache-root path — makes ``trace()`` and ``plan()`` serve repeated
        job shapes from validated on-disk artifacts (see docs/SERVE.md).
        Custom workload objects bypass the cache: their traced programs
        are not a pure function of the registry name."""
        if workload is not None and workload.name != spec.workload:
            raise ValueError(f"workload object {workload.name!r} does not "
                             f"match spec.workload {spec.workload!r}")
        self.workload: Workload = workload if workload is not None \
            else get(spec.workload)
        self.spec = spec.normalized(self.workload)
        self._progs: list[Program | ProgramFile] | None = None
        self._planned: list[Program | ProgramFile] | None = None
        self._cfgs: list[PlanConfig | None] | None = None
        self._ws: dict[int, int] = {}
        self._tmpdir: str | None = None
        self._cache = None
        self._plan_probed = False
        self._trace_anns: list[str] | None = None
        #: per-stage cache outcomes of THIS session: {"trace"|"plan":
        #: "hit"|"miss"}; stages that never consulted the cache are absent
        self.cache_events: dict[str, str] = {}
        if cache is not None:
            self.set_cache(cache)
        self.plan_reports: list[PlanReport] = []
        self.engine_stats: list[EngineStats] = []
        #: sent-traffic accounting of the last execute()'s fabric,
        #: (src_rank, dst_rank, tag) -> LinkStats
        self.transport_stats: dict[tuple[int, int, int], LinkStats] = {}

    def set_cache(self, cache) -> None:
        """Attach an artifact cache (an ArtifactCache or a root path)."""
        from .serve_daemon.cache import ArtifactCache
        if not isinstance(cache, ArtifactCache):
            cache = ArtifactCache(cache)
        self._cache = cache

    @property
    def cache(self):
        """The attached ArtifactCache, or None."""
        return self._cache

    def _usable_cache(self):
        """Custom (non-registry) workload objects must bypass the cache."""
        if self._cache is None:
            return None
        try:
            registered = get(self.spec.workload)
        except KeyError:
            return None
        return self._cache if self.workload is registered else None

    # -- lifecycle -------------------------------------------------------------

    def close(self) -> None:
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- helpers ---------------------------------------------------------------

    @property
    def protocol(self) -> str:
        return self.workload.protocol

    def ckks_params(self) -> CkksParams:
        from .workloads.ckks_workloads import PARAMS as DEFAULT_CKKS
        base = self.workload.params.get("ckks_params", DEFAULT_CKKS)
        if self.spec.ckks_ring is None and self.spec.ckks_levels is None:
            return base
        # replace, don't rebuild: keep the base's scale/noise parameters
        return dataclasses.replace(
            base, n_ring=self.spec.ckks_ring or base.n_ring,
            levels=self.spec.ckks_levels or base.levels)

    def working_set(self, worker: int = 0) -> int:
        """Peak live pages of one worker's virtual trace (w of §2.4.3)."""
        if worker not in self._ws:
            prog = self.trace()[worker]
            self._ws[worker] = working_set_pages_stream(prog)
        return self._ws[worker]

    def _workdir(self) -> str | None:
        if self.spec.workdir is not None:
            return self.spec.workdir
        if self.spec.plan_mode == "streaming":
            if self._tmpdir is None:
                self._tmpdir = tempfile.mkdtemp(prefix="mage_job_")
            return self._tmpdir
        return None

    # -- stage 1: trace --------------------------------------------------------

    def trace(self, cache_dir=None) -> list[Program | ProgramFile]:
        """Trace the workload's DSL program, one bytecode per worker; the
        spec hash is stamped into every program's meta (placement, §6.1).

        With a cache attached (``cache_dir=`` here, or ``cache=`` at
        construction), a repeated trace shape (``spec.trace_hash()``) is
        served as validated FREE-stripped bytecode files + next-use
        sidecars instead of re-running the DSL — the slowest §8.2 stage.
        A fresh trace populates the cache, and the session adopts the
        cached files so cold and hot runs plan identically."""
        if cache_dir is not None:
            self.set_cache(cache_dir)
        if self._progs is None:
            spec = self.spec
            cache = self._usable_cache()
            if cache is not None:
                got = cache.get_trace(spec, self.workload)
                if got is not None:
                    self.cache_events["trace"] = "hit"
                    self._adopt_trace(*got)
                    return self._progs
                self.cache_events["trace"] = "miss"
            extra = {}
            if self.protocol == "ckks":
                extra["ckks_params"] = self.ckks_params()
            progs = self.workload.trace(spec.n, spec.num_workers, **extra)
            if cache is not None:
                self._adopt_trace(*cache.put_trace(
                    spec, self.workload, progs,
                    chunk_instrs=spec.chunk_instrs))
            else:
                h = spec.plan_hash(self.workload)
                for p in progs:
                    p.meta["spec_hash"] = h
                    p.meta["job_spec"] = spec.to_dict()
                self._progs = progs
        return self._progs

    def _adopt_trace(self, progs: list[ProgramFile],
                     anns: list[str]) -> None:
        """Use cache-resident bytecode files as this session's trace; the
        spec stamp lives in the entry as a pure trace hash, so the
        session's own spec identity is restamped in-memory."""
        h = self.spec.plan_hash(self.workload)
        for pf in progs:
            pf.meta["spec_hash"] = h
            pf.meta["job_spec"] = self.spec.to_dict()
        self._progs = list(progs)
        self._trace_anns = list(anns)

    # -- stage 2: plan ---------------------------------------------------------

    def plan(self, cache_dir=None) -> list[Program | ProgramFile]:
        """Replacement + scheduling per worker (§6.1) under the spec's
        budget and mode; returns memory programs (files when streaming).

        With a cache attached, a repeated plan shape (``spec.plan_hash()``)
        is served from validated memory-program files — zero tracing and
        zero planning — with the resolved per-worker configs and reports
        restored, so a cache-hit session can still ``simulate()``."""
        if cache_dir is not None:
            self.set_cache(cache_dir)
        if self._planned is None:
            spec = self.spec
            if spec.plan_mode != "unbounded" and self.plan_if_cached():
                return self._planned
            progs = self.trace()
            if spec.plan_mode == "unbounded":
                self._planned = list(progs)
                self._cfgs = [None] * len(progs)
                self.plan_reports = [PlanReport() for _ in progs]
            else:
                streaming = spec.plan_mode == "streaming"
                cfgs = [resolve_plan_config(spec, p, self.working_set(i))
                        if isinstance(spec.memory_budget, float)
                        else resolve_plan_config(spec, p)
                        for i, p in enumerate(progs)]
                if not streaming:
                    # the in-memory planner cores need .instrs; cache-hit
                    # traces are files, so materialize them (small by
                    # definition of the in-memory mode)
                    progs = [p.read_program() if isinstance(p, ProgramFile)
                             else p for p in progs]
                planned, reports = plan_workers(
                    progs, cfgs, parallel=spec.parallel_plan,
                    streaming=streaming,
                    workdir=self._workdir(),
                    track_memory=spec.track_plan_memory,
                    chunk_instrs=spec.chunk_instrs,
                    annotations=self._trace_anns if streaming else None)
                self._planned = planned
                self._cfgs = cfgs
                self.plan_reports = reports
                cache = self._usable_cache()
                if cache is not None:
                    cache.put_plan(spec, self.workload, planned, cfgs,
                                   reports)
        return self._planned

    def plan_if_cached(self) -> bool:
        """Probe the artifact cache for this spec's plan; on a hit, load
        the memory programs + resolved configs + reports and return True
        (the daemon uses this to size admission without planning)."""
        if self._planned is not None:
            return True
        cache = self._usable_cache()
        if cache is None or self.spec.plan_mode == "unbounded" or \
                self._plan_probed:   # one probe per session: don't double-
            return False             # count misses when plan() re-enters
        self._plan_probed = True
        got = cache.get_plan(self.spec, self.workload)
        if got is None:
            self.cache_events["plan"] = "miss"
            return False
        self.cache_events["plan"] = "hit"
        planned, cfgs, reports = got
        self._planned = list(planned)
        self._cfgs = list(cfgs)
        self.plan_reports = list(reports)
        return True

    # -- stage 3a: execute -----------------------------------------------------

    def _driver_name(self, real: bool | None) -> str:
        if real is None or self.protocol != "gc":
            return self.spec.driver      # CKKS is real crypto either way
        return "gc-2party" if real else "gc-plaintext"

    def execute(self, real: bool | None = None,
                check: bool = False) -> dict[int, np.ndarray]:
        """Run the planned programs through the engine; returns the merged
        ``tag → value`` outputs of the endpoints THIS process hosts.
        ``real`` overrides the spec's driver for GC (True → two-party
        crypto, False → plaintext oracle).

        Placement comes from the spec's transport/fabric: the default
        hosts every (party, worker) engine here on threads over the
        ``inproc`` backend; a spec with ``fabric.rank=k`` runs exactly
        one engine against remote peers (distributed mode — outputs are
        then partial, so ``check`` is refused)."""
        planned = self.plan()
        spec = self.spec
        ddef = _driver_def(self._driver_name(real))
        try:
            make_storage = STORAGE_BACKENDS[spec.storage]
        except KeyError:
            raise KeyError(f"unknown storage {spec.storage!r}; registered: "
                           f"{sorted(STORAGE_BACKENDS)}") from None

        p = spec.num_workers
        fx = build_fabric(spec.transport, ddef.parties * p, spec.fabric)
        if check and fx.distributed:
            raise ValueError("check=True needs the full outputs; a "
                             "distributed rank only holds its own (run "
                             "`python -m repro fabric` for a checked fleet)")
        scheds = self._batch_schedules(planned) \
            if spec.exec_backend == "batched" else None
        oscheds = self._overlap_schedules(planned) \
            if spec.exec_backend == "overlap" else None
        outputs: dict[int, np.ndarray] = {}
        try:
            fx.connect()
            drivers = ddef.factory(self, fx)
            jobs = []
            for r in sorted(drivers):
                party, wk = divmod(r, p)
                drv = drivers[r]
                if scheds is not None or oscheds is not None:
                    # overlap reuses the batched drivers for its K_LOCAL
                    # groups, so both backends wrap the scalar driver
                    from .exec import make_batched
                    drv = make_batched(drv)
                prog = planned[wk]
                storage = make_storage((prog.page_slots, drv.lane),
                                       drv.dtype)
                jobs.append(EngineJob(prog, drv,
                                      net=fx.view(r, party * p, p),
                                      storage=storage,
                                      batch_schedule=(scheds[wk] if scheds
                                                      else None),
                                      overlap_schedule=(oscheds[wk]
                                                        if oscheds
                                                        else None),
                                      tag=f"party{party}/worker{wk}"))
            self.engine_stats = run_engines(jobs)
            if fx.distributed:
                # hold the process until every peer drained its traffic
                fx.barrier()
            self.transport_stats = fx.stats()
            for d in drivers.values():
                outputs.update(getattr(d, "outputs", {}))
        finally:
            fx.close()
        if check:
            check_outputs(self.workload, spec.n, outputs)
        return outputs

    def _batch_schedules(self, planned) -> list:
        """One exec/ batch schedule per worker memory program, served from
        the artifact cache when possible (see docs/ENGINE.md).

        Keyed by ``plan_hash`` like the plan entry it describes.  Unbounded
        runs build in-process: ``plan_mode`` is excluded from the plan hash
        (the planned pipelines are output-identical), but an unbounded
        "plan" is the raw trace, so its sidecar would collide with the
        memory-mode entry of the same spec."""
        from .exec.batching import build_batch_schedule
        spec = self.spec
        cache = self._usable_cache()
        if cache is not None and spec.plan_mode != "unbounded":
            got = cache.get_batch(spec, self.workload)
            if got is not None and len(got) == len(planned):
                self.cache_events["batch"] = "hit"
                return got
            self.cache_events["batch"] = "miss"
            scheds = [build_batch_schedule(p, spec.chunk_instrs)
                      for p in planned]
            cache.put_batch(spec, self.workload, scheds)
            return scheds
        return [build_batch_schedule(p, spec.chunk_instrs) for p in planned]

    def _overlap_schedules(self, planned) -> list:
        """One exec/ overlap schedule per worker memory program, served
        from the artifact cache when possible (docs/OVERLAP.md).  Same
        keying and unbounded-mode caveat as ``_batch_schedules``."""
        from .exec.overlap import build_overlap_schedule
        spec = self.spec
        cache = self._usable_cache()
        if cache is not None and spec.plan_mode != "unbounded":
            got = cache.get_overlap(spec, self.workload)
            if got is not None and len(got) == len(planned):
                self.cache_events["overlap"] = "hit"
                return got
            self.cache_events["overlap"] = "miss"
            scheds = [build_overlap_schedule(p, spec.chunk_instrs)
                      for p in planned]
            cache.put_overlap(spec, self.workload, scheds)
            return scheds
        return [build_overlap_schedule(p, spec.chunk_instrs)
                for p in planned]

    # -- stage 3b: simulate ----------------------------------------------------

    def simulate(self, cost_fn: Callable, model: DeviceModel | None = None,
                 os_page_bytes: int | None = None,
                 slot_bytes: int | None = None,
                 core: str | None = None) -> list[WorkerScenarios]:
        """Replay the three §8.2 scenarios (Unbounded / OS swap / MAGE)
        per worker with the given per-instruction cost model.

        ``core`` overrides the spec's ``sim_core``: ``"array"`` (default)
        replays record chunks through the vectorized simulator cores —
        pricing whole chunks with ``cost_fn.cost_chunk`` when the cost
        object provides one — while ``"scalar"`` runs the per-instruction
        reference loops.  Results are exactly equal either way (see
        docs/SIMULATOR.md)."""
        if self.spec.plan_mode == "unbounded":
            raise ValueError("simulate() compares scenarios under a memory "
                             "budget; plan_mode='unbounded' has none")
        progs = self.trace()
        planned = self.plan()
        if any(c is None for c in self._cfgs):
            raise ValueError(
                "simulate() needs the plan configs and reports of an "
                "in-session plan(); a Session loaded with from_plan() can "
                "only execute() its artifacts")
        sb = slot_bytes if slot_bytes is not None else SLOT_BYTES[self.protocol]
        sim_core = core if core is not None else self.spec.sim_core
        chunk = self.spec.chunk_instrs
        out = []
        for wk, prog in enumerate(progs):
            page_bytes = prog.page_slots * sb
            cfg = self._cfgs[wk]
            ub = simulate_unbounded(prog, cost_fn, core=sim_core,
                                    chunk_instrs=chunk)
            osr = simulate_os_paging(prog, cost_fn, cfg.num_frames,
                                     page_bytes, model,
                                     os_page_bytes=os_page_bytes,
                                     core=sim_core, chunk_instrs=chunk)
            mem = planned[wk]
            mage = simulate_memory_program(mem, cost_fn, page_bytes, model,
                                           core=sim_core, chunk_instrs=chunk)
            if isinstance(mem, ProgramFile):
                nbytes = os.path.getsize(mem.path)
            else:
                from .core.bytecode import RECORD_BYTES
                nbytes = len(mem) * RECORD_BYTES
            out.append(WorkerScenarios(
                unbounded=ub, os=osr, mage=mage,
                report=self.plan_reports[wk], config=cfg,
                working_set_pages=self.working_set(wk),
                page_bytes=page_bytes, instructions=len(prog),
                program_bytes=nbytes))
        return out

    # -- plan artifacts --------------------------------------------------------

    def save_plan(self, outdir: str | os.PathLike) -> str:
        """Write the planned memory programs + a ``job.json`` manifest to
        ``outdir``; returns the manifest path.  Streaming plan files are
        moved (they can be far larger than RAM), in-memory plans are
        serialized."""
        outdir = os.fspath(outdir)
        os.makedirs(outdir, exist_ok=True)
        planned = self.plan()
        names = []
        cache_hit = self.cache_events.get("plan") == "hit"
        for i, p in enumerate(planned):
            dst = os.path.join(outdir, f"worker{i}.memory.bc")
            if isinstance(p, ProgramFile):
                if os.path.abspath(p.path) != os.path.abspath(dst):
                    if cache_hit:
                        # cache-resident artifacts stay in the cache
                        shutil.copyfile(p.path, dst)
                    else:
                        shutil.move(p.path, dst)
                        srcdir = os.path.dirname(p.path)
                        if not os.listdir(srcdir):
                            os.rmdir(srcdir)
                planned[i] = ProgramFile(dst)
            else:
                planned[i] = write_program(p, dst)
            names.append(os.path.basename(dst))
        manifest = {"format": 1, "spec": self.spec.to_dict(),
                    "spec_hash": self.spec.plan_hash(self.workload),
                    "programs": names}
        path = os.path.join(outdir, JOB_FILE)
        with open(path, "w") as f:
            json.dump(manifest, f, indent=2)
        return path

    @classmethod
    def from_plan(cls, jobdir: str | os.PathLike,
                  storage: str | None = None,
                  driver: str | None = None,
                  transport: str | None = None,
                  fabric: FabricSpec | None = None) -> "Session":
        """Load a saved plan for direct execution.

        The spec hash is recomputed from the manifest's spec and validated
        against both the manifest and every program file's stamped meta —
        a mismatch (edited job.json, swapped plan files, changed planner
        semantics) raises :class:`SpecMismatchError` instead of executing
        a stale plan.  ``storage``/``driver``/``transport``/``fabric``
        override execution details (which are excluded from the hash by
        design) — the same artifact runs in-process or as one rank of a
        TCP fleet."""
        jobdir = os.fspath(jobdir)
        with open(os.path.join(jobdir, JOB_FILE)) as f:
            manifest = json.load(f)
        spec = JobSpec.from_dict(manifest["spec"])
        expect = spec.plan_hash()
        if manifest.get("spec_hash") != expect:
            raise SpecMismatchError(
                f"job.json spec hashes to {expect} but manifest claims "
                f"{manifest.get('spec_hash')} — spec was modified after "
                f"planning; re-run `plan`")
        overrides = {}
        if storage is not None:
            overrides["storage"] = storage
        if driver is not None:
            overrides["driver"] = driver
        if transport is not None:
            overrides["transport"] = transport
        if fabric is not None:
            overrides["fabric"] = fabric
        if overrides:
            spec = dataclasses.replace(spec, **overrides)
        sess = cls(spec)
        names = manifest["programs"]
        if len(names) != sess.spec.num_workers:
            raise SpecMismatchError(
                f"{len(names)} program files for "
                f"{sess.spec.num_workers} workers")
        planned = []
        for name in names:
            pf = ProgramFile(os.path.join(jobdir, name))
            got = pf.meta.get("spec_hash")
            if got != expect:
                raise SpecMismatchError(
                    f"{name} was planned for spec {got}, job.json says "
                    f"{expect} — artifact and spec disagree")
            planned.append(pf)
        sess._planned = planned
        sess._cfgs = [None] * len(planned)
        return sess


# ---------------------------------------------------------------------------
# oracle check
# ---------------------------------------------------------------------------


def check_outputs(w: Workload, n: int, outputs: dict[int, np.ndarray],
                  atol: float = 2e-2) -> None:
    """Compare executed outputs against the workload's numpy oracle."""
    exp = w.oracle(n)
    missing = set(exp) - set(outputs)
    assert not missing, f"{w.name}: missing outputs {sorted(missing)[:5]}..."
    for tag, e in exp.items():
        got = outputs[tag]
        if w.protocol in ("gc", "shamir"):
            assert np.array_equal(got, e), \
                f"{w.name} tag {tag}: {got[:4]} != {e[:4]}"
        else:
            err = np.max(np.abs(np.asarray(got) - e))
            assert err < atol, f"{w.name} tag {tag}: err {err}"


def run_job(spec: JobSpec, real: bool | None = None,
            check: bool = False, cache=None) -> dict[int, np.ndarray]:
    """One-shot convenience: trace, plan, execute, clean up."""
    with Session(spec, cache=cache) as s:
        return s.execute(real=real, check=check)


def plan(spec: JobSpec, outdir: str | os.PathLike, cache=None) -> str:
    """One-shot plan: trace + plan ``spec`` (cache-aware when ``cache``
    is an ArtifactCache or cache-root path) and save the memory programs
    plus ``job.json`` manifest to ``outdir``; returns the manifest path.

    The blessed top-level entry point (``repro.plan``) mirroring
    ``python -m repro plan``; execute the artifacts later with
    :meth:`Session.from_plan` or ``python -m repro run``."""
    with Session(spec, cache=cache) as s:
        return s.save_plan(outdir)


# ---------------------------------------------------------------------------
# admission sizing (the serving daemon's resource model)
# ---------------------------------------------------------------------------


def estimate_job_resources(sess: Session) -> tuple[int, int]:
    """(frames, bytes) one job will pin while planning and executing.

    Frames are the paper's T summed over workers — resolved from a
    cached plan's configs when available (zero tracing), directly from
    an integer budget, or by tracing for working-set-fractional budgets.
    Bytes add the planner's O(frames) peak estimate
    (:func:`repro.core.planner.plan_memory_estimate`) to the engine's
    resident frame memory (frames x page bytes x parties).  This is what
    the serving daemon's admission controller charges per tenant."""
    from .core.planner import plan_memory_estimate
    spec = sess.spec
    cfgs: list[PlanConfig] | None = None
    if spec.plan_mode == "unbounded":
        # no plan: the engine keeps the whole working set resident
        frames_w = [sess.working_set(i) for i in range(spec.num_workers)]
    elif sess.plan_if_cached():
        cfgs = [c for c in sess._cfgs if c is not None]
        frames_w = [c.num_frames for c in cfgs]
        cfgs = None                     # planning is skipped on a hit
    elif not isinstance(spec.memory_budget, float):
        cfgs = [resolve_plan_config(spec, None)] * spec.num_workers
        frames_w = [c.num_frames for c in cfgs]
    else:
        cfgs = [resolve_plan_config(spec, p, self_ws)
                for p, self_ws in ((sess.trace()[i], sess.working_set(i))
                                   for i in range(spec.num_workers))]
        frames_w = [c.num_frames for c in cfgs]
    frames = sum(frames_w)
    page_bytes = (1 << sess.workload.page_shift) * SLOT_BYTES[sess.protocol]
    parties = driver_parties(spec.driver) if spec.driver in DRIVERS else 1
    engine_bytes = frames * page_bytes * parties
    planner_bytes = sum(plan_memory_estimate(c, spec.chunk_instrs)
                        for c in cfgs) if cfgs else 0
    return frames, engine_bytes + planner_bytes
