"""Fault tolerance + straggler mitigation for the training loop.

Mechanisms (all exercised by tests on CPU; at scale they compose with the
multi-host runtime):

  * NaN/Inf sentinel: every step's loss/grad-norm is checked; a bad step
    triggers rollback to the last checkpoint and a data-skip past the bad
    batch (deterministic resume — the data pipeline is step-indexed).
  * Crash restart: checkpoints are atomic (checkpoint.py); the loop always
    resumes from latest_step().
  * Preemption: a SIGTERM-style flag forces an immediate checkpoint.
  * Straggler mitigation: a pluggable StepTimer tracks a rolling step-time
    distribution; steps exceeding mean + k*std raise a straggler event —
    at scale the runner responds by excluding/replacing the slow host and
    re-forming the mesh (elastic reshard path in checkpoint.restore);
    here the policy logic itself is what is under test.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class FaultConfig:
    checkpoint_every: int = 50
    max_rollbacks: int = 3
    straggler_window: int = 32
    straggler_sigma: float = 4.0


class StepTimer:
    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.times: deque[float] = deque(maxlen=cfg.straggler_window)
        self.events: list[dict] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        if len(self.times) >= 8:
            mean = float(np.mean(self.times))
            std = float(np.std(self.times)) + 1e-9
            if seconds > mean + self.cfg.straggler_sigma * std:
                self.events.append({"step": step, "seconds": seconds,
                                    "mean": mean, "std": std})
                self.times.append(seconds)
                return True
        self.times.append(seconds)
        return False


def is_bad(metrics: dict) -> bool:
    for k in ("loss", "grad_norm"):
        v = metrics.get(k)
        if v is not None and not np.isfinite(float(v)):
            return True
    return False


class Preemption:
    """Cooperative preemption flag (SIGTERM handler sets it at scale)."""

    def __init__(self):
        self.requested = False

    def request(self):
        self.requested = True


@dataclasses.dataclass
class RunReport:
    steps_run: int = 0
    rollbacks: int = 0
    checkpoints: int = 0
    stragglers: int = 0
    final_step: int = 0
