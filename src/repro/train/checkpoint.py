"""Fault-tolerant checkpointing: atomic, async-capable, elastic.

Format: one .npz per save (flattened pytree with '/'-joined keys) + a JSON
manifest (step, config name, tree structure).  Writes go to a temp dir then
are atomically renamed, so a crash mid-save never corrupts the latest
checkpoint; restore picks the newest complete manifest.  Multi-host: each
host saves its process-local shard files (suffix _h<k>) — on CPU this is
exercised with a single host, and the elastic-reshard test reloads under a
different mesh (values are saved unsharded per leaf, so any mesh can load
them with new shardings).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            key += "__bf16"
            arr = arr.view(np.uint16)
        out[key] = arr
    return out


def _unflatten_leaf(data, key: str):
    if key + "__bf16" in data:
        import ml_dtypes
        return data[key + "__bf16"].view(ml_dtypes.bfloat16)
    return data[key]


def save(ckpt_dir: str, step: int, params: Any, opt_state: Any,
         meta: dict | None = None, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        np.savez(os.path.join(tmp, "opt.npz"), **_flatten(opt_state))
        manifest = {"step": int(step), "meta": meta or {}, "complete": True}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{int(step):010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, params: Any, opt_state: Any,
               meta: dict | None = None) -> threading.Thread:
    """Snapshot to host memory synchronously, write on a thread."""
    params_host = jax.tree_util.tree_map(np.asarray, params)
    opt_host = jax.tree_util.tree_map(np.asarray, opt_state)
    t = threading.Thread(target=save,
                         args=(ckpt_dir, step, params_host, opt_host, meta),
                         daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in sorted(os.listdir(ckpt_dir)):
        if not d.startswith("step_"):
            continue
        mf = os.path.join(ckpt_dir, d, "manifest.json")
        try:
            with open(mf) as f:
                if json.load(f).get("complete"):
                    best = int(d.split("_")[1])
        except (OSError, json.JSONDecodeError):
            continue  # incomplete/corrupt save: skip (crash tolerance)
    return best


def restore(ckpt_dir: str, step: int, params_like: Any, opt_like: Any,
            shardings: Any = None) -> tuple[Any, Any, dict]:
    """Load into the structure of params_like/opt_like.  ``shardings``
    (same tree shape) enables elastic reload onto a different mesh."""
    d = os.path.join(ckpt_dir, f"step_{int(step):010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def load(npz_path, like, shard_tree):
        data = np.load(npz_path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shards = (treedef.flatten_up_to(shard_tree) if shard_tree is not None
                  else [None] * len(flat))
        leaves = []
        for (path, leaf), sh in zip(flat, shards):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = _unflatten_leaf(data, key)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        return treedef.unflatten(leaves)

    p_sh = o_sh = None
    if shardings is not None:
        p_sh, o_sh = shardings
    params = load(os.path.join(d, "params.npz"), params_like, p_sh)
    opt = load(os.path.join(d, "opt.npz"), opt_like, o_sh)
    return params, opt, manifest
