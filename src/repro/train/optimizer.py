"""AdamW with WSD (warmup–stable–decay) schedule (MiniCPM [arXiv:2404.06395])
and global-norm clipping.  Pure pytree implementation (no optax dependency):
moments in f32, params may be bf16 (f32 master copies optional)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "wsd"       # wsd | cosine | const


def wsd_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    w, st, d = cfg.warmup_steps, cfg.stable_steps, cfg.decay_steps
    warm = s / jnp.maximum(w, 1)
    if cfg.schedule == "const":
        frac = jnp.minimum(warm, 1.0)
    elif cfg.schedule == "cosine":
        t = jnp.clip((s - w) / jnp.maximum(st + d - w, 1), 0, 1)
        frac = jnp.where(s < w, warm,
                         cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    else:  # wsd: linear warmup, flat, then linear decay to min_lr_frac
        decay_t = jnp.clip((s - w - st) / jnp.maximum(d, 1), 0, 1)
        frac = jnp.where(s < w, warm,
                         jnp.where(s < w + st, 1.0,
                                   1.0 - (1.0 - cfg.min_lr_frac) * decay_t))
    return cfg.peak_lr * frac


def init_opt_state(params: Any) -> dict:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: OptConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    lr = wsd_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
