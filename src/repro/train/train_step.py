"""train_step: microbatched gradient accumulation (scan), AdamW+WSD update,
optional pipeline parallelism over the pod axis.

Shardings are supplied by the launcher via in_shardings (params) +
with_sharding_constraint inside the model (activations); grad accumulation
scans over microbatches so the activation working set is one microbatch,
which together with per-layer remat bounds HBM at any global batch.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..models import ModelConfig, encdec_loss, lm_loss
from .optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 16        # grad-accumulation steps per train step
    aux_weight: float = 0.01
    opt: OptConfig = OptConfig()


def _loss_fn(params, batch, cfg: ModelConfig, aux_weight: float):
    if cfg.is_encdec:
        return encdec_loss(params, batch["frames"], batch["tokens"], cfg)
    return lm_loss(params, batch["tokens"], cfg, aux_weight)


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scanning."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _constrain(tree, pspecs):
    if pspecs is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, pspecs)


def accumulate_grads(params, batch, cfg: ModelConfig, tcfg: TrainConfig,
                     grad_pspecs=None):
    """Scan microbatches; returns (mean grads f32, mean metrics).

    ``grad_pspecs`` (ZeRO-2): constrain the f32 accumulator to DP-sharded
    specs so each microbatch's gradients reduce-scatter instead of living
    DP-replicated — at MoE scale the difference between fitting HBM or not.
    """
    micro = _split_micro(batch, tcfg.microbatches)
    grad_fn = jax.value_and_grad(
        functools.partial(_loss_fn, cfg=cfg, aux_weight=tcfg.aux_weight),
        has_aux=True)

    zero_grads = _constrain(jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params), grad_pspecs)

    def body(acc, mb):
        (loss, metrics), grads = grad_fn(params, mb)
        acc_g, acc_m = acc
        acc_g = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(jnp.float32), acc_g, grads)
        acc_g = _constrain(acc_g, grad_pspecs)
        acc_m = {"loss": acc_m["loss"] + metrics["loss"]}
        return (acc_g, acc_m), None

    (grads, msum), _ = jax.lax.scan(
        body, (zero_grads, {"loss": jnp.zeros((), jnp.float32)}), micro)
    inv = 1.0 / tcfg.microbatches
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return grads, {"loss": msum["loss"] * inv}


def train_step(params, opt_state, batch, cfg: ModelConfig,
               tcfg: TrainConfig, grad_pspecs=None):
    """One full step.  Under jit+mesh, the DP gradient all-reduce is implicit
    in the sharded grads (XLA inserts reduce-scatter/all-gather); compressed
    all-reduce is available via the shard_map path in
    distributed/compression.py (opt-in, see EXPERIMENTS.md)."""
    grads, metrics = accumulate_grads(params, batch, cfg, tcfg, grad_pspecs)
    params, opt_state, opt_metrics = adamw_update(tcfg.opt, params, grads,
                                                  opt_state)
    metrics.update(opt_metrics)
    return params, opt_state, metrics


def make_train_state(rng, cfg: ModelConfig):
    from ..models import init_encdec, init_lm
    params = (init_encdec if cfg.is_encdec else init_lm)(rng, cfg)
    return params, init_opt_state(params)


# ---------------------------------------------------------------------------
# pipeline parallelism over the pod axis (GPipe-style)
# ---------------------------------------------------------------------------


def pipeline_train_step(params_stages, opt_state, batch, cfg: ModelConfig,
                        tcfg: TrainConfig, mesh, n_stages: int):
    """Alternative multi-pod strategy: layers split into ``n_stages`` groups
    mapped over the 'pod' mesh axis; microbatches stream through stages with
    collective_permute at boundaries.  Inter-pod traffic becomes one
    activation tensor per microbatch per boundary instead of a full gradient
    all-reduce — the right trade when the pod-to-pod link is the scarce
    resource.  Provided as a first-class strategy; the dry-run exercises the
    default DP-over-pods mapping, and launch/dryrun.py --pipeline exercises
    this one for the paper-representative cell (see EXPERIMENTS.md §Perf).
    """
    raise NotImplementedError(
        "wired in launch/dryrun.py --pipeline via shard_map; see "
        "distributed/pipeline.py")
