"""Calibrated §8.2 scenario harness: Unbounded / OS Swapping / MAGE.

Canonical home of what used to be hand-wired in ``benchmarks/common.py``:
the storage-device calibration, the protocol cost models with input/output
file streaming, and ``run_workload`` — now a thin wrapper over
``repro.api.Session.simulate`` so every benchmark (fig8/fig9/fig10, table1,
``python -m repro bench``) shares one trace→plan→simulate path, including
the out-of-core streaming planner for past-planner-cap trace sizes.

Calibration (documented, see EXPERIMENTS.md §Methodology): cloud-SSD-class
storage (~1 GB/s streaming, 300 us op latency, deep queue); the OS baseline
pays demand-paging costs at 4 KiB granularity with an effective readahead of
2 (swap-slot fragmentation defeats clustering) and direct-reclaim write
throttling, while MAGE moves its own 64 KiB/128 KiB pages with planned,
overlapped I/O — the same asymmetry the paper measures on Azure D16d_v4
(its local SSD swap vs MAGE's O_DIRECT aio).  Compute costs come from the
protocol drivers' gate/NTT cost models (GC: ~80ns per AND garbling; CKKS:
~N log N per NTT).  Absolute times are model outputs; the CLAIMS we
validate are the paper's ratios (MAGE-vs-OS speedups, %-of-Unbounded).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .api import SLOT_BYTES, FabricSpec, JobSpec, Session
from .core.transport import LinkStats, aggregate_links
from .core import DeviceModel
from .core.bytecode import _IMM_OFF, _IN_OFF, _OUT_OFF, Op, unpack_heads
from .protocols.ckks import CkksCostModel, CkksParams
from .protocols.garbled.cost import GCCostModel
from .workloads import get

# --- calibration ------------------------------------------------------------

STORAGE = DeviceModel(bandwidth=1e9, latency=300e-6, fault_overhead=5e-6,
                      readahead=2, os_writeback_throttle_s=0.02)
OS_PAGE_BYTES = 4096
FILE_BW = 1e9               # input/output file streaming (all scenarios)
GC_SLOT_BYTES = SLOT_BYTES["gc"]      # one wire label
CKKS_SLOT_BYTES = SLOT_BYTES["ckks"]  # one 8-byte word
BENCH_CKKS = CkksParams(n_ring=1024, levels=2)

# paper defaults (§8.2): GC l=10000, B=256 pages; CKKS l=100, B=16
GC_PLAN = dict(lookahead=10_000, prefetch_pages=64)
CKKS_PLAN = dict(lookahead=100, prefetch_pages=16)

#: the streaming planner's own memory cap (MiB) — trace files larger than
#: this are "past-planner-cap" sizes that only the file pipeline can plan
#: within budget (Table 1 / docs/PLANNER.md)
PLANNER_CAP_MB = 8.0


class ScenarioCost:
    """Driver cost model + input/output FILE streaming (paid identically in
    every scenario — §8.1.3 phase 1/3).

    Callable per instruction (the scalar simulator cores' interface) and
    chunkable via :meth:`cost_chunk` over raw record chunks (what the
    ``core="array"`` simulators consume) — per-instruction values are
    IDENTICAL between the two paths (property-tested), which is what makes
    the array and scalar simulator cores exactly equal end-to-end."""

    def __init__(self, protocol: str, n_ring: int | None = None):
        self.protocol = protocol
        self.slot_bytes = GC_SLOT_BYTES if protocol == "gc" \
            else CKKS_SLOT_BYTES
        if protocol == "gc":
            self.model = GCCostModel()
            self._base = self.model.cost
        else:
            self.model = CkksCostModel(pointwise=1.2e-9)
            self.n_ring = n_ring if n_ring is not None else BENCH_CKKS.n_ring
            self._base = lambda instr: self.model.cost(instr, self.n_ring)

    def __call__(self, instr) -> float:
        c = self._base(instr)
        if instr.op in (Op.INPUT, Op.OUTPUT):
            spans = instr.outs if instr.op == Op.INPUT else instr.ins
            nbytes = sum(s[1] for s in spans) * self.slot_bytes
            c += nbytes / FILE_BW
        return c

    def cost_chunk(self, rec: np.ndarray) -> np.ndarray:
        """Per-instruction seconds for one [m, RECORD_WORDS] record chunk:
        the protocol model's vectorized formulas plus the INPUT/OUTPUT
        file-streaming bytes (span slot counts straight off the zero-padded
        record columns)."""
        ops, _n_outs, _n_ins, n_imm = unpack_heads(rec[:, 0])
        imm = rec[:, _IMM_OFF:]
        if self.protocol == "gc":
            c = self.model.cost_chunk(ops, imm, n_imm)
        else:
            c = self.model.cost_chunk(ops, imm, self.n_ring)
        is_in = ops == int(Op.INPUT)
        io = is_in | (ops == int(Op.OUTPUT))
        if io.any():
            sel = rec[io]
            outs_n = sel[:, _OUT_OFF + 1] + sel[:, _OUT_OFF + 3]
            ins_n = sel[:, _IN_OFF + 1:_IN_OFF + 8:2].sum(axis=1)
            nbytes = np.where(is_in[io], outs_n, ins_n) * self.slot_bytes
            c[io] += nbytes.astype(np.float64) / FILE_BW
        return c


def cost_fn(protocol: str) -> ScenarioCost:
    """The calibrated §8.2 cost model for one protocol (see ScenarioCost)."""
    return ScenarioCost(protocol)


@dataclasses.dataclass
class ScenarioResult:
    unbounded_s: float
    os_s: float
    mage_s: float
    plan_s: float
    plan_peak_mb: float
    swaps_in: int
    swaps_out: int
    prefetched: int
    working_set_pages: int
    budget_pages: int
    instructions: int
    program_bytes: int = 0
    plan_mode: str = "memory"
    sim_core: str = "array"
    #: bytes the simulated device actually transferred (fig8's I/O columns):
    #: OS faults read whole readahead clusters, so os_read_bytes can exceed
    #: pages * page_bytes; write-backs and MAGE swaps move whole pages.
    os_read_bytes: int = 0
    os_write_bytes: int = 0
    mage_read_bytes: int = 0
    mage_write_bytes: int = 0

    @property
    def speedup_vs_os(self) -> float:
        return self.os_s / self.mage_s

    @property
    def pct_of_unbounded(self) -> float:
        return self.mage_s / self.unbounded_s - 1.0


def scenario_spec(name: str, n: int, budget_frac: float = 0.25,
                  num_workers: int = 1, plan_overrides: dict | None = None,
                  plan_mode: str = "memory",
                  sim_core: str = "array",
                  plan_core: str = "array") -> JobSpec:
    """The JobSpec the §8.2 benchmarks use for one (workload, size) case."""
    w = get(name)
    knobs = dict(GC_PLAN if w.protocol == "gc" else CKKS_PLAN)
    knobs.update(plan_overrides or {})
    allowed = {"lookahead", "prefetch_pages", "policy", "swap_bypass"}
    unknown = set(knobs) - allowed
    if unknown:
        raise ValueError(f"unknown plan knobs {sorted(unknown)}; "
                         f"allowed: {sorted(allowed)}")
    extra = {}
    if w.protocol == "ckks":
        extra = dict(ckks_ring=BENCH_CKKS.n_ring,
                     ckks_levels=BENCH_CKKS.levels)
    return JobSpec(workload=name, n=n, num_workers=num_workers,
                   memory_budget=float(budget_frac),
                   lookahead=knobs["lookahead"],
                   prefetch_pages=knobs["prefetch_pages"],
                   policy=knobs.get("policy", "min"),
                   swap_bypass=knobs.get("swap_bypass", False),
                   plan_mode=plan_mode, sim_core=sim_core,
                   plan_core=plan_core,
                   track_plan_memory=True, **extra)


def run_workload_workers(name: str, n: int, num_workers: int = 1,
                         budget_frac: float = 0.25,
                         plan_overrides: dict | None = None,
                         plan_mode: str = "memory",
                         sim_core: str = "array",
                         plan_core: str = "array",
                         cache_dir=None) -> list[ScenarioResult]:
    """All three scenarios for every worker of one case (one Session).

    ``cache_dir`` attaches the artifact cache (docs/SERVE.md): repeated
    bench/figure invocations of the same case skip re-tracing (and, for
    streaming cases, re-planning)."""
    spec = scenario_spec(name, n, budget_frac=budget_frac,
                         num_workers=num_workers,
                         plan_overrides=plan_overrides, plan_mode=plan_mode,
                         sim_core=sim_core, plan_core=plan_core)
    with Session(spec, cache=cache_dir) as s:
        scenarios = s.simulate(cost_fn(s.protocol), model=STORAGE,
                               os_page_bytes=OS_PAGE_BYTES)
    out = []
    for sc in scenarios:
        out.append(ScenarioResult(
            unbounded_s=sc.unbounded.total, os_s=sc.os.total,
            mage_s=sc.mage.total, plan_s=sc.report.total_s,
            plan_peak_mb=sc.report.peak_mem_bytes / 2**20,
            swaps_in=sc.report.replacement.swap_ins,
            swaps_out=sc.report.replacement.swap_outs,
            prefetched=sc.report.schedule.prefetched,
            working_set_pages=sc.working_set_pages,
            budget_pages=sc.config.num_frames,
            instructions=sc.instructions,
            program_bytes=sc.program_bytes,
            plan_mode=plan_mode, sim_core=sim_core,
            os_read_bytes=sc.os.read_bytes,
            os_write_bytes=sc.os.write_bytes,
            mage_read_bytes=sc.mage.read_bytes,
            mage_write_bytes=sc.mage.write_bytes))
    return out


def run_workload(name: str, n: int, budget_frac: float = 0.25,
                 num_workers: int = 1, worker: int = 0,
                 plan_overrides: dict | None = None,
                 plan_mode: str = "memory",
                 sim_core: str = "array",
                 plan_core: str = "array",
                 cache_dir=None) -> ScenarioResult:
    """One worker's scenarios.  Note: plans and simulates ALL workers of
    the trace (one Session); with num_workers > 1 and a single worker of
    interest, call sites wanting to skip the others should drive Session
    directly."""
    return run_workload_workers(name, n, num_workers=num_workers,
                                budget_frac=budget_frac,
                                plan_overrides=plan_overrides,
                                plan_mode=plan_mode,
                                sim_core=sim_core, plan_core=plan_core,
                                cache_dir=cache_dir)[worker]


def fmt_row(name: str, r: ScenarioResult) -> str:
    return (f"{name:12s} n/a={r.instructions:7d}i ws={r.working_set_pages:5d} "
            f"budget={r.budget_pages:5d} | unb={r.unbounded_s:8.3f}s "
            f"os={r.os_s:8.3f}s mage={r.mage_s:8.3f}s | "
            f"speedup={r.speedup_vs_os:5.2f}x "
            f"overhead={100*r.pct_of_unbounded:6.1f}%")


def fmt_io_row(name: str, r: ScenarioResult) -> str:
    """The I/O columns: bytes the simulated device actually moved."""
    mib = 2**20
    return (f"{name:12s} io: os r/w={r.os_read_bytes / mib:8.1f}/"
            f"{r.os_write_bytes / mib:8.1f} MiB  "
            f"mage r/w={r.mage_read_bytes / mib:8.1f}/"
            f"{r.mage_write_bytes / mib:8.1f} MiB  "
            f"(mage moves {(r.mage_read_bytes + r.mage_write_bytes) / max(r.os_read_bytes + r.os_write_bytes, 1):.2f}x the OS bytes)")


# --- measured traffic (the transport fabric's accounting) -------------------


@dataclasses.dataclass
class TrafficReport:
    """One REAL execution's measured communication + wall time.

    ``links`` is the fabric's send-side accounting aggregated per
    (src_rank, dst_rank); ``stats`` keeps the per-tag detail (for GC the
    tags are the protocol kinds — ``PartyChannel.TAGS`` — so e.g. OT
    batches are ``stats[(g, e, TAGS['ot'])].messages``)."""

    seconds: float
    outputs: dict[int, np.ndarray]
    stats: dict[tuple[int, int, int], LinkStats]
    links: dict[tuple[int, int], LinkStats]

    @property
    def total_bytes(self) -> int:
        return sum(s.bytes for s in self.links.values())

    @property
    def total_messages(self) -> int:
        return sum(s.messages for s in self.links.values())


def measure_traffic(name: str, n: int, num_workers: int = 1,
                    driver: str = "auto", transport: str = "inproc",
                    fabric: FabricSpec | None = None,
                    check: bool = False, exec_backend: str = "scalar",
                    warmup: bool = False) -> TrafficReport:
    """Run a workload for REAL (unbounded plan) and report what actually
    crossed the fabric — the measured replacement for fig10/fig11's
    modeled byte counts.  ``transport="shaped"`` with a fabric carrying
    ``latency_s``/``bandwidth`` makes ``seconds`` a WAN measurement.

    ``exec_backend="overlap"`` runs the planned out-of-order engine
    (docs/OVERLAP.md); ``warmup=True`` executes once untimed first so
    the timed run does not pay one-time import/compile costs."""
    spec = JobSpec(workload=name, n=n, num_workers=num_workers,
                   plan_mode="unbounded", driver=driver,
                   transport=transport, fabric=fabric,
                   exec_backend=exec_backend)
    if warmup:
        with Session(spec) as w:
            w.execute(check=False)
    with Session(spec) as s:
        s.plan()                      # keep trace/plan out of the timing
        t0 = time.perf_counter()
        outs = s.execute(check=check)
        seconds = time.perf_counter() - t0
        stats = s.transport_stats
    return TrafficReport(seconds=seconds, outputs=outs,
                         stats=stats, links=aggregate_links(stats))


# --- the `python -m repro bench` sweep --------------------------------------

#: fig8-style §8.2 sweep (scaled); the streaming case's virtual trace
#: (~11.6 MiB) exceeds the planner cap, so it runs the file pipeline.
BENCH_CASES = [("merge", 16384), ("sort", 16384), ("ljoin", 256),
               ("mvmul", 384), ("binfclayer", 2048), ("rsum", 256),
               ("rstats", 128), ("rmvmul", 24), ("n_rmatmul", 8),
               ("t_rmatmul", 8)]
TINY_BENCH_CASES = [("merge", 2048), ("rsum", 128)]
STREAMING_CASE = ("merge", 131072)
TINY_STREAMING_CASE = ("merge", 4096)


def run_bench(cases=None, budget_frac: float = 0.4, check: bool = True,
              streaming_case=None, sim_core: str = "array",
              plan_core: str = "array", cache_dir=None) -> list[dict]:
    """Drive the §8.2 scenarios; returns JSON-ready row dicts."""
    cases = cases if cases is not None else BENCH_CASES
    rows = []
    for name, n in cases:
        r = run_workload(name, n, budget_frac=budget_frac,
                         sim_core=sim_core, plan_core=plan_core,
                         cache_dir=cache_dir)
        print("bench:", fmt_row(name, r), flush=True)
        rows.append({"workload": name, "n": n,
                     "speedup_vs_os": r.speedup_vs_os,
                     "pct_of_unbounded": r.pct_of_unbounded,
                     **dataclasses.asdict(r)})
    if streaming_case is not None:
        name, n = streaming_case
        r = run_workload(name, n, budget_frac=budget_frac,
                         plan_mode="streaming", sim_core=sim_core,
                         plan_core=plan_core, cache_dir=cache_dir)
        print("bench (streaming):", fmt_row(name, r), flush=True)
        rows.append({"workload": name, "n": n,
                     "speedup_vs_os": r.speedup_vs_os,
                     "pct_of_unbounded": r.pct_of_unbounded,
                     **dataclasses.asdict(r)})
    if check:
        beats = sum(r["os_s"] > r["mage_s"] for r in rows)
        assert beats == len(rows), \
            f"MAGE must beat OS on all cases, got {beats}/{len(rows)}"
    return rows


#: the `bench --sweep` grid: how the planner's two main knobs trade off
SWEEP_BUDGETS = (0.15, 0.25, 0.4, 0.6)
SWEEP_LOOKAHEADS = (100, 1_000, 10_000)


def run_sweep(cases=None, budgets=SWEEP_BUDGETS,
              lookaheads=SWEEP_LOOKAHEADS, sim_core: str = "array",
              plan_core: str = "array", cache_dir=None) -> list[dict]:
    """Budget x lookahead grid over the §8.2 scenarios: one row per
    (case, budget_frac, lookahead) cell, replayed on the vectorized
    simulator cores.  With ``cache_dir`` the trace is built once per
    case and every grid cell replans from the cached artifact."""
    cases = cases if cases is not None else TINY_BENCH_CASES
    rows = []
    for name, n in cases:
        for b in budgets:
            for la in lookaheads:
                r = run_workload(name, n, budget_frac=float(b),
                                 plan_overrides={"lookahead": int(la)},
                                 sim_core=sim_core, plan_core=plan_core,
                                 cache_dir=cache_dir)
                print(f"sweep: {name:12s} n={n} budget={b:<5} "
                      f"lookahead={la:<6} | mage={r.mage_s:8.3f}s "
                      f"os={r.os_s:8.3f}s speedup={r.speedup_vs_os:5.2f}x "
                      f"overhead={100 * r.pct_of_unbounded:6.1f}%",
                      flush=True)
                rows.append({"workload": name, "n": n,
                             "budget_frac": float(b), "lookahead": int(la),
                             "speedup_vs_os": r.speedup_vs_os,
                             "pct_of_unbounded": r.pct_of_unbounded,
                             **dataclasses.asdict(r)})
    return rows
