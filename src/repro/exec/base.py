"""BatchedProtocolDriver: the contract between the engine's batched fast
path and protocol-specific batch kernels.

A batched driver *wraps* an existing scalar ``ProtocolDriver`` — the scalar
driver remains the bitwise reference and still handles every instruction
the batch path declines (barriers, ops outside ``batch_ops``, singleton
groups).  The engine hands a batch as column arrays:

    execute_batch(op, imm, out_idx, in_idx, memory)

* ``op``      — the shared opcode of the group;
* ``imm``     — the group's (uniform) immediate tuple;
* ``out_idx`` / ``in_idx`` — one ``(starts, length)`` pair per operand
  slot: ``starts`` is an int64 ``(count,)`` array of span start addresses,
  ``length`` the shared span length;
* ``memory``  — the engine array, shape ``(n_slots, lane)``.

The driver gathers operand columns, runs one vectorized/compiled kernel
over the whole group, and scatters results back.  Gather/scatter helpers
below write exactly the slots the scalar driver writes, so engine memory is
bitwise identical after a batched group and after the equivalent scalar
replay — the property the digest tests assert.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from ..core.bytecode import Instr, Op
from ..core.engine import ProtocolDriver

#: one operand column: (span start addresses (count,), shared span length)
SpanCol = tuple[np.ndarray, int]


def gather_spans(memory: np.ndarray, col: SpanCol) -> np.ndarray:
    """(count, length, lane) copy of the group's operand spans."""
    starts, length = col
    return memory[starts[:, None] + np.arange(length, dtype=np.int64)]


def scatter_spans(memory: np.ndarray, col: SpanCol,
                  vals: np.ndarray) -> None:
    starts, length = col
    memory[starts[:, None] + np.arange(length, dtype=np.int64)] = vals


def strided_positions(col: SpanCol, n: int, stride: int) -> np.ndarray:
    """(count, n) slot addresses at ``start + k*stride`` — the wire-strided
    value positions the plaintext driver reads/writes."""
    starts, _ = col
    return starts[:, None] + np.arange(n, dtype=np.int64) * stride


class BatchedProtocolDriver(ProtocolDriver):
    """Wraps a scalar driver; adds ``execute_batch`` over span columns.

    Scalar calls (``execute``/``cost``/``finalize``/``outputs``) delegate
    to the wrapped driver, so a batched driver is a drop-in
    ``ProtocolDriver`` even on the scalar engine path.
    """

    #: ops this driver can execute batched; everything else scalar-delegates
    batch_ops: frozenset = frozenset()

    def __init__(self, inner: ProtocolDriver):
        self.inner = inner
        self.lane = inner.lane
        self.dtype = inner.dtype
        self.name = f"{inner.name}+batched"

    @property
    def outputs(self) -> dict:
        return getattr(self.inner, "outputs", {})

    def execute(self, op: Op, imm: tuple, outs, ins) -> None:
        self.inner.execute(op, imm, outs, ins)

    def cost(self, instr: Instr) -> float:
        return self.inner.cost(instr)

    def finalize(self) -> None:
        self.inner.finalize()

    def execute_batch(self, op: Op, imm: tuple, out_idx: list[SpanCol],
                      in_idx: list[SpanCol], memory: np.ndarray) -> None:
        raise NotImplementedError


def make_batched(driver: ProtocolDriver) -> Any:
    """Wrap ``driver`` in its protocol's batched driver, if one exists.

    Unknown driver types pass through unchanged — the engine only takes
    the batched fast path when the driver actually has ``execute_batch``,
    so exotic drivers silently keep scalar semantics.
    """
    from ..protocols.ckks.driver import CkksDriver
    from ..protocols.garbled.driver import _GCDriverBase, PlaintextDriver
    from ..protocols.shamir.driver import ShamirDriver
    from .batched_ckks import BatchedCkksDriver
    from .batched_gc import BatchedGCDriver, BatchedPlaintextDriver
    from .batched_shamir import BatchedShamirDriver
    if isinstance(driver, PlaintextDriver):
        return BatchedPlaintextDriver(driver)
    if isinstance(driver, _GCDriverBase):
        return BatchedGCDriver(driver)
    if isinstance(driver, CkksDriver):
        return BatchedCkksDriver(driver)
    if isinstance(driver, ShamirDriver):
        return BatchedShamirDriver(driver)
    return driver
