"""Batched Shamir kernels: share-local field ops over span columns.

Linear ops and share-wise products vectorize trivially — gather the
group's operand spans into one (rows, length, lane) block, run the
GF(2^61 - 1) kernel once, scatter back.  ``F_EVAL`` is deliberately NOT
batchable: its immediates carry a per-instruction round id, so the batch
scheduler's uniform-immediate grouping always leaves it a singleton and
the scalar driver (whose PRF is keyed by that same rid, not by execution
order) remains the single implementation of resharing randomness.
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import Op
from ..protocols.shamir.field import (P, addmod, mulmod, mulmod_scalar,
                                      submod)
from .base import BatchedProtocolDriver, SpanCol, gather_spans, scatter_spans


class BatchedShamirDriver(BatchedProtocolDriver):
    batch_ops = frozenset({Op.F_ADD, Op.F_SUB, Op.F_MUL_LOCAL, Op.F_MULC,
                           Op.F_ADDC, Op.F_MULC_ADD, Op.COPY})

    def execute_batch(self, op: Op, imm: tuple, out_idx: list[SpanCol],
                      in_idx: list[SpanCol], memory: np.ndarray) -> None:
        a = gather_spans(memory, in_idx[0])
        if op == Op.COPY:
            scatter_spans(memory, out_idx[0], a)
            return
        if op == Op.F_ADD:
            r = addmod(a, gather_spans(memory, in_idx[1]))
        elif op == Op.F_SUB:
            r = submod(a, gather_spans(memory, in_idx[1]))
        elif op == Op.F_MUL_LOCAL:
            r = mulmod(a, gather_spans(memory, in_idx[1]))
        elif op == Op.F_MULC:
            r = mulmod_scalar(a, imm[1])
        elif op == Op.F_ADDC:
            r = addmod(a, np.uint64(imm[1] % P))
        elif op == Op.F_MULC_ADD:
            r = addmod(a, mulmod_scalar(gather_spans(memory, in_idx[1]),
                                        imm[1]))
        else:  # pragma: no cover - batch_ops gates what reaches us
            raise NotImplementedError(op)
        scatter_spans(memory, out_idx[0], r)
