"""Overlap planner: turn a memory program into an *overlap schedule* —
planned out-of-order issue windows that hide network latency.

MAGE's premise (§3) is that SC programs are oblivious: the instruction
stream — and therefore the full dependency structure, including every
``NET_SEND``/``NET_RECV`` — is known before execution.  The in-order
engine pays a full RTT at every ``NET_RECV`` because it completes the
receive at its program position; this pass precomputes, once per plan,
an issue order in which

* each ``NET_SEND`` is hoisted to its *earliest* legal point (right
  after the last writer of its input span),
* each ``NET_RECV`` is posted as a deferred completion handle
  (``Transport.recv_async``) as soon as its anti-dependences allow, and
  its *completion* (the blocking receive, including any shaped
  delivery-time sleep) is deferred until the schedule has no independent
  local work left before an instruction that needs the data,
* independent local work is scheduled into the gap, grouped exactly like
  the batch planner's groups so the batched drivers keep batching.

The result is an :class:`OverlapSchedule` sidecar — flat int64 arrays,
chunk-aligned like :class:`~repro.exec.batching.BatchSchedule` — keyed by
``plan_hash`` and cached through the serve daemon's ``ArtifactCache``
(see docs/OVERLAP.md for the on-disk format and the legality rules).

Correctness argument: within a window, two instructions conflict iff any
of their operand spans overlap (a ``NET_SEND`` *reads* its input span, a
``NET_RECV``'s completion *writes* its output span), and the builder
schedules from an explicit dependency DAG over those conflicts — RAW,
WAW and WAR edges plus per-``(peer, tag)`` channel-order chains (the
fabric's FIFO is per ``(src, dst, tag)``, so two NET ops on the same
channel must keep program order; distinct tags buffer independently).
Non-NET directives (swaps), ``INPUT``/``OUTPUT`` and float-immediate
rows stay barriers in exact program order — NET ops never cross a swap
boundary, so they only ever touch resident spans.  Every handle is
posted and waited within its window, so no completion outlives a
barrier.  Span-keyed conflict tracking assumes spans are pairwise
identical-or-disjoint; the builder verifies that per window and falls
back to scalar program order where it does not hold.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..core.bytecode import (DEFAULT_CHUNK_INSTRS, DIRECTIVES, MAX_INS,
                             MAX_OUTS, _IMM_OFF, _IN_OFF, _OUT_OFF, Op,
                             Program, ProgramFile, iter_record_chunks,
                             unpack_heads)
from .batching import _window_groups

OVERLAP_VERSION = 1

#: group kinds (``OverlapSchedule.group_kind``)
K_LOCAL = 0      #: compute/directive rows; batched when group_op >= 0
K_SEND = 1       #: NET_SEND rows, issued (hoisted) at this point
K_RECV_POST = 2  #: NET_RECV rows: post deferred completion handles
K_RECV_WAIT = 3  #: NET_RECV rows: complete (wait) previously posted handles

#: ops that stay hard barriers for the overlap pass: every directive
#: *except* the NET traffic this pass exists to move, plus I/O against
#: the input provider / output channel and float-immediate rows.  NET
#: ops must not cross swap barriers (a hoisted send would read a
#: not-yet-resident span), so windows end at every swap directive.
_OVERLAP_BARRIER_OPS = (frozenset(int(o) for o in DIRECTIVES)
                        - {int(Op.NET_SEND), int(Op.NET_RECV)}) \
    | {int(Op.INPUT), int(Op.OUTPUT)}

_NET_SEND = int(Op.NET_SEND)
_NET_RECV = int(Op.NET_RECV)
_FREE = int(Op.FREE)


@dataclasses.dataclass
class OverlapSchedule:
    """Precomputed out-of-order issue schedule for one worker's program.

    Flat-array encoding (int64), chunk-aligned to ``chunk_instrs``:

    * ``order``        — chunk-LOCAL row indices, concatenated group by
                         group.  A ``NET_RECV`` row appears TWICE: once
                         in a ``K_RECV_POST`` group and once in a
                         ``K_RECV_WAIT`` group, so ``len(order)`` is
                         ``n_records + deferred_recvs``;
    * ``bounds``       — ``n_groups + 1`` offsets into ``order``;
    * ``group_kind``   — per group, one of ``K_LOCAL``/``K_SEND``/
                         ``K_RECV_POST``/``K_RECV_WAIT``;
    * ``group_op``     — per ``K_LOCAL`` group the shared opcode for
                         structurally batchable groups (same contract as
                         ``BatchSchedule.group_op``), else ``-1``;
    * ``chunk_groups`` — ``n_chunks + 1`` offsets into ``group_kind``.

    Groups never cross chunk or barrier boundaries, and every posted
    handle is waited inside its own chunk."""

    chunk_instrs: int
    n_records: int
    order: np.ndarray
    bounds: np.ndarray
    group_kind: np.ndarray
    group_op: np.ndarray
    chunk_groups: np.ndarray

    @property
    def n_groups(self) -> int:
        return len(self.group_kind)

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_groups) - 1

    def stats(self) -> dict:
        sizes = np.diff(self.bounds)
        send = self.group_kind == K_SEND
        wait = self.group_kind == K_RECV_WAIT
        local = self.group_kind == K_LOCAL
        batch = local & (self.group_op >= 0) & (sizes >= 2)
        return {
            "n_records": int(self.n_records),
            "n_chunks": int(self.n_chunks),
            "n_groups": int(self.n_groups),
            "hoisted_sends": int(sizes[send].sum()),
            "deferred_recvs": int(sizes[wait].sum()),
            "batchable_instructions": int(sizes[batch].sum()),
            "scalar_instructions": int(sizes[local & ~batch].sum()),
        }

    # -- persistence (the sidecar artifact format) ---------------------------

    def save(self, path: str | os.PathLike) -> None:
        with open(path, "wb") as f:
            np.savez(f,
                     version=np.array([OVERLAP_VERSION], dtype=np.int64),
                     chunk_instrs=np.array([self.chunk_instrs],
                                           dtype=np.int64),
                     n_records=np.array([self.n_records], dtype=np.int64),
                     order=self.order.astype(np.int64),
                     bounds=self.bounds.astype(np.int64),
                     group_kind=self.group_kind.astype(np.int64),
                     group_op=self.group_op.astype(np.int64),
                     chunk_groups=self.chunk_groups.astype(np.int64))

    @classmethod
    def load(cls, path: str | os.PathLike) -> "OverlapSchedule":
        with np.load(path) as z:
            ver = int(z["version"][0])
            if ver != OVERLAP_VERSION:
                raise ValueError(
                    f"overlap schedule version {ver} != {OVERLAP_VERSION}")
            return cls(chunk_instrs=int(z["chunk_instrs"][0]),
                       n_records=int(z["n_records"][0]),
                       order=z["order"], bounds=z["bounds"],
                       group_kind=z["group_kind"], group_op=z["group_op"],
                       chunk_groups=z["chunk_groups"])

    def validate_for(self, prog: Program | ProgramFile) -> None:
        n = len(prog) if isinstance(prog, Program) else prog.num_records
        if n != self.n_records:
            raise ValueError(
                f"overlap schedule covers {self.n_records} records but the "
                f"program has {n}; stale sidecar?")


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------

#: one scheduled group: (kind, batch op or -1, chunk-local rows)
_Group = tuple  # (int, int, list[int])


def _row_spans(row: list, no: int, ni: int, op: int
               ) -> tuple[list[tuple[int, int]], list[tuple[int, int]]]:
    """(writes, reads) as (addr, len) lists.  NET_SEND reads ins[0];
    NET_RECV (its completion) writes outs[0]; FREE touches nothing the
    engine can observe."""
    if op == _FREE:
        return [], []
    writes = [(row[_OUT_OFF + 2 * j], row[_OUT_OFF + 1 + 2 * j])
              for j in range(no) if row[_OUT_OFF + 1 + 2 * j] > 0]
    reads = [(row[_IN_OFF + 2 * j], row[_IN_OFF + 1 + 2 * j])
             for j in range(ni) if row[_IN_OFF + 1 + 2 * j] > 0]
    return writes, reads


def _spans_exact(spans: list[tuple[int, int]]) -> bool:
    """Pairwise identical-or-disjoint check (span-keyed maps are only
    sound under it)."""
    if not spans:
        return True
    seen: dict[int, int] = {}
    for a, ln in spans:
        if seen.setdefault(a, ln) != ln:
            return False
    ss = sorted(seen.items())
    return all(ss[i][0] + ss[i][1] <= ss[i + 1][0]
               for i in range(len(ss) - 1))


def _net_window_groups(rec: np.ndarray, rows: np.ndarray, op: np.ndarray,
                       n_outs: np.ndarray, n_ins: np.ndarray,
                       n_imm: np.ndarray) -> list[_Group]:
    """Greedy list-schedule of one barrier-free window containing NET
    traffic.  Builds the explicit conflict DAG, then repeatedly: issue
    every ready send, post every ready recv, run all ready local rows
    (grouped by shape for the batched drivers), and only when nothing
    else can make progress, complete the earliest outstanding receive."""
    m = len(rows)
    rows_l = rows.tolist()
    rec_l = rec[rows].tolist()
    op_l = op[rows].tolist()
    no_l, ni_l = n_outs[rows].tolist(), n_ins[rows].tolist()

    writes_l, reads_l, all_spans = [], [], []
    for k in range(m):
        wts, rds = _row_spans(rec_l[k], no_l[k], ni_l[k], op_l[k])
        writes_l.append(wts)
        reads_l.append(rds)
        all_spans += wts + rds
    if not _spans_exact(all_spans):
        # address-reuse overlap inside the window: run it in program order
        return [(K_LOCAL, -1, [int(r) for r in rows_l])]

    succ: list[list[int]] = [[] for _ in range(m)]
    indeg = [0] * m
    last_writer: dict[int, int] = {}
    readers: dict[int, list[int]] = {}
    chan: dict[tuple, int] = {}
    for k in range(m):
        deps: set[int] = set()
        for a, _ in reads_l[k]:
            lw = last_writer.get(a)
            if lw is not None:
                deps.add(lw)
            readers.setdefault(a, []).append(k)
        for a, _ in writes_l[k]:
            lw = last_writer.get(a)
            if lw is not None:
                deps.add(lw)
            for rd in readers.get(a, ()):
                if rd != k:
                    deps.add(rd)
            last_writer[a] = k
            readers[a] = []
        o = op_l[k]
        if o == _NET_SEND or o == _NET_RECV:
            # per-(direction, peer, tag) FIFO: keep channel program order
            key = (o, rec_l[k][_IMM_OFF], rec_l[k][_IMM_OFF + 1])
            prev = chan.get(key)
            if prev is not None:
                deps.add(prev)
            chan[key] = k
        for d in deps:
            succ[d].append(k)
            indeg[k] += 1

    from heapq import heapify, heappop, heappush
    r_send: list[int] = []
    r_recv: list[int] = []
    r_local: list[int] = []
    for k in range(m):
        if indeg[k] == 0:
            o = op_l[k]
            (r_send if o == _NET_SEND else
             r_recv if o == _NET_RECV else r_local).append(k)
    heapify(r_send), heapify(r_recv), heapify(r_local)
    posted: list[int] = []          # recv rows posted, not yet waited

    def complete(k: int) -> None:
        for s in succ[k]:
            indeg[s] -= 1
            if indeg[s] == 0:
                o = op_l[s]
                if o == _NET_SEND:
                    heappush(r_send, s)
                elif o == _NET_RECV:
                    heappush(r_recv, s)
                else:
                    heappush(r_local, s)

    groups: list[_Group] = []
    done = 0
    while done < m:
        if r_send:
            batch = []
            while r_send:
                batch.append(heappop(r_send))
            groups.append((K_SEND, -1, [rows_l[k] for k in batch]))
            for k in batch:
                done += 1
                complete(k)
        elif r_recv:
            batch = []
            while r_recv:
                k = heappop(r_recv)
                batch.append(k)
                heappush(posted, k)
            groups.append((K_RECV_POST, -1, [rows_l[k] for k in batch]))
        elif r_local:
            batch = []
            while r_local:
                batch.append(heappop(r_local))
            # all simultaneously-ready rows are pairwise independent
            # (conflicting rows are connected in the DAG); subgroup by
            # shape so the batched drivers can take them in one call
            shape: dict[tuple, list[int]] = {}
            for k in batch:
                row = rec_l[k]
                key = (row[0],
                       tuple(row[_OUT_OFF + 1 + 2 * j]
                             for j in range(no_l[k])),
                       tuple(row[_IN_OFF + 1 + 2 * j]
                             for j in range(ni_l[k])),
                       tuple(row[_IMM_OFF + j]
                             for j in range(int(n_imm[rows_l[k]]))))
                shape.setdefault(key, []).append(k)
            for key, ks in sorted(shape.items(),
                                  key=lambda kv: kv[1][0]):
                g_op = int(key[0] & 0xFFFF) if len(ks) >= 2 else -1
                groups.append((K_LOCAL, g_op, [rows_l[k] for k in ks]))
            for k in batch:
                done += 1
                complete(k)
        elif posted:
            k = heappop(posted)
            groups.append((K_RECV_WAIT, -1, [rows_l[k]]))
            done += 1
            complete(k)
        else:  # pragma: no cover - the DAG is acyclic by construction
            raise AssertionError("overlap scheduler stalled")
    return groups


def _chunk_overlap_groups(rec: np.ndarray | None, m: int) -> list[_Group]:
    """Schedule one program chunk; rows are chunk-local."""
    if rec is None:
        # inexpressible in-memory chunk: record columns unavailable
        return [(K_LOCAL, -1, list(range(m)))]
    op, n_outs, n_ins, n_imm = unpack_heads(rec[:, 0])
    fmask = (rec[:, 0] >> 28) & 0x3F
    barrier = np.isin(op, list(_OVERLAP_BARRIER_OPS)) | (fmask != 0)
    has_net = (op == _NET_SEND) | (op == _NET_RECV)
    free = (op == _FREE) & ~barrier
    groups: list[_Group] = []
    bpos = np.flatnonzero(barrier)
    w0 = 0
    for b in list(bpos) + [m]:
        if b > w0:
            win = np.arange(w0, b, dtype=np.int64)
            fr = win[free[win]]
            if len(fr):
                win = win[~free[win]]
            if len(win):
                if has_net[win].any():
                    groups.extend(_net_window_groups(
                        rec, win, op, n_outs, n_ins, n_imm))
                else:
                    # pure-local window: the batch planner's levelling is
                    # already the best issue order — reuse it verbatim
                    groups.extend(
                        (K_LOCAL, g_op, rws) for g_op, rws in
                        _window_groups(rec, win, op, n_outs, n_ins, n_imm))
            if len(fr):
                groups.append((K_LOCAL, -1, [int(r) for r in fr]))
        if b < m:
            groups.append((K_LOCAL, -1, [int(b)]))
        w0 = b + 1
    # merge adjacent scalar LOCAL groups; demote singleton batch groups
    merged: list[_Group] = []
    for kind, g_op, rws in groups:
        if kind == K_LOCAL and len(rws) < 2:
            g_op = -1
        if (kind == K_LOCAL and g_op == -1 and merged
                and merged[-1][0] == K_LOCAL and merged[-1][1] == -1):
            merged[-1][2].extend(rws)
        else:
            merged.append((kind, g_op, list(rws)))
    return merged


def build_overlap_schedule(prog: Program | ProgramFile,
                           chunk_instrs: int | None = None
                           ) -> OverlapSchedule:
    """One streaming pass over the program's record chunks ->
    OverlapSchedule.  Runs on any phase, is O(chunk) in memory, and is
    intended to run once per plan and be cached under ``plan_hash``
    (``ArtifactCache.get_overlap``/``put_overlap``)."""
    if chunk_instrs is None:
        chunk_instrs = DEFAULT_CHUNK_INSTRS
    order: list[np.ndarray] = []
    bounds = [0]
    group_kind: list[int] = []
    group_op: list[int] = []
    chunk_groups = [0]
    n_records = 0
    for start, rec, instrs in iter_record_chunks(prog, chunk_instrs):
        m = rec.shape[0] if rec is not None else len(instrs)
        n_records += m
        for kind, g_op, rws in _chunk_overlap_groups(rec, m):
            order.append(np.asarray(rws, dtype=np.int64))
            bounds.append(bounds[-1] + len(rws))
            group_kind.append(kind)
            group_op.append(g_op)
        chunk_groups.append(len(group_op))
    return OverlapSchedule(
        chunk_instrs=chunk_instrs,
        n_records=n_records,
        order=(np.concatenate(order) if order
               else np.zeros(0, dtype=np.int64)),
        bounds=np.asarray(bounds, dtype=np.int64),
        group_kind=np.asarray(group_kind, dtype=np.int64),
        group_op=np.asarray(group_op, dtype=np.int64),
        chunk_groups=np.asarray(chunk_groups, dtype=np.int64))
