"""Batched CKKS driver: vectorized RNS ciphertext arithmetic over groups.

CKKS ops are pure modular arithmetic over per-prime residue planes, and the
numpy NTT (``protocols.ckks.ntt``) already vectorizes over arbitrary
leading axes — so a batch of ``count`` independent CT_ADD / CT_ADD_PLAIN /
CT_MUL_NR instructions collapses to one broadcasted expression (or one
leading-dim NTT sweep) per prime.  All primes are < 2^31, so uint64 sums
and products of residues never overflow and the batched formulas replay the
scalar ``CkksContext`` arithmetic bit for bit.

CT_MUL / CT_RELIN / INPUT / OUTPUT stay scalar: relinearization walks the
eval-key digit structure and INPUT consumes the driver RNG, both of which
are cheaper to keep on the reference path than to batch (and INPUT must
preserve RNG order anyway — the schedule builder pins it as a barrier).

With a compiled XLA backend present (``kernels.use_pallas``), the NTT
sweeps route through the Pallas kernels (``kernels.ntt.ops``), proven
bitwise-identical to the numpy transform.
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import Op
from ..kernels import use_pallas
from ..kernels.ntt import ops as ntt_ops
from ..protocols.ckks import ntt as ntt_np
from ..protocols.ckks.driver import CkksDriver
from .base import (BatchedProtocolDriver, SpanCol, gather_spans,
                   scatter_spans)


class BatchedCkksDriver(BatchedProtocolDriver):
    batch_ops = frozenset({Op.COPY, Op.CT_ADD, Op.CT_ADD_PLAIN,
                           Op.CT_MUL_NR})

    def __init__(self, inner: CkksDriver):
        super().__init__(inner)
        self.p = inner.p

    def _ntt(self):
        if use_pallas():
            return (lambda a, q: ntt_ops.ntt_forward(a, q, interpret=False),
                    lambda a, q: ntt_ops.ntt_inverse(a, q, interpret=False))
        return ntt_np.ntt_forward, ntt_np.ntt_inverse

    def _cts(self, memory: np.ndarray, col: SpanCol, level: int,
             ncomp: int = 2) -> np.ndarray:
        """(count, ncomp, level+1, n_ring) gathered ciphertext columns."""
        count = len(col[0])
        return gather_spans(memory, col)[:, :, 0].reshape(
            count, ncomp, level + 1, self.p.n_ring)

    def execute_batch(self, op: Op, imm: tuple, out_idx: list[SpanCol],
                      in_idx: list[SpanCol], memory: np.ndarray) -> None:
        p = self.p
        if op == Op.COPY:
            scatter_spans(memory, out_idx[0],
                          gather_spans(memory, in_idx[0]))
            return
        level = imm[0]
        primes = p.level_primes(level)
        count = len(out_idx[0][0])
        # (1, level+1, 1): broadcasts over (count, level+1, n_ring) planes
        qs = np.asarray(primes, dtype=np.uint64)[None, :, None]
        if op == Op.CT_ADD:
            nc1, nc2 = imm[1], imm[2]
            sub = bool(imm[3]) if len(imm) > 3 else False
            A = self._cts(memory, in_idx[0], level, nc1)
            B = self._cts(memory, in_idx[1], level, nc2)
            nc = max(nc1, nc2)
            out = np.zeros((count, nc, level + 1, p.n_ring),
                           dtype=np.uint64)
            for k in range(nc):
                x = A[:, k] if k < nc1 else np.uint64(0)
                y = B[:, k] if k < nc2 else np.uint64(0)
                out[:, k] = ((x + qs - y % qs) if sub else (x + y)) % qs
            scatter_spans(memory, out_idx[0],
                          out.reshape(count, -1, 1))
        elif op == Op.CT_ADD_PLAIN:
            ct = self._cts(memory, in_idx[0], level)
            # encoded plaintexts span the FULL prime chain; add uses the
            # first level+1 planes (scalar add_plain indexes per level prime)
            pt = gather_spans(memory, in_idx[1])[:, :, 0].reshape(
                count, p.levels + 1, p.n_ring)[:, :level + 1]
            out = ct.copy()
            out[:, 0] = (ct[:, 0] + pt) % qs
            scatter_spans(memory, out_idx[0],
                          out.reshape(count, -1, 1))
        elif op == Op.CT_MUL_NR:
            fwd, inv = self._ntt()
            c1 = self._cts(memory, in_idx[0], level)
            c2 = self._cts(memory, in_idx[1], level)
            out = np.zeros((count, 3, level + 1, p.n_ring),
                           dtype=np.uint64)
            for j, qj in enumerate(primes):
                qq = np.uint64(qj)
                a0 = fwd(c1[:, 0, j] % qq, qj)
                a1 = fwd(c1[:, 1, j] % qq, qj)
                b0 = fwd(c2[:, 0, j] % qq, qj)
                b1 = fwd(c2[:, 1, j] % qq, qj)
                out[:, 0, j] = inv((a0 * b0) % qq, qj)
                out[:, 1, j] = inv(((a0 * b1) % qq + (a1 * b0) % qq) % qq,
                                   qj)
                out[:, 2, j] = inv((a1 * b1) % qq, qj)
            scatter_spans(memory, out_idx[0],
                          out.reshape(count, -1, 1))
        else:  # pragma: no cover - engine checks batch_ops first
            raise NotImplementedError(f"batched ckks: {op}")
