"""Batched drivers for the GC protocol family (garbler/evaluator and the
plaintext oracle).

The GC drivers are elementwise over the value axis: an ADD of n values is n
independent ripple-carry subcircuits.  A batch of ``count`` independent
ADDs is therefore exactly one ADD of ``count * n`` values — gather the
label columns, stack them on the value axis, and run the *same*
``AndXorOps`` subcircuit code once.  Bit-level gates (XOR/AND/OR/NOT)
flatten all the way to one ``Gates`` call per batch, which also collapses
the per-column garbled-table messages into one table message per batch.

Both parties derive the identical batch schedule from the identical plan,
so their gate-id streams and table messages stay in lockstep — the same
lockstep argument the scalar drivers rely on, applied to the reordered
stream.  Revealed outputs are plaintext values and match the scalar run
bitwise; the digest tests assert exactly that.

When a compiled XLA backend is present (``kernels.use_pallas``), AND gates
route through the Pallas half-gates kernels (``kernels.garble.ops``),
which are proven bitwise-identical to the numpy gates; on CPU the numpy
gates run directly (compiled ``pallas_call`` cannot lower on the CPU
backend).
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import Op
from ..kernels import use_pallas
from ..kernels.garble import ops as garble_ops
from ..protocols.garbled.driver import PlaintextDriver, _GCDriverBase
from ..protocols.garbled.gates import GarblerGates
from .base import (BatchedProtocolDriver, SpanCol, gather_spans,
                   scatter_spans, strided_positions)

_GC_BATCH_OPS = frozenset({
    Op.COPY, Op.XOR, Op.AND, Op.OR, Op.NOT, Op.ADD, Op.SUB, Op.MUL,
    Op.CMP_GE, Op.CMP_EQ, Op.SELECT, Op.MINMAX, Op.REVERSE,
    Op.SORT_LOCAL,
})


def _sort_network(n: int, direction_up: bool, merge_only: bool):
    """Yield the public bitonic-network steps ``(lo, hi, up)`` exactly as
    ``engineops.sort_local`` walks them — the layout only depends on
    ``(n, direction, merge_only)``, never on the data, so a batch of
    independent sorts shares one walk."""
    k = 2 * n if merge_only else 2
    while (k <= 2 * n) if merge_only else (k <= n):
        j = min(k, n) // 2 if merge_only else k // 2
        while j >= 1:
            idx = np.arange(n)
            partner = idx ^ j
            lo = idx[idx < partner]
            hi = lo ^ j
            if merge_only:
                up = np.full(len(lo), direction_up)
            else:
                up = ((lo & k) == 0) == direction_up
            yield lo, hi, up
            j //= 2
        if merge_only:
            break
        k *= 2


class BatchedGCDriver(BatchedProtocolDriver):
    """Batched garbler/evaluator driver (wraps a ``_GCDriverBase``)."""

    batch_ops = _GC_BATCH_OPS

    def __init__(self, inner: _GCDriverBase):
        super().__init__(inner)
        self.gates = inner.gates
        self.ops = inner.ops
        self._garbler = isinstance(inner.gates, GarblerGates)

    # -- gate primitives over flat (m, 2) label arrays -----------------------

    def _and_flat(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        g = self.gates
        if not use_pallas():
            return g.and_(a, b)
        # compiled path: the Pallas half-gates kernels are bitwise-identical
        # to the numpy gates (tests/test_kernels.py), so the table stream
        # interoperates with either implementation on the far side
        m = len(a)
        gid0 = g.gid
        g.gid += m
        g.counts.ands += m
        if self._garbler:
            c0, tab = garble_ops.garble_and(a, b, g.R, gid0,
                                            interpret=False)
            g.ch.send("tab", tab)
            return c0
        tab = g.ch.recv("tab")
        return garble_ops.eval_and(a, b, tab, gid0, interpret=False)

    def _bit_flat(self, op: Op, a: np.ndarray,
                  b: np.ndarray | None) -> np.ndarray:
        g = self.gates
        if op == Op.NOT:
            return g.not_(a)
        if op == Op.XOR:
            return g.xor(a, b)
        if op == Op.AND:
            return self._and_flat(a, b)
        return g.xor(g.xor(a, b), self._and_flat(a, b))  # OR

    # -- the batch entry point ----------------------------------------------

    def execute_batch(self, op: Op, imm: tuple, out_idx: list[SpanCol],
                      in_idx: list[SpanCol], memory: np.ndarray) -> None:
        if op == Op.COPY:
            scatter_spans(memory, out_idx[0],
                          gather_spans(memory, in_idx[0]))
            return
        n, w = imm[0], imm[1]
        count = len(out_idx[0][0])
        o = self.ops

        def stacked(col: SpanCol, ww: int) -> np.ndarray:
            # (count, n*ww, 2) labels -> (count*n, ww, 2): batch on the
            # value axis, where every GC subcircuit is elementwise
            return gather_spans(memory, col).reshape(count * n, ww, 2)

        def put(col: SpanCol, r: np.ndarray) -> None:
            scatter_spans(memory, col, r.reshape(count, -1, 2))

        if op in (Op.XOR, Op.AND, Op.OR, Op.NOT):
            a = gather_spans(memory, in_idx[0]).reshape(-1, 2)
            b = None if op == Op.NOT else \
                gather_spans(memory, in_idx[1]).reshape(-1, 2)
            put(out_idx[0], self._bit_flat(op, a, b))
        elif op == Op.ADD:
            put(out_idx[0], o.add(stacked(in_idx[0], w),
                                  stacked(in_idx[1], w)))
        elif op == Op.SUB:
            put(out_idx[0], o.sub(stacked(in_idx[0], w),
                                  stacked(in_idx[1], w)))
        elif op == Op.MUL:
            put(out_idx[0], o.mul(stacked(in_idx[0], w),
                                  stacked(in_idx[1], w)))
        elif op == Op.CMP_GE:
            put(out_idx[0], o.cmp_ge(stacked(in_idx[0], w),
                                     stacked(in_idx[1], w), imm[2]))
        elif op == Op.CMP_EQ:
            put(out_idx[0], o.cmp_eq(stacked(in_idx[0], w),
                                     stacked(in_idx[1], w), imm[2]))
        elif op == Op.SELECT:
            put(out_idx[0], o.select(stacked(in_idx[0], 1),
                                     stacked(in_idx[1], w),
                                     stacked(in_idx[2], w)))
        elif op == Op.MINMAX:
            mn, mx = o.minmax(stacked(in_idx[0], w),
                              stacked(in_idx[1], w), imm[2])
            put(out_idx[0], mn)
            put(out_idx[1], mx)
        elif op == Op.REVERSE:
            x = gather_spans(memory, in_idx[0]).reshape(count, n, w, 2)
            put(out_idx[0], x[:, ::-1])
        elif op == Op.SORT_LOCAL:
            kw = imm[2]
            desc = bool(imm[3]) if len(imm) > 3 else False
            merge_only = bool(imm[4]) if len(imm) > 4 else False
            # count independent bitonic networks over the same public
            # layout: each compare-exchange step is ONE minmax over the
            # stacked (count * pairs) columns instead of count calls
            v = gather_spans(memory, in_idx[0]).reshape(count, n, w, 2)
            for lo, hi, up in _sort_network(n, not desc, merge_only):
                p = len(lo)
                mn, mx = o.minmax(v[:, lo].reshape(count * p, w, 2),
                                  v[:, hi].reshape(count * p, w, 2), kw)
                mn = mn.reshape(count, p, w, 2)
                mx = mx.reshape(count, p, w, 2)
                sel = up[None, :, None, None]
                new = np.array(v)
                new[:, lo] = np.where(sel, mn, mx)
                new[:, hi] = np.where(sel, mx, mn)
                v = new
            put(out_idx[0], v)
        else:  # pragma: no cover - engine checks batch_ops first
            raise NotImplementedError(f"batched GC: {op}")


class BatchedPlaintextDriver(BatchedProtocolDriver):
    """Batched plaintext oracle: the vectorized mirror of
    ``PlaintextDriver``'s stride-w value layout.  Writes exactly the slots
    the scalar driver writes (stride positions only for value ops), so the
    engine array stays bitwise identical to a scalar replay."""

    batch_ops = _GC_BATCH_OPS

    def __init__(self, inner: PlaintextDriver):
        super().__init__(inner)

    def execute_batch(self, op: Op, imm: tuple, out_idx: list[SpanCol],
                      in_idx: list[SpanCol], memory: np.ndarray) -> None:
        if op == Op.COPY:
            scatter_spans(memory, out_idx[0],
                          gather_spans(memory, in_idx[0]))
            return
        n, w = imm[0], imm[1]
        mask = PlaintextDriver._m

        def val(col: SpanCol, stride: int) -> np.ndarray:
            return memory[strided_positions(col, n, stride), 0]

        def put(col: SpanCol, stride: int, vals: np.ndarray) -> None:
            memory[strided_positions(col, n, stride), 0] = vals

        if op == Op.ADD:
            put(out_idx[0], w, (val(in_idx[0], w) + val(in_idx[1], w))
                & mask(w))
        elif op == Op.SUB:
            put(out_idx[0], w, (val(in_idx[0], w) - val(in_idx[1], w))
                & mask(w))
        elif op == Op.MUL:
            put(out_idx[0], w, (val(in_idx[0], w) * val(in_idx[1], w))
                & mask(w))
        elif op == Op.XOR:
            put(out_idx[0], w, val(in_idx[0], w) ^ val(in_idx[1], w))
        elif op == Op.AND:
            put(out_idx[0], w, val(in_idx[0], w) & val(in_idx[1], w))
        elif op == Op.OR:
            put(out_idx[0], w, val(in_idx[0], w) | val(in_idx[1], w))
        elif op == Op.NOT:
            put(out_idx[0], w, (~val(in_idx[0], w)) & mask(w))
        elif op in (Op.CMP_GE, Op.CMP_EQ):
            km = mask(imm[2])
            a, b = val(in_idx[0], w) & km, val(in_idx[1], w) & km
            r = (a >= b) if op == Op.CMP_GE else (a == b)
            put(out_idx[0], 1, r.astype(np.uint64))
        elif op == Op.SELECT:
            put(out_idx[0], w, np.where(val(in_idx[0], 1).astype(bool),
                                        val(in_idx[1], w),
                                        val(in_idx[2], w)))
        elif op == Op.MINMAX:
            km = mask(imm[2])
            a, b = val(in_idx[0], w), val(in_idx[1], w)
            ge = (a & km) >= (b & km)
            put(out_idx[0], w, np.where(ge, b, a))
            put(out_idx[1], w, np.where(ge, a, b))
        elif op == Op.REVERSE:
            put(out_idx[0], w, val(in_idx[0], w)[:, ::-1])
        elif op == Op.SORT_LOCAL:
            km = mask(imm[2])
            desc = bool(imm[3]) if len(imm) > 3 else False
            v = val(in_idx[0], w)
            order = np.argsort(v & km, axis=1, kind="stable")
            if desc:
                order = order[:, ::-1]
            put(out_idx[0], w, np.take_along_axis(v, order, axis=1))
        else:  # pragma: no cover - engine checks batch_ops first
            raise NotImplementedError(f"batched plaintext: {op}")
